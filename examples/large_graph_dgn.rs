//! Large Graph Extension demo (paper §4.6, Fig. 8, Table 5).
//!
//! Two halves:
//! 1. **Numeric path** — a scaled-down citation graph (preserving
//!    Cora's density) through the real `dgn_large` PJRT artifact,
//!    node-level predictions out.
//! 2. **Full-scale analysis** — the cycle-level large-graph simulator
//!    on the real Table 5 sizes, with the §4.6 ablations (prefetcher,
//!    packed transfers) and the Fig. 8 CPU/GPU comparison.
//!
//! ```sh
//! cargo run --release --example large_graph_dgn
//! ```

use gengnn::baselines::{cpu, gpu, GraphStats};
use gengnn::datagen::citation::{dataset, dataset_scaled, CitationDataset};
use gengnn::models::ModelConfig;
use gengnn::report::table5;
use gengnn::runtime::{Artifacts, Engine};
use gengnn::sim::{LargeGraphSim, PipelineMode};
use gengnn::util::stats::fmt_secs;

fn main() -> anyhow::Result<()> {
    // ---- numeric path on the scaled graph ------------------------------
    let artifacts = Artifacts::load(Artifacts::default_dir())?;
    let meta = artifacts.model("dgn_large")?.clone();
    let g_small = dataset_scaled(CitationDataset::Cora, 11, 300, meta.in_dim);
    eprintln!(
        "[numeric] scaled Cora: {} nodes, {} edges through dgn_large ...",
        g_small.n,
        g_small.num_edges()
    );
    let mut engine = Engine::load(&artifacts, &["dgn_large"])?;
    let t0 = std::time::Instant::now();
    let out = engine.infer("dgn_large", &g_small)?;
    let live = g_small.n * meta.out_dim;
    println!(
        "[numeric] node-level logits for {} nodes in {} (first node: {:?})",
        g_small.n,
        fmt_secs(t0.elapsed().as_secs_f64()),
        &out[..meta.out_dim]
    );
    anyhow::ensure!(out[live..].iter().all(|&v| v == 0.0), "mask check");

    // ---- full-scale simulation + Fig. 8 --------------------------------
    let model = ModelConfig::by_name("dgn_large")?;
    println!("\n[simulated] DGN + Large Graph Extension at Table 5 sizes:");
    println!(
        "{:<10} {:>10} {:>11} {:>11} {:>11} {:>9} {:>9}",
        "dataset", "GenGNN", "-prefetch", "-packing", "non-pipe", "CPU", "GPU"
    );
    for which in CitationDataset::all() {
        let g = dataset(which, 3);
        let base = LargeGraphSim::default();
        let t = |sim: &LargeGraphSim| sim.simulate(&g, &model).secs;
        let full = t(&base);
        let no_pf = t(&LargeGraphSim {
            prefetch: false,
            ..base.clone()
        });
        let no_pk = t(&LargeGraphSim {
            packed: false,
            ..base.clone()
        });
        let non = t(&LargeGraphSim {
            mode: PipelineMode::NonPipelined,
            ..base.clone()
        });
        let s = GraphStats::of(&g);
        println!(
            "{:<10} {:>10} {:>11} {:>11} {:>11} {:>9} {:>9}",
            which.name(),
            fmt_secs(full),
            fmt_secs(no_pf),
            fmt_secs(no_pk),
            fmt_secs(non),
            fmt_secs(cpu::latency(&model, s)),
            fmt_secs(gpu::latency(&model, s)),
        );
    }

    println!("\n{}", table5::render());
    Ok(())
}

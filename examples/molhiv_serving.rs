//! End-to-end serving driver — the flagship example: all six molecular
//! models compiled from their artifacts, then a 2,000-graph
//! MolHIV-like stream served through the full coordinator stack
//! (bounded ingest → prep workers → dispatch batcher → executor),
//! reporting per-model latency and aggregate throughput. Python never
//! runs here.
//!
//! ```sh
//! cargo run --release --example molhiv_serving [-- --count 2000 --lanes 4]
//! ```

use gengnn::coordinator::{Admission, AdmissionPolicy, BatchPolicy, Server, ServerConfig};
use gengnn::datagen::{molecular_graph, MolConfig};
use gengnn::util::cli::Args;
use gengnn::util::rng::Rng;
use gengnn::util::stats::fmt_secs;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let count = args.usize_or("count", 2000)?;
    let models: Vec<String> = args.list_or(
        "models",
        &["gcn", "gin", "gin_vn", "gat", "pna", "dgn"],
    );

    let lanes = args.usize_or("lanes", 2)?;
    eprintln!(
        "[molhiv_serving] compiling {} artifacts on {lanes} lane(s) ...",
        models.len()
    );
    let t_compile = std::time::Instant::now();
    let server = Server::start(ServerConfig {
        models: models.clone(),
        prep_workers: 3,
        executor_lanes: lanes,
        queue_capacity: 512,
        admission: AdmissionPolicy::Block,
        batch: BatchPolicy {
            max_batch: 16,
            sticky: true,
        },
        ..ServerConfig::default()
    })?;
    eprintln!(
        "[molhiv_serving] ready in {} — streaming {count} graphs",
        fmt_secs(t_compile.elapsed().as_secs_f64())
    );

    let responses = server.responses();
    let drain = std::thread::spawn(move || {
        let (mut ok, mut err) = (0u64, 0u64);
        while ok + err < count as u64 {
            match responses.recv() {
                Some(r) if r.is_ok() => ok += 1,
                Some(_) => err += 1,
                None => break,
            }
        }
        (ok, err)
    });

    // The stream: raw molecular graphs, round-robin across models —
    // zero preprocessing, like the paper's consecutive raw-graph feed.
    let mut rng = Rng::new(0x1234);
    let t0 = std::time::Instant::now();
    for i in 0..count {
        let g = molecular_graph(&mut rng, &MolConfig::molhiv());
        let model = &models[i % models.len()];
        let (adm, _) = server.submit(model, g);
        assert_eq!(adm, Admission::Accepted);
    }
    let (ok, err) = drain.join().unwrap();
    let wall = t0.elapsed().as_secs_f64();

    let metrics = server.shutdown();
    println!("{}", metrics.render());
    println!(
        "stream: {count} graphs in {} → {:.0} graphs/s end-to-end (ok {ok}, err {err})",
        fmt_secs(wall),
        ok as f64 / wall
    );
    anyhow::ensure!(err == 0, "all requests must succeed");
    Ok(())
}

//! End-to-end serving driver — the flagship example, now over the
//! wire: all six molecular models compiled from their artifacts and
//! exposed through the TCP front-end on loopback, then an open-loop
//! MolHIV-like stream driven at a target request rate through the full
//! network path (framed TCP → per-connection readers → bounded ingest
//! → prep workers → dispatch batcher → executor lanes → demux →
//! writers), reporting latency percentiles and aggregate throughput.
//! Python never runs here — and neither does anything in-process: the
//! client side only speaks the wire protocol.
//!
//! ```sh
//! cargo run --release --example molhiv_serving [-- --count 2000 --rps 400 --lanes 4]
//! ```

use gengnn::coordinator::{AdmissionPolicy, BatchPolicy, ServerConfig};
use gengnn::net::{loadgen, LoadGenConfig, NetServer, NetServerConfig};
use gengnn::util::cli::Args;
use gengnn::util::stats::fmt_secs;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let count = args.usize_or("count", 2000)?;
    let rps = args.f64_or("rps", 400.0)?;
    let connections = args.usize_or("connections", 4)?;
    let models: Vec<String> = args.list_or(
        "models",
        &["gcn", "gin", "gin_vn", "gat", "pna", "dgn"],
    );

    let lanes = args.usize_or("lanes", 2)?;
    eprintln!(
        "[molhiv_serving] compiling {} artifacts on {lanes} lane(s) ...",
        models.len()
    );
    let t_compile = std::time::Instant::now();
    let net = NetServer::start(NetServerConfig {
        listen: "127.0.0.1:0".to_string(),
        server: ServerConfig {
            models: models.clone(),
            prep_workers: 3,
            executor_lanes: lanes,
            queue_capacity: 512,
            admission: AdmissionPolicy::Block,
            batch: BatchPolicy {
                max_batch: 16,
                sticky: true,
            },
            ..ServerConfig::default()
        },
    })?;
    let addr = net.local_addr();
    eprintln!(
        "[molhiv_serving] ready in {} — listening on {addr}, \
         streaming {count} graphs @ {rps} rps over {connections} connection(s)",
        fmt_secs(t_compile.elapsed().as_secs_f64())
    );

    // The stream: raw molecular graphs over the wire, round-robin
    // across models on a deterministic open-loop schedule — zero
    // preprocessing, like the paper's consecutive raw-graph feed.
    let report = loadgen::run(&LoadGenConfig {
        addr: addr.to_string(),
        rps,
        count,
        connections,
        models,
        seed: 0x1234,
        graph_pool: 64,
        drain_timeout: std::time::Duration::from_secs(60),
    })?;
    print!("{}", report.render());

    let metrics = net.shutdown();
    println!("{}", metrics.render());
    anyhow::ensure!(report.reconciles(), "request accounting must reconcile");
    anyhow::ensure!(report.failed == 0, "all requests must succeed");
    Ok(())
}

//! Developer calibration dump: prints every reproduced table/figure so
//! model constants can be tuned against the paper's envelopes.
use gengnn::report::{fig7, fig8, fig9, table4, table5};

fn main() {
    let hiv = fig7::compute(fig7::MolDataset::MolHiv, 150, 1);
    println!("{}", fig7::render(fig7::MolDataset::MolHiv, &hiv));
    let pcba = fig7::compute(fig7::MolDataset::MolPcba, 150, 1);
    println!("{}", fig7::render(fig7::MolDataset::MolPcba, &pcba));
    println!("{}", fig8::render(&fig8::compute(2)));
    println!("{}", fig9::render_grid(&fig9::default_grid(80, 3)));
    println!("{}", fig9::render_mol("MolHIV/GIN", &fig9::molhiv(150, 4, false)));
    println!("{}", fig9::render_mol("MolHIV/GIN+VN", &fig9::molhiv(150, 4, true)));
    println!("{}", table4::render());
    println!("{}", table5::render());
}

//! Pipelining-strategy ablation (paper Fig. 4 / Fig. 9): sweep the
//! Fig. 9(a) random-graph grid, the MolHIV benchmark, the virtual-node
//! variant, and the VN *placement* ablation (§4.5: "as long as it is
//! processed early enough").
//!
//! ```sh
//! cargo run --release --example pipeline_ablation
//! ```

use gengnn::datagen::{molecular, MolConfig};
use gengnn::models::ModelConfig;
use gengnn::report::fig9;
use gengnn::sim::{Accelerator, PipelineMode};

fn main() -> anyhow::Result<()> {
    // Fig. 9(a): the grid.
    println!("{}", fig9::render_grid(&fig9::default_grid(150, 9)));

    // Fig. 9(b)/(c): real molecular benchmark, with and without VN.
    print!(
        "{}",
        fig9::render_mol("b: MolHIV, GIN", &fig9::molhiv(300, 9, false))
    );
    print!(
        "{}",
        fig9::render_mol("c: MolHIV, GIN+VN", &fig9::molhiv(300, 9, true))
    );

    // VN placement ablation: first vs last in the processing order.
    let cfg = ModelConfig::by_name("gin_vn")?;
    let graphs = molecular::dataset(17, 200, &MolConfig::molhiv());
    let mut first = Accelerator::new(cfg.clone(), PipelineMode::Streaming);
    first.vn_first = true;
    let mut last = Accelerator::new(cfg, PipelineMode::Streaming);
    last.vn_first = false;
    let (mut c_first, mut c_last) = (0u64, 0u64);
    for g in &graphs {
        c_first += first.simulate(g).cycles;
        c_last += last.simulate(g).cycles;
    }
    println!(
        "\nVN placement (streaming): first-in-order {} cycles, last-in-order {} cycles ({:+.1}%)",
        c_first,
        c_last,
        (c_last as f64 / c_first as f64 - 1.0) * 100.0
    );

    // FIFO depth sweep around the paper's depth-10 choice.
    println!("\nFIFO depth sweep (GIN, streaming, 200 MolHIV graphs):");
    let gin = ModelConfig::by_name("gin")?;
    for depth in [1usize, 2, 4, 10, 32] {
        let mut acc = Accelerator::new(gin.clone(), PipelineMode::Streaming);
        acc.params.fifo_depth = depth;
        let total: u64 = graphs.iter().map(|g| acc.simulate(g).cycles).sum();
        println!("  depth {depth:>3}: {total} cycles");
    }
    Ok(())
}

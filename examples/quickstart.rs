//! Quickstart: the smallest end-to-end use of GenGNN.
//!
//! 1. load the artifact manifest (checked-in fixtures at `artifacts/`
//!    work out of the box; regenerate the full set with `make artifacts`),
//! 2. run a raw COO molecular graph through a compiled model,
//! 3. cross-check the cycle-level simulator's latency estimate.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use gengnn::prelude::*;
use gengnn::runtime::Artifacts;
use gengnn::util::stats::fmt_secs;

fn main() -> anyhow::Result<()> {
    // A raw graph, exactly as a real-time producer would emit it:
    // an unordered COO edge list plus node/edge features.
    let mut rng = Rng::new(7);
    let graph = molecular_graph(&mut rng, &MolConfig::molhiv());
    println!(
        "graph: {} atoms, {} directed bonds",
        graph.n,
        graph.num_edges()
    );

    // Layer 2/1: the AOT-compiled GIN artifact, served via PJRT.
    let artifacts = Artifacts::load(Artifacts::default_dir())?;
    let mut engine = Engine::load(&artifacts, &["gin"])?;
    let t0 = std::time::Instant::now();
    let out = engine.infer("gin", &graph)?;
    println!(
        "gin prediction = {:.6} ({} on {})",
        out[0],
        fmt_secs(t0.elapsed().as_secs_f64()),
        engine.platform()
    );

    // Layer 3 analysis: what would this cost on the paper's U50?
    let cfg = ModelConfig::by_name("gin")?;
    for mode in PipelineMode::all() {
        let acc = Accelerator::new(cfg.clone(), mode);
        let r = acc.simulate(&graph);
        println!(
            "simulated {:<14} {:>8} cycles  ({} @ 300 MHz)",
            mode.as_str(),
            r.cycles,
            fmt_secs(r.secs)
        );
    }
    Ok(())
}

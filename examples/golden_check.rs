//! Developer smoke check: compile every artifact, replay its golden
//! input, verify numerics, and report steady-state inference latency.
//!
//! Runs out of the box against the checked-in fixtures at `artifacts/`
//! (resolved via `Artifacts::default_dir`, so it works from any cwd);
//! point `GENGNN_ARTIFACTS` elsewhere to check a freshly generated set.
use gengnn::runtime::{Artifacts, Engine, Golden};

fn main() -> anyhow::Result<()> {
    let arts = Artifacts::load(Artifacts::default_dir())?;
    for name in arts.model_names() {
        let t0 = std::time::Instant::now();
        let mut e = Engine::load(&arts, &[name])?;
        let compile = t0.elapsed();
        let tol = e.golden_tolerance();
        let meta = e.meta(name)?.clone();
        let g = Golden::load(&meta)?;
        let out = e.infer_with_eig(name, &g.graph, g.eig.as_deref())?;
        let ok = out.len() == g.output.len()
            && out
                .iter()
                .zip(&g.output)
                .all(|(a, b)| (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())));
        // Steady state: average of 20 runs after warmup.
        let t1 = std::time::Instant::now();
        for _ in 0..20 {
            e.infer_with_eig(name, &g.graph, g.eig.as_deref())?;
        }
        let steady = t1.elapsed() / 20;
        println!(
            "{name:10} compile {compile:>8.0?}  steady {steady:>9.0?}  golden {}",
            if ok { "OK" } else { "MISMATCH" }
        );
        assert!(ok, "{name} golden mismatch");
    }
    Ok(())
}

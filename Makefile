# GenGNN reproduction — build/verify entry points.
#
# Tier-1 verify (what CI gates on):      make check
# Full artifact regeneration (needs jax): make artifacts

.PHONY: build test check fmt clippy doc artifacts artifacts-golden \
	bench-snapshot serve loadgen loadgen-deadline-smoke deploy-smoke \
	resident-smoke ingress-smoke check-artifacts check-plans lint-plans \
	clean

# Wire serving defaults (override: make serve SERVE_ADDR=0.0.0.0:9000).
SERVE_ADDR ?= 127.0.0.1:7447

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

# Rustdoc with warnings promoted to errors, so intra-doc links (the
# module-contract cross-references docs/ relies on) stay live.
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p gengnn

check: build test fmt clippy doc lint-plans

# Full artifact set: HLO text + goldens + manifest (Layer 2 lowering).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

# Fixture set: goldens + manifest only, HLO elided (what is checked in).
artifacts-golden:
	cd python && python3 -m compile.aot --out-dir ../artifacts --golden-only

# Expose the wire protocol over TCP (runs until killed).
serve:
	cargo run --release --bin gengnn -- serve --listen $(SERVE_ADDR)

# Drive a running `make serve` with the open-loop load generator.
loadgen:
	cargo run --release --bin gengnn -- loadgen --addr $(SERVE_ADDR) \
		--rps 200 --count 2000

# Self-contained QoS overload smoke (CI's bench-smoke deadline step):
# a one-lane server with a queue of 2, a paced burst carrying 1 ms
# TTLs, and the exported snapshot must reconcile and carry a nonzero
# loadgen/shed_by_deadline series.
DEADLINE_ADDR ?= 127.0.0.1:17447
loadgen-deadline-smoke: build
	@set -e; \
	./target/release/gengnn serve --listen $(DEADLINE_ADDR) --models gin \
		--lanes 1 --prep-workers 1 --queue 2 --duration 120 & \
	pid=$$!; \
	trap 'kill $$pid 2>/dev/null || true' EXIT; \
	sleep 2; \
	GENGNN_BENCH_JSON=$(CURDIR)/BENCH_loadgen_smoke.json \
		./target/release/gengnn loadgen --addr $(DEADLINE_ADDR) \
		--rps 5000 --count 200 --connections 4 --models gin \
		--ttl-ms 1 --priority-mix high:1,normal:2,low:1; \
	python3 python/tools/check_bench_schema.py BENCH_loadgen_smoke.json \
		--schema BENCH_seed.json --require-measured \
		--require-result "loadgen/shed_by_deadline>0"

# Control-plane smoke (CI's bench-smoke deploy step): boot a server on
# gcn only, live-deploy the staged gin over the wire, drive real
# traffic at it (the snapshot must show completed requests), roll back,
# and assert every registry state transition via LIST_MODELS.
DEPLOY_ADDR ?= 127.0.0.1:17448
deploy-smoke: build
	@set -e; \
	./target/release/gengnn serve --listen $(DEPLOY_ADDR) --models gcn \
		--lanes 2 --duration 120 & \
	pid=$$!; \
	trap 'kill $$pid 2>/dev/null || true' EXIT; \
	sleep 2; \
	./target/release/gengnn models --addr $(DEPLOY_ADDR) --json \
		| python3 python/tools/check_registry_state.py --live gcn --staged gin; \
	./target/release/gengnn deploy gin --addr $(DEPLOY_ADDR); \
	./target/release/gengnn models --addr $(DEPLOY_ADDR) --json \
		| python3 python/tools/check_registry_state.py --live gcn,gin; \
	GENGNN_BENCH_JSON=$(CURDIR)/BENCH_deploy_smoke.json \
		./target/release/gengnn loadgen --addr $(DEPLOY_ADDR) \
		--rps 100 --count 100 --connections 2 --models gin; \
	python3 python/tools/check_bench_schema.py BENCH_deploy_smoke.json \
		--schema BENCH_seed.json --require-measured \
		--require-result "loadgen/e2e_latency>0"; \
	./target/release/gengnn deploy --rollback 0 --addr $(DEPLOY_ADDR); \
	./target/release/gengnn models --addr $(DEPLOY_ADDR) --json \
		| python3 python/tools/check_registry_state.py --live gcn --staged gin

# Resident-serving smoke (CI's bench-smoke resident step): boot a
# server hosting the Cora-scale resident graph, drive a mixed
# molecular/query/mutate scenario stream over a diurnal schedule, and
# require the exported snapshot to reconcile and carry nonzero
# resident series (queries completed, mutation ops applied). The
# fanout cap keeps 2-hop closures inside the resident plan's 512-node
# capacity on hub-heavy citation graphs (see docs/SCENARIOS.md).
RESIDENT_ADDR ?= 127.0.0.1:17449
resident-smoke: build
	@set -e; \
	./target/release/gengnn serve --listen $(RESIDENT_ADDR) --models gcn \
		--resident cora --lanes 2 --duration 120 & \
	pid=$$!; \
	trap 'kill $$pid 2>/dev/null || true' EXIT; \
	sleep 3; \
	GENGNN_BENCH_JSON=$(CURDIR)/BENCH_resident_smoke.json \
		./target/release/gengnn loadgen --addr $(RESIDENT_ADDR) \
		--rps 100 --count 200 --connections 2 --models gcn \
		--scenario molecular:1,query:2,mutate:1 --diurnal \
		--query-hops 2 --query-fanout 8 --resident-nodes 2708; \
	python3 python/tools/check_bench_schema.py BENCH_resident_smoke.json \
		--schema BENCH_seed.json --require-measured \
		--require-result "loadgen/query_completed>0" \
		--require-result "loadgen/mutate_applied>0"

# Cluster-tier smoke (CI's bench-smoke ingress step): generate a
# two-backend partitioned cluster.toml (both replicas managed by the
# ingress reconciler), boot `gengnn ingress` over it, and drive a
# mixed gcn/gin burst under an active fault plan: frame 120 is
# corrupted after its id rewrite (a deterministic loadgen/failed
# count — the backend's BadRequest flows back under the caller's own
# id, never lost) and frame 200 SIGKILLs the gin replica mid-run
# (link-death sweep, ejection, reconciler respawn, probation
# walk-back). The first snapshot must reconcile with a nonzero
# loadgen/failed series; the second, gin-only run is the recovery
# gate — gin is served ONLY by the killed replica, so a completed
# request (nonzero loadgen/e2e_latency) proves the respawned process
# rejoined the pool and took traffic (see docs/CLUSTER.md).
INGRESS_ADDR ?= 127.0.0.1:17450
INGRESS_B0 ?= 127.0.0.1:17451
INGRESS_B1 ?= 127.0.0.1:17452
ingress-smoke: build
	@set -e; \
	mkdir -p target; \
	{ \
	  echo '[ingress]'; \
	  echo 'listen = "$(INGRESS_ADDR)"'; \
	  echo 'balance = "round-robin"'; \
	  echo 'drain_timeout_ms = 5000'; \
	  echo '[probe]'; \
	  echo 'interval_ms = 200'; \
	  echo 'timeout_ms = 1000'; \
	  echo 'eject_after = 2'; \
	  echo 'probation_successes = 2'; \
	  echo '[reconcile]'; \
	  echo 'restart_after_ms = 500'; \
	  echo 'max_restarts = 5'; \
	  echo '[[backend]]'; \
	  echo 'addr = "$(INGRESS_B0)"'; \
	  echo 'models = ["gcn"]'; \
	  echo 'command = ["$(CURDIR)/target/release/gengnn", "serve", "--listen", "$(INGRESS_B0)", "--models", "gcn", "--duration", "180"]'; \
	  echo '[[backend]]'; \
	  echo 'addr = "$(INGRESS_B1)"'; \
	  echo 'models = ["gin"]'; \
	  echo 'command = ["$(CURDIR)/target/release/gengnn", "serve", "--listen", "$(INGRESS_B1)", "--models", "gin", "--duration", "180"]'; \
	} > target/cluster_smoke.toml; \
	GENGNN_FAULT_PLAN="corrupt-frame=120;kill-backend=1@200" \
		./target/release/gengnn ingress --spec target/cluster_smoke.toml \
		--duration 180 & \
	pid=$$!; \
	trap 'kill $$pid 2>/dev/null || true; \
	      pkill -f "serve --listen $(INGRESS_B0)" 2>/dev/null || true; \
	      pkill -f "serve --listen $(INGRESS_B1)" 2>/dev/null || true' EXIT; \
	sleep 3; \
	GENGNN_BENCH_JSON=$(CURDIR)/BENCH_ingress_smoke.json \
		./target/release/gengnn loadgen --addr $(INGRESS_ADDR) \
		--rps 200 --count 600 --connections 4 --models gcn,gin; \
	python3 python/tools/check_bench_schema.py BENCH_ingress_smoke.json \
		--schema BENCH_seed.json --require-measured \
		--require-result "loadgen/e2e_latency>0" \
		--require-result "loadgen/failed>0"; \
	sleep 6; \
	GENGNN_BENCH_JSON=$(CURDIR)/BENCH_ingress_recovery.json \
		./target/release/gengnn loadgen --addr $(INGRESS_ADDR) \
		--rps 100 --count 100 --connections 2 --models gin; \
	python3 python/tools/check_bench_schema.py BENCH_ingress_recovery.json \
		--schema BENCH_seed.json --require-measured \
		--require-result "loadgen/e2e_latency>0"

# Re-validate the checked-in golden/manifest fixtures (CI's
# artifacts-integrity job).
check-artifacts:
	python3 python/tools/check_artifacts.py artifacts

# Lower every manifest model through the real binary and validate the
# stage-IR dumps (CI's plan-coverage step).
check-plans: build
	@mkdir -p target/plans; \
	models=$$(python3 -c "import json; print(' '.join(x['name'] for x in json.load(open('artifacts/manifest.json'))['models']))"); \
	test -n "$$models" || { echo "no models in artifacts/manifest.json"; exit 1; }; \
	for m in $$models; do \
		./target/release/gengnn plan $$m --json > target/plans/$$m.json && \
		python3 python/tools/check_plan_schema.py target/plans/$$m.json --model $$m || exit 1; \
	done

# Run the stage-IR static analyzer over every manifest model and
# validate the findings JSON against the lint schema (part of `check`
# and CI's plan-coverage step; see docs/STATIC_ANALYSIS.md).
lint-plans: build
	@mkdir -p target/plans; \
	./target/release/gengnn lint-plan --all --json > target/plans/lint.json && \
	python3 python/tools/check_plan_schema.py target/plans/lint.json --lint-all

# Refresh the perf-trajectory anchor from the micro bench.
# (cargo runs benches with cwd = rust/, so anchor the path to the repo root.)
bench-snapshot:
	GENGNN_BENCH_JSON=$(CURDIR)/BENCH_seed.json cargo bench --bench micro

clean:
	cargo clean

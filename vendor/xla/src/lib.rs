//! API-compatible stub of the `xla-rs` PJRT surface that
//! `gengnn`'s optional `xla` feature compiles against.
//!
//! The build container has neither crates.io access nor the XLA C++
//! runtime, so this crate keeps the PJRT-backed client code
//! *compiling* while returning a clear runtime error from every entry
//! point. Swapping in the real `xla-rs` (same module paths, same
//! method signatures) re-enables HLO execution without touching
//! `gengnn` sources — see `rust/README.md` § Backends.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA PJRT runtime not present in this build \
         (vendored API stub; install xla-rs + libxla to enable)"
    ))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let path = path.as_ref();
        std::fs::metadata(path)
            .map_err(|e| Error(format!("reading HLO text {path:?}: {e}")))?;
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_errors_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_ok());
        assert!(lit.to_vec::<f32>().is_err());
    }
}

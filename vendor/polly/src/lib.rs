//! A minimal readiness poller for the gengnn reactor front-end.
//!
//! Vendored like `anyhow`/`xla`: no registry deps, no build script —
//! the whole OS surface is a handful of `extern "C"` declarations
//! against the C library every supported target already links. The
//! API is the small mio-shaped core the reactor needs and nothing
//! more:
//!
//! * [`Poller`] — register/modify/deregister interest in raw fds and
//!   [`Poller::wait`] for [`Event`]s, **level-triggered** (an event
//!   repeats every wait until the condition is consumed, so a reactor
//!   that processes partially is never stranded).
//! * [`Waker`] — a nonblocking self-pipe for cross-thread wakeups
//!   (accept loop and response pump poke reactors out of `wait`).
//! * [`nofile_limit`] / [`raise_nofile_limit`] — `RLIMIT_NOFILE`
//!   introspection so "thousands of connections" does not die at the
//!   default 1024 soft cap.
//!
//! On Linux the backend is epoll(7); elsewhere a poll(2) scan keeps
//! the crate compiling and tests honest (the reactor only targets
//! Linux in CI, but a laptop build should not need a cfg fence).
//!
//! Tokens are caller-chosen `u64`s echoed back verbatim in events; fd
//! lifetime stays with the caller (`deregister` before close).

use std::io;

/// Raw file descriptor. `std::os::unix::io::RawFd` without pulling
/// the unix prelude into every caller.
pub type Fd = i32;

/// Readiness delivered by [`Poller::wait`]. `readable` is set on
/// error/hangup too so a reader always observes EOF-ish conditions;
/// `hangup` singles out peer-close for callers that want to fast-path
/// teardown.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    pub hangup: bool,
}

/// Interest to (re)arm for an fd.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest {
        readable: true,
        writable: false,
    };
    pub const WRITE: Interest = Interest {
        readable: false,
        writable: true,
    };
    pub const BOTH: Interest = Interest {
        readable: true,
        writable: true,
    };
}

mod sys {
    //! The entire FFI surface. Everything here is a direct
    //! declaration of a libc symbol; no types leave this module
    //! except through the safe wrappers below.
    #![allow(non_camel_case_types)]

    pub type c_int = i32;

    #[repr(C)]
    pub struct rlimit {
        pub rlim_cur: u64,
        pub rlim_max: u64,
    }

    /// `RLIMIT_NOFILE`: 7 on Linux, 8 on the macOS/BSD family.
    #[cfg(target_os = "linux")]
    pub const RLIMIT_NOFILE: c_int = 7;
    #[cfg(not(target_os = "linux"))]
    pub const RLIMIT_NOFILE: c_int = 8;

    pub const F_SETFL: c_int = 4;
    pub const F_GETFL: c_int = 3;
    #[cfg(target_os = "linux")]
    pub const O_NONBLOCK: c_int = 0o4000;
    #[cfg(not(target_os = "linux"))]
    pub const O_NONBLOCK: c_int = 0x0004;

    #[cfg(target_os = "linux")]
    pub mod epoll {
        use super::c_int;

        pub const EPOLL_CLOEXEC: c_int = 0o2000000;
        pub const EPOLL_CTL_ADD: c_int = 1;
        pub const EPOLL_CTL_DEL: c_int = 2;
        pub const EPOLL_CTL_MOD: c_int = 3;

        pub const EPOLLIN: u32 = 0x001;
        pub const EPOLLPRI: u32 = 0x002;
        pub const EPOLLOUT: u32 = 0x004;
        pub const EPOLLERR: u32 = 0x008;
        pub const EPOLLHUP: u32 = 0x010;
        pub const EPOLLRDHUP: u32 = 0x2000;

        /// Kernel ABI layout: packed on x86 so the 64-bit payload sits
        /// at offset 4 (the historical i386 layout the syscall expects
        /// on both x86 widths); natural alignment everywhere else.
        #[repr(C)]
        #[cfg_attr(
            any(target_arch = "x86_64", target_arch = "x86"),
            repr(packed)
        )]
        #[derive(Clone, Copy)]
        pub struct epoll_event {
            pub events: u32,
            pub data: u64,
        }

        extern "C" {
            pub fn epoll_create1(flags: c_int) -> c_int;
            pub fn epoll_ctl(
                epfd: c_int,
                op: c_int,
                fd: c_int,
                event: *mut epoll_event,
            ) -> c_int;
            pub fn epoll_wait(
                epfd: c_int,
                events: *mut epoll_event,
                maxevents: c_int,
                timeout: c_int,
            ) -> c_int;
        }
    }

    #[cfg(not(target_os = "linux"))]
    pub mod pollsys {
        use super::c_int;

        pub const POLLIN: i16 = 0x001;
        pub const POLLPRI: i16 = 0x002;
        pub const POLLOUT: i16 = 0x004;
        pub const POLLERR: i16 = 0x008;
        pub const POLLHUP: i16 = 0x010;

        #[repr(C)]
        #[derive(Clone, Copy)]
        pub struct pollfd {
            pub fd: c_int,
            pub events: i16,
            pub revents: i16,
        }

        /// `nfds_t` is `u32` on every non-Linux unix we could build on
        /// (Linux takes the epoll path above).
        pub type nfds_t = u32;

        extern "C" {
            pub fn poll(fds: *mut pollfd, nfds: nfds_t, timeout: c_int) -> c_int;
        }
    }

    extern "C" {
        pub fn close(fd: c_int) -> c_int;
        pub fn pipe(fds: *mut c_int) -> c_int;
        pub fn fcntl(fd: c_int, cmd: c_int, ...) -> c_int;
        pub fn read(fd: c_int, buf: *mut u8, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const u8, count: usize) -> isize;
        pub fn getrlimit(resource: c_int, rlim: *mut rlimit) -> c_int;
        pub fn setrlimit(resource: c_int, rlim: *const rlimit) -> c_int;
    }
}

fn cvt(ret: i32) -> io::Result<i32> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

/// Mark an fd nonblocking (used for the waker pipe; sockets go
/// through `TcpStream::set_nonblocking`).
fn set_nonblocking(fd: Fd) -> io::Result<()> {
    unsafe {
        let flags = cvt(sys::fcntl(fd, sys::F_GETFL))?;
        cvt(sys::fcntl(fd, sys::F_SETFL, flags | sys::O_NONBLOCK))?;
    }
    Ok(())
}

/// Current `(soft, hard)` RLIMIT_NOFILE.
pub fn nofile_limit() -> io::Result<(u64, u64)> {
    let mut r = sys::rlimit {
        rlim_cur: 0,
        rlim_max: 0,
    };
    cvt(unsafe { sys::getrlimit(sys::RLIMIT_NOFILE, &mut r) })?;
    Ok((r.rlim_cur, r.rlim_max))
}

/// Raise the soft RLIMIT_NOFILE toward `want`, clamped to the hard
/// limit (unprivileged processes cannot exceed it). Returns the soft
/// limit actually in effect afterwards; never lowers it.
pub fn raise_nofile_limit(want: u64) -> io::Result<u64> {
    let (soft, hard) = nofile_limit()?;
    let target = want.min(hard);
    if target <= soft {
        return Ok(soft);
    }
    let r = sys::rlimit {
        rlim_cur: target,
        rlim_max: hard,
    };
    cvt(unsafe { sys::setrlimit(sys::RLIMIT_NOFILE, &r) })?;
    Ok(target)
}

#[cfg(target_os = "linux")]
mod backend {
    use super::sys::epoll::*;
    use super::{cvt, Event, Fd, Interest};
    use std::io;

    fn mask(interest: Interest) -> u32 {
        let mut m = EPOLLRDHUP;
        if interest.readable {
            m |= EPOLLIN;
        }
        if interest.writable {
            m |= EPOLLOUT;
        }
        m
    }

    /// epoll(7) instance. Level-triggered; interest is per-fd.
    pub struct Poller {
        epfd: Fd,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let epfd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
            Ok(Poller { epfd })
        }

        fn ctl(&self, op: i32, fd: Fd, token: u64, interest: Interest) -> io::Result<()> {
            let mut ev = epoll_event {
                events: mask(interest),
                data: token,
            };
            cvt(unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) })?;
            Ok(())
        }

        pub fn register(&self, fd: Fd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        pub fn modify(&self, fd: Fd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        pub fn deregister(&self, fd: Fd) -> io::Result<()> {
            let mut ev = epoll_event { events: 0, data: 0 };
            cvt(unsafe { epoll_ctl(self.epfd, EPOLL_CTL_DEL, fd, &mut ev) })?;
            Ok(())
        }

        /// Block up to `timeout_ms` (-1 = forever) and append ready
        /// events. EINTR retries; returns the number appended.
        pub fn wait(&self, events: &mut Vec<Event>, timeout_ms: i32) -> io::Result<usize> {
            const CAP: usize = 1024;
            let mut buf = [epoll_event { events: 0, data: 0 }; CAP];
            let n = loop {
                let r = unsafe {
                    epoll_wait(self.epfd, buf.as_mut_ptr(), CAP as i32, timeout_ms)
                };
                if r >= 0 {
                    break r as usize;
                }
                let err = io::Error::last_os_error();
                if err.kind() != io::ErrorKind::Interrupted {
                    return Err(err);
                }
            };
            for ev in &buf[..n] {
                let bits = ev.events;
                events.push(Event {
                    token: ev.data,
                    readable: bits & (EPOLLIN | EPOLLPRI | EPOLLERR | EPOLLHUP | EPOLLRDHUP)
                        != 0,
                    writable: bits & (EPOLLOUT | EPOLLERR) != 0,
                    hangup: bits & (EPOLLHUP | EPOLLRDHUP) != 0,
                });
            }
            Ok(n)
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe { super::sys::close(self.epfd) };
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod backend {
    use super::sys::pollsys::*;
    use super::{cvt, Event, Fd, Interest};
    use std::collections::HashMap;
    use std::io;
    use std::sync::Mutex;

    /// poll(2) scan over the registered set. O(fds) per wait — fine
    /// for dev boxes; production reactors run the Linux epoll path.
    pub struct Poller {
        registered: Mutex<HashMap<Fd, (u64, Interest)>>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller {
                registered: Mutex::new(HashMap::new()),
            })
        }

        pub fn register(&self, fd: Fd, token: u64, interest: Interest) -> io::Result<()> {
            let mut reg = self.registered.lock().unwrap_or_else(|e| e.into_inner());
            if reg.insert(fd, (token, interest)).is_some() {
                return Err(io::Error::new(
                    io::ErrorKind::AlreadyExists,
                    "fd already registered",
                ));
            }
            Ok(())
        }

        pub fn modify(&self, fd: Fd, token: u64, interest: Interest) -> io::Result<()> {
            let mut reg = self.registered.lock().unwrap_or_else(|e| e.into_inner());
            match reg.get_mut(&fd) {
                Some(slot) => {
                    *slot = (token, interest);
                    Ok(())
                }
                None => Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered")),
            }
        }

        pub fn deregister(&self, fd: Fd) -> io::Result<()> {
            let mut reg = self.registered.lock().unwrap_or_else(|e| e.into_inner());
            match reg.remove(&fd) {
                Some(_) => Ok(()),
                None => Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered")),
            }
        }

        pub fn wait(&self, events: &mut Vec<Event>, timeout_ms: i32) -> io::Result<usize> {
            let mut fds: Vec<pollfd> = Vec::new();
            let mut tokens: Vec<u64> = Vec::new();
            {
                let reg = self.registered.lock().unwrap_or_else(|e| e.into_inner());
                for (&fd, &(token, interest)) in reg.iter() {
                    let mut ev = 0i16;
                    if interest.readable {
                        ev |= POLLIN | POLLPRI;
                    }
                    if interest.writable {
                        ev |= POLLOUT;
                    }
                    fds.push(pollfd {
                        fd,
                        events: ev,
                        revents: 0,
                    });
                    tokens.push(token);
                }
            }
            let n = loop {
                let r = unsafe { poll(fds.as_mut_ptr(), fds.len() as nfds_t, timeout_ms) };
                match cvt(r) {
                    Ok(r) => break r as usize,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => return Err(e),
                }
            };
            for (pfd, &token) in fds.iter().zip(&tokens) {
                let bits = pfd.revents;
                if bits == 0 {
                    continue;
                }
                events.push(Event {
                    token,
                    readable: bits & (POLLIN | POLLPRI | POLLERR | POLLHUP) != 0,
                    writable: bits & (POLLOUT | POLLERR) != 0,
                    hangup: bits & POLLHUP != 0,
                });
            }
            Ok(n)
        }
    }
}

pub use backend::Poller;

/// Cross-thread wakeup: a nonblocking self-pipe whose read end the
/// owning reactor registers under a reserved token. `wake` is safe
/// from any thread; a full pipe already guarantees a pending wakeup,
/// so `EAGAIN` counts as success.
pub struct Waker {
    read_fd: Fd,
    write_fd: Fd,
}

impl Waker {
    pub fn new() -> io::Result<Waker> {
        let mut fds = [0i32; 2];
        cvt(unsafe { sys::pipe(fds.as_mut_ptr()) })?;
        let (read_fd, write_fd) = (fds[0], fds[1]);
        for fd in [read_fd, write_fd] {
            if let Err(e) = set_nonblocking(fd) {
                unsafe {
                    sys::close(read_fd);
                    sys::close(write_fd);
                }
                return Err(e);
            }
        }
        Ok(Waker { read_fd, write_fd })
    }

    /// Register the pipe's read end with `poller` under `token`.
    pub fn register(&self, poller: &Poller, token: u64) -> io::Result<()> {
        poller.register(self.read_fd, token, Interest::READ)
    }

    /// Poke the poller out of `wait`. Never blocks.
    pub fn wake(&self) -> io::Result<()> {
        let byte = [1u8];
        let r = unsafe { sys::write(self.write_fd, byte.as_ptr(), 1) };
        if r >= 0 {
            return Ok(());
        }
        let err = io::Error::last_os_error();
        match err.kind() {
            // Pipe full: a wakeup is already pending, job done.
            io::ErrorKind::WouldBlock | io::ErrorKind::Interrupted => Ok(()),
            _ => Err(err),
        }
    }

    /// Consume all pending wakeup bytes (call when the waker token
    /// fires, before scanning inboxes, so level-triggered polling
    /// does not spin).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            let r = unsafe { sys::read(self.read_fd, buf.as_mut_ptr(), buf.len()) };
            if r <= 0 {
                return;
            }
        }
    }
}

impl Drop for Waker {
    fn drop(&mut self) {
        unsafe {
            sys::close(self.read_fd);
            sys::close(self.write_fd);
        }
    }
}

// The fds are plain integers; wake() and drain() are independent ends
// of the pipe and each is atomic at the syscall level.
unsafe impl Send for Waker {}
unsafe impl Sync for Waker {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};

    fn wait_for(poller: &Poller, token: u64, want_read: bool) -> Event {
        let mut events = Vec::new();
        for _ in 0..100 {
            events.clear();
            poller.wait(&mut events, 100).expect("wait");
            if let Some(ev) = events
                .iter()
                .find(|e| e.token == token && (!want_read || e.readable))
            {
                return *ev;
            }
        }
        panic!("token {token} never became ready");
    }

    #[test]
    fn socket_readiness_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let mut client = TcpStream::connect(addr).expect("connect");
        let (server, _) = listener.accept().expect("accept");
        server.set_nonblocking(true).expect("nonblocking");

        let poller = Poller::new().expect("poller");
        use std::os::unix::io::AsRawFd;
        let fd = server.as_raw_fd();
        poller.register(fd, 7, Interest::BOTH).expect("register");

        // A fresh socket with empty buffers: writable, not readable.
        let ev = wait_for(&poller, 7, false);
        assert!(ev.writable && !ev.readable, "{ev:?}");

        client.write_all(b"ping").expect("write");
        let ev = wait_for(&poller, 7, true);
        assert!(ev.readable, "{ev:?}");

        // Level-triggered: still readable on the next wait because the
        // bytes were not consumed.
        let ev = wait_for(&poller, 7, true);
        assert!(ev.readable, "{ev:?}");

        // Dropping write interest stops writable events.
        poller.modify(fd, 7, Interest::READ).expect("modify");
        let ev = wait_for(&poller, 7, true);
        assert!(ev.readable && !ev.writable, "{ev:?}");

        // Peer close surfaces as readable (EOF) with hangup.
        drop(client);
        let ev = wait_for(&poller, 7, true);
        assert!(ev.readable, "{ev:?}");
        let mut one = [0u8; 16];
        let mut s = &server;
        assert_eq!(s.read(&mut one).expect("read data"), 4);

        poller.deregister(fd).expect("deregister");
        let mut events = Vec::new();
        poller.wait(&mut events, 10).expect("wait");
        assert!(
            events.iter().all(|e| e.token != 7),
            "deregistered fd still reported: {events:?}"
        );
    }

    #[test]
    fn waker_crosses_threads_and_drains() {
        let poller = Poller::new().expect("poller");
        let waker = std::sync::Arc::new(Waker::new().expect("waker"));
        waker.register(&poller, 0).expect("register");

        // No wake yet: a short wait returns nothing for token 0.
        let mut events = Vec::new();
        poller.wait(&mut events, 10).expect("wait");
        assert!(events.iter().all(|e| e.token != 0));

        let w = std::sync::Arc::clone(&waker);
        let t = std::thread::spawn(move || {
            for _ in 0..100 {
                w.wake().expect("wake");
            }
        });
        let ev = wait_for(&poller, 0, true);
        assert!(ev.readable);
        t.join().expect("join");

        // After draining, the level-triggered readable condition is
        // gone (100 coalesced bytes consumed in one drain).
        waker.drain();
        events.clear();
        poller.wait(&mut events, 10).expect("wait");
        assert!(events.iter().all(|e| e.token != 0), "{events:?}");

        // Wake again after drain still works.
        waker.wake().expect("wake");
        let ev = wait_for(&poller, 0, true);
        assert!(ev.readable);
    }

    #[test]
    fn nofile_limit_helpers() {
        let (soft, hard) = nofile_limit().expect("getrlimit");
        assert!(soft > 0 && hard >= soft, "soft={soft} hard={hard}");
        // Raising toward an absurd target clamps to the hard limit and
        // never errors or lowers the soft limit.
        let now = raise_nofile_limit(u64::MAX).expect("setrlimit");
        assert!(now >= soft && now <= hard);
        // Asking for less than the current soft limit is a no-op.
        assert_eq!(raise_nofile_limit(1).expect("noop"), now.max(1));
    }

    #[test]
    fn register_duplicate_fd_errors() {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        use std::os::unix::io::AsRawFd;
        let fd = listener.as_raw_fd();
        let poller = Poller::new().expect("poller");
        poller.register(fd, 1, Interest::READ).expect("register");
        assert!(poller.register(fd, 2, Interest::READ).is_err());
        poller.deregister(fd).expect("deregister");
        assert!(poller.deregister(fd).is_err());
    }
}

//! Offline drop-in shim for the subset of the `anyhow` API this
//! workspace uses: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The container this repo builds in has no crates.io access, so the
//! real `anyhow` cannot be fetched; this path crate keeps the call
//! sites source-compatible. Like the real crate, [`Error`] deliberately
//! does *not* implement `std::error::Error` — that is what makes the
//! blanket `From<E: std::error::Error>` conversion and the blanket
//! [`Context`] impl coherent.

use std::fmt;

/// A string-backed error value with `anyhow`-style context layering.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Layer a context message in front of this error.
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error {
            msg: format!("{c}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow`-style result alias: the error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::Error;

    /// Sealed conversion helper so [`super::Context`] applies both to
    /// std errors and to [`Error`] itself (mirrors anyhow's `ext`).
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> Error {
            Error::msg(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: ext::IntoError> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/nonexistent/anyhow-shim-test")
            .context("reading test file")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().starts_with("reading test file: "));
    }

    #[test]
    fn macros_compose() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("x too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).unwrap_err().to_string().contains("positive"));
        assert!(f(11).unwrap_err().to_string().contains("too big"));
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn context_layers_outermost_first() {
        let e: Result<()> = Err(anyhow!("inner"));
        let e = e.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(e.to_string(), "outer 1: inner");
    }
}

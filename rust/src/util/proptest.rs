//! Minimal property-testing substrate (proptest is not in the offline
//! vendor set). Runs a property over many seeded random cases and, on
//! failure, reports the failing seed so the case can be replayed
//! deterministically — the part of proptest we actually need for the
//! coordinator/graph invariants.

use crate::util::rng::Rng;

/// Outcome of one case: Ok, or a message describing the violation.
pub type CaseResult = Result<(), String>;

/// Run `cases` random cases of `prop`, each with a forked RNG.
/// Panics with the seed + message of the first failure.
pub fn forall(name: &str, cases: usize, base_seed: u64, prop: impl Fn(&mut Rng) -> CaseResult) {
    for i in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(i as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {i} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Assert helper returning CaseResult instead of panicking, so the
/// failing seed is reported by `forall`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Approximate float comparison for property bodies.
pub fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("x*0==0", 50, 1, |rng| {
            let x = rng.f64();
            if x * 0.0 == 0.0 {
                Ok(())
            } else {
                Err(format!("{x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn forall_reports_seed_on_failure() {
        forall("always-fails", 5, 2, |_| Err("nope".into()));
    }

    #[test]
    fn close_tolerates_relative_error() {
        assert!(close(1000.0, 1000.5, 1e-3));
        assert!(!close(1.0, 2.0, 1e-3));
    }
}

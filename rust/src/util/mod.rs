//! Substrate utilities built from scratch for the offline environment:
//! PRNG, JSON, CLI parsing, statistics, concurrency, property testing.

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod sync;

//! Micro-bench harness used by `rust/benches/*` (criterion is heavier
//! than needed and not in the offline vendor set): warmup, repeated
//! timed runs, outlier-trimmed summary.

use std::time::Instant;

use super::stats::{fmt_secs, Sample};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: f64,
    pub p50: f64,
    pub min: f64,
}

/// Time `f` for `iters` iterations after `warmup` runs; the closure's
/// return value is black-boxed to keep the optimizer honest.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut s = Sample::new();
    let mut min = f64::INFINITY;
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        let dt = t0.elapsed().as_secs_f64();
        s.push(dt);
        min = min.min(dt);
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean: s.trimmed_mean(0.05),
        p50: s.median(),
        min,
    };
    println!(
        "{:<44} {:>11}/iter (p50 {:>11}, min {:>11}, n={})",
        r.name,
        fmt_secs(r.mean),
        fmt_secs(r.p50),
        fmt_secs(r.min),
        r.iters
    );
    r
}

/// Optimizer barrier (std::hint::black_box re-export for benches).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Serialize bench results as the `BENCH_*.json` snapshot schema
/// (perf-trajectory anchors checked in at the repo root).
pub fn results_to_json(label: &str, results: &[BenchResult]) -> String {
    use crate::util::json::{self, Json};
    let entries: Vec<Json> = results
        .iter()
        .map(|r| {
            json::obj(vec![
                ("name", Json::Str(r.name.clone())),
                ("iters", json::num(r.iters as f64)),
                ("mean_s", json::num(r.mean)),
                ("p50_s", json::num(r.p50)),
                ("min_s", json::num(r.min)),
            ])
        })
        .collect();
    json::obj(vec![
        ("bench", Json::Str(label.to_string())),
        ("status", Json::Str("measured".to_string())),
        ("results", Json::Arr(entries)),
    ])
    .to_string_pretty()
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_times() {
        let r = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.mean > 0.0);
        assert!(r.min <= r.mean * 1.01);
        assert_eq!(r.iters, 5);
    }
}

//! Deterministic PRNG substrate (no external crates available offline).
//!
//! SplitMix64 for seeding, xoshiro256** for the stream — the standard
//! pairing. Every generator in the repo (datagen, proptest, coordinator
//! jitter) goes through this module so runs are reproducible from a seed.

/// xoshiro256** seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-thread / per-graph RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Pick one element uniformly.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_bijection() {
        let mut r = Rng::new(5);
        let p = r.permutation(50);
        let mut seen = vec![false; 50];
        for &v in &p {
            assert!(!seen[v]);
            seen[v] = true;
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(3);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}

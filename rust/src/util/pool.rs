//! Concurrency substrate: bounded MPMC channel (backpressure-capable),
//! a scoped thread pool, and reusable scratch buffers. Tokio is not in
//! the offline vendor set, so the coordinator's event loop is built on
//! these primitives — which also map more directly onto the paper's
//! hardware FIFOs: the bounded channel *is* the streaming FIFO of
//! Section 3.5, with `send` blocking exactly like a full on-chip queue
//! stalls the NE PE. The scratch [`BufferPool`] is the software analog
//! of statically-allocated on-chip BRAM: each executor lane re-uses the
//! same working buffers for every graph it processes instead of
//! re-allocating per request.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Bounded MPMC channel. `send` blocks when full (backpressure),
/// `recv` blocks when empty, `close` wakes all waiters.
pub struct Channel<T> {
    inner: Arc<ChannelInner<T>>,
}

struct ChannelInner<T> {
    q: Mutex<ChannelState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

struct ChannelState<T> {
    buf: VecDeque<T>,
    closed: bool,
    peak: usize,
}

impl<T> Clone for Channel<T> {
    fn clone(&self) -> Self {
        Channel {
            inner: Arc::clone(&self.inner),
        }
    }
}

impl<T> Channel<T> {
    pub fn bounded(cap: usize) -> Self {
        assert!(cap > 0, "channel capacity must be positive");
        Channel {
            inner: Arc::new(ChannelInner {
                q: Mutex::new(ChannelState {
                    buf: VecDeque::with_capacity(cap),
                    closed: false,
                    peak: 0,
                }),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
                cap,
            }),
        }
    }

    /// Blocking send; Err(v) if the channel is closed.
    pub fn send(&self, v: T) -> Result<(), T> {
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if st.closed {
                return Err(v);
            }
            if st.buf.len() < self.inner.cap {
                st.buf.push_back(v);
                let depth = st.buf.len();
                st.peak = st.peak.max(depth);
                drop(st);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send; Err(v) when full or closed.
    pub fn try_send(&self, v: T) -> Result<(), T> {
        let mut st = self.inner.q.lock().unwrap();
        if st.closed || st.buf.len() >= self.inner.cap {
            return Err(v);
        }
        st.buf.push_back(v);
        let d = st.buf.len();
        st.peak = st.peak.max(d);
        drop(st);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Blocking receive; None once closed *and* drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if let Some(v) = st.buf.pop_front() {
                drop(st);
                self.inner.not_full.notify_one();
                return Some(v);
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    /// Blocking receive with a deadline — what an executor lane parks
    /// on so it can periodically wake and steal from sibling lanes.
    pub fn recv_timeout(&self, timeout: Duration) -> RecvTimeout<T> {
        let deadline = Instant::now() + timeout;
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if let Some(v) = st.buf.pop_front() {
                drop(st);
                self.inner.not_full.notify_one();
                return RecvTimeout::Item(v);
            }
            if st.closed {
                return RecvTimeout::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return RecvTimeout::TimedOut;
            }
            let (guard, _) = self
                .inner
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
        }
    }

    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.inner.q.lock().unwrap();
        let v = st.buf.pop_front();
        if v.is_some() {
            drop(st);
            self.inner.not_full.notify_one();
        }
        v
    }

    /// Close the channel: senders fail, receivers drain then get None.
    pub fn close(&self) {
        let mut st = self.inner.q.lock().unwrap();
        st.closed = true;
        drop(st);
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.q.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// High-water mark of queue depth (backpressure diagnostics).
    pub fn peak_depth(&self) -> usize {
        self.inner.q.lock().unwrap().peak
    }
}

/// Outcome of a bounded-wait receive ([`Channel::recv_timeout`]).
#[derive(Debug)]
pub enum RecvTimeout<T> {
    /// An item arrived within the deadline.
    Item(T),
    /// The deadline elapsed with the channel open but empty.
    TimedOut,
    /// The channel is closed and fully drained.
    Closed,
}

/// Recycled f32 scratch buffers: `take_zeroed` hands out a cleared
/// buffer (re-using a previously returned allocation when one is
/// available), `put` returns one. Bounded by buffer count *and* total
/// retained bytes, so neither a burst of many buffers nor a phase of
/// oversized graphs can pin unbounded memory for the thread's life.
pub struct BufferPool {
    free: Vec<Vec<f32>>,
    max_buffers: usize,
    max_bytes: usize,
    retained_bytes: usize,
    hits: u64,
    misses: u64,
}

impl BufferPool {
    pub fn new(max_buffers: usize, max_bytes: usize) -> BufferPool {
        BufferPool {
            free: Vec::new(),
            max_buffers,
            max_bytes,
            retained_bytes: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// A zero-filled buffer of exactly `len` elements. Prefers a
    /// recycled allocation whose capacity already covers `len`.
    pub fn take_zeroed(&mut self, len: usize) -> Vec<f32> {
        match self.take_raw(len) {
            Some(mut b) => {
                b.resize(len, 0.0);
                b
            }
            None => vec![0.0; len],
        }
    }

    /// A buffer holding a copy of `src` (no intermediate zero-fill).
    pub fn take_copied(&mut self, src: &[f32]) -> Vec<f32> {
        match self.take_raw(src.len()) {
            Some(mut b) => {
                b.extend_from_slice(src);
                b
            }
            None => src.to_vec(),
        }
    }

    /// Pop a cleared recycled buffer, first-fit on capacity; None when
    /// the pool is empty (caller allocates fresh). A take no pooled
    /// buffer can satisfy still recycles the last buffer — it grows to
    /// the new size and re-enters the pool, adapting it to the
    /// workload — but counts as a miss, since it reallocates exactly
    /// like a fresh `Vec` would.
    fn take_raw(&mut self, len: usize) -> Option<Vec<f32>> {
        if let Some(p) = self.free.iter().position(|b| b.capacity() >= len) {
            let mut b = self.free.swap_remove(p);
            self.retained_bytes -= b.capacity() * std::mem::size_of::<f32>();
            b.clear();
            self.hits += 1;
            return Some(b);
        }
        self.misses += 1;
        self.free.pop().map(|mut b| {
            self.retained_bytes -= b.capacity() * std::mem::size_of::<f32>();
            b.clear();
            b
        })
    }

    /// Return a buffer for re-use. Zero-capacity buffers and overflow
    /// beyond `max_buffers` / `max_bytes` are dropped.
    pub fn put(&mut self, buf: Vec<f32>) {
        let bytes = buf.capacity() * std::mem::size_of::<f32>();
        if buf.capacity() > 0
            && self.free.len() < self.max_buffers
            && self.retained_bytes + bytes <= self.max_bytes
        {
            self.retained_bytes += bytes;
            self.free.push(buf);
        }
    }

    /// Bytes currently parked in the pool.
    pub fn retained_bytes(&self) -> usize {
        self.retained_bytes
    }

    /// `(hits, misses)` across the pool's lifetime.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

impl Default for BufferPool {
    /// 32 buffers / 16 MiB per thread: comfortably covers a lane's
    /// live set for the largest fixture model (dgn_large temporaries
    /// are ~1 MiB each) without pinning unbounded memory after a
    /// large-graph phase ends.
    fn default() -> Self {
        BufferPool::new(32, 16 << 20)
    }
}

thread_local! {
    /// Per-thread scratch pool: each executor lane (its own thread)
    /// recycles forward-pass temporaries across the requests it serves.
    static SCRATCH: RefCell<BufferPool> = RefCell::new(BufferPool::default());
}

/// Take a zero-filled f32 buffer from this thread's scratch pool.
/// Falls back to a plain allocation if the pool is unavailable
/// (re-entrant use or thread teardown).
pub fn scratch_take_zeroed(len: usize) -> Vec<f32> {
    SCRATCH
        .try_with(|p| match p.try_borrow_mut() {
            Ok(mut pool) => pool.take_zeroed(len),
            Err(_) => vec![0.0; len],
        })
        .unwrap_or_else(|_| vec![0.0; len])
}

/// Take a buffer holding a copy of `src` from this thread's pool.
pub fn scratch_take_copied(src: &[f32]) -> Vec<f32> {
    SCRATCH
        .try_with(|p| match p.try_borrow_mut() {
            Ok(mut pool) => pool.take_copied(src),
            Err(_) => src.to_vec(),
        })
        .unwrap_or_else(|_| src.to_vec())
}

/// Return a buffer to this thread's scratch pool (drops it if the
/// pool is unavailable or full).
pub fn scratch_put(buf: Vec<f32>) {
    let _ = SCRATCH.try_with(|p| {
        if let Ok(mut pool) = p.try_borrow_mut() {
            pool.put(buf);
        }
    });
}

/// `(hits, misses)` of this thread's scratch pool.
pub fn scratch_stats() -> (u64, u64) {
    SCRATCH
        .try_with(|p| p.borrow().stats())
        .unwrap_or((0, 0))
}

/// Fixed-size worker pool executing closures from a shared queue.
pub struct ThreadPool {
    tx: Channel<Box<dyn FnOnce() + Send + 'static>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(workers: usize) -> Self {
        let tx: Channel<Box<dyn FnOnce() + Send + 'static>> =
            Channel::bounded(workers.max(1) * 64);
        let mut handles = Vec::new();
        for i in 0..workers.max(1) {
            let rx = tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("gengnn-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx, handles }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .send(Box::new(f))
            .unwrap_or_else(|_| panic!("pool closed"));
    }

    /// Close the queue and join all workers.
    pub fn join(self) {
        self.tx.close();
        for h in self.handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn channel_fifo_order() {
        let ch = Channel::bounded(8);
        for i in 0..5 {
            ch.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(ch.recv(), Some(i));
        }
    }

    #[test]
    fn channel_backpressure_blocks_until_drained() {
        let ch = Channel::bounded(2);
        ch.send(1).unwrap();
        ch.send(2).unwrap();
        assert!(ch.try_send(3).is_err());
        let ch2 = ch.clone();
        let t = std::thread::spawn(move || ch2.send(3).is_ok());
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(ch.recv(), Some(1));
        assert!(t.join().unwrap());
        assert_eq!(ch.recv(), Some(2));
        assert_eq!(ch.recv(), Some(3));
    }

    #[test]
    fn close_drains_then_none() {
        let ch = Channel::bounded(4);
        ch.send("a").unwrap();
        ch.close();
        assert!(ch.send("b").is_err());
        assert_eq!(ch.recv(), Some("a"));
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn peak_depth_tracks_high_water() {
        let ch = Channel::bounded(10);
        for i in 0..7 {
            ch.send(i).unwrap();
        }
        while ch.try_recv().is_some() {}
        assert_eq!(ch.peak_depth(), 7);
    }

    #[test]
    fn recv_timeout_times_out_then_delivers() {
        let ch: Channel<u32> = Channel::bounded(2);
        match ch.recv_timeout(Duration::from_millis(5)) {
            RecvTimeout::TimedOut => {}
            other => panic!("expected timeout, got {other:?}"),
        }
        ch.send(7).unwrap();
        match ch.recv_timeout(Duration::from_millis(5)) {
            RecvTimeout::Item(7) => {}
            other => panic!("expected item, got {other:?}"),
        }
        ch.close();
        match ch.recv_timeout(Duration::from_millis(5)) {
            RecvTimeout::Closed => {}
            other => panic!("expected closed, got {other:?}"),
        }
    }

    #[test]
    fn recv_timeout_drains_before_reporting_closed() {
        let ch: Channel<u32> = Channel::bounded(2);
        ch.send(1).unwrap();
        ch.close();
        match ch.recv_timeout(Duration::from_millis(5)) {
            RecvTimeout::Item(1) => {}
            other => panic!("expected item, got {other:?}"),
        }
        match ch.recv_timeout(Duration::from_millis(5)) {
            RecvTimeout::Closed => {}
            other => panic!("expected closed, got {other:?}"),
        }
    }

    #[test]
    fn buffer_pool_recycles_and_zeroes() {
        let mut pool = BufferPool::new(4, 1 << 20);
        let mut a = pool.take_zeroed(8);
        assert_eq!(a, vec![0.0; 8]);
        a[3] = 5.0;
        pool.put(a);
        let b = pool.take_zeroed(8);
        assert_eq!(b, vec![0.0; 8], "recycled buffer must be re-zeroed");
        let (hits, misses) = pool.stats();
        assert_eq!((hits, misses), (1, 1));
    }

    #[test]
    fn buffer_pool_take_copied() {
        let mut pool = BufferPool::new(4, 1 << 20);
        pool.put(vec![9.0; 16]);
        let b = pool.take_copied(&[1.0, 2.0, 3.0]);
        assert_eq!(b, vec![1.0, 2.0, 3.0]);
        assert_eq!(pool.stats().0, 1, "copy should reuse the pooled buffer");
    }

    #[test]
    fn buffer_pool_bounds_retained_buffers() {
        let mut pool = BufferPool::new(2, 1 << 20);
        for _ in 0..5 {
            pool.put(vec![0.0; 4]);
        }
        assert_eq!(pool.free.len(), 2);
        pool.put(Vec::new()); // zero-capacity: dropped, not retained
        assert_eq!(pool.free.len(), 2);
    }

    #[test]
    fn buffer_pool_bounds_retained_bytes() {
        // 100-float budget: one 80-float buffer fits, a second is
        // dropped; taking the first frees its bytes again.
        let mut pool = BufferPool::new(32, 100 * std::mem::size_of::<f32>());
        pool.put(vec![0.0f32; 80]);
        assert_eq!(pool.free.len(), 1);
        assert!(pool.retained_bytes() >= 80 * std::mem::size_of::<f32>());
        pool.put(vec![0.0f32; 80]); // would exceed the byte cap
        assert_eq!(pool.free.len(), 1);
        let b = pool.take_zeroed(10);
        assert_eq!(pool.retained_bytes(), 0);
        assert_eq!(b.len(), 10);
        pool.put(b);
        assert!(pool.retained_bytes() > 0);
    }

    #[test]
    fn thread_scratch_reuses_across_calls() {
        // Run on a dedicated thread so other tests' scratch use cannot
        // perturb the counters.
        std::thread::spawn(|| {
            let a = scratch_take_zeroed(64);
            scratch_put(a);
            let b = scratch_take_zeroed(64);
            assert_eq!(b.len(), 64);
            let (hits, _) = scratch_stats();
            assert!(hits >= 1, "second take must hit the pool");
        })
        .join()
        .unwrap();
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn mpmc_multiple_consumers() {
        let ch: Channel<usize> = Channel::bounded(16);
        let sum = Arc::new(AtomicUsize::new(0));
        let mut joins = Vec::new();
        for _ in 0..3 {
            let rx = ch.clone();
            let s = Arc::clone(&sum);
            joins.push(std::thread::spawn(move || {
                while let Some(v) = rx.recv() {
                    s.fetch_add(v, Ordering::SeqCst);
                }
            }));
        }
        for i in 1..=100 {
            ch.send(i).unwrap();
        }
        ch.close();
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(sum.load(Ordering::SeqCst), 5050);
    }
}

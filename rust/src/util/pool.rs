//! Concurrency substrate: bounded MPMC channel (backpressure-capable)
//! and a scoped thread pool. Tokio is not in the offline vendor set, so
//! the coordinator's event loop is built on these primitives — which
//! also map more directly onto the paper's hardware FIFOs: the bounded
//! channel *is* the streaming FIFO of Section 3.5, with `send` blocking
//! exactly like a full on-chip queue stalls the NE PE.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

/// Bounded MPMC channel. `send` blocks when full (backpressure),
/// `recv` blocks when empty, `close` wakes all waiters.
pub struct Channel<T> {
    inner: Arc<ChannelInner<T>>,
}

struct ChannelInner<T> {
    q: Mutex<ChannelState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

struct ChannelState<T> {
    buf: VecDeque<T>,
    closed: bool,
    peak: usize,
}

impl<T> Clone for Channel<T> {
    fn clone(&self) -> Self {
        Channel {
            inner: Arc::clone(&self.inner),
        }
    }
}

impl<T> Channel<T> {
    pub fn bounded(cap: usize) -> Self {
        assert!(cap > 0, "channel capacity must be positive");
        Channel {
            inner: Arc::new(ChannelInner {
                q: Mutex::new(ChannelState {
                    buf: VecDeque::with_capacity(cap),
                    closed: false,
                    peak: 0,
                }),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
                cap,
            }),
        }
    }

    /// Blocking send; Err(v) if the channel is closed.
    pub fn send(&self, v: T) -> Result<(), T> {
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if st.closed {
                return Err(v);
            }
            if st.buf.len() < self.inner.cap {
                st.buf.push_back(v);
                let depth = st.buf.len();
                st.peak = st.peak.max(depth);
                drop(st);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send; Err(v) when full or closed.
    pub fn try_send(&self, v: T) -> Result<(), T> {
        let mut st = self.inner.q.lock().unwrap();
        if st.closed || st.buf.len() >= self.inner.cap {
            return Err(v);
        }
        st.buf.push_back(v);
        let d = st.buf.len();
        st.peak = st.peak.max(d);
        drop(st);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Blocking receive; None once closed *and* drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if let Some(v) = st.buf.pop_front() {
                drop(st);
                self.inner.not_full.notify_one();
                return Some(v);
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.inner.q.lock().unwrap();
        let v = st.buf.pop_front();
        if v.is_some() {
            drop(st);
            self.inner.not_full.notify_one();
        }
        v
    }

    /// Close the channel: senders fail, receivers drain then get None.
    pub fn close(&self) {
        let mut st = self.inner.q.lock().unwrap();
        st.closed = true;
        drop(st);
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.q.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// High-water mark of queue depth (backpressure diagnostics).
    pub fn peak_depth(&self) -> usize {
        self.inner.q.lock().unwrap().peak
    }
}

/// Fixed-size worker pool executing closures from a shared queue.
pub struct ThreadPool {
    tx: Channel<Box<dyn FnOnce() + Send + 'static>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(workers: usize) -> Self {
        let tx: Channel<Box<dyn FnOnce() + Send + 'static>> =
            Channel::bounded(workers.max(1) * 64);
        let mut handles = Vec::new();
        for i in 0..workers.max(1) {
            let rx = tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("gengnn-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx, handles }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .send(Box::new(f))
            .unwrap_or_else(|_| panic!("pool closed"));
    }

    /// Close the queue and join all workers.
    pub fn join(self) {
        self.tx.close();
        for h in self.handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn channel_fifo_order() {
        let ch = Channel::bounded(8);
        for i in 0..5 {
            ch.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(ch.recv(), Some(i));
        }
    }

    #[test]
    fn channel_backpressure_blocks_until_drained() {
        let ch = Channel::bounded(2);
        ch.send(1).unwrap();
        ch.send(2).unwrap();
        assert!(ch.try_send(3).is_err());
        let ch2 = ch.clone();
        let t = std::thread::spawn(move || ch2.send(3).is_ok());
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(ch.recv(), Some(1));
        assert!(t.join().unwrap());
        assert_eq!(ch.recv(), Some(2));
        assert_eq!(ch.recv(), Some(3));
    }

    #[test]
    fn close_drains_then_none() {
        let ch = Channel::bounded(4);
        ch.send("a").unwrap();
        ch.close();
        assert!(ch.send("b").is_err());
        assert_eq!(ch.recv(), Some("a"));
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn peak_depth_tracks_high_water() {
        let ch = Channel::bounded(10);
        for i in 0..7 {
            ch.send(i).unwrap();
        }
        while ch.try_recv().is_some() {}
        assert_eq!(ch.peak_depth(), 7);
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn mpmc_multiple_consumers() {
        let ch: Channel<usize> = Channel::bounded(16);
        let sum = Arc::new(AtomicUsize::new(0));
        let mut joins = Vec::new();
        for _ in 0..3 {
            let rx = ch.clone();
            let s = Arc::clone(&sum);
            joins.push(std::thread::spawn(move || {
                while let Some(v) = rx.recv() {
                    s.fetch_add(v, Ordering::SeqCst);
                }
            }));
        }
        for i in 1..=100 {
            ch.send(i).unwrap();
        }
        ch.close();
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(sum.load(Ordering::SeqCst), 5050);
    }
}

//! Poison-tolerant lock acquisition for the serving hot paths.
//!
//! `std`'s mutexes poison when a holder panics; `.lock().unwrap()`
//! then turns one crashed worker into a cascading panic in every
//! thread that touches the same lock — connection registries, metrics
//! shards, the load generator's pending table. The serving data these
//! locks guard (counters, socket maps, in-flight tables) stays
//! internally consistent under a mid-update panic at worst to the tune
//! of one lost increment, so the right degradation is: take the data
//! anyway, log the first recovery, and keep serving.
//!
//! `rust/src/net/` and `rust/src/coordinator/` deny
//! `clippy::unwrap_used` outside tests; these helpers are what the
//! swept `lock().unwrap()` call sites became. A once-only `eprintln`
//! records that degraded mode was entered; [`poison_recoveries`]
//! exposes the running count for tests and debugging.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};

static POISON_RECOVERIES: AtomicU64 = AtomicU64::new(0);

fn note_poison() {
    if POISON_RECOVERIES.fetch_add(1, Ordering::Relaxed) == 0 {
        eprintln!(
            "[sync] recovered a poisoned lock (a thread panicked while holding it); \
             counters may undercount from here on"
        );
    }
}

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(p) => {
            note_poison();
            p.into_inner()
        }
    }
}

/// Read-lock an `RwLock`, recovering the guard on poison.
pub fn read<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|p| {
        note_poison();
        p.into_inner()
    })
}

/// Write-lock an `RwLock`, recovering the guard on poison.
pub fn write<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

/// How many poisoned-lock recoveries have happened process-wide.
pub fn poison_recoveries() -> u64 {
    POISON_RECOVERIES.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recovers_after_a_holder_panics() {
        let m = Arc::new(Mutex::new(41));
        let m2 = Arc::clone(&m);
        let before = poison_recoveries();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        *lock(&m) += 1;
        assert_eq!(*lock(&m), 42, "data survives the recovery");
        assert!(poison_recoveries() > before);
    }

    #[test]
    fn rwlock_recovers_both_guards() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison the rwlock");
        })
        .join();
        assert_eq!(read(&l).len(), 3);
        write(&l).push(4);
        assert_eq!(read(&l).len(), 4);
    }

    #[test]
    fn healthy_locks_pass_straight_through() {
        let m = Mutex::new(7);
        assert_eq!(*lock(&m), 7);
        let l = RwLock::new(7);
        assert_eq!(*read(&l), 7);
        *write(&l) = 8;
        assert_eq!(*read(&l), 8);
    }
}

//! Minimal JSON reader/writer substrate (serde is not in the offline
//! vendor set). Parses the artifact manifest and golden files, and
//! serializes report output. Supports the full JSON grammar except
//! `\u` surrogate pairs outside the BMP (not produced by our tooling).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key).filter(|v| !matches!(v, Json::Null)),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    /// Flatten a (possibly nested) numeric array into f32s.
    pub fn as_f32_flat(&self) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        fn rec(v: &Json, out: &mut Vec<f32>) -> Result<()> {
            match v {
                Json::Num(x) => out.push(*x as f32),
                Json::Arr(a) => {
                    for e in a {
                        rec(e, out)?;
                    }
                }
                _ => bail!("non-numeric element in numeric array"),
            }
            Ok(())
        }
        rec(self, &mut out)?;
        Ok(out)
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |n: usize| "  ".repeat(n);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&pad(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&pad(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&pad(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&pad(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at c.
                    let start = self.i - 1;
                    let len = utf8_len(c)?;
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| {
            anyhow!("bad number {txt:?} at byte {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0x00..=0x7f => Ok(1),
        0xc0..=0xdf => Ok(2),
        0xe0..=0xef => Ok(3),
        0xf0..=0xf7 => Ok(4),
        _ => bail!("invalid UTF-8 lead byte {first:#x}"),
    }
}

/// Convenience constructors for report serialization.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" null ").unwrap(), Json::Null);
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name": "gin", "dims": [1, 2.5, -3], "ok": true, "none": null}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_pretty();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn flat_f32() {
        let v = Json::parse("[[1, 2], [3, 4.5]]").unwrap();
        assert_eq!(v.as_f32_flat().unwrap(), vec![1.0, 2.0, 3.0, 4.5]);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v, Json::Str("Aé".into()));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v, Json::Str("héllo ✓".into()));
    }
}

//! Statistics substrate: streaming moments, percentiles, a lock-free
//! HDR-style latency histogram, and the latency summaries printed by
//! the coordinator and bench harness.

use std::sync::atomic::{AtomicU64, Ordering};

/// Welford streaming mean/variance plus min/max.
#[derive(Clone, Debug)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

// A derived `Default` would zero-initialize `min`/`max`, so an empty
// accumulator reports a spurious `min = 0.0`; the ±INFINITY sentinels
// in `new()` are load-bearing.
impl Default for Welford {
    fn default() -> Self {
        Self::new()
    }
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact percentile over a retained sample (fine at our scales).
#[derive(Clone, Debug, Default)]
pub struct Sample {
    xs: Vec<f64>,
    sorted: bool,
}

impl Sample {
    pub fn new() -> Self {
        Sample {
            xs: Vec::new(),
            sorted: true,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn std(&self) -> f64 {
        let n = self.xs.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (n - 1) as f64)
            .sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
            self.sorted = true;
        }
    }

    /// Linear-interpolated percentile; `p` is clamped to [0, 100] so
    /// an out-of-range request cannot index past either end.
    pub fn percentile(&mut self, p: f64) -> f64 {
        self.ensure_sorted();
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let p = p.clamp(0.0, 100.0);
        let rank = p / 100.0 * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let w = rank - lo as f64;
        self.xs[lo] * (1.0 - w) + self.xs[hi] * w
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    /// Trim a fraction from each tail (bench outlier rejection).
    /// `frac >= 0.5` trims everything; the cut is clamped to `len / 2`
    /// so the slice range can never invert, and an empty core falls
    /// back to the untrimmed mean.
    pub fn trimmed_mean(&mut self, frac: f64) -> f64 {
        self.ensure_sorted();
        let k = (self.xs.len() as f64 * frac.max(0.0)) as usize;
        let k = k.min(self.xs.len() / 2);
        let core = &self.xs[k..self.xs.len() - k];
        if core.is_empty() {
            return self.mean();
        }
        core.iter().sum::<f64>() / core.len() as f64
    }
}

/// Sub-buckets per power of two in [`LatencyHistogram`] — 32 gives a
/// worst-case relative quantile error of 1/64 (~1.6%), HDR-histogram
/// territory at a fraction of the footprint.
const HIST_SUB_BUCKETS: usize = 32;
/// Bucket count covering the full u64 nanosecond range: indices 0..32
/// are exact 1 ns buckets, then 32 log-spaced sub-buckets per octave
/// up to 2^63 ns (~292 years).
const HIST_BUCKETS: usize = 60 * HIST_SUB_BUCKETS;

/// Lock-free log-bucketed latency histogram (HDR-histogram style):
/// bounded memory regardless of sample count, ~1.6% worst-case
/// quantile error, recordable concurrently from every server stage
/// without a lock. Values are durations in seconds, stored as integer
/// nanoseconds.
///
/// This is the telemetry substrate behind the wire front-end's
/// end-to-end latency report and the load generator's p50/p95/p99
/// summary — the retained-sample [`Sample`] stays exact but grows with
/// the stream, which a server holding millions of requests cannot do.
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_nanos: AtomicU64,
    min_nanos: AtomicU64,
    max_nanos: AtomicU64,
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_nanos: AtomicU64::new(0),
            min_nanos: AtomicU64::new(u64::MAX),
            max_nanos: AtomicU64::new(0),
        }
    }

    /// Bucket index for a nanosecond value: exact below 32 ns, then
    /// `HIST_SUB_BUCKETS` linear sub-buckets per power of two.
    fn bucket_index(nanos: u64) -> usize {
        if nanos < HIST_SUB_BUCKETS as u64 {
            nanos as usize
        } else {
            let msb = 63 - nanos.leading_zeros() as usize;
            let sub = ((nanos >> (msb - 5)) & (HIST_SUB_BUCKETS as u64 - 1)) as usize;
            (msb - 4) * HIST_SUB_BUCKETS + sub
        }
    }

    /// Inclusive lower bound of a bucket, in nanoseconds.
    fn bucket_lower(idx: usize) -> u64 {
        if idx < HIST_SUB_BUCKETS {
            idx as u64
        } else {
            let msb = idx / HIST_SUB_BUCKETS + 4;
            let sub = (idx % HIST_SUB_BUCKETS) as u64;
            (HIST_SUB_BUCKETS as u64 + sub) << (msb - 5)
        }
    }

    /// Representative (midpoint) value of a bucket, in nanoseconds.
    fn bucket_mid(idx: usize) -> u64 {
        let lo = Self::bucket_lower(idx);
        if idx < HIST_SUB_BUCKETS {
            lo
        } else {
            let width = 1u64 << (idx / HIST_SUB_BUCKETS - 1);
            lo + width / 2
        }
    }

    pub fn record_nanos(&self, nanos: u64) {
        self.buckets[Self::bucket_index(nanos)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
        self.min_nanos.fetch_min(nanos, Ordering::Relaxed);
        self.max_nanos.fetch_max(nanos, Ordering::Relaxed);
    }

    /// Record a duration in seconds (negative values clamp to zero).
    pub fn record(&self, secs: f64) {
        self.record_nanos((secs.max(0.0) * 1e9).round() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Mean recorded duration in seconds (NaN when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        self.sum_nanos.load(Ordering::Relaxed) as f64 * 1e-9 / n as f64
    }

    /// Smallest recorded duration in seconds (NaN when empty).
    pub fn min(&self) -> f64 {
        if self.is_empty() {
            return f64::NAN;
        }
        self.min_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Largest recorded duration in seconds (NaN when empty).
    pub fn max(&self) -> f64 {
        if self.is_empty() {
            return f64::NAN;
        }
        self.max_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Quantile in seconds, `q` in [0, 1]; NaN when empty. The walk is
    /// a snapshot — concurrent recording may perturb the answer by the
    /// in-flight samples, never corrupt it.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return f64::NAN;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                let mid = Self::bucket_mid(idx) as f64 * 1e-9;
                // Bucket midpoints can exceed the true extremes; the
                // recorded min/max are exact, so clamp to them. A
                // racing first `record_nanos` may have bumped the
                // bucket before min/max — skip the clamp while the
                // extremes are still at their sentinels (min > max),
                // f64::clamp panics on an inverted range.
                let lo = self.min_nanos.load(Ordering::Relaxed);
                let hi = self.max_nanos.load(Ordering::Relaxed);
                if lo <= hi {
                    return mid.clamp(lo as f64 * 1e-9, hi as f64 * 1e-9);
                }
                return mid;
            }
        }
        self.max()
    }

    /// The `p50 / p95 / p99` line every latency report prints.
    pub fn render_quantiles(&self) -> String {
        if self.is_empty() {
            return "p50 - p95 - p99 -".to_string();
        }
        format!(
            "p50 {} p95 {} p99 {}",
            fmt_secs(self.quantile(0.50)),
            fmt_secs(self.quantile(0.95)),
            fmt_secs(self.quantile(0.99)),
        )
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Human format for a duration in seconds.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 16.0);
    }

    #[test]
    fn welford_default_matches_new() {
        // Regression: a derived Default zero-initialized min/max, so a
        // default-constructed accumulator reported min = 0.0 even when
        // every pushed sample was positive.
        let d = Welford::default();
        assert_eq!(d.count(), 0);
        assert_eq!(d.min(), f64::INFINITY);
        assert_eq!(d.max(), f64::NEG_INFINITY);
        let mut from_default = Welford::default();
        let mut from_new = Welford::new();
        for &x in &[3.5, 7.25, 5.0] {
            from_default.push(x);
            from_new.push(x);
        }
        assert_eq!(from_default.min(), from_new.min());
        assert_eq!(from_default.max(), from_new.max());
        assert_eq!(from_default.mean(), from_new.mean());
        assert_eq!(from_default.var(), from_new.var());
        assert!(from_default.min() > 0.0, "spurious zero min resurfaced");
    }

    #[test]
    fn percentiles() {
        let mut s = Sample::new();
        for i in 0..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 50.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!((s.percentile(99.0) - 99.0).abs() < 1e-9);
    }

    #[test]
    fn trimmed_mean_drops_outliers() {
        let mut s = Sample::new();
        for _ in 0..98 {
            s.push(1.0);
        }
        s.push(1000.0);
        s.push(-1000.0);
        assert!((s.trimmed_mean(0.05) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_out_of_range_clamps() {
        let mut s = Sample::new();
        for i in 0..10 {
            s.push(i as f64);
        }
        // Regression: p > 100 used to compute rank past the end and
        // index out of bounds; p < 0 produced a negative rank.
        assert_eq!(s.percentile(150.0), 9.0);
        assert_eq!(s.percentile(-25.0), 0.0);
        assert_eq!(s.percentile(100.0), 9.0);
        assert_eq!(s.percentile(0.0), 0.0);
    }

    #[test]
    fn trimmed_mean_never_panics_across_fracs_and_lens() {
        // Regression: frac >= 0.5 made k > len - k and the core slice
        // panicked on an inverted range. Sweep the satellite matrix.
        for len in [0usize, 1, 3] {
            for frac in [0.0f64, 0.49, 0.5, 0.9] {
                let mut s = Sample::new();
                for i in 0..len {
                    s.push(i as f64 + 1.0);
                }
                let tm = s.trimmed_mean(frac);
                if len == 0 {
                    assert!(tm.is_nan(), "len=0 frac={frac}");
                } else {
                    // Fully-trimmed cores fall back to the plain mean,
                    // which also bounds every partial trim of 1..=3
                    // symmetric samples.
                    assert!(
                        (tm - s.mean()).abs() < 1e-12,
                        "len={len} frac={frac}: {tm}"
                    );
                }
            }
        }
        // A len-2 sample with frac 0.5 trims both elements: empty core
        // must fall back to the mean instead of underflowing.
        let mut two = Sample::new();
        two.push(1.0);
        two.push(3.0);
        assert_eq!(two.trimmed_mean(0.5), 2.0);
        // And an asymmetric sample where trimming actually changes the
        // answer still works.
        let mut s = Sample::new();
        for x in [1.0, 2.0, 3.0, 4.0, 100.0] {
            s.push(x);
        }
        assert_eq!(s.trimmed_mean(0.2), 3.0);
        assert_eq!(s.trimmed_mean(0.9), 3.0);
    }

    #[test]
    fn histogram_bucket_roundtrip() {
        // Every bucket's representative value must map back to the
        // same bucket, and lower bounds must be strictly increasing.
        let mut prev = 0u64;
        for idx in 0..HIST_BUCKETS {
            let lo = LatencyHistogram::bucket_lower(idx);
            assert_eq!(LatencyHistogram::bucket_index(lo), idx, "lower of {idx}");
            let mid = LatencyHistogram::bucket_mid(idx);
            assert_eq!(LatencyHistogram::bucket_index(mid), idx, "mid of {idx}");
            if idx > 0 {
                assert!(lo > prev, "bucket {idx} not increasing");
            }
            prev = lo;
        }
    }

    #[test]
    fn histogram_quantiles_track_exact_sample() {
        // 10 µs .. 10 ms in distinct steps against the exact Sample
        // implementation: the log buckets promise <= 1/64 relative
        // error (the 5% bound also absorbs the rank-definition gap).
        let h = LatencyHistogram::new();
        let mut s = Sample::new();
        for i in 1..=1000u64 {
            let secs = i as f64 * 1e-5;
            h.record(secs);
            s.push(secs);
        }
        assert_eq!(h.count(), 1000);
        for q in [0.5, 0.95, 0.99] {
            let exact = s.percentile(q * 100.0);
            let approx = h.quantile(q);
            assert!(
                (approx - exact).abs() <= exact * 0.05 + 1e-9,
                "q{q}: approx {approx} vs exact {exact}"
            );
        }
        assert!((h.mean() - s.mean()).abs() < 1e-4);
        assert!((h.min() - 1e-5).abs() < 1e-8);
        assert!((h.max() - 1e-2).abs() < 1e-6);
        assert!(h.render_quantiles().contains("p99"));
    }

    #[test]
    fn histogram_empty_is_nan() {
        let h = LatencyHistogram::new();
        assert!(h.is_empty());
        assert!(h.quantile(0.5).is_nan());
        assert!(h.mean().is_nan() && h.min().is_nan() && h.max().is_nan());
        assert_eq!(h.render_quantiles(), "p50 - p95 - p99 -");
    }

    #[test]
    fn histogram_concurrent_recording_reconciles() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let h = std::sync::Arc::clone(&h);
            joins.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    h.record_nanos((t + 1) * 1000 + i);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
        let p100 = h.quantile(1.0);
        assert!(p100 <= h.max() + 1e-12);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_secs(2.0), "2.000 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert!(fmt_secs(3.2e-6).contains("µs"));
        assert!(fmt_secs(5e-8).contains("ns"));
    }
}

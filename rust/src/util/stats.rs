//! Statistics substrate: streaming moments, percentiles, and the
//! latency summaries printed by the coordinator and bench harness.

/// Welford streaming mean/variance plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact percentile over a retained sample (fine at our scales).
#[derive(Clone, Debug, Default)]
pub struct Sample {
    xs: Vec<f64>,
    sorted: bool,
}

impl Sample {
    pub fn new() -> Self {
        Sample {
            xs: Vec::new(),
            sorted: true,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn std(&self) -> f64 {
        let n = self.xs.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (n - 1) as f64)
            .sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
            self.sorted = true;
        }
    }

    /// Linear-interpolated percentile, p in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        self.ensure_sorted();
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let rank = p / 100.0 * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let w = rank - lo as f64;
        self.xs[lo] * (1.0 - w) + self.xs[hi] * w
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    /// Trim a fraction from each tail (bench outlier rejection).
    pub fn trimmed_mean(&mut self, frac: f64) -> f64 {
        self.ensure_sorted();
        let k = (self.xs.len() as f64 * frac) as usize;
        let core = &self.xs[k..self.xs.len() - k];
        if core.is_empty() {
            return self.mean();
        }
        core.iter().sum::<f64>() / core.len() as f64
    }
}

/// Human format for a duration in seconds.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 16.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Sample::new();
        for i in 0..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 50.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!((s.percentile(99.0) - 99.0).abs() < 1e-9);
    }

    #[test]
    fn trimmed_mean_drops_outliers() {
        let mut s = Sample::new();
        for _ in 0..98 {
            s.push(1.0);
        }
        s.push(1000.0);
        s.push(-1000.0);
        assert!((s.trimmed_mean(0.05) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_secs(2.0), "2.000 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert!(fmt_secs(3.2e-6).contains("µs"));
        assert!(fmt_secs(5e-8).contains("ns"));
    }
}

//! Tiny CLI argument parser substrate (clap is not in the offline
//! vendor set). Supports `--key value`, `--key=value`, boolean
//! `--flag`, and positional arguments, with typed getters.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    /// Parse an argv slice (without the program/subcommand names).
    /// `bool_flags` lists flags that take no value.
    pub fn parse(argv: &[String], bool_flags: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&name) {
                    out.bools.push(name.to_string());
                } else {
                    i += 1;
                    let v = argv.get(i).ok_or_else(|| {
                        anyhow!("flag --{name} expects a value")
                    })?;
                    out.flags.insert(name.to_string(), v.clone());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name) || self.flags.contains_key(name)
    }

    pub fn str_opt(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.str_opt(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<usize>()
                .with_context(|| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<u64>()
                .with_context(|| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .with_context(|| format!("--{name} expects a number, got {v:?}")),
        }
    }

    /// Comma-separated list flag.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.flags.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_positional() {
        let a =
            Args::parse(&argv(&["run", "--n", "5", "--name=x", "file"]), &[])
                .unwrap();
        assert_eq!(a.positional, vec!["run", "file"]);
        assert_eq!(a.usize_or("n", 0).unwrap(), 5);
        assert_eq!(a.str_or("name", ""), "x");
    }

    #[test]
    fn bool_flags() {
        let a = Args::parse(&argv(&["--verbose", "--n", "2"]), &["verbose"])
            .unwrap();
        assert!(a.has("verbose"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 2);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&argv(&["--n"]), &[]).is_err());
    }

    #[test]
    fn bad_int_is_error() {
        let a = Args::parse(&argv(&["--n", "zork"]), &[]).unwrap();
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn list_flag() {
        let a = Args::parse(&argv(&["--models", "gcn, gin,gat"]), &[]).unwrap();
        assert_eq!(a.list_or("models", &[]), vec!["gcn", "gin", "gat"]);
        assert_eq!(a.list_or("other", &["x"]), vec!["x"]);
    }
}

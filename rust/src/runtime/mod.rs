//! Model runtime: load the AOT artifact manifest
//! (`artifacts/manifest.json`, written by `python/compile/aot.py`) and
//! execute models from the Rust hot path. Python never runs at request
//! time.
//!
//! Two backends (see [`client`]): the always-available **native**
//! backend lowers each manifest entry to a composable stage-IR plan
//! (`crate::models::lower`) and executes it through the generic sparse
//! interpreter, and the optional `xla`-feature **PJRT** path parses +
//! compiles the `<name>.hlo.txt` artifacts through the XLA PJRT CPU
//! client.
//!
//! * [`artifact`]  — manifest parsing + golden-file access
//! * [`client`]    — backend selection + per-artifact compilation
//! * [`native`]    — native backend: thin shim over plan execution
//! * [`interp`]    — the generic stage-IR interpreter (sparse, O(edges))
//! * [`dense_ref`] — legacy dense-matmul forwards (test/bench reference)
//! * [`literal`]   — graph → padded input-tensor packing (PJRT staging)
//! * [`exec`]      — the [`Engine`]: end-to-end `CooGraph` → output vector

pub mod artifact;
pub mod client;
pub mod dense_ref;
pub mod exec;
pub mod interp;
pub mod literal;
pub mod native;
mod tensor;

pub use artifact::{Artifacts, Golden, InputSpec, ModelMeta};
pub use client::Client;
pub use dense_ref::DenseRef;
pub use exec::Engine;
pub use literal::InputPack;
pub use native::NativeModel;

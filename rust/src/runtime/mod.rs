//! Model runtime: load the AOT artifact manifest
//! (`artifacts/manifest.json`, written by `python/compile/aot.py`) and
//! execute models from the Rust hot path. Python never runs at request
//! time.
//!
//! Two backends (see [`client`]): the always-available **native**
//! reference executor re-implements the Layer-2 forward passes in pure
//! Rust with the same seeded weights the artifacts bake in, and the
//! optional `xla`-feature **PJRT** path parses + compiles the
//! `<name>.hlo.txt` artifacts through the XLA PJRT CPU client.
//!
//! * [`artifact`] — manifest parsing + golden-file access
//! * [`client`]   — backend selection + per-artifact compilation
//! * [`native`]   — pure-Rust reference executor (MT19937 weight port)
//! * [`literal`]  — graph → padded input-tensor packing (zero-alloc refill)
//! * [`exec`]     — the [`Engine`]: end-to-end `CooGraph` → output vector

pub mod artifact;
pub mod client;
pub mod exec;
pub mod literal;
pub mod native;

pub use artifact::{Artifacts, Golden, ModelMeta};
pub use client::Client;
pub use exec::Engine;
pub use literal::InputPack;
pub use native::NativeModel;

//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`, lowered
//! once at build time by `python/compile/aot.py`) and execute them from
//! the Rust hot path. Python never runs at request time — the HLO text
//! is parsed, compiled, and executed through the XLA PJRT CPU client.
//!
//! * [`artifact`] — manifest parsing + golden-file access
//! * [`client`]   — PJRT client + compilation cache
//! * [`literal`]  — graph → padded input-tensor packing (zero-alloc refill)
//! * [`exec`]     — the [`Engine`]: end-to-end `CooGraph` → output vector

pub mod artifact;
pub mod client;
pub mod exec;
pub mod literal;

pub use artifact::{Artifacts, Golden, ModelMeta};
pub use client::Client;
pub use exec::Engine;
pub use literal::InputPack;

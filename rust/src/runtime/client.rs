//! Execution-backend client + per-artifact compilation.
//!
//! Two backends sit behind one [`Client`]:
//!
//! * **native** (always available, the default): the pure-Rust
//!   reference executor in [`super::native`], which regenerates the
//!   artifact's baked-in weights from the manifest seed and runs the
//!   forward pass directly — no XLA, no Python, no HLO parsing.
//! * **PJRT** (cargo feature `xla`): parses the `<name>.hlo.txt`
//!   artifact and compiles it for the XLA PJRT CPU client — the text
//!   parser reassigns instruction ids, which is why text is the
//!   interchange format. The workspace vendors an API stub for
//!   `xla-rs`, so enabling the feature compiles everywhere but
//!   executes only where the real XLA runtime is linked; [`Client::cpu`]
//!   falls back to native when PJRT cannot come up.
//!
//! Clients are cheap and thread-confined: the coordinator's executor
//! pool brings up one per lane (each lane's [`super::Engine`] owns its
//! own), rather than sharing one across threads.

use anyhow::Result;

use super::artifact::ModelMeta;
use super::native::NativeModel;

#[cfg(feature = "xla")]
use anyhow::Context as _;

/// A compiled model, ready for repeated execution.
pub enum Compiled {
    /// Pure-Rust reference executor.
    Native(NativeModel),
    /// PJRT executable compiled from HLO text.
    #[cfg(feature = "xla")]
    Pjrt(xla::PjRtLoadedExecutable),
}

enum Backend {
    Native,
    #[cfg(feature = "xla")]
    Pjrt(xla::PjRtClient),
}

/// The device the artifacts run on.
pub struct Client {
    backend: Backend,
}

impl Client {
    /// Bring up the best available backend: PJRT when the `xla`
    /// feature is enabled *and* the runtime is actually present,
    /// otherwise the native reference executor.
    pub fn cpu() -> Result<Client> {
        #[cfg(feature = "xla")]
        if let Ok(c) = xla::PjRtClient::cpu() {
            return Ok(Client {
                backend: Backend::Pjrt(c),
            });
        }
        Ok(Client {
            backend: Backend::Native,
        })
    }

    pub fn platform_name(&self) -> String {
        match &self.backend {
            Backend::Native => "native-reference".to_string(),
            #[cfg(feature = "xla")]
            Backend::Pjrt(c) => c.platform_name(),
        }
    }

    pub fn device_count(&self) -> usize {
        match &self.backend {
            Backend::Native => 1,
            #[cfg(feature = "xla")]
            Backend::Pjrt(c) => c.device_count(),
        }
    }

    /// Compile one manifest entry for this backend. Both paths check
    /// the artifact file so a missing/bogus path is a clean error.
    pub fn compile_model(&self, meta: &ModelMeta, weight_seed: u64) -> Result<Compiled> {
        match &self.backend {
            Backend::Native => {
                if !meta.hlo_path.exists() {
                    anyhow::bail!(
                        "artifact file {:?} missing (run `make artifacts`)",
                        meta.hlo_path
                    );
                }
                Ok(Compiled::Native(NativeModel::build(meta, weight_seed)?))
            }
            #[cfg(feature = "xla")]
            Backend::Pjrt(c) => {
                let proto = xla::HloModuleProto::from_text_file(&meta.hlo_path)
                    .with_context(|| format!("parsing HLO text {:?}", meta.hlo_path))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = c
                    .compile(&comp)
                    .with_context(|| format!("compiling {:?}", meta.hlo_path))?;
                Ok(Compiled::Pjrt(exe))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::InputSpec;

    #[test]
    fn cpu_client_comes_up() {
        let c = Client::cpu().unwrap();
        assert!(c.device_count() >= 1);
        assert!(!c.platform_name().is_empty());
    }

    #[test]
    fn bad_artifact_path_is_clean_error() {
        let c = Client::cpu().unwrap();
        let meta = ModelMeta {
            name: "gcn".into(),
            layers: 2,
            dim: 8,
            heads: 0,
            n_max: 8,
            in_dim: 4,
            out_dim: 1,
            node_level: false,
            inputs: vec![
                InputSpec {
                    name: "x".into(),
                    shape: vec![8, 4],
                },
                InputSpec {
                    name: "adj".into(),
                    shape: vec![8, 8],
                },
                InputSpec {
                    name: "mask".into(),
                    shape: vec![8],
                },
            ],
            hlo_path: "/nonexistent.hlo.txt".into(),
            golden_path: "/nonexistent.golden.json".into(),
        };
        let err = c.compile_model(&meta, 0).unwrap_err().to_string();
        assert!(err.contains("nonexistent"), "{err}");
    }
}

//! PJRT client wrapper + artifact compilation cache.
//!
//! One process-wide CPU client; each HLO-text artifact is parsed
//! (`HloModuleProto::from_text_file` — the text parser reassigns
//! instruction ids, which is why text is the interchange format; see
//! DESIGN.md) and compiled once, then executed many times.

use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT CPU client with compile helpers.
pub struct Client {
    inner: xla::PjRtClient,
}

impl Client {
    /// Create the CPU client (the "device" the artifacts run on).
    pub fn cpu() -> Result<Client> {
        Ok(Client {
            inner: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
        })
    }

    pub fn platform_name(&self) -> String {
        self.inner.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.inner.device_count()
    }

    /// Parse an HLO-text artifact and compile it for this client.
    pub fn compile_hlo_text(
        &self,
        path: impl AsRef<Path>,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.inner
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let c = Client::cpu().unwrap();
        assert!(c.device_count() >= 1);
        assert!(!c.platform_name().is_empty());
    }

    #[test]
    fn bad_path_is_clean_error() {
        let c = Client::cpu().unwrap();
        assert!(c.compile_hlo_text("/nonexistent.hlo.txt").is_err());
    }
}

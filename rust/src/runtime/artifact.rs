//! Artifact manifest + golden files (the build-time contract).
//!
//! `make artifacts` produces, per model: `<name>.hlo.txt` (the lowered
//! computation with baked-in weights), `<name>.golden.json` (a seeded
//! input graph and its expected output — the stand-in for the paper's
//! "cross-check with PyTorch" end-to-end guarantee), and a shared
//! `manifest.json` describing input tensor order and shapes.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::graph::CooGraph;
use crate::util::json::Json;

/// One input tensor slot of a model artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl InputSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Metadata of one compiled model (mirrors a manifest entry).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub layers: usize,
    pub dim: usize,
    /// Attention heads (GAT only, 0 otherwise).
    pub heads: usize,
    pub n_max: usize,
    pub in_dim: usize,
    pub out_dim: usize,
    pub node_level: bool,
    pub inputs: Vec<InputSpec>,
    pub hlo_path: PathBuf,
    pub golden_path: PathBuf,
}

impl ModelMeta {
    pub fn needs_edge_attr(&self) -> bool {
        self.inputs.iter().any(|i| i.name == "edge_attr")
    }

    pub fn needs_eig(&self) -> bool {
        self.inputs.iter().any(|i| i.name == "eig")
    }
}

/// The loaded artifact directory.
#[derive(Clone, Debug)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub weight_seed: u64,
    pub models: Vec<ModelMeta>,
}

impl Artifacts {
    /// Parse `manifest.json` from an artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Artifacts> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;
        let weight_seed = v.get("weight_seed")?.as_usize()? as u64;
        let mut models = Vec::new();
        for m in v.get("models")?.as_arr()? {
            let name = m.get("name")?.as_str()?.to_string();
            let mut inputs = Vec::new();
            for i in m.get("inputs")?.as_arr()? {
                let shape = i
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<Vec<_>>>()?;
                inputs.push(InputSpec {
                    name: i.get("name")?.as_str()?.to_string(),
                    shape,
                });
            }
            models.push(ModelMeta {
                hlo_path: dir.join(m.get("artifact")?.as_str()?),
                golden_path: dir.join(m.get("golden")?.as_str()?),
                name,
                layers: m.get("layers")?.as_usize()?,
                dim: m.get("dim")?.as_usize()?,
                heads: match m.opt("heads") {
                    Some(h) => h.as_usize()?,
                    None => 0,
                },
                n_max: m.get("n_max")?.as_usize()?,
                in_dim: m.get("in_dim")?.as_usize()?,
                out_dim: m.get("out_dim")?.as_usize()?,
                node_level: m.get("node_level")?.as_bool()?,
                inputs,
            });
        }
        Ok(Artifacts {
            dir,
            weight_seed,
            models,
        })
    }

    /// Default artifact directory: `GENGNN_ARTIFACTS` if set, else
    /// `./artifacts` when it holds a manifest (binaries run from the
    /// repo root), else the repo-root `artifacts/` located relative to
    /// this crate — so `cargo test` (cwd `rust/`) and examples find the
    /// checked-in fixtures without configuration.
    pub fn default_dir() -> PathBuf {
        if let Some(d) = std::env::var_os("GENGNN_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let cwd_relative = PathBuf::from("artifacts");
        if cwd_relative.join("manifest.json").exists() {
            return cwd_relative;
        }
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow::anyhow!("model {name:?} not in manifest"))
    }

    pub fn model_names(&self) -> Vec<&str> {
        self.models.iter().map(|m| m.name.as_str()).collect()
    }
}

/// A golden cross-check case: input graph + expected output.
#[derive(Clone, Debug)]
pub struct Golden {
    pub model: String,
    pub graph: CooGraph,
    /// Precomputed Laplacian eigenvector (padded), when the model needs it.
    pub eig: Option<Vec<f32>>,
    pub output: Vec<f32>,
    pub output_shape: Vec<usize>,
}

impl Golden {
    /// Load a `<name>.golden.json` file.
    pub fn load(meta: &ModelMeta) -> Result<Golden> {
        let text = std::fs::read_to_string(&meta.golden_path)
            .with_context(|| format!("reading {:?}", meta.golden_path))?;
        let v = Json::parse(&text)?;
        let n = v.get("n")?.as_usize()?;
        let mut undirected = Vec::new();
        for e in v.get("edges")?.as_arr()? {
            let pair = e.as_arr()?;
            if pair.len() != 2 {
                bail!("bad edge entry");
            }
            undirected.push((pair[0].as_usize()? as u32, pair[1].as_usize()? as u32));
        }
        let node_feat = v.get("node_feat")?.as_f32_flat()?;
        let f_node = if n > 0 { node_feat.len() / n } else { 0 };
        let (edge_feat, f_edge) = match v.opt("edge_feat") {
            Some(ef) => {
                let flat = ef.as_f32_flat()?;
                let fe = if undirected.is_empty() {
                    0
                } else {
                    flat.len() / undirected.len()
                };
                (flat, fe)
            }
            None => (Vec::new(), 0),
        };
        let graph = CooGraph::from_undirected(
            n,
            &undirected,
            node_feat,
            f_node,
            &edge_feat,
            f_edge,
        )?;
        let eig = match v.opt("eig") {
            Some(e) => Some(e.as_f32_flat()?),
            None => None,
        };
        Ok(Golden {
            model: v.get("model")?.as_str()?.to_string(),
            graph,
            eig,
            output: v.get("output")?.as_f32_flat()?,
            output_shape: v
                .get("output_shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<Artifacts> {
        Artifacts::load(Artifacts::default_dir()).ok()
    }

    #[test]
    fn manifest_lists_all_seven_models() {
        let Some(a) = artifacts() else { return };
        for name in ["gcn", "gin", "gin_vn", "gat", "pna", "dgn", "dgn_large"] {
            assert!(a.model(name).is_ok(), "missing {name}");
        }
    }

    #[test]
    fn input_order_matches_contract() {
        let Some(a) = artifacts() else { return };
        let gin = a.model("gin").unwrap();
        let names: Vec<&str> = gin.inputs.iter().map(|i| i.name.as_str()).collect();
        assert_eq!(names, vec!["x", "adj", "edge_attr", "mask"]);
        let dgn = a.model("dgn").unwrap();
        let names: Vec<&str> = dgn.inputs.iter().map(|i| i.name.as_str()).collect();
        assert_eq!(names, vec!["x", "adj", "eig", "mask"]);
        assert!(dgn.needs_eig() && !dgn.needs_edge_attr());
    }

    #[test]
    fn shapes_are_consistent_with_config() {
        let Some(a) = artifacts() else { return };
        for m in &a.models {
            let x = &m.inputs[0];
            assert_eq!(x.shape, vec![m.n_max, m.in_dim], "{}", m.name);
            let adj = &m.inputs[1];
            assert_eq!(adj.shape, vec![m.n_max, m.n_max], "{}", m.name);
            assert!(m.hlo_path.exists(), "{:?}", m.hlo_path);
        }
    }

    #[test]
    fn golden_files_parse_and_validate() {
        let Some(a) = artifacts() else { return };
        for m in &a.models {
            let g = Golden::load(m).unwrap();
            assert_eq!(g.model, m.name);
            g.graph.validate().unwrap();
            assert!(!g.output.is_empty());
            if m.needs_eig() {
                let eig = g.eig.as_ref().expect("eig present");
                assert_eq!(eig.len(), m.n_max);
            }
        }
    }

    #[test]
    fn missing_dir_is_a_clean_error() {
        let e = Artifacts::load("/nonexistent/path").unwrap_err();
        assert!(e.to_string().contains("manifest.json"));
    }
}

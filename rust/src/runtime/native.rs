//! Native backend: a thin shim over stage-IR plan execution.
//!
//! [`NativeModel::build`] lowers the manifest entry through the
//! per-kind registry ([`crate::models::lower`]), regenerating the
//! artifact's baked-in weights from the manifest seed (an MT19937 port
//! of numpy's legacy `RandomState.uniform` stream — see
//! [`crate::models::params`]); [`NativeModel::forward_batch`] hands the
//! plan to the generic sparse interpreter ([`super::interp`]), which
//! walks CSR-style in-neighbor lists in O(edges) — the padded
//! O(n_max²) dense tensors of the legacy path are never materialized.
//! Golden files produced by `python/compile/aot.py` remain directly
//! comparable: the interpreter is bit-identical to the legacy dense
//! forwards ([`super::dense_ref`]), which match the JAX reference to
//! float32-accumulation tolerance.

use anyhow::{bail, Result};

use crate::analysis::PlanFacts;
use crate::graph::{FusedBatch, GraphBatch};
use crate::models::lower;
use crate::models::plan::ModelPlan;

use super::artifact::ModelMeta;
use super::interp;

/// A model compiled for the native backend: the lowered stage-IR plan
/// with its regenerated baked-in weights, plus the static analyzer's
/// fusion-safety facts derived once at build time.
pub struct NativeModel {
    plan: ModelPlan,
    facts: PlanFacts,
}

impl NativeModel {
    /// Lower the manifest entry to its executable plan. Lowering runs
    /// the static analyzer as a mandatory gate (see
    /// [`crate::models::lower::lower`]); the fusion-safety facts are
    /// derived here and consulted on every fused forward.
    pub fn build(meta: &ModelMeta, weight_seed: u64) -> Result<NativeModel> {
        let plan = lower::lower(meta, weight_seed)?;
        let facts = crate::analysis::plan_facts(&plan);
        Ok(NativeModel { plan, facts })
    }

    /// The lowered stage sequence (what `gengnn plan` dumps).
    pub fn plan(&self) -> &ModelPlan {
        &self.plan
    }

    /// Whether every stage of the plan carries a fusion-safety fact —
    /// the scheduler consults this before grouping requests for fused
    /// execution instead of trying and falling back.
    pub fn fusable(&self) -> bool {
        self.facts.fusable()
    }

    /// Run one ingested graph through the plan interpreter.
    ///
    /// `eig_override` supplies a precomputed Laplacian eigenvector
    /// padded to the artifact capacity (golden replay / the prep
    /// stage's eigensolve); otherwise eig-consuming models solve on the
    /// batch's CSR right here, with the same iteration budget the prep
    /// stage uses. Graph-level models return `[out_dim]`; node-level
    /// `[n_max * out_dim]` zero-padded.
    pub fn forward_batch(
        &self,
        batch: &GraphBatch,
        eig_override: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        // The batch's in-neighbor view is built on first forward and
        // reused by every later forward over the same batch; input
        // validation happens once, inside `execute_over`.
        let nbrs = batch.in_nbrs();
        if self.plan.needs_eig() {
            if let Some(e) = eig_override {
                if e.len() != self.plan.n_max {
                    bail!("eig override has wrong length");
                }
                return interp::execute_over(&self.plan, &batch.graph, nbrs, Some(e));
            }
            let r = batch.fiedler(400, 1e-9);
            return interp::execute_over(&self.plan, &batch.graph, nbrs, Some(&r.vector));
        }
        // Models that do not consume an eigenvector ignore a supplied
        // one (a producer may attach eig to any request).
        interp::execute_over(&self.plan, &batch.graph, nbrs, None)
    }

    /// Run several ingested graphs through **one** fused interpreter
    /// pass, returning one output per graph (input order).
    ///
    /// `eigs` pairs one optional precomputed eigenvector (padded to
    /// the artifact capacity, like [`NativeModel::forward_batch`])
    /// with each graph; for eig-consuming models, missing entries are
    /// solved per graph on the part's CSR with the same iteration
    /// budget the sequential path uses — so fused outputs are
    /// bit-identical to per-request outputs either way.
    pub fn forward_fused(
        &self,
        parts: &[&GraphBatch],
        eigs: &[Option<&[f32]>],
    ) -> Result<Vec<Vec<f32>>> {
        if parts.len() != eigs.len() {
            bail!(
                "{} graphs paired with {} eig slots",
                parts.len(),
                eigs.len()
            );
        }
        if parts.is_empty() {
            return Ok(Vec::new());
        }
        let fused = FusedBatch::fuse_checked(parts, &self.facts, &self.plan.model)?;
        // Per-segment capacity check *before* the eig concat below
        // slices overrides with `seg.n` (an oversized graph must get
        // the same clean error the sequential path returns, not a
        // slice panic). `execute_fused` re-checks harmlessly.
        for seg in fused.segments() {
            if seg.n > self.plan.n_max {
                bail!(
                    "graph with {} nodes exceeds capacity {}",
                    seg.n,
                    self.plan.n_max
                );
            }
        }
        let eig_buf: Option<Vec<f32>> = if self.plan.needs_eig() {
            let mut buf = vec![0.0f32; fused.total_nodes()];
            for ((part, eig), seg) in parts.iter().zip(eigs).zip(fused.segments()) {
                let dst = &mut buf[seg.node_offset..seg.node_offset + seg.n];
                match eig {
                    Some(e) => {
                        if e.len() != self.plan.n_max {
                            bail!("eig override has wrong length");
                        }
                        dst.copy_from_slice(&e[..seg.n]);
                    }
                    None => {
                        let r = part.fiedler(400, 1e-9);
                        dst.copy_from_slice(&r.vector);
                    }
                }
            }
            Some(buf)
        } else {
            None
        };
        interp::execute_fused(&self.plan, &fused, eig_buf.as_deref())
    }

    /// Expected output length for shape checks.
    pub fn output_len(&self, n_max: usize) -> usize {
        if self.plan.node_level {
            n_max * self.plan.out_dim
        } else {
            self.plan.out_dim
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::CooGraph;
    use crate::runtime::artifact::InputSpec;

    fn tiny_meta(name: &str) -> ModelMeta {
        let n_max = 8;
        let in_dim = 4;
        let mut inputs = vec![
            InputSpec {
                name: "x".into(),
                shape: vec![n_max, in_dim],
            },
            InputSpec {
                name: "adj".into(),
                shape: vec![n_max, n_max],
            },
        ];
        if name.starts_with("gin") {
            inputs.push(InputSpec {
                name: "edge_attr".into(),
                shape: vec![n_max, n_max, 3],
            });
        }
        if name.starts_with("dgn") {
            inputs.push(InputSpec {
                name: "eig".into(),
                shape: vec![n_max],
            });
        }
        inputs.push(InputSpec {
            name: "mask".into(),
            shape: vec![n_max],
        });
        ModelMeta {
            name: name.to_string(),
            layers: 2,
            dim: 8,
            heads: if name == "gat" { 2 } else { 0 },
            n_max,
            in_dim,
            out_dim: 1,
            node_level: false,
            inputs,
            hlo_path: "unused.hlo.txt".into(),
            golden_path: "unused.golden.json".into(),
        }
    }

    fn tiny_graph(feat_scale: f32) -> CooGraph {
        CooGraph::from_undirected(
            5,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4), (1, 3)],
            (0..5 * 4).map(|i| feat_scale * (i % 5) as f32).collect(),
            4,
            &(0..6 * 3).map(|i| (i % 3) as f32).collect::<Vec<f32>>(),
            3,
        )
        .unwrap()
    }

    fn batch(feat_scale: f32) -> GraphBatch {
        GraphBatch::ingest(tiny_graph(feat_scale)).unwrap()
    }

    #[test]
    fn all_kinds_build_and_run() {
        for name in ["gcn", "gin", "gin_vn", "gat", "pna", "sgc", "sage", "dgn"] {
            let meta = tiny_meta(name);
            let m = NativeModel::build(&meta, 0).unwrap();
            let out = m.forward_batch(&batch(1.0), None).unwrap();
            assert_eq!(out.len(), m.output_len(meta.n_max), "{name}");
            assert!(
                out.iter().all(|v| v.is_finite()),
                "{name}: non-finite output {out:?}"
            );
        }
    }

    #[test]
    fn forward_is_deterministic_and_input_sensitive() {
        let m = NativeModel::build(&tiny_meta("gcn"), 0).unwrap();
        let b1 = batch(1.0);
        let a = m.forward_batch(&b1, None).unwrap();
        let b = m.forward_batch(&b1, None).unwrap();
        let c = m.forward_batch(&batch(2.0), None).unwrap();
        assert_eq!(a, b, "same input must give identical output");
        assert_ne!(a, c, "different features must change the output");
    }

    #[test]
    fn weight_seed_changes_outputs() {
        let meta = tiny_meta("gin");
        let b = batch(1.0);
        let a = NativeModel::build(&meta, 0)
            .unwrap()
            .forward_batch(&b, None)
            .unwrap();
        let z = NativeModel::build(&meta, 1)
            .unwrap()
            .forward_batch(&b, None)
            .unwrap();
        assert_ne!(a, z);
    }

    #[test]
    fn node_level_output_is_padded_with_zeros() {
        let mut meta = tiny_meta("dgn");
        meta.node_level = true;
        meta.out_dim = 3;
        let m = NativeModel::build(&meta, 0).unwrap();
        let b = batch(1.0);
        let out = m.forward_batch(&b, None).unwrap();
        assert_eq!(out.len(), meta.n_max * 3);
        let live = b.n() * 3;
        assert!(out[live..].iter().all(|&v| v == 0.0), "padding not zeroed");
        assert!(out[..live].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn eig_override_must_be_padded_to_capacity() {
        let meta = tiny_meta("dgn");
        let m = NativeModel::build(&meta, 0).unwrap();
        let b = batch(1.0);
        let short = vec![0.5f32; b.n()];
        assert!(m.forward_batch(&b, Some(&short)).is_err());
        let padded = vec![0.5f32; meta.n_max];
        m.forward_batch(&b, Some(&padded)).unwrap();
    }

    #[test]
    fn non_eig_models_ignore_a_supplied_eigenvector() {
        // Producers may attach eig to any request; models that do not
        // consume one must not reject it (whatever its length).
        let m = NativeModel::build(&tiny_meta("gcn"), 0).unwrap();
        let b = batch(1.0);
        let plain = m.forward_batch(&b, None).unwrap();
        let stray = vec![0.25f32; 3];
        let with_eig = m.forward_batch(&b, Some(&stray)).unwrap();
        assert_eq!(plain, with_eig);
    }

    #[test]
    fn scratch_pool_reuses_buffers_without_changing_outputs() {
        // Dedicated thread: the scratch pool is per-thread, so other
        // tests cannot perturb the counters.
        std::thread::spawn(|| {
            let m = NativeModel::build(&tiny_meta("gcn"), 0).unwrap();
            let b = batch(1.0);
            let a = m.forward_batch(&b, None).unwrap();
            let (hits_before, _) = crate::util::pool::scratch_stats();
            let c = m.forward_batch(&b, None).unwrap();
            let (hits_after, _) = crate::util::pool::scratch_stats();
            assert_eq!(a, c, "pooled scratch must not change outputs");
            assert!(
                hits_after > hits_before,
                "second forward must recycle scratch buffers \
                 ({hits_before} -> {hits_after} hits)"
            );
        })
        .join()
        .unwrap();
    }

    #[test]
    fn fused_forward_matches_sequential_for_every_kind() {
        for name in ["gcn", "gin", "gin_vn", "gat", "pna", "sgc", "sage", "dgn"] {
            let meta = tiny_meta(name);
            let m = NativeModel::build(&meta, 0).unwrap();
            let batches = [batch(1.0), batch(2.0), batch(0.5)];
            let parts: Vec<&GraphBatch> = batches.iter().collect();
            let eigs: Vec<Option<&[f32]>> = vec![None; parts.len()];
            let fused = m.forward_fused(&parts, &eigs).unwrap();
            assert_eq!(fused.len(), parts.len(), "{name}");
            for (b, out) in batches.iter().zip(&fused) {
                assert_eq!(
                    *out,
                    m.forward_batch(b, None).unwrap(),
                    "{name}: fused output diverges from sequential"
                );
            }
        }
    }

    #[test]
    fn every_kind_carries_fusion_safety_facts() {
        for name in ["gcn", "gin", "gin_vn", "gat", "pna", "sgc", "sage", "dgn"] {
            let m = NativeModel::build(&tiny_meta(name), 0).unwrap();
            assert!(m.fusable(), "{name}: component library must be fusable");
        }
    }

    #[test]
    fn fused_node_level_outputs_are_split_and_padded() {
        let mut meta = tiny_meta("dgn");
        meta.node_level = true;
        meta.out_dim = 3;
        let m = NativeModel::build(&meta, 0).unwrap();
        let batches = [batch(1.0), batch(2.0)];
        let parts: Vec<&GraphBatch> = batches.iter().collect();
        let fused = m.forward_fused(&parts, &[None, None]).unwrap();
        for (b, out) in batches.iter().zip(&fused) {
            assert_eq!(out.len(), meta.n_max * 3);
            assert_eq!(*out, m.forward_batch(b, None).unwrap());
        }
    }

    #[test]
    fn fused_eig_overrides_match_sequential_overrides() {
        let meta = tiny_meta("dgn");
        let m = NativeModel::build(&meta, 0).unwrap();
        let (b1, b2) = (batch(1.0), batch(2.0));
        let e1: Vec<f32> = (0..meta.n_max).map(|i| i as f32 * 0.1 - 0.3).collect();
        let e2: Vec<f32> = (0..meta.n_max).map(|i| 0.5 - i as f32 * 0.05).collect();
        let fused = m
            .forward_fused(&[&b1, &b2], &[Some(&e1), Some(&e2)])
            .unwrap();
        assert_eq!(fused[0], m.forward_batch(&b1, Some(&e1)).unwrap());
        assert_eq!(fused[1], m.forward_batch(&b2, Some(&e2)).unwrap());
        // Length mismatches are clean errors.
        assert!(m.forward_fused(&[&b1], &[]).is_err());
        let short = vec![0.5f32; 3];
        assert!(m.forward_fused(&[&b1], &[Some(&short)]).is_err());
        // Empty fuse is a no-op.
        assert!(m.forward_fused(&[], &[]).unwrap().is_empty());
    }

    #[test]
    fn fused_oversized_graph_is_a_clean_error() {
        // Must match the sequential error, not panic slicing the eig
        // override with the oversized node count.
        let meta = tiny_meta("dgn");
        let m = NativeModel::build(&meta, 0).unwrap();
        let big = CooGraph::from_undirected(
            9,
            &[(0, 1)],
            (0..9 * 4).map(|i| i as f32).collect(),
            4,
            &[2.0, 1.0, 0.0],
            3,
        )
        .unwrap();
        let big = GraphBatch::ingest(big).unwrap();
        let e = vec![0.5f32; meta.n_max];
        let err = m
            .forward_fused(&[&big], &[Some(&e)])
            .unwrap_err()
            .to_string();
        assert!(err.contains("exceeds capacity"), "{err}");
    }

    #[test]
    fn unknown_model_is_a_clean_error() {
        let mut meta = tiny_meta("gcn");
        meta.name = "transformer".into();
        assert!(NativeModel::build(&meta, 0).is_err());
    }

    #[test]
    fn virtual_node_changes_gin_output() {
        let b = batch(1.0);
        let a = NativeModel::build(&tiny_meta("gin"), 0)
            .unwrap()
            .forward_batch(&b, None)
            .unwrap();
        let v = NativeModel::build(&tiny_meta("gin_vn"), 0)
            .unwrap()
            .forward_batch(&b, None)
            .unwrap();
        assert_ne!(a, v);
    }
}

//! Graph → padded input-tensor packing (PJRT staging + dense
//! reference tests — **not** on the native serving path).
//!
//! The artifact contract (mirrors `python/compile/graphgen.densify`
//! bit-for-bit, see `graph::dense`): inputs arrive in manifest order —
//! `x, adj, [edge_attr], [eig], mask` — all f32, padded to the model's
//! node capacity. `InputPack` owns the scratch buffers so repeated
//! fills allocate nothing (the f32 staging is reused). Filling consumes
//! an ingested [`crate::graph::GraphBatch`], so the eigensolve for
//! eig-consuming models reuses the batch's CSR instead of re-deriving
//! adjacency.
//!
//! Since the stage-IR redesign the native backend executes plans over
//! sparse neighbor lists and never stages these tensors; the engine
//! builds an `InputPack` lazily only when a PJRT executable actually
//! needs the padded layout.

use anyhow::{bail, Result};

use crate::graph::{DenseGraph, GraphBatch};

use super::artifact::ModelMeta;

/// Reusable packing state for one model.
#[derive(Clone, Debug)]
pub struct InputPack {
    dense: DenseGraph,
    needs_eig: bool,
    n_max: usize,
}

impl InputPack {
    pub fn new(meta: &ModelMeta) -> InputPack {
        let f_edge = meta
            .inputs
            .iter()
            .find(|i| i.name == "edge_attr")
            .map(|i| *i.shape.last().unwrap_or(&0))
            .unwrap_or(0);
        InputPack {
            dense: DenseGraph {
                n_max: meta.n_max,
                n_real: 0,
                f_node: meta.in_dim,
                x: vec![0.0; meta.n_max * meta.in_dim],
                adj: vec![0.0; meta.n_max * meta.n_max],
                edge_attr: vec![0.0; meta.n_max * meta.n_max * f_edge],
                f_edge,
                mask: vec![0.0; meta.n_max],
                eig: vec![0.0; meta.n_max],
            },
            needs_eig: meta.needs_eig(),
            n_max: meta.n_max,
        }
    }

    /// Refill the scratch tensors from an ingested batch.
    /// `eig_override` supplies a precomputed eigenvector (golden replay
    /// / the paper's DGN flow where eigenvectors are an input
    /// parameter); otherwise the packer solves on the batch's CSR.
    pub fn fill(&mut self, batch: &GraphBatch, eig_override: Option<&[f32]>) -> Result<()> {
        let g = &batch.graph;
        if g.n > self.n_max {
            bail!("graph with {} nodes exceeds capacity {}", g.n, self.n_max);
        }
        self.dense.fill_from(g)?;
        if self.needs_eig {
            match eig_override {
                Some(e) => {
                    if e.len() != self.n_max {
                        bail!("eig override has wrong length");
                    }
                    self.dense.eig.copy_from_slice(e);
                }
                None => {
                    let r = batch.fiedler(400, 1e-9);
                    self.dense.eig.fill(0.0);
                    self.dense.eig[..g.n].copy_from_slice(&r.vector);
                }
            }
        }
        Ok(())
    }

    /// Borrow the staged f32 buffer for one manifest input slot.
    pub fn slot(&self, name: &str) -> Result<&[f32]> {
        Ok(match name {
            "x" => &self.dense.x,
            "adj" => &self.dense.adj,
            "edge_attr" => &self.dense.edge_attr,
            "eig" => &self.dense.eig,
            "mask" => &self.dense.mask,
            _ => bail!("unknown input slot {name:?}"),
        })
    }

    /// Staged buffers in manifest order, shape-checked — what the
    /// native executor consumes and what the PJRT literal path wraps.
    pub fn staged_inputs<'a>(&'a self, meta: &ModelMeta) -> Result<Vec<&'a [f32]>> {
        let mut out = Vec::with_capacity(meta.inputs.len());
        for spec in &meta.inputs {
            let buf = self.slot(&spec.name)?;
            if buf.len() != spec.elems() {
                bail!(
                    "slot {} staged {} elems, artifact wants {:?}",
                    spec.name,
                    buf.len(),
                    spec.shape
                );
            }
            out.push(buf);
        }
        Ok(out)
    }

    /// The staged dense tensors (the native executor's input view).
    pub fn dense(&self) -> &DenseGraph {
        &self.dense
    }

    /// Build the PJRT literals in manifest order.
    #[cfg(feature = "xla")]
    pub fn literals(&self, meta: &ModelMeta) -> Result<Vec<xla::Literal>> {
        let mut out = Vec::with_capacity(meta.inputs.len());
        for (spec, buf) in meta.inputs.iter().zip(self.staged_inputs(meta)?) {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            out.push(xla::Literal::vec1(buf).reshape(&dims)?);
        }
        Ok(out)
    }

    pub fn n_real(&self) -> usize {
        self.dense.n_real
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::CooGraph;
    use crate::runtime::artifact::Artifacts;

    fn meta(name: &str) -> Option<crate::runtime::artifact::ModelMeta> {
        Artifacts::load(Artifacts::default_dir())
            .ok()?
            .model(name)
            .ok()
            .cloned()
    }

    fn mol() -> GraphBatch {
        let mut rng = crate::util::rng::Rng::new(5);
        let g = crate::datagen::molecular_graph(&mut rng, &crate::datagen::MolConfig::molhiv());
        GraphBatch::ingest_unchecked(g)
    }

    #[test]
    fn refill_is_idempotent() {
        let Some(m) = meta("gin") else { return };
        let b = mol();
        let mut p = InputPack::new(&m);
        p.fill(&b, None).unwrap();
        let x1 = p.slot("x").unwrap().to_vec();
        let a1 = p.slot("adj").unwrap().to_vec();
        p.fill(&b, None).unwrap();
        assert_eq!(p.slot("x").unwrap(), &x1[..]);
        assert_eq!(p.slot("adj").unwrap(), &a1[..]);
    }

    #[test]
    fn refill_clears_previous_graph() {
        let Some(m) = meta("gin") else { return };
        let big = mol();
        let small = {
            let mut rng = crate::util::rng::Rng::new(9);
            let g = crate::datagen::molecular_graph(
                &mut rng,
                &crate::datagen::MolConfig {
                    mean_nodes: 6.0,
                    std_nodes: 0.5,
                    ..crate::datagen::MolConfig::molhiv()
                },
            );
            GraphBatch::ingest_unchecked(g)
        };
        let mut p = InputPack::new(&m);
        p.fill(&big, None).unwrap();
        p.fill(&small, None).unwrap();
        let mask = p.slot("mask").unwrap();
        let live: usize = mask.iter().map(|&v| v as usize).sum();
        assert_eq!(live, small.n());
        // Adjacency must hold exactly small's directed edges.
        let nnz = p.slot("adj").unwrap().iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nnz, small.num_edges());
    }

    #[test]
    fn eig_computed_for_dgn() {
        let Some(m) = meta("dgn") else { return };
        let b = mol();
        let mut p = InputPack::new(&m);
        p.fill(&b, None).unwrap();
        let eig = p.slot("eig").unwrap();
        let norm: f32 = eig.iter().map(|v| v * v).sum();
        assert!((norm - 1.0).abs() < 1e-3, "unit-norm eig, got {norm}");
        assert!(eig[b.n()..].iter().all(|&v| v == 0.0), "padding zeroed");
    }

    #[test]
    fn oversized_graph_rejected() {
        let Some(m) = meta("gin") else { return };
        let mut rng = crate::util::rng::Rng::new(3);
        let g = crate::datagen::citation::citation_graph(rng.next_u64(), 200, 600, 9);
        let mut p = InputPack::new(&m);
        assert!(p.fill(&GraphBatch::ingest_unchecked(g), None).is_err());
    }

    #[test]
    fn staged_inputs_shape_checked_without_artifacts() {
        // A hand-built meta exercises the shape check even on a clean
        // checkout with no artifact directory.
        use crate::runtime::artifact::{InputSpec, ModelMeta};
        let m = ModelMeta {
            name: "gcn".into(),
            layers: 1,
            dim: 4,
            heads: 0,
            n_max: 4,
            in_dim: 2,
            out_dim: 1,
            node_level: false,
            inputs: vec![
                InputSpec {
                    name: "x".into(),
                    shape: vec![4, 2],
                },
                InputSpec {
                    name: "adj".into(),
                    shape: vec![4, 4],
                },
                InputSpec {
                    name: "mask".into(),
                    shape: vec![4],
                },
            ],
            hlo_path: "unused".into(),
            golden_path: "unused".into(),
        };
        let g = CooGraph {
            n: 2,
            edges: vec![(0, 1), (1, 0)],
            node_feat: vec![1.0, 2.0, 3.0, 4.0],
            f_node: 2,
            edge_feat: vec![],
            f_edge: 0,
        };
        let mut p = InputPack::new(&m);
        p.fill(&GraphBatch::ingest_unchecked(g), None).unwrap();
        let staged = p.staged_inputs(&m).unwrap();
        assert_eq!(staged.len(), 3);
        assert_eq!(staged[0].len(), 8);
        assert_eq!(staged[1].len(), 16);
        assert_eq!(staged[2].len(), 4);
        assert_eq!(p.n_real(), 2);
    }
}

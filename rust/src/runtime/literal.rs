//! Graph → padded input-tensor packing.
//!
//! The artifact contract (mirrors `python/compile/graphgen.densify`
//! bit-for-bit, see `graph::dense`): inputs arrive in manifest order —
//! `x, adj, [edge_attr], [eig], mask` — all f32, padded to the model's
//! node capacity. `InputPack` owns the scratch buffers so the serving
//! hot path re-fills them per request with **zero allocation** (the f32
//! staging is reused; only the PJRT literal creation copies).

use anyhow::{bail, Result};

use crate::graph::{fiedler_vector, CooGraph, DenseGraph};

use super::artifact::ModelMeta;

/// Reusable packing state for one model.
#[derive(Clone, Debug)]
pub struct InputPack {
    dense: DenseGraph,
    needs_eig: bool,
    n_max: usize,
}

impl InputPack {
    pub fn new(meta: &ModelMeta) -> InputPack {
        InputPack {
            dense: DenseGraph {
                n_max: meta.n_max,
                n_real: 0,
                f_node: meta.in_dim,
                x: vec![0.0; meta.n_max * meta.in_dim],
                adj: vec![0.0; meta.n_max * meta.n_max],
                edge_attr: if meta.needs_edge_attr() {
                    let fe = meta
                        .inputs
                        .iter()
                        .find(|i| i.name == "edge_attr")
                        .map(|i| i.shape[2])
                        .unwrap_or(0);
                    vec![0.0; meta.n_max * meta.n_max * fe]
                } else {
                    Vec::new()
                },
                f_edge: if meta.needs_edge_attr() {
                    meta.inputs
                        .iter()
                        .find(|i| i.name == "edge_attr")
                        .map(|i| i.shape[2])
                        .unwrap_or(0)
                } else {
                    0
                },
                mask: vec![0.0; meta.n_max],
                eig: vec![0.0; meta.n_max],
            },
            needs_eig: meta.needs_eig(),
            n_max: meta.n_max,
        }
    }

    /// Refill the scratch tensors from a raw graph. `eig_override`
    /// supplies a precomputed eigenvector (golden replay); otherwise the
    /// packer computes it on the fly for eig-consuming models — matching
    /// the paper's DGN flow where eigenvectors are an input parameter.
    pub fn fill(&mut self, g: &CooGraph, eig_override: Option<&[f32]>) -> Result<()> {
        if g.n > self.n_max {
            bail!("graph with {} nodes exceeds capacity {}", g.n, self.n_max);
        }
        self.dense.fill_from(g)?;
        if self.needs_eig {
            match eig_override {
                Some(e) => {
                    if e.len() != self.n_max {
                        bail!("eig override has wrong length");
                    }
                    self.dense.eig.copy_from_slice(e);
                }
                None => {
                    let r = fiedler_vector(g, 400, 1e-9);
                    self.dense.eig.fill(0.0);
                    self.dense.eig[..g.n].copy_from_slice(&r.vector);
                }
            }
        }
        Ok(())
    }

    /// Borrow the staged f32 buffer for one manifest input slot.
    pub fn slot(&self, name: &str) -> Result<&[f32]> {
        Ok(match name {
            "x" => &self.dense.x,
            "adj" => &self.dense.adj,
            "edge_attr" => &self.dense.edge_attr,
            "eig" => &self.dense.eig,
            "mask" => &self.dense.mask,
            _ => bail!("unknown input slot {name:?}"),
        })
    }

    /// Build the PJRT literals in manifest order.
    pub fn literals(&self, meta: &ModelMeta) -> Result<Vec<xla::Literal>> {
        let mut out = Vec::with_capacity(meta.inputs.len());
        for spec in &meta.inputs {
            let buf = self.slot(&spec.name)?;
            if buf.len() != spec.elems() {
                bail!(
                    "slot {} staged {} elems, artifact wants {:?}",
                    spec.name,
                    buf.len(),
                    spec.shape
                );
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            out.push(xla::Literal::vec1(buf).reshape(&dims)?);
        }
        Ok(out)
    }

    pub fn n_real(&self) -> usize {
        self.dense.n_real
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::Artifacts;

    fn meta(name: &str) -> Option<crate::runtime::artifact::ModelMeta> {
        Artifacts::load(Artifacts::default_dir())
            .ok()?
            .model(name)
            .ok()
            .cloned()
    }

    fn mol() -> CooGraph {
        let mut rng = crate::util::rng::Rng::new(5);
        crate::datagen::molecular_graph(&mut rng, &crate::datagen::MolConfig::molhiv())
    }

    #[test]
    fn refill_is_idempotent() {
        let Some(m) = meta("gin") else { return };
        let g = mol();
        let mut p = InputPack::new(&m);
        p.fill(&g, None).unwrap();
        let x1 = p.slot("x").unwrap().to_vec();
        let a1 = p.slot("adj").unwrap().to_vec();
        p.fill(&g, None).unwrap();
        assert_eq!(p.slot("x").unwrap(), &x1[..]);
        assert_eq!(p.slot("adj").unwrap(), &a1[..]);
    }

    #[test]
    fn refill_clears_previous_graph() {
        let Some(m) = meta("gin") else { return };
        let big = mol();
        let small = {
            let mut rng = crate::util::rng::Rng::new(9);
            crate::datagen::molecular_graph(
                &mut rng,
                &crate::datagen::MolConfig {
                    mean_nodes: 6.0,
                    std_nodes: 0.5,
                    ..crate::datagen::MolConfig::molhiv()
                },
            )
        };
        let mut p = InputPack::new(&m);
        p.fill(&big, None).unwrap();
        p.fill(&small, None).unwrap();
        let mask = p.slot("mask").unwrap();
        let live: usize = mask.iter().map(|&v| v as usize).sum();
        assert_eq!(live, small.n);
        // Adjacency must hold exactly small's directed edges.
        let nnz = p.slot("adj").unwrap().iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nnz, small.num_edges());
    }

    #[test]
    fn eig_computed_for_dgn() {
        let Some(m) = meta("dgn") else { return };
        let g = mol();
        let mut p = InputPack::new(&m);
        p.fill(&g, None).unwrap();
        let eig = p.slot("eig").unwrap();
        let norm: f32 = eig.iter().map(|v| v * v).sum();
        assert!((norm - 1.0).abs() < 1e-3, "unit-norm eig, got {norm}");
        assert!(eig[g.n..].iter().all(|&v| v == 0.0), "padding zeroed");
    }

    #[test]
    fn oversized_graph_rejected() {
        let Some(m) = meta("gin") else { return };
        let mut rng = crate::util::rng::Rng::new(3);
        let g = crate::datagen::citation::citation_graph(rng.next_u64(), 200, 600, 9);
        let mut p = InputPack::new(&m);
        assert!(p.fill(&g, None).is_err());
    }
}

//! The [`Engine`]: compiled executables per model — the complete
//! request-path inference stack (raw COO graph in, output vector out),
//! with Python nowhere in sight. Native models execute their lowered
//! stage-IR plans sparsely; dense input staging exists only for the
//! PJRT backend, built lazily per compiled executable.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::graph::{CooGraph, GraphBatch};

use super::artifact::{Artifacts, ModelMeta};
use super::client::{Client, Compiled};
#[cfg(feature = "xla")]
use super::literal::InputPack;

struct LoadedModel {
    meta: ModelMeta,
    exe: Compiled,
    /// Dense input staging — PJRT only. The native path executes the
    /// stage-IR plan sparsely and never materializes padded tensors,
    /// so a native engine holds no O(n_max²) buffers at all.
    #[cfg(feature = "xla")]
    pack: Option<InputPack>,
}

/// Inference engine over a set of compiled artifacts.
///
/// Runs on the native reference backend by default; with the `xla`
/// feature and a real PJRT runtime it executes the HLO artifacts
/// instead. Handles are thread-confined either way, so the coordinator
/// builds one `Engine` per executor lane from the shared artifacts —
/// the software analog of instantiating N parallel processing lanes on
/// the fabric. Weights regenerate from the manifest seed, so every
/// lane's engine is bit-identical and lane count never changes outputs.
pub struct Engine {
    client: Client,
    models: BTreeMap<String, LoadedModel>,
    artifacts: Artifacts,
}

impl Engine {
    /// Compile `names` (or every manifest model if empty) from an
    /// artifact directory.
    pub fn load(artifacts: &Artifacts, names: &[&str]) -> Result<Engine> {
        let client = Client::cpu()?;
        let mut models = BTreeMap::new();
        let wanted: Vec<&str> = if names.is_empty() {
            artifacts.model_names()
        } else {
            names.to_vec()
        };
        for name in wanted {
            let meta = artifacts.model(name)?.clone();
            let exe = client
                .compile_model(&meta, artifacts.weight_seed)
                .with_context(|| format!("loading model {name}"))?;
            models.insert(
                name.to_string(),
                LoadedModel {
                    meta,
                    exe,
                    #[cfg(feature = "xla")]
                    pack: None,
                },
            );
        }
        Ok(Engine {
            client,
            models,
            artifacts: artifacts.clone(),
        })
    }

    /// An engine with no compiled models — the starting point for a
    /// lane that syncs its model set from a live registry snapshot
    /// (see [`Engine::ensure_model`]).
    pub fn empty(artifacts: &Artifacts) -> Result<Engine> {
        Ok(Engine {
            client: Client::cpu()?,
            models: BTreeMap::new(),
            artifacts: artifacts.clone(),
        })
    }

    /// Compile `meta` into this engine if it is not already resident.
    /// Returns `true` when a compile actually happened.
    ///
    /// Compilation is deterministic (weights regenerate from the
    /// artifact seed), so skipping an already-resident model is not an
    /// optimization shortcut but the bit-exactness guarantee for
    /// same-digest live reloads: the plan that served the last request
    /// is — by identity, not just by construction — the plan that
    /// serves the next one.
    pub fn ensure_model(&mut self, meta: &ModelMeta) -> Result<bool> {
        if self.models.contains_key(&meta.name) {
            return Ok(false);
        }
        let exe = self
            .client
            .compile_model(meta, self.artifacts.weight_seed)
            .with_context(|| format!("loading model {}", meta.name))?;
        self.models.insert(
            meta.name.clone(),
            LoadedModel {
                meta: meta.clone(),
                exe,
                #[cfg(feature = "xla")]
                pack: None,
            },
        );
        Ok(true)
    }

    /// Drop a compiled model. Returns whether it was resident. The
    /// serving lanes deliberately do *not* call this on unload —
    /// in-flight requests drain against the cached plan — but
    /// memory-conscious embedders can.
    pub fn evict_model(&mut self, name: &str) -> bool {
        self.models.remove(name).is_some()
    }

    /// Convenience: load from the default artifact dir.
    pub fn from_default_dir(names: &[&str]) -> Result<Engine> {
        let artifacts = Artifacts::load(Artifacts::default_dir())?;
        Engine::load(&artifacts, names)
    }

    pub fn artifacts(&self) -> &Artifacts {
        &self.artifacts
    }

    pub fn loaded_models(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    pub fn meta(&self, model: &str) -> Result<&ModelMeta> {
        Ok(&self.get(model)?.meta)
    }

    fn get(&self, model: &str) -> Result<&LoadedModel> {
        self.models
            .get(model)
            .ok_or_else(|| anyhow::anyhow!("model {model:?} not loaded"))
    }

    fn get_mut(&mut self, model: &str) -> Result<&mut LoadedModel> {
        self.models
            .get_mut(model)
            .ok_or_else(|| anyhow::anyhow!("model {model:?} not loaded"))
    }

    /// Run one graph through one model; returns the flat output vector
    /// (graph-level: `[out_dim]`; node-level: `[n_max * out_dim]`).
    /// Convenience wrapper that ingests on the spot — the serving path
    /// uses [`Engine::infer_batch`] with the prep stage's batch.
    pub fn infer(&mut self, model: &str, g: &CooGraph) -> Result<Vec<f32>> {
        self.infer_with_eig(model, g, None)
    }

    /// `infer` with a caller-provided Laplacian eigenvector (golden
    /// replay / precomputed-eig flows).
    pub fn infer_with_eig(
        &mut self,
        model: &str,
        g: &CooGraph,
        eig: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        let batch = GraphBatch::ingest(g.clone())?;
        self.infer_batch(model, &batch, eig)
    }

    /// The core inference path over an already-ingested batch — no
    /// re-validation, no re-conversion (zero-preprocessing contract).
    /// On the native backend this executes the model's stage-IR plan
    /// over the batch's sparse neighbor lists: per-request memory is
    /// O(edges), never O(n_max²).
    pub fn infer_batch(
        &mut self,
        model: &str,
        batch: &GraphBatch,
        eig: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        let lm = self.get_mut(model)?;
        match &lm.exe {
            Compiled::Native(native) => native.forward_batch(batch, eig),
            #[cfg(feature = "xla")]
            Compiled::Pjrt(exe) => {
                // PJRT consumes the AOT artifact's padded dense input
                // layout; the staging pack is built lazily so native
                // engines (and the xla-feature fallback) never pay for
                // it.
                let pack = lm.pack.get_or_insert_with(|| InputPack::new(&lm.meta));
                pack.fill(batch, eig)?;
                let literals = pack.literals(&lm.meta)?;
                let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
                // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
                let out = result.to_tuple1()?;
                Ok(out.to_vec::<f32>()?)
            }
        }
    }

    /// Run several same-model requests as **one** fused interpreter
    /// pass over a block-diagonal merge of their graphs, returning one
    /// output per request (input order), bit-identical to calling
    /// [`Engine::infer_batch`] per request.
    ///
    /// `eigs` pairs one optional precomputed eigenvector with each
    /// graph (same contract as [`Engine::infer_batch`]). Native
    /// backend only — the PJRT artifacts are batch-1 by construction,
    /// so that path errors and the caller (the executor lane) falls
    /// back to per-request execution.
    pub fn infer_fused(
        &mut self,
        model: &str,
        parts: &[&GraphBatch],
        eigs: &[Option<&[f32]>],
    ) -> Result<Vec<Vec<f32>>> {
        let lm = self.get_mut(model)?;
        match &lm.exe {
            Compiled::Native(native) => native.forward_fused(parts, eigs),
            #[cfg(feature = "xla")]
            Compiled::Pjrt(_) => {
                anyhow::bail!("fused execution requires the native backend")
            }
        }
    }

    /// Whether this model may serve fused micro-batches: true only on
    /// the native backend and only when the static analyzer derived a
    /// fusion-safety fact for every stage of the lowered plan. The
    /// executor lane consults this before grouping a chunk, so an
    /// unfusable plan is never even merged (PJRT artifacts are batch-1
    /// by construction and always answer `false`).
    pub fn fusable(&self, model: &str) -> bool {
        match self.get(model) {
            Ok(lm) => match &lm.exe {
                Compiled::Native(native) => native.fusable(),
                #[cfg(feature = "xla")]
                Compiled::Pjrt(_) => false,
            },
            Err(_) => false,
        }
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Relative tolerance for golden cross-checks on this backend: the
    /// native executor re-implements the forward pass (accumulated-f32
    /// noise vs the JAX reference), while a PJRT backend executes the
    /// identical HLO and must match tighter.
    pub fn golden_tolerance(&self) -> f32 {
        if self.platform() == "native-reference" {
            1e-3
        } else {
            1e-4
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::Golden;

    fn engine(names: &[&str]) -> Option<Engine> {
        Engine::from_default_dir(names).ok()
    }

    fn close(a: &[f32], b: &[f32], tol: f32) -> bool {
        a.len() == b.len()
            && a.iter()
                .zip(b)
                .all(|(x, y)| (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())))
    }

    #[test]
    fn gcn_golden_matches() {
        let Some(mut e) = engine(&["gcn"]) else { return };
        let meta = e.meta("gcn").unwrap().clone();
        let g = Golden::load(&meta).unwrap();
        let tol = e.golden_tolerance();
        let out = e.infer("gcn", &g.graph).unwrap();
        assert!(close(&out, &g.output, tol), "{out:?} vs {:?}", g.output);
    }

    #[test]
    fn inference_is_deterministic() {
        let Some(mut e) = engine(&["gcn"]) else { return };
        let meta = e.meta("gcn").unwrap().clone();
        let g = Golden::load(&meta).unwrap();
        let a = e.infer("gcn", &g.graph).unwrap();
        let b = e.infer("gcn", &g.graph).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn batch_and_coo_paths_agree_exactly() {
        let Some(mut e) = engine(&["gcn"]) else { return };
        let meta = e.meta("gcn").unwrap().clone();
        let g = Golden::load(&meta).unwrap();
        let via_coo = e.infer("gcn", &g.graph).unwrap();
        let batch = GraphBatch::ingest(g.graph.clone()).unwrap();
        let via_batch = e.infer_batch("gcn", &batch, None).unwrap();
        assert_eq!(via_coo, via_batch);
    }

    #[test]
    fn fused_path_matches_sequential_batches() {
        let Some(mut e) = engine(&["gcn"]) else { return };
        let meta = e.meta("gcn").unwrap().clone();
        let g = Golden::load(&meta).unwrap();
        let b = GraphBatch::ingest(g.graph.clone()).unwrap();
        let seq = e.infer_batch("gcn", &b, None).unwrap();
        let fused = e.infer_fused("gcn", &[&b, &b], &[None, None]).unwrap();
        assert_eq!(fused, vec![seq.clone(), seq]);
        assert!(e.infer_fused("gat", &[&b], &[None]).is_err(), "unloaded");
        assert!(e.fusable("gcn"), "native gcn must expose fusion facts");
        assert!(!e.fusable("gat"), "unloaded model is not fusable");
    }

    #[test]
    fn unloaded_model_is_an_error() {
        let Some(mut e) = engine(&["gcn"]) else { return };
        let meta = e.meta("gcn").unwrap().clone();
        let g = Golden::load(&meta).unwrap();
        assert!(e.infer("gat", &g.graph).is_err());
    }

    #[test]
    fn ensure_model_compiles_once_and_serves_identically() {
        let Some(mut e) = engine(&["gcn"]) else { return };
        let baseline = {
            let meta = e.meta("gcn").unwrap().clone();
            let g = Golden::load(&meta).unwrap();
            e.infer("gcn", &g.graph).unwrap()
        };
        // Live-load a second model into the running engine.
        let gin_meta = e.artifacts().model("gin").unwrap().clone();
        assert!(e.ensure_model(&gin_meta).unwrap(), "first ensure compiles");
        assert!(!e.ensure_model(&gin_meta).unwrap(), "second ensure is a no-op");
        let g = Golden::load(&gin_meta).unwrap();
        assert!(e.infer("gin", &g.graph).is_ok());
        // The resident model's outputs are untouched by the live load.
        let meta = e.meta("gcn").unwrap().clone();
        let g = Golden::load(&meta).unwrap();
        assert_eq!(e.infer("gcn", &g.graph).unwrap(), baseline);
        // Eviction frees the slot; ensure recompiles bit-identically.
        assert!(e.evict_model("gin"));
        assert!(!e.evict_model("gin"), "double evict is a no-op");
        assert!(e.ensure_model(&gin_meta).unwrap());
    }

    #[test]
    fn empty_engine_grows_from_snapshots() {
        let Ok(artifacts) = Artifacts::load(Artifacts::default_dir()) else {
            return;
        };
        let mut e = Engine::empty(&artifacts).unwrap();
        assert!(e.loaded_models().is_empty());
        let meta = artifacts.model("gcn").unwrap().clone();
        e.ensure_model(&meta).unwrap();
        let g = Golden::load(&meta).unwrap();
        let out = e.infer("gcn", &g.graph).unwrap();
        // Bit-identical to a startup-loaded engine: live load is not a
        // different compile path.
        let mut boot = Engine::load(&artifacts, &["gcn"]).unwrap();
        assert_eq!(out, boot.infer("gcn", &g.graph).unwrap());
    }
}

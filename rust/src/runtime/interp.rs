//! The generic stage-IR interpreter: one executor for every model in
//! the zoo.
//!
//! Executes a lowered [`ModelPlan`] over a raw COO graph by walking
//! sorted, deduplicated in-neighbor lists ([`InNbrs`]) — per-request
//! cost O(edges · dim), memory O(edges + n · dim). The padded
//! O(n_max²) adjacency of the legacy path never exists here.
//!
//! **Bit-exactness contract:** for every plan the lowering registry
//! produces, this interpreter's output is bit-identical to the dense
//! reference executor ([`super::dense_ref`]) — live entries exactly,
//! node-level padding exactly `+0.0`. The contract holds because
//! per-row primitives are shared (`super::tensor`) and every
//! neighborhood accumulation walks ascending node order, which is the
//! order the dense reference's ascending-j loops sum in (the skipped
//! zero adjacency entries are additive no-ops). The executable spec of
//! this ordering argument is `python/tools/plan_replica.py`; the Rust
//! property tests live in `tests/plan_equivalence.rs`.
//!
//! Interpreter state is a two-register machine plus optional
//! virtual-node state:
//!
//! * `h` — live features: `[n, d]` node rows until a pooling readout
//!   collapses them to one row per graph;
//! * `m` — the latest [`Stage::SparseAggregate`] result, consumed by
//!   the next combine stage (`TakeAggregate`, `EpsCombine`,
//!   `ResidualLinear`, `DualLinear`);
//! * `vn` — the virtual-node vector(s), seeded from
//!   [`ModelPlan::vn_init`] — one per graph.
//!
//! Per-graph spectral/normalization contexts (GCN inverse-sqrt
//! degrees, DGN directional weights) are computed lazily once per
//! request and shared across the layers that need them.
//!
//! **Fused micro-batches:** the core loop is *segmented*. Per-request
//! execution ([`execute_over`]) runs it with a single segment spanning
//! the whole graph; fused execution ([`execute_fused`]) runs the same
//! loop once over a block-diagonal [`FusedBatch`] with one segment per
//! source graph. Per-node stages never look at segments (a fused
//! node's neighborhood is its per-graph neighborhood, offset-shifted);
//! only the readouts ([`Readout::MaskedMeanPool`] pools per segment,
//! [`Readout::NodeHead`] splits per segment) and the `VirtualNode*`
//! stages (independent per-graph state, batched through one
//! row-independent MLP evaluation) consult the segment table — which
//! is why fused outputs are bit-identical to sequential ones.

use anyhow::{bail, Result};

use crate::graph::{CooGraph, FusedBatch, FusedSegment, InNbrs};
use crate::models::params::Dense;
use crate::models::plan::{Act, Aggregate, ModelPlan, Readout, Stage};

use super::tensor::{apply_act, avg_log_deg, l2_normalize_rows, linear, Mat};

/// Validate a raw graph against a plan's input contract (same checks
/// the dense packing path performed).
pub fn check_input(plan: &ModelPlan, g: &CooGraph) -> Result<()> {
    if g.n > plan.n_max {
        bail!("graph with {} nodes exceeds capacity {}", g.n, plan.n_max);
    }
    if g.f_node != plan.in_dim {
        bail!("node feature width {} != {}", g.f_node, plan.in_dim);
    }
    if plan.edge_dim > 0 && g.f_edge != plan.edge_dim {
        bail!("edge feature width {} != {}", g.f_edge, plan.edge_dim);
    }
    Ok(())
}

/// [`execute_over`] with the in-neighbor view derived on the spot
/// (tests and one-shot flows; the serving path reuses the batch's
/// cached view).
pub fn execute(plan: &ModelPlan, g: &CooGraph, eig: Option<&[f32]>) -> Result<Vec<f32>> {
    execute_over(plan, g, &InNbrs::from_coo(g), eig)
}

/// Execute a plan over one graph and its in-neighbor view. `eig` must
/// cover the graph's real nodes when the plan needs it (extra padded
/// entries are ignored). Graph-level plans return `[out_dim]`;
/// node-level plans `[n_max * out_dim]` with `+0.0` padding.
///
/// This is the degenerate single-segment case of the segmented core:
/// fused multi-graph execution ([`execute_fused`]) runs the *same*
/// stage implementations over a block-diagonal graph, which is how
/// the fused path inherits the bit-exactness contract instead of
/// re-proving it.
pub fn execute_over(
    plan: &ModelPlan,
    g: &CooGraph,
    nbrs: &InNbrs,
    eig: Option<&[f32]>,
) -> Result<Vec<f32>> {
    check_input(plan, g)?;
    let n = g.n;
    let eig = match (plan.needs_eig(), eig) {
        (true, None) => bail!("model {} needs an eig input", plan.model),
        (true, Some(e)) if e.len() < n => {
            bail!("eig has {} entries for {} nodes", e.len(), n)
        }
        (_, e) => e,
    };
    let whole = [FusedSegment {
        node_offset: 0,
        n,
        edge_offset: 0,
        e: g.num_edges(),
    }];
    let mut outs = execute_segments(plan, g, nbrs, &whole, eig)?;
    Ok(outs.pop().expect("one segment yields one output"))
}

/// Execute a plan **once** over a fused block-diagonal batch,
/// returning one output vector per source graph (fuse order).
///
/// Per-node stages are oblivious to fusion (every node's neighborhood
/// is its per-graph neighborhood shifted by a constant offset);
/// readout and virtual-node stages operate per segment. Outputs are
/// bit-identical to executing each graph alone — pinned by
/// `rust/tests/fused_equivalence.rs` across the model zoo.
pub fn execute_fused(
    plan: &ModelPlan,
    fused: &FusedBatch,
    eig: Option<&[f32]>,
) -> Result<Vec<Vec<f32>>> {
    // Consume the analyzer's derived fusion-safety facts instead of
    // assuming every stage kind can run over merged segments: a plan
    // containing a cross-segment-unsafe stage is refused up front (the
    // caller falls back to per-request execution), never miscomputed.
    crate::analysis::assert_fusable(plan)?;
    let g = fused.graph();
    for seg in fused.segments() {
        if seg.n > plan.n_max {
            bail!("graph with {} nodes exceeds capacity {}", seg.n, plan.n_max);
        }
    }
    if g.f_node != plan.in_dim {
        bail!("node feature width {} != {}", g.f_node, plan.in_dim);
    }
    if plan.edge_dim > 0 && g.f_edge != plan.edge_dim {
        bail!("edge feature width {} != {}", g.f_edge, plan.edge_dim);
    }
    let eig = match (plan.needs_eig(), eig) {
        (true, None) => bail!("model {} needs an eig input", plan.model),
        (true, Some(e)) if e.len() < g.n => {
            bail!("eig has {} entries for {} fused nodes", e.len(), g.n)
        }
        (_, e) => e,
    };
    execute_segments(plan, g, fused.in_nbrs(), fused.segments(), eig)
}

/// The segmented interpreter core shared by [`execute_over`] (one
/// segment spanning the whole graph) and [`execute_fused`] (one
/// segment per source graph). Inputs are assumed validated.
fn execute_segments(
    plan: &ModelPlan,
    g: &CooGraph,
    nbrs: &InNbrs,
    segments: &[FusedSegment],
    eig: Option<&[f32]>,
) -> Result<Vec<Vec<f32>>> {
    let n = g.n;
    let mut h = Mat::from_slice(n, plan.in_dim, &g.node_feat);
    let mut m: Option<Mat> = None;
    // Virtual-node state is per graph: one vector per segment.
    let mut vn: Option<Vec<Vec<f32>>> = plan
        .vn_init
        .as_ref()
        .map(|v| segments.iter().map(|_| v.clone()).collect());
    let mut gcn_isq: Option<Vec<f32>> = None;
    let mut dgn_ctx: Option<DgnCtx> = None;
    for (si, stage) in plan.stages.iter().enumerate() {
        // Belt to execute_fused's suspenders: no stage without a
        // fusion-safety fact may reach a multi-segment pass.
        debug_assert!(
            segments.len() <= 1
                || crate::analysis::facts::stage_fact(stage)
                    != crate::analysis::FusionFact::CrossSegmentUnsafe,
            "unfusable stage {si} reached the segmented core"
        );
        match stage {
            Stage::Linear { w, act } => h = linear(&h, w, *act),
            Stage::SparseAggregate(agg) => {
                let out =
                    dispatch_aggregate(agg, nbrs, g, &h, eig, &mut gcn_isq, &mut dgn_ctx)?;
                m = Some(out);
            }
            Stage::TakeAggregate => h = take(&mut m, si)?,
            Stage::EpsCombine { eps } => {
                let mm = take(&mut m, si)?;
                for (hv, &mv) in h.d.iter_mut().zip(&mm.d) {
                    *hv = (1.0 + eps) * *hv + mv;
                }
            }
            Stage::ResidualLinear { w, act } => {
                let mm = take(&mut m, si)?;
                let up = linear(&mm, w, *act);
                for (hv, &uv) in h.d.iter_mut().zip(&up.d) {
                    *hv = uv + *hv;
                }
            }
            Stage::DualLinear { w_self, w_nbr } => {
                let mm = take(&mut m, si)?;
                let hs = linear(&h, w_self, Act::None);
                let hn = linear(&mm, w_nbr, Act::None);
                h = hs;
                for (hv, &nv) in h.d.iter_mut().zip(&hn.d) {
                    *hv += nv;
                }
            }
            Stage::EdgeAttention { heads, a_src, a_dst } => {
                h = edge_attention(nbrs, plan.n_max, &h, a_src, a_dst, *heads);
            }
            Stage::Activation(a) => apply_act(&mut h, *a),
            Stage::L2Normalize => l2_normalize_rows(&mut h),
            Stage::VirtualNodeAdd => {
                let vns = vn
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("stage {si}: no virtual-node state"))?;
                for (seg, vnv) in segments.iter().zip(vns) {
                    for i in seg.nodes() {
                        // mask is 1.0 on every real row: `vv * mk == vv`.
                        for (hv, &vv) in h.row_mut(i).iter_mut().zip(vnv) {
                            *hv += vv;
                        }
                    }
                }
            }
            Stage::VirtualNodeUpdate { w1, w2 } => {
                let vns = vn
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("stage {si}: no virtual-node state"))?;
                // Stack the per-segment accumulators into one matrix:
                // `linear` is row-independent, so the stacked MLP is
                // bit-identical to per-graph `[1, d]` updates.
                let width = vns[0].len();
                let mut gacc = Mat::zeros(segments.len(), width);
                for (s, (seg, vnv)) in segments.iter().zip(vns.iter()).enumerate() {
                    let gr = &mut gacc.d[s * width..(s + 1) * width];
                    gr.copy_from_slice(vnv);
                    for i in seg.nodes() {
                        for (gv, &hv) in gr.iter_mut().zip(h.row(i)) {
                            *gv += hv;
                        }
                    }
                }
                let updated = linear(&linear(&gacc, w1, Act::Relu), w2, Act::Relu);
                for (s, vnv) in vns.iter_mut().enumerate() {
                    vnv.copy_from_slice(updated.row(s));
                }
            }
            Stage::Readout(r) => match r {
                Readout::MaskedMeanPool => h = pool_segments(&h, segments),
                Readout::NodeHead => {}
            },
        }
    }
    if plan.node_level {
        let d = plan.out_dim;
        let mut outs = Vec::with_capacity(segments.len());
        for seg in segments {
            let mut out = vec![0.0f32; plan.n_max * d];
            let live = seg.n * d;
            out[..live]
                .copy_from_slice(&h.d[seg.node_offset * d..seg.node_offset * d + live]);
            outs.push(out);
        }
        Ok(outs)
    } else {
        // After the pooling readout `h` holds one row per segment.
        if h.r != segments.len() {
            bail!(
                "plan left {} rows for {} graphs (missing pooling readout?)",
                h.r,
                segments.len()
            );
        }
        Ok((0..segments.len()).map(|s| h.row(s).to_vec()).collect())
    }
}

/// Run one aggregation component standalone over `[n, width]` features
/// (the property-test entry point for the component library — the
/// exact dispatch the plan executor runs, nothing re-implemented).
pub fn run_aggregate(
    agg: &Aggregate,
    g: &CooGraph,
    h_flat: &[f32],
    width: usize,
    eig: Option<&[f32]>,
) -> Result<Vec<f32>> {
    if h_flat.len() != g.n * width {
        bail!("feature buffer is {} floats, want {}", h_flat.len(), g.n * width);
    }
    if let Aggregate::EdgeReluSum { bond } = agg {
        if g.f_edge != bond.fin {
            bail!("edge feature width {} != bond input {}", g.f_edge, bond.fin);
        }
    }
    let nbrs = InNbrs::from_coo(g);
    let h = Mat::from_slice(g.n, width, h_flat);
    let out = dispatch_aggregate(agg, &nbrs, g, &h, eig, &mut None, &mut None)?;
    Ok(out.into_vec())
}

/// The single aggregation dispatch shared by [`execute_over`] and
/// [`run_aggregate`]: one implementation, so the property tests
/// exercise exactly the code the serving path executes. Per-graph
/// contexts (GCN inverse-sqrt degrees, DGN directional weights) are
/// built on first use into the caller-owned caches.
fn dispatch_aggregate(
    agg: &Aggregate,
    nbrs: &InNbrs,
    g: &CooGraph,
    h: &Mat,
    eig: Option<&[f32]>,
    gcn_isq: &mut Option<Vec<f32>>,
    dgn_ctx: &mut Option<DgnCtx>,
) -> Result<Mat> {
    Ok(match agg {
        Aggregate::GcnNorm => {
            let isq = gcn_isq.get_or_insert_with(|| gcn_inv_sqrt(nbrs));
            agg_gcn_norm(nbrs, isq, h)
        }
        Aggregate::DgnDirectional => {
            let e = eig.ok_or_else(|| anyhow::anyhow!("dgn aggregation needs eig"))?;
            if e.len() < nbrs.n() {
                bail!("eig has {} entries for {} nodes", e.len(), nbrs.n());
            }
            let ctx = dgn_ctx.get_or_insert_with(|| DgnCtx::build(nbrs, e));
            agg_dgn_directional(nbrs, ctx, h)
        }
        Aggregate::EdgeReluSum { bond } => agg_edge_relu_sum(nbrs, g, h, bond),
        Aggregate::Sum => agg_sum(nbrs, h),
        Aggregate::Mean => agg_mean(nbrs, h),
        Aggregate::Max => agg_minmax(nbrs, h, true),
        Aggregate::Min => agg_minmax(nbrs, h, false),
        Aggregate::PnaTower => agg_pna_tower(nbrs, h),
    })
}

fn take(m: &mut Option<Mat>, stage: usize) -> Result<Mat> {
    m.take()
        .ok_or_else(|| anyhow::anyhow!("stage {stage}: no pending aggregation"))
}

/// Graph-level readout, one output row per segment: mean over the
/// segment's real rows. A segment's `n` real nodes each carry mask
/// 1.0, so the dense reference's mask sum is exactly `n as f32` and
/// its `v * mk` accumulate is exactly `v`; rows are summed in
/// ascending order within the segment, exactly as a per-graph pool
/// would.
fn pool_segments(h: &Mat, segments: &[FusedSegment]) -> Mat {
    let mut out = Mat::zeros(segments.len(), h.c);
    for (s, seg) in segments.iter().enumerate() {
        let denom = (seg.n as f32).max(1.0);
        let or = &mut out.d[s * h.c..(s + 1) * h.c];
        for i in seg.nodes() {
            for (o, &v) in or.iter_mut().zip(h.row(i)) {
                *o += v;
            }
        }
        or.iter_mut().for_each(|v| *v /= denom);
    }
    out
}

/// Ascending walk of row i of `A + diag(mask)`: deduped neighbors carry
/// 1.0, the diagonal (inserted at its sorted position) carries
/// `adj[i][i] + 1.0` — i.e. 2.0 when a self-edge exists, else 1.0.
struct MergedRow<'a> {
    row: &'a [u32],
    i: u32,
    idx: usize,
    diag_done: bool,
}

impl<'a> MergedRow<'a> {
    fn new(nbrs: &'a InNbrs, i: usize) -> MergedRow<'a> {
        MergedRow {
            row: nbrs.row(i),
            i: i as u32,
            idx: 0,
            diag_done: false,
        }
    }
}

impl Iterator for MergedRow<'_> {
    type Item = (usize, f32);

    fn next(&mut self) -> Option<(usize, f32)> {
        if self.idx < self.row.len() {
            let s = self.row[self.idx];
            if !self.diag_done && s >= self.i {
                self.diag_done = true;
                if s == self.i {
                    self.idx += 1;
                    return Some((s as usize, 2.0));
                }
                return Some((self.i as usize, 1.0));
            }
            self.idx += 1;
            return Some((s as usize, 1.0));
        }
        if !self.diag_done {
            self.diag_done = true;
            return Some((self.i as usize, 1.0));
        }
        None
    }
}

/// Per-row `1/sqrt(deg)` of `A + diag(mask)` — the GCN-norm context.
fn gcn_inv_sqrt(nbrs: &InNbrs) -> Vec<f32> {
    let n = nbrs.n();
    let mut isq = vec![0.0f32; n];
    for (i, slot) in isq.iter_mut().enumerate() {
        let mut deg = 0.0f32;
        for (_, a_hat) in MergedRow::new(nbrs, i) {
            deg += a_hat;
        }
        if deg > 0.0 {
            *slot = 1.0 / deg.max(1e-12).sqrt();
        }
    }
    isq
}

/// `m ← D^-1/2 (A + diag(mask)) D^-1/2 · h`, ascending per row with the
/// diagonal merged in order — the dense `gcn_norm_adj` matmul exactly.
fn agg_gcn_norm(nbrs: &InNbrs, isq: &[f32], h: &Mat) -> Mat {
    let n = nbrs.n();
    let mut out = Mat::zeros(n, h.c);
    for i in 0..n {
        let or = &mut out.d[i * h.c..(i + 1) * h.c];
        for (j, a_hat) in MergedRow::new(nbrs, i) {
            // Same expression shape as the dense reference:
            // a_hat * (isq_i * isq_j), then skip exact zeros.
            let av = a_hat * (isq[i] * isq[j]);
            if av != 0.0 {
                for (o, &hv) in or.iter_mut().zip(h.row(j)) {
                    *o += av * hv;
                }
            }
        }
    }
    out
}

fn agg_sum(nbrs: &InNbrs, h: &Mat) -> Mat {
    let n = nbrs.n();
    let mut out = Mat::zeros(n, h.c);
    for i in 0..n {
        let or = &mut out.d[i * h.c..(i + 1) * h.c];
        for &j in nbrs.row(i) {
            // adjacency entries are exactly 1.0: `a * v == v`.
            for (o, &hv) in or.iter_mut().zip(h.row(j as usize)) {
                *o += hv;
            }
        }
    }
    out
}

/// Neighbor mean with the degree clamped to ≥ 1 (the dense reference's
/// `matmul` then row-divide, fused).
fn agg_mean(nbrs: &InNbrs, h: &Mat) -> Mat {
    let mut out = agg_sum(nbrs, h);
    for i in 0..out.r {
        let dv = (nbrs.deg(i) as f32).max(1.0);
        out.row_mut(i).iter_mut().for_each(|v| *v /= dv);
    }
    out
}

/// Elementwise neighbor max/min; isolated rows stay 0.0.
fn agg_minmax(nbrs: &InNbrs, h: &Mat, is_max: bool) -> Mat {
    let n = nbrs.n();
    let mut out = Mat::zeros(n, h.c);
    for i in 0..n {
        let row = nbrs.row(i);
        let Some((&first, rest)) = row.split_first() else {
            continue;
        };
        let or = &mut out.d[i * h.c..(i + 1) * h.c];
        or.copy_from_slice(h.row(first as usize));
        for &j in rest {
            for (o, &hv) in or.iter_mut().zip(h.row(j as usize)) {
                *o = if is_max { o.max(hv) } else { o.min(hv) };
            }
        }
    }
    out
}

/// GIN message sum: `m[u] = Σ_v relu(h[v] + bond(edge_attr[u,v]))`
/// over deduped in-neighbors, each edge carrying the features of its
/// last COO occurrence (the dense last-write-wins contract).
fn agg_edge_relu_sum(nbrs: &InNbrs, g: &CooGraph, h: &Mat, bond: &Dense) -> Mat {
    let n = nbrs.n();
    let d = bond.fout;
    let de = bond.fin;
    let mut out = Mat::zeros(n, d);
    let mut e_row = vec![0.0f32; d];
    for u in 0..n {
        let mr = &mut out.d[u * d..(u + 1) * d];
        for (&v, &ei) in nbrs.row(u).iter().zip(nbrs.row_edges(u)) {
            e_row.copy_from_slice(&bond.b);
            let ei = ei as usize;
            let ea = &g.edge_feat[ei * de..(ei + 1) * de];
            for (k, &ev) in ea.iter().enumerate() {
                if ev != 0.0 {
                    let wr = &bond.w[k * d..(k + 1) * d];
                    for (o, &wv) in e_row.iter_mut().zip(wr) {
                        *o += ev * wv;
                    }
                }
            }
            let hv = h.row(v as usize);
            for j in 0..d {
                // adjacency entry is exactly 1.0: `a * msg == msg`.
                let msg = (hv[j] + e_row[j]).max(0.0);
                mr[j] += msg;
            }
        }
    }
    out
}

/// PNA tower: [mean, std, max, min] × [identity, amplification,
/// attenuation] scalers → `[n, 12·d]`, scalar expressions identical to
/// the dense reference.
fn agg_pna_tower(nbrs: &InNbrs, h: &Mat) -> Mat {
    let n = nbrs.n();
    let d = h.c;
    let avg = avg_log_deg();
    const NEG: f32 = -3.0e38;
    const POS: f32 = 3.0e38;
    let mut out = Mat::zeros(n, 12 * d);
    let mut s = vec![0.0f32; d];
    let mut ss = vec![0.0f32; d];
    let mut mx = vec![0.0f32; d];
    let mut mn = vec![0.0f32; d];
    for i in 0..n {
        s.fill(0.0);
        ss.fill(0.0);
        mx.fill(NEG);
        mn.fill(POS);
        for &j in nbrs.row(i) {
            let hj = h.row(j as usize);
            for k in 0..d {
                let v = hj[k];
                // a == 1.0: `a*v == v` and `a*v*v == v*v` bitwise.
                s[k] += v;
                ss[k] += v * v;
                mx[k] = mx[k].max(v);
                mn[k] = mn[k].min(v);
            }
        }
        let dg = nbrs.deg(i) as f32;
        let dg1 = dg.max(1.0);
        let has = if dg > 0.0 { 1.0 } else { 0.0 };
        let log_deg = (dg + 1.0).ln();
        let amp = log_deg / avg;
        let att = if dg > 0.0 {
            avg / log_deg.max(1e-6)
        } else {
            0.0
        };
        let fr = &mut out.d[i * 12 * d..(i + 1) * 12 * d];
        for k in 0..d {
            let mean = s[k] / dg1;
            let var = (ss[k] / dg1 - mean * mean).max(0.0);
            let std = (var + 1e-8).sqrt() * has;
            let agg = [mean, std, mx[k] * has, mn[k] * has];
            for (b, &v) in agg.iter().enumerate() {
                fr[b * d + k] = v;
                fr[(4 + b) * d + k] = v * amp;
                fr[(8 + b) * d + k] = v * att;
            }
        }
    }
    out
}

/// Per-graph DGN directional context: per row the mean weight
/// `1/max(deg,1)`, the normalized eigen-gradient weights `b_vals`
/// (flat, row-major over the deduped entries), and their row sums.
struct DgnCtx {
    inv: Vec<f32>,
    b_vals: Vec<f32>,
    b_row: Vec<f32>,
}

impl DgnCtx {
    fn build(nbrs: &InNbrs, eig: &[f32]) -> DgnCtx {
        let n = nbrs.n();
        let mut inv = vec![0.0f32; n];
        let mut b_vals = Vec::with_capacity(nbrs.num_entries());
        let mut b_row = vec![0.0f32; n];
        for i in 0..n {
            let dg1 = (nbrs.deg(i) as f32).max(1.0);
            inv[i] = 1.0 / dg1;
            let start = b_vals.len();
            let mut abs_sum = 0.0f32;
            for &j in nbrs.row(i) {
                // adjacency entry 1.0: `a * diff == diff`.
                let fm = eig[j as usize] - eig[i];
                b_vals.push(fm);
                abs_sum += fm.abs();
            }
            let denom = abs_sum + 1e-8;
            let mut row_sum = 0.0f32;
            for bv in &mut b_vals[start..] {
                *bv /= denom;
                row_sum += *bv;
            }
            b_row[i] = row_sum;
        }
        DgnCtx { inv, b_vals, b_row }
    }
}

/// DGN directional pair: `m = [mean ‖ |B·h − b_row∘h|]` → `[n, 2·d]`.
fn agg_dgn_directional(nbrs: &InNbrs, ctx: &DgnCtx, h: &Mat) -> Mat {
    let n = nbrs.n();
    let d = h.c;
    let mut out = Mat::zeros(n, 2 * d);
    let mut bh = vec![0.0f32; d];
    let mut cursor = 0usize;
    for i in 0..n {
        let row = nbrs.row(i);
        let b_vals = &ctx.b_vals[cursor..cursor + row.len()];
        cursor += row.len();
        let yr = &mut out.d[i * 2 * d..(i + 1) * 2 * d];
        let inv = ctx.inv[i];
        for &j in row {
            for (o, &hv) in yr[..d].iter_mut().zip(h.row(j as usize)) {
                *o += inv * hv;
            }
        }
        bh.fill(0.0);
        for (&j, &bv) in row.iter().zip(b_vals) {
            // The dense matmul skips exact-zero entries — so do we.
            if bv != 0.0 {
                for (o, &hv) in bh.iter_mut().zip(h.row(j as usize)) {
                    *o += bv * hv;
                }
            }
        }
        let hr = h.row(i);
        for k in 0..d {
            yr[d + k] = (bh[k] - ctx.b_row[i] * hr[k]).abs();
        }
    }
    out
}

/// GAT layer over the projected features `z`: per-head softmax over
/// neighbors ∪ {self} (ascending, self merged at its sorted position).
/// `n_max` matters: the dense reference's softmax max() runs over
/// padded non-neighbors stamped -1e9, so any row with fewer than
/// `n_max` merged entries seeds its max with -1e9 too.
fn edge_attention(
    nbrs: &InNbrs,
    n_max: usize,
    z: &Mat,
    a_src: &[f32],
    a_dst: &[f32],
    heads: usize,
) -> Mat {
    let n = z.r;
    let d = z.c;
    let fh = d / heads;
    let mut sl = vec![0.0f32; n * heads];
    let mut dl = vec![0.0f32; n * heads];
    for i in 0..n {
        let zr = z.row(i);
        for hh in 0..heads {
            let zs = &zr[hh * fh..(hh + 1) * fh];
            let asr = &a_src[hh * fh..(hh + 1) * fh];
            let ads = &a_dst[hh * fh..(hh + 1) * fh];
            sl[i * heads + hh] = zs.iter().zip(asr).map(|(a, b)| a * b).sum();
            dl[i * heads + hh] = zs.iter().zip(ads).map(|(a, b)| a * b).sum();
        }
    }
    let mut out = Mat::zeros(n, d);
    let mut merged: Vec<u32> = Vec::new();
    let mut logits: Vec<f32> = Vec::new();
    for i in 0..n {
        merged.clear();
        let row = nbrs.row(i);
        match row.binary_search(&(i as u32)) {
            Ok(_) => merged.extend_from_slice(row),
            Err(pos) => {
                merged.extend_from_slice(&row[..pos]);
                merged.push(i as u32);
                merged.extend_from_slice(&row[pos..]);
            }
        }
        for hh in 0..heads {
            logits.clear();
            let mut lmax = f32::NEG_INFINITY;
            for &j in &merged {
                let mut l = sl[i * heads + hh] + dl[j as usize * heads + hh];
                if l <= 0.0 {
                    l *= 0.2;
                }
                logits.push(l);
                lmax = lmax.max(l);
            }
            if merged.len() < n_max {
                lmax = lmax.max(-1.0e9);
            }
            let mut denom = 0.0f32;
            for l in logits.iter_mut() {
                let p = (*l - lmax).exp();
                *l = p;
                denom += p;
            }
            let denom = denom.max(1e-16);
            let or = &mut out.d[i * d + hh * fh..i * d + (hh + 1) * fh];
            for (&j, &p0) in merged.iter().zip(&logits) {
                let p = p0 / denom;
                if p != 0.0 {
                    let zs = &z.row(j as usize)[hh * fh..(hh + 1) * fh];
                    for (o, &zv) in or.iter_mut().zip(zs) {
                        *o += p * zv;
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::params::WInit;
    use crate::models::plan::Readout;

    fn line_graph(n: usize, f_node: usize) -> CooGraph {
        let und: Vec<(u32, u32)> = (0..n.saturating_sub(1))
            .map(|i| (i as u32, (i + 1) as u32))
            .collect();
        CooGraph::from_undirected(
            n,
            &und,
            (0..n * f_node).map(|i| (i % 7) as f32 - 3.0).collect(),
            f_node,
            &[],
            0,
        )
        .unwrap()
    }

    fn tiny_plan() -> ModelPlan {
        let mut wi = WInit::new(3);
        ModelPlan {
            model: "tiny".into(),
            n_max: 8,
            in_dim: 4,
            out_dim: 2,
            edge_dim: 0,
            node_level: false,
            vn_init: None,
            stages: vec![
                Stage::Linear {
                    w: wi.dense(4, 6),
                    act: Act::Relu,
                },
                Stage::SparseAggregate(Aggregate::GcnNorm),
                Stage::TakeAggregate,
                Stage::Readout(Readout::MaskedMeanPool),
                Stage::Linear {
                    w: wi.dense(6, 2),
                    act: Act::None,
                },
            ],
        }
    }

    #[test]
    fn executes_and_is_deterministic() {
        let plan = tiny_plan();
        let g = line_graph(5, 4);
        let a = execute(&plan, &g, None).unwrap();
        let b = execute(&plan, &g, None).unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(a, b);
        assert!(a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn input_contract_is_enforced() {
        let plan = tiny_plan();
        let big = line_graph(9, 4);
        assert!(execute(&plan, &big, None).is_err(), "capacity");
        let narrow = line_graph(4, 3);
        assert!(execute(&plan, &narrow, None).is_err(), "feature width");
    }

    #[test]
    fn sum_mean_max_min_components() {
        // Node features: node i carries [i+1]. Graph 0-1-2 (undirected).
        let g = CooGraph::from_undirected(
            3,
            &[(0, 1), (1, 2)],
            vec![1.0, 2.0, 3.0],
            1,
            &[],
            0,
        )
        .unwrap();
        let h = [1.0f32, 2.0, 3.0];
        let sum = run_aggregate(&Aggregate::Sum, &g, &h, 1, None).unwrap();
        assert_eq!(sum, vec![2.0, 4.0, 2.0]);
        let mean = run_aggregate(&Aggregate::Mean, &g, &h, 1, None).unwrap();
        assert_eq!(mean, vec![2.0, 2.0, 2.0]);
        let max = run_aggregate(&Aggregate::Max, &g, &h, 1, None).unwrap();
        assert_eq!(max, vec![2.0, 3.0, 2.0]);
        let min = run_aggregate(&Aggregate::Min, &g, &h, 1, None).unwrap();
        assert_eq!(min, vec![2.0, 1.0, 2.0]);
    }

    #[test]
    fn isolated_nodes_aggregate_to_zero() {
        let g = CooGraph {
            n: 3,
            edges: vec![(0, 1)],
            node_feat: vec![5.0, -7.0, 9.0],
            f_node: 1,
            edge_feat: vec![],
            f_edge: 0,
        };
        for agg in [Aggregate::Sum, Aggregate::Mean, Aggregate::Max, Aggregate::Min] {
            let out = run_aggregate(&agg, &g, &[5.0, -7.0, 9.0], 1, None).unwrap();
            assert_eq!(out[0], 0.0, "{agg:?} row 0 has no in-edges");
            assert_eq!(out[2], 0.0, "{agg:?} row 2 isolated");
        }
    }

    #[test]
    fn merged_row_inserts_diagonal_in_order() {
        let g = CooGraph {
            n: 4,
            edges: vec![(0, 2), (3, 2), (2, 2), (1, 0)],
            node_feat: vec![0.0; 4],
            f_node: 1,
            edge_feat: vec![],
            f_edge: 0,
        };
        let nbrs = InNbrs::from_coo(&g);
        // Row 2 has in-nbrs {0, 2, 3}; diagonal (self-edge) carries 2.0.
        let walk: Vec<(usize, f32)> = MergedRow::new(&nbrs, 2).collect();
        assert_eq!(walk, vec![(0, 1.0), (2, 2.0), (3, 1.0)]);
        // Row 0 has in-nbr {1}; synthetic diagonal first (0 < 1).
        let walk: Vec<(usize, f32)> = MergedRow::new(&nbrs, 0).collect();
        assert_eq!(walk, vec![(0, 1.0), (1, 1.0)]);
        // Row 3 has no in-nbrs; only the synthetic diagonal.
        let walk: Vec<(usize, f32)> = MergedRow::new(&nbrs, 3).collect();
        assert_eq!(walk, vec![(3, 1.0)]);
    }

    fn ingest_all(graphs: &[CooGraph]) -> Vec<crate::graph::GraphBatch> {
        graphs
            .iter()
            .map(|g| crate::graph::GraphBatch::ingest(g.clone()).unwrap())
            .collect()
    }

    fn fuse_all(batches: &[crate::graph::GraphBatch]) -> FusedBatch {
        let parts: Vec<&crate::graph::GraphBatch> = batches.iter().collect();
        FusedBatch::fuse(&parts).unwrap()
    }

    #[test]
    fn fused_execution_matches_per_graph_execution() {
        let plan = tiny_plan();
        // Mixed sizes, including a single-node graph (pool denom 1).
        let graphs = [line_graph(5, 4), line_graph(1, 4), line_graph(3, 4)];
        let batches = ingest_all(&graphs);
        let outs = execute_fused(&plan, &fuse_all(&batches), None).unwrap();
        assert_eq!(outs.len(), graphs.len());
        for (g, out) in graphs.iter().zip(&outs) {
            assert_eq!(*out, execute(&plan, g, None).unwrap());
        }
    }

    #[test]
    fn fused_virtual_node_state_is_per_graph() {
        // VN plan: the per-graph virtual-node state must not bleed
        // across segments (a shared accumulator would).
        let mut wi = WInit::new(9);
        let plan = ModelPlan {
            model: "tiny_vn".into(),
            n_max: 8,
            in_dim: 4,
            out_dim: 2,
            edge_dim: 0,
            node_level: false,
            vn_init: Some(vec![0.25; 6]),
            stages: vec![
                Stage::Linear {
                    w: wi.dense(4, 6),
                    act: Act::Relu,
                },
                Stage::VirtualNodeAdd,
                Stage::SparseAggregate(Aggregate::Sum),
                Stage::TakeAggregate,
                Stage::VirtualNodeUpdate {
                    w1: wi.dense(6, 6),
                    w2: wi.dense(6, 6),
                },
                Stage::VirtualNodeAdd,
                Stage::Readout(Readout::MaskedMeanPool),
                Stage::Linear {
                    w: wi.dense(6, 2),
                    act: Act::None,
                },
            ],
        };
        plan.validate().unwrap();
        let graphs = [line_graph(4, 4), line_graph(6, 4), line_graph(2, 4)];
        let batches = ingest_all(&graphs);
        let outs = execute_fused(&plan, &fuse_all(&batches), None).unwrap();
        for (g, out) in graphs.iter().zip(&outs) {
            assert_eq!(*out, execute(&plan, g, None).unwrap());
        }
    }

    #[test]
    fn fused_handles_empty_segments() {
        let plan = tiny_plan();
        let empty = CooGraph {
            n: 0,
            edges: vec![],
            node_feat: vec![],
            f_node: 4,
            edge_feat: vec![],
            f_edge: 0,
        };
        let graphs = [line_graph(3, 4), empty.clone(), line_graph(2, 4)];
        let batches = ingest_all(&graphs);
        let outs = execute_fused(&plan, &fuse_all(&batches), None).unwrap();
        assert_eq!(outs[1], execute(&plan, &empty, None).unwrap());
        assert_eq!(outs[0], execute(&plan, &graphs[0], None).unwrap());
        assert_eq!(outs[2], execute(&plan, &graphs[2], None).unwrap());
    }

    #[test]
    fn fused_enforces_per_segment_capacity() {
        let plan = tiny_plan(); // n_max = 8
        let graphs = [line_graph(3, 4), line_graph(9, 4)];
        let batches = ingest_all(&graphs);
        let err = execute_fused(&plan, &fuse_all(&batches), None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("exceeds capacity"), "{err}");
    }

    #[test]
    fn missing_eig_is_a_clean_error() {
        let mut plan = tiny_plan();
        plan.stages[1] = Stage::SparseAggregate(Aggregate::DgnDirectional);
        // (invalid widths aside, the eig check fires first)
        let g = line_graph(3, 4);
        let err = execute(&plan, &g, None).unwrap_err().to_string();
        assert!(err.contains("eig"), "{err}");
    }
}

//! Pooled float32 matrix primitives shared by the stage-IR interpreter
//! ([`super::interp`]) and the dense reference executor
//! ([`super::dense_ref`]).
//!
//! Sharing matters for more than code size: the bit-exactness contract
//! between the two executors holds because every per-row primitive
//! (`linear`, activation, pooling) is literally the same code on both
//! sides — only the neighborhood aggregation differs, and there the
//! ascending-neighbor iteration order is pinned by
//! `python/tools/plan_replica.py`.
//!
//! Hot-loop temporaries ([`Mat`]) draw their storage from the
//! per-thread scratch pool in [`crate::util::pool`] and return it on
//! drop, so an executor lane running forward after forward recycles the
//! same allocations instead of hitting the allocator per request (the
//! software analog of statically-allocated on-chip buffers). Buffers
//! are fully re-initialized on take, so pooling can never change an
//! output bit.

use crate::models::params::Dense;
use crate::models::plan::Act;
use crate::util::pool::{scratch_put, scratch_take_copied, scratch_take_zeroed};

/// Row-major `[r, c]` float32 matrix. Storage comes from the calling
/// thread's scratch pool and is returned on drop; [`Mat::into_vec`]
/// lets a result escape the pool (model outputs).
#[derive(Debug)]
pub(crate) struct Mat {
    pub r: usize,
    pub c: usize,
    pub d: Vec<f32>,
}

impl Mat {
    pub fn zeros(r: usize, c: usize) -> Mat {
        Mat {
            r,
            c,
            d: scratch_take_zeroed(r * c),
        }
    }

    pub fn from_slice(r: usize, c: usize, d: &[f32]) -> Mat {
        debug_assert_eq!(d.len(), r * c);
        Mat {
            r,
            c,
            d: scratch_take_copied(d),
        }
    }

    /// Take the backing buffer out of the pool's reach (for outputs
    /// that outlive the forward pass). An output much smaller than the
    /// recycled buffer backing it is copied out instead, so responses
    /// never pin a large pooled allocation.
    pub fn into_vec(mut self) -> Vec<f32> {
        let d = std::mem::take(&mut self.d);
        if d.capacity() > 2 * d.len().max(32) {
            let out = d.to_vec();
            scratch_put(d);
            return out;
        }
        d
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.d[i * self.c..(i + 1) * self.c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.d[i * self.c..(i + 1) * self.c]
    }

    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.d[i * self.c + j]
    }
}

impl Clone for Mat {
    fn clone(&self) -> Mat {
        Mat {
            r: self.r,
            c: self.c,
            d: scratch_take_copied(&self.d),
        }
    }
}

impl Drop for Mat {
    fn drop(&mut self) {
        // `into_vec` leaves an empty, zero-capacity Vec behind, which
        // the pool ignores.
        scratch_put(std::mem::take(&mut self.d));
    }
}

/// `x @ w + b` with optional activation (`model.py linear`).
pub(crate) fn linear(x: &Mat, l: &Dense, act: Act) -> Mat {
    debug_assert_eq!(x.c, l.fin);
    let mut out = Mat::zeros(x.r, l.fout);
    for i in 0..x.r {
        let xr = x.row(i);
        let or = &mut out.d[i * l.fout..(i + 1) * l.fout];
        or.copy_from_slice(&l.b);
        for (k, &xv) in xr.iter().enumerate() {
            if xv != 0.0 {
                let wr = &l.w[k * l.fout..(k + 1) * l.fout];
                for (o, &wv) in or.iter_mut().zip(wr) {
                    *o += xv * wv;
                }
            }
        }
        apply_act_slice(or, act);
    }
    out
}

/// Plain `a @ b` (dense reference only — the sparse interpreter never
/// materializes an adjacency matrix).
pub(crate) fn matmul(a: &Mat, b: &Mat) -> Mat {
    debug_assert_eq!(a.c, b.r);
    let mut out = Mat::zeros(a.r, b.c);
    for i in 0..a.r {
        let or = &mut out.d[i * b.c..(i + 1) * b.c];
        for k in 0..a.c {
            let av = a.at(i, k);
            if av != 0.0 {
                let br = b.row(k);
                for (o, &bv) in or.iter_mut().zip(br) {
                    *o += av * bv;
                }
            }
        }
    }
    out
}

fn apply_act_slice(s: &mut [f32], act: Act) {
    match act {
        Act::None => {}
        Act::Relu => s.iter_mut().for_each(|v| *v = v.max(0.0)),
        Act::Elu => s.iter_mut().for_each(|v| {
            if *v <= 0.0 {
                *v = v.exp_m1();
            }
        }),
    }
}

pub(crate) fn apply_act(m: &mut Mat, act: Act) {
    apply_act_slice(&mut m.d, act);
}

/// Multiply non-real rows down to zero (dense reference only — the
/// sparse interpreter holds real rows exclusively).
pub(crate) fn mask_rows(m: &mut Mat, mask: &[f32]) {
    for i in 0..m.r {
        let mk = mask[i];
        if mk != 1.0 {
            m.d[i * m.c..(i + 1) * m.c].iter_mut().for_each(|v| *v *= mk);
        }
    }
}

/// Masked mean pool -> `[1, c]` (`model.py masked_mean_pool`).
pub(crate) fn masked_mean_pool(h: &Mat, mask: &[f32]) -> Mat {
    let denom = mask.iter().sum::<f32>().max(1.0);
    let mut out = Mat::zeros(1, h.c);
    for i in 0..h.r {
        let mk = mask[i];
        if mk != 0.0 {
            for (o, &v) in out.d.iter_mut().zip(h.row(i)) {
                *o += v * mk;
            }
        }
    }
    out.d.iter_mut().for_each(|v| *v /= denom);
    out
}

/// Row-wise L2 normalization (GraphSAGE).
pub(crate) fn l2_normalize_rows(h: &mut Mat) {
    for i in 0..h.r {
        let row = &mut h.d[i * h.c..(i + 1) * h.c];
        let norm = row.iter().map(|v| v * v).sum::<f32>().sqrt();
        let div = norm.max(1e-6);
        row.iter_mut().for_each(|v| *v /= div);
    }
}

/// `ln(1 + 2.15)` — mean degree constant of the PNA scalers, computed
/// in f64 exactly as `model.py` does.
pub(crate) fn avg_log_deg() -> f32 {
    (1.0f64 + 2.15f64).ln() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::params::WInit;

    #[test]
    fn linear_matches_manual_matmul() {
        let mut wi = WInit::new(1);
        let l = wi.dense(3, 2);
        let x = Mat::from_slice(2, 3, &[1.0, 0.0, 2.0, -1.0, 0.5, 0.0]);
        let out = linear(&x, &l, Act::None);
        for i in 0..2 {
            for j in 0..2 {
                let mut want = l.b[j];
                for k in 0..3 {
                    let xv = x.at(i, k);
                    if xv != 0.0 {
                        want += xv * l.w[k * 2 + j];
                    }
                }
                assert_eq!(out.at(i, j), want);
            }
        }
    }

    #[test]
    fn activations() {
        let mut m = Mat::from_slice(1, 3, &[-1.0, 0.0, 2.0]);
        apply_act(&mut m, Act::Relu);
        assert_eq!(m.d, vec![0.0, 0.0, 2.0]);
        let mut m = Mat::from_slice(1, 2, &[-1.0, 2.0]);
        apply_act(&mut m, Act::Elu);
        assert_eq!(m.d, vec![(-1.0f32).exp_m1(), 2.0]);
    }

    #[test]
    fn pool_divides_by_live_count() {
        let h = Mat::from_slice(3, 2, &[2.0, 4.0, 4.0, 8.0, 9.0, 9.0]);
        let p = masked_mean_pool(&h, &[1.0, 1.0, 0.0]);
        assert_eq!(p.d, vec![3.0, 6.0]);
    }
}

//! The legacy dense-matmul forward passes, preserved verbatim as the
//! **reference executor** — no serving path reaches this module.
//!
//! Until the stage-IR redesign, these monolithic per-model forwards
//! *were* the native backend: every request densified its graph into an
//! O(n_max²) padded adjacency ([`crate::graph::DenseGraph`]) and ran
//! one of seven hand-written `fwd_*` bodies. The serving path now
//! executes lowered [`crate::models::ModelPlan`]s through the sparse
//! interpreter ([`super::interp`]); this module remains for exactly two
//! consumers:
//!
//! * the bit-exactness property tests (`tests/plan_equivalence.rs`),
//!   which pin the interpreter to these loops bit-for-bit, and
//! * the `plan_vs_legacy` micro benches, which track the speedup of
//!   sparse plan execution over the dense reference.
//!
//! It mirrors `python/compile/native_ref.py` (the cross-language spec
//! pinned to the JAX models) operation-for-operation, with the same
//! seeded weights the AOT artifacts bake in.

use anyhow::{bail, Result};

use crate::graph::DenseGraph;
use crate::models::params::{Dense, WInit};
use crate::models::plan::Act;

use super::artifact::ModelMeta;
use super::tensor::{
    apply_act, avg_log_deg, linear, mask_rows, masked_mean_pool, matmul, Mat,
};

const EPS_GIN: f32 = 0.1;

/// Symmetric GCN normalization `D^-1/2 (A + diag(mask)) D^-1/2`.
fn gcn_norm_adj(adj: &Mat, mask: &[f32]) -> Mat {
    let n = adj.r;
    let mut a_hat = adj.clone();
    for i in 0..n {
        a_hat.d[i * n + i] += mask[i];
    }
    let mut inv_sqrt = vec![0.0f32; n];
    for i in 0..n {
        let deg: f32 = a_hat.row(i).iter().sum();
        if deg > 0.0 {
            inv_sqrt[i] = 1.0 / deg.max(1e-12).sqrt();
        }
    }
    for i in 0..n {
        for j in 0..n {
            a_hat.d[i * n + j] *= inv_sqrt[i] * inv_sqrt[j];
        }
    }
    a_hat
}

/// Which reference forward to run (resolved from the manifest name).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum RefKind {
    Gcn,
    Gin { virtual_node: bool },
    Gat,
    Pna,
    Sgc,
    Sage,
    Dgn,
}

fn kind_of(name: &str) -> Result<RefKind> {
    Ok(match name {
        "gcn" => RefKind::Gcn,
        "gin" => RefKind::Gin {
            virtual_node: false,
        },
        "gin_vn" => RefKind::Gin { virtual_node: true },
        "gat" => RefKind::Gat,
        "pna" => RefKind::Pna,
        "sgc" => RefKind::Sgc,
        "sage" => RefKind::Sage,
        "dgn" | "dgn_large" => RefKind::Dgn,
        _ => bail!("dense reference has no forward pass for model {name:?}"),
    })
}

enum Weights {
    Gcn {
        embed: Dense,
        convs: Vec<Dense>,
        head: Dense,
    },
    Gin {
        embed: Dense,
        bond: Vec<Dense>,
        mlps: Vec<(Dense, Dense)>,
        head: Dense,
        /// `(vn0, vn_mlps)` for GIN+VN.
        vn: Option<(Vec<f32>, Vec<(Dense, Dense)>)>,
    },
    Gat {
        embed: Dense,
        /// Per layer: projection + per-head (a_src, a_dst) vectors.
        convs: Vec<(Dense, Vec<f32>, Vec<f32>)>,
        head: Dense,
    },
    Pna {
        embed: Dense,
        convs: Vec<Dense>,
        head: [Dense; 3],
    },
    Sgc {
        w: Dense,
        head: Dense,
    },
    Sage {
        embed: Dense,
        convs: Vec<(Dense, Dense)>,
        head: Dense,
    },
    Dgn {
        embed: Dense,
        convs: Vec<Dense>,
        head: [Dense; 3],
    },
}

/// The dense reference model: resolved kind, manifest dims, and the
/// regenerated baked-in weights.
pub struct DenseRef {
    kind: RefKind,
    layers: usize,
    dim: usize,
    heads: usize,
    out_dim: usize,
    node_level: bool,
    edge_dim: usize,
    weights: Weights,
}

impl DenseRef {
    /// Rebuild the model's weights from the manifest entry and the
    /// artifact weight seed (same draw order as `model.py`'s builders).
    pub fn build(meta: &ModelMeta, weight_seed: u64) -> Result<DenseRef> {
        if weight_seed > u32::MAX as u64 {
            bail!("weight_seed {weight_seed} exceeds the scalar MT19937 seeding range");
        }
        let kind = kind_of(&meta.name)?;
        let d = meta.dim;
        if d == 0 || meta.layers == 0 {
            bail!("model {:?} has degenerate dims", meta.name);
        }
        let edge_dim = meta
            .inputs
            .iter()
            .find(|i| i.name == "edge_attr")
            .map(|i| *i.shape.last().unwrap_or(&0))
            .unwrap_or(0);
        let mut wi = WInit::new(weight_seed as u32);
        let weights = match kind {
            RefKind::Gcn => Weights::Gcn {
                embed: wi.dense(meta.in_dim, d),
                convs: (0..meta.layers).map(|_| wi.dense(d, d)).collect(),
                head: wi.dense(d, meta.out_dim),
            },
            RefKind::Gin { virtual_node } => {
                if edge_dim == 0 {
                    bail!("GIN artifact {:?} lists no edge_attr input", meta.name);
                }
                let embed = wi.dense(meta.in_dim, d);
                let bond: Vec<Dense> =
                    (0..meta.layers).map(|_| wi.dense(edge_dim, d)).collect();
                let mlps: Vec<(Dense, Dense)> = (0..meta.layers)
                    .map(|_| (wi.dense(d, 2 * d), wi.dense(2 * d, d)))
                    .collect();
                let head = wi.dense(d, meta.out_dim);
                let vn = if virtual_node {
                    let vn0 = wi.vec(d);
                    let vn_mlps = (0..meta.layers - 1)
                        .map(|_| (wi.dense(d, 2 * d), wi.dense(2 * d, d)))
                        .collect();
                    Some((vn0, vn_mlps))
                } else {
                    None
                };
                Weights::Gin {
                    embed,
                    bond,
                    mlps,
                    head,
                    vn,
                }
            }
            RefKind::Gat => {
                if meta.heads == 0 || d % meta.heads != 0 {
                    bail!(
                        "GAT artifact {:?}: dim {} not divisible by heads {}",
                        meta.name,
                        d,
                        meta.heads
                    );
                }
                let embed = wi.dense(meta.in_dim, d);
                let convs = (0..meta.layers)
                    .map(|_| {
                        let w = wi.dense(d, d);
                        let a_src = wi.vec(d);
                        let a_dst = wi.vec(d);
                        (w, a_src, a_dst)
                    })
                    .collect();
                Weights::Gat {
                    embed,
                    convs,
                    head: wi.dense(d, meta.out_dim),
                }
            }
            RefKind::Pna => Weights::Pna {
                embed: wi.dense(meta.in_dim, d),
                convs: (0..meta.layers).map(|_| wi.dense(12 * d, d)).collect(),
                head: [
                    wi.dense(d, d / 2),
                    wi.dense(d / 2, d / 4),
                    wi.dense(d / 4, meta.out_dim),
                ],
            },
            RefKind::Sgc => Weights::Sgc {
                w: wi.dense(meta.in_dim, d),
                head: wi.dense(d, meta.out_dim),
            },
            RefKind::Sage => Weights::Sage {
                embed: wi.dense(meta.in_dim, d),
                convs: (0..meta.layers)
                    .map(|_| (wi.dense(d, d), wi.dense(d, d)))
                    .collect(),
                head: wi.dense(d, meta.out_dim),
            },
            RefKind::Dgn => Weights::Dgn {
                embed: wi.dense(meta.in_dim, d),
                convs: (0..meta.layers).map(|_| wi.dense(2 * d, d)).collect(),
                head: [
                    wi.dense(d, d / 2),
                    wi.dense(d / 2, d / 4),
                    wi.dense(d / 4, meta.out_dim),
                ],
            },
        };
        Ok(DenseRef {
            kind,
            layers: meta.layers,
            dim: d,
            heads: meta.heads,
            out_dim: meta.out_dim,
            node_level: meta.node_level,
            edge_dim,
            weights,
        })
    }

    /// Run the forward pass over staged dense tensors. Graph-level
    /// models return `[out_dim]`; node-level `[n_max * out_dim]`.
    pub fn forward(&self, dense: &DenseGraph) -> Result<Vec<f32>> {
        let n = dense.n_max;
        let x = Mat::from_slice(n, dense.f_node, &dense.x);
        let adj = Mat::from_slice(n, n, &dense.adj);
        let mask = &dense.mask;
        let out = match (&self.kind, &self.weights) {
            (RefKind::Gcn, Weights::Gcn { embed, convs, head }) => {
                self.fwd_gcn(&x, &adj, mask, embed, convs, head)
            }
            (RefKind::Sgc, Weights::Sgc { w, head }) => {
                self.fwd_sgc(&x, &adj, mask, w, head)
            }
            (
                RefKind::Gin { .. },
                Weights::Gin {
                    embed,
                    bond,
                    mlps,
                    head,
                    vn,
                },
            ) => {
                if self.edge_dim == 0 || dense.f_edge != self.edge_dim {
                    bail!(
                        "GIN forward needs {}-wide edge features, staged {}",
                        self.edge_dim,
                        dense.f_edge
                    );
                }
                self.fwd_gin(&x, &adj, dense, mask, embed, bond, mlps, head, vn.as_ref())
            }
            (RefKind::Gat, Weights::Gat { embed, convs, head }) => {
                self.fwd_gat(&x, &adj, mask, embed, convs, head)
            }
            (RefKind::Pna, Weights::Pna { embed, convs, head }) => {
                self.fwd_pna(&x, &adj, mask, embed, convs, head)
            }
            (RefKind::Sage, Weights::Sage { embed, convs, head }) => {
                self.fwd_sage(&x, &adj, mask, embed, convs, head)
            }
            (RefKind::Dgn, Weights::Dgn { embed, convs, head }) => {
                self.fwd_dgn(&x, &adj, &dense.eig, mask, embed, convs, head)
            }
            _ => bail!("dense reference weight/kind mismatch"),
        };
        Ok(out)
    }

    fn fwd_gcn(
        &self,
        x: &Mat,
        adj: &Mat,
        mask: &[f32],
        embed: &Dense,
        convs: &[Dense],
        head: &Dense,
    ) -> Vec<f32> {
        let a_norm = gcn_norm_adj(adj, mask);
        let mut h = linear(x, embed, Act::Relu);
        for (li, conv) in convs.iter().enumerate() {
            let hw = linear(&h, conv, Act::None);
            h = matmul(&a_norm, &hw);
            if li + 1 < convs.len() {
                apply_act(&mut h, Act::Relu);
            }
        }
        mask_rows(&mut h, mask);
        if self.node_level {
            linear(&h, head, Act::None).into_vec()
        } else {
            linear(&masked_mean_pool(&h, mask), head, Act::None).into_vec()
        }
    }

    fn fwd_sgc(&self, x: &Mat, adj: &Mat, mask: &[f32], w: &Dense, head: &Dense) -> Vec<f32> {
        let a_norm = gcn_norm_adj(adj, mask);
        let mut h = x.clone();
        for _ in 0..self.layers {
            h = matmul(&a_norm, &h);
        }
        let mut h = linear(&h, w, Act::Relu);
        mask_rows(&mut h, mask);
        if self.node_level {
            linear(&h, head, Act::None).into_vec()
        } else {
            linear(&masked_mean_pool(&h, mask), head, Act::None).into_vec()
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn fwd_gin(
        &self,
        x: &Mat,
        adj: &Mat,
        dense: &DenseGraph,
        mask: &[f32],
        embed: &Dense,
        bond: &[Dense],
        mlps: &[(Dense, Dense)],
        head: &Dense,
        vn: Option<&(Vec<f32>, Vec<(Dense, Dense)>)>,
    ) -> Vec<f32> {
        let n = adj.r;
        let d = self.dim;
        let de = self.edge_dim;
        let mut h = linear(x, embed, Act::Relu);
        let mut vn_state: Option<Vec<f32>> = vn.map(|(vn0, _)| vn0.clone());
        for li in 0..self.layers {
            if let Some(vn_vec) = &vn_state {
                for i in 0..n {
                    let mk = mask[i];
                    if mk != 0.0 {
                        let hr = &mut h.d[i * d..(i + 1) * d];
                        for (hv, &vv) in hr.iter_mut().zip(vn_vec) {
                            *hv += vv * mk;
                        }
                    }
                }
            }
            // Edge embedding + merged scatter-gather:
            //   m[u] = sum_v adj[u,v] * relu(h[v] + (edge_attr[u,v] @ We + be))
            let bl = &bond[li];
            let mut m = Mat::zeros(n, d);
            let mut e_row = vec![0.0f32; d];
            for u in 0..n {
                let mr = &mut m.d[u * d..(u + 1) * d];
                for v in 0..n {
                    let a = adj.at(u, v);
                    if a == 0.0 {
                        continue;
                    }
                    e_row.copy_from_slice(&bl.b);
                    let ea = &dense.edge_attr[(u * n + v) * de..(u * n + v + 1) * de];
                    for (k, &ev) in ea.iter().enumerate() {
                        if ev != 0.0 {
                            let wr = &bl.w[k * d..(k + 1) * d];
                            for (o, &wv) in e_row.iter_mut().zip(wr) {
                                *o += ev * wv;
                            }
                        }
                    }
                    let hv = h.row(v);
                    for j in 0..d {
                        let msg = (hv[j] + e_row[j]).max(0.0);
                        mr[j] += a * msg;
                    }
                }
            }
            // (1 + eps) x + m through the 2-layer MLP.
            let mut z = Mat::zeros(n, d);
            for i in 0..n * d {
                z.d[i] = (1.0 + EPS_GIN) * h.d[i] + m.d[i];
            }
            let (w1, w2) = &mlps[li];
            h = linear(&linear(&z, w1, Act::Relu), w2, Act::Relu);
            mask_rows(&mut h, mask);
            if let Some(vn_vec) = &mut vn_state {
                if li + 1 < self.layers {
                    let (_, vn_mlps) = vn.unwrap();
                    let mut g = Mat::zeros(1, d);
                    g.d.copy_from_slice(vn_vec);
                    for i in 0..n {
                        let mk = mask[i];
                        if mk != 0.0 {
                            for (gv, &hv) in g.d.iter_mut().zip(h.row(i)) {
                                *gv += hv * mk;
                            }
                        }
                    }
                    let (w1, w2) = &vn_mlps[li];
                    let updated = linear(&linear(&g, w1, Act::Relu), w2, Act::Relu);
                    vn_vec.copy_from_slice(&updated.d);
                }
            }
        }
        linear(&masked_mean_pool(&h, mask), head, Act::None).into_vec()
    }

    fn fwd_gat(
        &self,
        x: &Mat,
        adj: &Mat,
        mask: &[f32],
        embed: &Dense,
        convs: &[(Dense, Vec<f32>, Vec<f32>)],
        head: &Dense,
    ) -> Vec<f32> {
        let n = adj.r;
        let d = self.dim;
        let heads = self.heads;
        let fh = d / heads;
        // Self-loops on real nodes: adj_sl = max(adj, diag(mask)).
        let mut adj_sl = adj.clone();
        for i in 0..n {
            let v = adj_sl.at(i, i).max(mask[i]);
            adj_sl.d[i * n + i] = v;
        }
        let mut h = linear(x, embed, Act::Relu);
        for (li, (w, a_src, a_dst)) in convs.iter().enumerate() {
            let z = linear(&h, w, Act::None); // [n, d] = [n, heads*fh]
            // Per-node, per-head logit dot products.
            let mut sl = vec![0.0f32; n * heads];
            let mut dl = vec![0.0f32; n * heads];
            for i in 0..n {
                let zr = z.row(i);
                for hh in 0..heads {
                    let zs = &zr[hh * fh..(hh + 1) * fh];
                    let asr = &a_src[hh * fh..(hh + 1) * fh];
                    let ads = &a_dst[hh * fh..(hh + 1) * fh];
                    sl[i * heads + hh] = zs.iter().zip(asr).map(|(a, b)| a * b).sum();
                    dl[i * heads + hh] = zs.iter().zip(ads).map(|(a, b)| a * b).sum();
                }
            }
            let mut out = Mat::zeros(n, d);
            let mut logits = vec![0.0f32; n];
            for hh in 0..heads {
                for i in 0..n {
                    // LeakyReLU(sl_i + dl_j), masked to the neighborhood.
                    let mut lmax = f32::NEG_INFINITY;
                    for j in 0..n {
                        let mut l = sl[i * heads + hh] + dl[j * heads + hh];
                        if l <= 0.0 {
                            l *= 0.2;
                        }
                        if adj_sl.at(i, j) <= 0.0 {
                            l = -1.0e9;
                        }
                        logits[j] = l;
                        lmax = lmax.max(l);
                    }
                    let mut denom = 0.0f32;
                    for (j, l) in logits.iter_mut().enumerate() {
                        let p = if adj_sl.at(i, j) > 0.0 {
                            (*l - lmax).exp()
                        } else {
                            0.0
                        };
                        *l = p;
                        denom += p;
                    }
                    let denom = denom.max(1e-16);
                    let or = &mut out.d[i * d + hh * fh..i * d + (hh + 1) * fh];
                    for j in 0..n {
                        let p = logits[j] / denom;
                        if p != 0.0 {
                            let zs = &z.row(j)[hh * fh..(hh + 1) * fh];
                            for (o, &zv) in or.iter_mut().zip(zs) {
                                *o += p * zv;
                            }
                        }
                    }
                }
            }
            h = out;
            if li + 1 < convs.len() {
                apply_act(&mut h, Act::Elu);
            }
            mask_rows(&mut h, mask);
        }
        linear(&masked_mean_pool(&h, mask), head, Act::None).into_vec()
    }

    fn fwd_pna(
        &self,
        x: &Mat,
        adj: &Mat,
        mask: &[f32],
        embed: &Dense,
        convs: &[Dense],
        head: &[Dense; 3],
    ) -> Vec<f32> {
        let n = adj.r;
        let d = self.dim;
        let mut h = linear(x, embed, Act::Relu);
        let deg: Vec<f32> = (0..n).map(|i| adj.row(i).iter().sum()).collect();
        let avg = avg_log_deg();
        const NEG: f32 = -3.0e38;
        const POS: f32 = 3.0e38;
        for conv in convs {
            // Four aggregators (sum, sumsq, max, min) over the neighborhood.
            let mut full = Mat::zeros(n, 12 * d);
            for i in 0..n {
                let mut s = vec![0.0f32; d];
                let mut ss = vec![0.0f32; d];
                let mut mx = vec![NEG; d];
                let mut mn = vec![POS; d];
                for j in 0..n {
                    let a = adj.at(i, j);
                    if a == 0.0 {
                        continue;
                    }
                    let hj = h.row(j);
                    for k in 0..d {
                        let v = hj[k];
                        s[k] += a * v;
                        ss[k] += a * v * v;
                        mx[k] = mx[k].max(v);
                        mn[k] = mn[k].min(v);
                    }
                }
                let dg = deg[i];
                let dg1 = dg.max(1.0);
                let has = if dg > 0.0 { 1.0 } else { 0.0 };
                let log_deg = (dg + 1.0).ln();
                let amp = log_deg / avg;
                let att = if dg > 0.0 {
                    avg / log_deg.max(1e-6)
                } else {
                    0.0
                };
                let fr = &mut full.d[i * 12 * d..(i + 1) * 12 * d];
                for k in 0..d {
                    let mean = s[k] / dg1;
                    let var = (ss[k] / dg1 - mean * mean).max(0.0);
                    let std = (var + 1e-8).sqrt() * has;
                    // agg = [mean, std, max, min], then scaled copies.
                    let agg = [mean, std, mx[k] * has, mn[k] * has];
                    for (b, &v) in agg.iter().enumerate() {
                        fr[b * d + k] = v;
                        fr[(4 + b) * d + k] = v * amp;
                        fr[(8 + b) * d + k] = v * att;
                    }
                }
            }
            let up = linear(&full, conv, Act::Relu);
            for i in 0..n * d {
                h.d[i] = up.d[i] + h.d[i];
            }
            mask_rows(&mut h, mask);
        }
        let mut p = masked_mean_pool(&h, mask);
        p = linear(&p, &head[0], Act::Relu);
        p = linear(&p, &head[1], Act::Relu);
        linear(&p, &head[2], Act::None).into_vec()
    }

    fn fwd_sage(
        &self,
        x: &Mat,
        adj: &Mat,
        mask: &[f32],
        embed: &Dense,
        convs: &[(Dense, Dense)],
        head: &Dense,
    ) -> Vec<f32> {
        let n = adj.r;
        let d = self.dim;
        let deg1: Vec<f32> = (0..n)
            .map(|i| adj.row(i).iter().sum::<f32>().max(1.0))
            .collect();
        let mut h = linear(x, embed, Act::Relu);
        for (li, (w_self, w_nbr)) in convs.iter().enumerate() {
            let mut mean_nbr = matmul(adj, &h);
            for i in 0..n {
                let dv = deg1[i];
                mean_nbr.d[i * d..(i + 1) * d]
                    .iter_mut()
                    .for_each(|v| *v /= dv);
            }
            let hs = linear(&h, w_self, Act::None);
            let hn = linear(&mean_nbr, w_nbr, Act::None);
            for i in 0..n * d {
                h.d[i] = hs.d[i] + hn.d[i];
            }
            if li + 1 < convs.len() {
                apply_act(&mut h, Act::Relu);
            }
            // Row-wise L2 normalization (GraphSage).
            super::tensor::l2_normalize_rows(&mut h);
            mask_rows(&mut h, mask);
        }
        linear(&masked_mean_pool(&h, mask), head, Act::None).into_vec()
    }

    #[allow(clippy::too_many_arguments)]
    fn fwd_dgn(
        &self,
        x: &Mat,
        adj: &Mat,
        eig: &[f32],
        mask: &[f32],
        embed: &Dense,
        convs: &[Dense],
        head: &[Dense; 3],
    ) -> Vec<f32> {
        let n = adj.r;
        let d = self.dim;
        // Mean-normalized adjacency + directional matrix B_dx (§4.4).
        let mut adj_norm = Mat::zeros(n, n);
        let mut b_dx = Mat::zeros(n, n);
        let mut b_row = vec![0.0f32; n];
        for i in 0..n {
            let deg: f32 = adj.row(i).iter().sum();
            let dg1 = deg.max(1.0);
            let mut abs_sum = 0.0f32;
            for j in 0..n {
                let a = adj.at(i, j);
                adj_norm.d[i * n + j] = a / dg1;
                let fm = a * (eig[j] - eig[i]);
                b_dx.d[i * n + j] = fm;
                abs_sum += fm.abs();
            }
            let denom = abs_sum + 1e-8;
            let mut row_sum = 0.0f32;
            for j in 0..n {
                b_dx.d[i * n + j] /= denom;
                row_sum += b_dx.d[i * n + j];
            }
            b_row[i] = row_sum;
        }
        let mut h = linear(x, embed, Act::Relu);
        for conv in convs {
            let mean = matmul(&adj_norm, &h);
            let bh = matmul(&b_dx, &h);
            let mut y = Mat::zeros(n, 2 * d);
            for i in 0..n {
                let yr = &mut y.d[i * 2 * d..(i + 1) * 2 * d];
                yr[..d].copy_from_slice(mean.row(i));
                let hr = h.row(i);
                let br = bh.row(i);
                for k in 0..d {
                    yr[d + k] = (br[k] - b_row[i] * hr[k]).abs();
                }
            }
            let up = linear(&y, conv, Act::Relu);
            for i in 0..n * d {
                h.d[i] = up.d[i] + h.d[i];
            }
            mask_rows(&mut h, mask);
        }
        let apply_head = |t: &Mat| -> Mat {
            let t = linear(t, &head[0], Act::Relu);
            let t = linear(&t, &head[1], Act::Relu);
            linear(&t, &head[2], Act::None)
        };
        if self.node_level {
            let mut out = apply_head(&h);
            mask_rows(&mut out, mask);
            out.into_vec()
        } else {
            apply_head(&masked_mean_pool(&h, mask)).into_vec()
        }
    }

    /// Expected output length for shape checks.
    pub fn output_len(&self, n_max: usize) -> usize {
        if self.node_level {
            n_max * self.out_dim
        } else {
            self.out_dim
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CooGraph, DenseGraph};
    use crate::runtime::artifact::InputSpec;

    fn tiny_meta(name: &str) -> ModelMeta {
        let n_max = 8;
        let in_dim = 4;
        let mut inputs = vec![
            InputSpec {
                name: "x".into(),
                shape: vec![n_max, in_dim],
            },
            InputSpec {
                name: "adj".into(),
                shape: vec![n_max, n_max],
            },
        ];
        if name.starts_with("gin") {
            inputs.push(InputSpec {
                name: "edge_attr".into(),
                shape: vec![n_max, n_max, 3],
            });
        }
        if name.starts_with("dgn") {
            inputs.push(InputSpec {
                name: "eig".into(),
                shape: vec![n_max],
            });
        }
        inputs.push(InputSpec {
            name: "mask".into(),
            shape: vec![n_max],
        });
        ModelMeta {
            name: name.to_string(),
            layers: 2,
            dim: 8,
            heads: if name == "gat" { 2 } else { 0 },
            n_max,
            in_dim,
            out_dim: 1,
            node_level: false,
            inputs,
            hlo_path: "unused.hlo.txt".into(),
            golden_path: "unused.golden.json".into(),
        }
    }

    fn tiny_graph() -> CooGraph {
        CooGraph::from_undirected(
            5,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4), (1, 3)],
            (0..5 * 4).map(|i| (i % 5) as f32).collect(),
            4,
            &(0..6 * 3).map(|i| (i % 3) as f32).collect::<Vec<f32>>(),
            3,
        )
        .unwrap()
    }

    fn dense_for(meta: &ModelMeta, g: &CooGraph) -> DenseGraph {
        let mut d = DenseGraph::from_coo(g, meta.n_max, meta.needs_edge_attr()).unwrap();
        if meta.needs_eig() {
            let r = crate::graph::fiedler_vector(g, 500, 1e-10);
            d.eig[..g.n].copy_from_slice(&r.vector);
        }
        d
    }

    #[test]
    fn all_reference_kinds_build_and_run() {
        for name in ["gcn", "gin", "gin_vn", "gat", "pna", "sgc", "sage", "dgn"] {
            let meta = tiny_meta(name);
            let m = DenseRef::build(&meta, 0).unwrap();
            let g = tiny_graph();
            let d = dense_for(&meta, &g);
            let out = m.forward(&d).unwrap();
            assert_eq!(out.len(), m.output_len(meta.n_max), "{name}");
            assert!(
                out.iter().all(|v| v.is_finite()),
                "{name}: non-finite output {out:?}"
            );
        }
    }

    #[test]
    fn unknown_model_is_a_clean_error() {
        let mut meta = tiny_meta("gcn");
        meta.name = "transformer".into();
        assert!(DenseRef::build(&meta, 0).is_err());
    }
}

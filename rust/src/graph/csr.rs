//! CSR / CSC adjacency (paper Section 3.2, Fig. 1) and the on-chip
//! COO converter (Section 3.2: "runs once when the graph is streamed
//! into the FPGA and is reused for all the GNN layers").
//!
//! CSR stores, per *source* node, the concatenated out-neighbors —
//! what the merged scatter-gather MP PE walks (Section 3.4). CSC is the
//! column-major mirror (in-neighbors per destination), used by the
//! gather-first execution variant. Both keep `edge_idx`, the position of
//! each entry in the original COO list, so edge features need no copy.

use super::coo::CooGraph;

/// Compressed sparse row: out-neighbors grouped by source.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    /// Degree table (out-degree per node) — first array in Fig. 1.
    pub degree: Vec<u32>,
    /// Exclusive prefix sums of `degree` (len n+1).
    pub offsets: Vec<u32>,
    /// Neighbor table — row-major concatenation of out-neighbors.
    pub neighbors: Vec<u32>,
    /// Original COO edge index for each neighbor entry (edge data table).
    pub edge_idx: Vec<u32>,
}

/// Compressed sparse column: in-neighbors grouped by destination.
#[derive(Clone, Debug, PartialEq)]
pub struct Csc {
    /// In-degree per node.
    pub degree: Vec<u32>,
    /// Exclusive prefix sums of `degree` (len n+1).
    pub offsets: Vec<u32>,
    /// Neighbor table — column-major concatenation of in-neighbors.
    pub neighbors: Vec<u32>,
    /// Original COO edge index for each neighbor entry (edge data table).
    pub edge_idx: Vec<u32>,
}

fn bucket(
    n: usize,
    m: usize,
    key: impl Fn(usize) -> usize,
    val: impl Fn(usize) -> u32,
) -> (Vec<u32>, Vec<u32>, Vec<u32>, Vec<u32>) {
    // Single-pass counting sort: the linear-time analog of the paper's
    // two-pass streaming hardware converter.
    let mut degree = vec![0u32; n];
    for e in 0..m {
        degree[key(e)] += 1;
    }
    let mut offsets = vec![0u32; n + 1];
    for v in 0..n {
        offsets[v + 1] = offsets[v] + degree[v];
    }
    let mut cursor = offsets[..n].to_vec();
    let mut neighbors = vec![0u32; m];
    let mut edge_idx = vec![0u32; m];
    for e in 0..m {
        let k = key(e);
        let slot = cursor[k] as usize;
        neighbors[slot] = val(e);
        edge_idx[slot] = e as u32;
        cursor[k] += 1;
    }
    (degree, offsets, neighbors, edge_idx)
}

impl Csr {
    /// COO -> CSR conversion (group by source node).
    pub fn from_coo(g: &CooGraph) -> Csr {
        let (degree, offsets, neighbors, edge_idx) = bucket(
            g.n,
            g.edges.len(),
            |e| g.edges[e].0 as usize,
            |e| g.edges[e].1,
        );
        Csr {
            degree,
            offsets,
            neighbors,
            edge_idx,
        }
    }

    pub fn n(&self) -> usize {
        self.degree.len()
    }

    pub fn num_edges(&self) -> usize {
        self.neighbors.len()
    }

    /// Out-neighbors of `v` (the same-colored slice of Fig. 1).
    pub fn row(&self, v: usize) -> &[u32] {
        &self.neighbors[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }

    /// COO edge ids matching `row(v)` entry-for-entry.
    pub fn row_edges(&self, v: usize) -> &[u32] {
        &self.edge_idx[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }
}

impl Csc {
    /// COO -> CSC conversion (group by destination node).
    pub fn from_coo(g: &CooGraph) -> Csc {
        let (degree, offsets, neighbors, edge_idx) = bucket(
            g.n,
            g.edges.len(),
            |e| g.edges[e].1 as usize,
            |e| g.edges[e].0,
        );
        Csc {
            degree,
            offsets,
            neighbors,
            edge_idx,
        }
    }

    pub fn n(&self) -> usize {
        self.degree.len()
    }

    /// In-neighbors of `v`.
    pub fn col(&self, v: usize) -> &[u32] {
        &self.neighbors[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }

    pub fn col_edges(&self, v: usize) -> &[u32] {
        &self.edge_idx[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::forall;
    use crate::util::rng::Rng;

    fn fig1_graph() -> CooGraph {
        // The example graph of paper Fig. 1: directed edges.
        CooGraph {
            n: 4,
            edges: vec![(0, 1), (0, 2), (1, 2), (2, 3), (3, 0), (1, 3)],
            node_feat: vec![0.0; 4],
            f_node: 1,
            edge_feat: vec![],
            f_edge: 0,
        }
    }

    #[test]
    fn csr_groups_by_source() {
        let csr = Csr::from_coo(&fig1_graph());
        assert_eq!(csr.degree, vec![2, 2, 1, 1]);
        assert_eq!(csr.row(0), &[1, 2]);
        assert_eq!(csr.row(1), &[2, 3]);
        assert_eq!(csr.row(2), &[3]);
        assert_eq!(csr.row(3), &[0]);
    }

    #[test]
    fn csc_groups_by_destination() {
        let csc = Csc::from_coo(&fig1_graph());
        assert_eq!(csc.degree, vec![1, 1, 2, 2]);
        assert_eq!(csc.col(2), &[0, 1]);
        assert_eq!(csc.col(3), &[2, 1]);
    }

    #[test]
    fn edge_idx_points_back_to_coo() {
        let g = fig1_graph();
        let csr = Csr::from_coo(&g);
        for v in 0..g.n {
            for (nbr, &ei) in csr.row(v).iter().zip(csr.row_edges(v)) {
                assert_eq!(g.edges[ei as usize], (v as u32, *nbr));
            }
        }
    }

    fn random_coo(rng: &mut Rng) -> CooGraph {
        let n = rng.range(1, 40);
        let m = rng.range(0, 120);
        let edges: Vec<(u32, u32)> = (0..m)
            .map(|_| (rng.below(n) as u32, rng.below(n) as u32))
            .collect();
        CooGraph {
            n,
            edges,
            node_feat: vec![0.0; n],
            f_node: 1,
            edge_feat: vec![],
            f_edge: 0,
        }
    }

    #[test]
    fn prop_csr_roundtrips_edge_multiset() {
        forall("csr-roundtrip", 200, 0xC5A, |rng| {
            let g = random_coo(rng);
            let csr = Csr::from_coo(&g);
            let mut rebuilt: Vec<(u32, u32)> = (0..g.n)
                .flat_map(|v| {
                    csr.row(v).iter().map(move |&t| (v as u32, t))
                })
                .collect();
            let mut orig = g.edges.clone();
            rebuilt.sort_unstable();
            orig.sort_unstable();
            prop_assert!(rebuilt == orig, "edge multiset changed");
            prop_assert!(
                csr.num_edges() == g.edges.len(),
                "edge count changed"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_csc_is_csr_of_transpose() {
        forall("csc-transpose", 200, 0xC5C, |rng| {
            let g = random_coo(rng);
            let csc = Csc::from_coo(&g);
            let gt = CooGraph {
                edges: g.edges.iter().map(|&(s, t)| (t, s)).collect(),
                ..g.clone()
            };
            let csr_t = Csr::from_coo(&gt);
            prop_assert!(
                csc.degree == csr_t.degree
                    && csc.offsets == csr_t.offsets
                    && csc.neighbors == csr_t.neighbors,
                "CSC != CSR(G^T)"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_degree_sums_to_edge_count() {
        forall("degree-sum", 100, 0xDE6, |rng| {
            let g = random_coo(rng);
            let csr = Csr::from_coo(&g);
            let total: u32 = csr.degree.iter().sum();
            prop_assert!(
                total as usize == g.edges.len(),
                "sum(degree) {} != E {}",
                total,
                g.edges.len()
            );
            Ok(())
        });
    }

    #[test]
    fn empty_graph() {
        let g = CooGraph {
            n: 3,
            edges: vec![],
            node_feat: vec![0.0; 3],
            f_node: 1,
            edge_feat: vec![],
            f_edge: 0,
        };
        let csr = Csr::from_coo(&g);
        assert_eq!(csr.degree, vec![0, 0, 0]);
        assert!(csr.row(1).is_empty());
    }
}

//! COO graph representation — the paper's *raw input* format
//! (Section 3.2): an arbitrarily-ordered directed edge list, exactly
//! what a real-time producer streams in with zero preprocessing.

use anyhow::{bail, Result};

/// A graph in COOrdinate format with dense per-node / per-edge features.
#[derive(Clone, Debug, PartialEq)]
pub struct CooGraph {
    pub n: usize,
    /// Directed edges (src, dst) in arbitrary order.
    pub edges: Vec<(u32, u32)>,
    /// Row-major [n, f_node] node features.
    pub node_feat: Vec<f32>,
    pub f_node: usize,
    /// Row-major [edges.len(), f_edge] edge features (empty if f_edge=0).
    pub edge_feat: Vec<f32>,
    pub f_edge: usize,
}

impl CooGraph {
    /// Build from an *undirected* edge list: each {u, v} is mirrored into
    /// (u, v) and (v, u), sharing the same edge feature — the convention
    /// of the molecular datasets (bonds are undirected).
    pub fn from_undirected(
        n: usize,
        undirected: &[(u32, u32)],
        node_feat: Vec<f32>,
        f_node: usize,
        edge_feat: &[f32],
        f_edge: usize,
    ) -> Result<CooGraph> {
        if node_feat.len() != n * f_node {
            bail!(
                "node_feat len {} != n*f_node {}",
                node_feat.len(),
                n * f_node
            );
        }
        if edge_feat.len() != undirected.len() * f_edge {
            bail!("edge_feat len mismatch");
        }
        let mut edges = Vec::with_capacity(undirected.len() * 2);
        let mut ef = Vec::with_capacity(edge_feat.len() * 2);
        for (i, &(u, v)) in undirected.iter().enumerate() {
            if u as usize >= n || v as usize >= n {
                bail!("edge ({u},{v}) out of range for n={n}");
            }
            edges.push((u, v));
            edges.push((v, u));
            let row = &edge_feat[i * f_edge..(i + 1) * f_edge];
            ef.extend_from_slice(row);
            ef.extend_from_slice(row);
        }
        Ok(CooGraph {
            n,
            edges,
            node_feat,
            f_node,
            edge_feat: ef,
            f_edge,
        })
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Out-degree histogram entry for node v.
    pub fn out_degrees(&self) -> Vec<u32> {
        let mut d = vec![0u32; self.n];
        for &(s, _) in &self.edges {
            d[s as usize] += 1;
        }
        d
    }

    pub fn in_degrees(&self) -> Vec<u32> {
        let mut d = vec![0u32; self.n];
        for &(_, t) in &self.edges {
            d[t as usize] += 1;
        }
        d
    }

    pub fn node_feat_row(&self, v: usize) -> &[f32] {
        &self.node_feat[v * self.f_node..(v + 1) * self.f_node]
    }

    /// Average degree (directed edges per node).
    pub fn avg_degree(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.edges.len() as f64 / self.n as f64
        }
    }

    /// Structural validation (bounds, feature sizes).
    pub fn validate(&self) -> Result<()> {
        if self.node_feat.len() != self.n * self.f_node {
            bail!("node feature size mismatch");
        }
        if self.edge_feat.len() != self.edges.len() * self.f_edge {
            bail!("edge feature size mismatch");
        }
        for &(s, t) in &self.edges {
            if s as usize >= self.n || t as usize >= self.n {
                bail!("edge ({s},{t}) out of range for n={}", self.n);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tri() -> CooGraph {
        CooGraph::from_undirected(
            3,
            &[(0, 1), (1, 2), (0, 2)],
            vec![1.0; 3 * 2],
            2,
            &[10.0, 20.0, 30.0],
            1,
        )
        .unwrap()
    }

    #[test]
    fn mirrors_undirected_edges() {
        let g = tri();
        assert_eq!(g.num_edges(), 6);
        assert!(g.edges.contains(&(0, 1)) && g.edges.contains(&(1, 0)));
        assert_eq!(g.edge_feat.len(), 6);
        g.validate().unwrap();
    }

    #[test]
    fn degrees_symmetric_for_undirected() {
        let g = tri();
        assert_eq!(g.out_degrees(), g.in_degrees());
        assert_eq!(g.out_degrees(), vec![2, 2, 2]);
    }

    #[test]
    fn rejects_out_of_range() {
        let r = CooGraph::from_undirected(2, &[(0, 5)], vec![0.0; 2], 1, &[], 0);
        assert!(r.is_err());
    }

    #[test]
    fn rejects_bad_feature_size() {
        let r = CooGraph::from_undirected(2, &[(0, 1)], vec![0.0; 3], 2, &[], 0);
        assert!(r.is_err());
    }

    #[test]
    fn avg_degree() {
        assert!((tri().avg_degree() - 2.0).abs() < 1e-12);
    }
}

//! Block-diagonal fusion of ingested graphs — the substrate of fused
//! micro-batch execution.
//!
//! The dispatcher's batcher groups same-model requests, but until this
//! module existed a lane still executed them one interpreter pass per
//! request. [`FusedBatch::fuse`] merges N ingested [`GraphBatch`]es
//! into **one** block-diagonal graph — offset-shifted COO edges,
//! concatenated node/edge features, and an offset-shifted concatenation
//! of the per-graph [`InNbrs`] views — plus a per-graph segment table,
//! so the stage-IR interpreter (`runtime::interp`) can run the whole
//! batch as a single pass and split the outputs back per request.
//!
//! **Bit-exactness contract:** fusion never changes an output bit
//! relative to per-request execution. Each node's in-neighbor list in
//! the fused view is its per-graph list shifted by a constant node
//! offset, so neighbor *order* (ascending), deduplication (last COO
//! occurrence wins), degrees, and therefore every accumulation order
//! the interpreter walks are untouched; readout and virtual-node
//! stages operate per segment. The equality of the shifted-concat view
//! with a from-scratch conversion of the fused COO is pinned by the
//! property tests below; fused-vs-sequential output equality across
//! the model zoo is pinned by `rust/tests/fused_equivalence.rs`.

use anyhow::{bail, Result};

use super::batch::GraphBatch;
use super::coo::CooGraph;
use super::nbr::InNbrs;

/// One source graph's slice of the fused index space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FusedSegment {
    /// First fused node index of this graph (its nodes occupy
    /// `node_offset .. node_offset + n`).
    pub node_offset: usize,
    /// Node count of this graph.
    pub n: usize,
    /// First fused COO edge index of this graph.
    pub edge_offset: usize,
    /// Directed edge count of this graph.
    pub e: usize,
}

impl FusedSegment {
    /// The segment's node range in the fused index space.
    pub fn nodes(&self) -> std::ops::Range<usize> {
        self.node_offset..self.node_offset + self.n
    }
}

/// N ingested graphs merged into one block-diagonal execution unit.
///
/// Built by the executor lane right before a fused interpreter pass;
/// never stored. The merged [`CooGraph`] and [`InNbrs`] are exactly
/// what per-request execution would walk, relocated by per-segment
/// constant offsets.
#[derive(Clone, Debug)]
pub struct FusedBatch {
    graph: CooGraph,
    nbrs: InNbrs,
    segments: Vec<FusedSegment>,
}

impl FusedBatch {
    /// Merge `parts` into one block-diagonal batch. All parts must
    /// share node/edge feature widths (guaranteed for a same-model
    /// batch that passed routing; mismatches bail so the caller can
    /// fall back to per-request execution and surface per-request
    /// errors). Reuses each part's cached in-neighbor view — no
    /// re-conversion, only offset shifts.
    pub fn fuse(parts: &[&GraphBatch]) -> Result<FusedBatch> {
        let Some(first) = parts.first() else {
            bail!("cannot fuse an empty batch");
        };
        let f_node = first.graph.f_node;
        let f_edge = first.graph.f_edge;
        let (mut total_n, mut total_e) = (0u64, 0u64);
        for p in parts {
            if p.graph.f_node != f_node {
                bail!(
                    "node feature width mismatch in fused batch: {} vs {}",
                    p.graph.f_node,
                    f_node
                );
            }
            if p.graph.f_edge != f_edge {
                bail!(
                    "edge feature width mismatch in fused batch: {} vs {}",
                    p.graph.f_edge,
                    f_edge
                );
            }
            total_n += p.n() as u64;
            total_e += p.num_edges() as u64;
        }
        if total_n > u32::MAX as u64 || total_e > u32::MAX as u64 {
            bail!("fused batch exceeds the u32 node/edge index space");
        }
        let mut graph = CooGraph {
            n: total_n as usize,
            edges: Vec::with_capacity(total_e as usize),
            node_feat: Vec::with_capacity(total_n as usize * f_node),
            f_node,
            edge_feat: Vec::with_capacity(total_e as usize * f_edge),
            f_edge,
        };
        let mut segments = Vec::with_capacity(parts.len());
        let mut nbr_parts = Vec::with_capacity(parts.len());
        let (mut node_off, mut edge_off) = (0usize, 0usize);
        for p in parts {
            let g = &p.graph;
            segments.push(FusedSegment {
                node_offset: node_off,
                n: g.n,
                edge_offset: edge_off,
                e: g.edges.len(),
            });
            let shift = node_off as u32;
            graph
                .edges
                .extend(g.edges.iter().map(|&(s, t)| (s + shift, t + shift)));
            graph.node_feat.extend_from_slice(&g.node_feat);
            graph.edge_feat.extend_from_slice(&g.edge_feat);
            nbr_parts.push((p.in_nbrs(), shift, edge_off as u32));
            node_off += g.n;
            edge_off += g.edges.len();
        }
        let nbrs = InNbrs::concat_shifted(&nbr_parts);
        Ok(FusedBatch {
            graph,
            nbrs,
            segments,
        })
    }

    /// [`FusedBatch::fuse`] behind the static analyzer's derived
    /// fusion-safety facts: refuses to build the block-diagonal merge
    /// at all when some stage of the plan that will execute it carries
    /// no safety argument. This is how the fused path consumes the
    /// facts instead of assuming every stage kind is mergeable — a
    /// future cross-segment-unsafe stage is turned away here (and
    /// again at `runtime::interp::execute_fused`), never miscomputed.
    pub fn fuse_checked(
        parts: &[&GraphBatch],
        facts: &crate::analysis::PlanFacts,
        model: &str,
    ) -> Result<FusedBatch> {
        facts.require_fusable(model)?;
        FusedBatch::fuse(parts)
    }

    /// The merged block-diagonal COO graph.
    pub fn graph(&self) -> &CooGraph {
        &self.graph
    }

    /// The merged in-neighbor view (offset-shifted per-graph rows).
    pub fn in_nbrs(&self) -> &InNbrs {
        &self.nbrs
    }

    /// Per-source-graph slices of the fused index space, in fuse order.
    pub fn segments(&self) -> &[FusedSegment] {
        &self.segments
    }

    /// Number of source graphs.
    pub fn num_graphs(&self) -> usize {
        self.segments.len()
    }

    /// Total node count across all segments.
    pub fn total_nodes(&self) -> usize {
        self.graph.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::forall;
    use crate::util::rng::Rng;

    fn random_coo(rng: &mut Rng, f_node: usize, f_edge: usize) -> CooGraph {
        let n = rng.range(0, 12);
        let m = if n == 0 { 0 } else { rng.range(0, 40) };
        let edges: Vec<(u32, u32)> = (0..m)
            .map(|_| (rng.below(n) as u32, rng.below(n) as u32))
            .collect();
        CooGraph {
            n,
            edges,
            node_feat: (0..n * f_node).map(|i| i as f32 * 0.5 - 1.0).collect(),
            f_node,
            edge_feat: (0..m * f_edge).map(|i| i as f32 * 0.25).collect(),
            f_edge,
        }
    }

    #[test]
    fn segment_table_covers_the_fused_index_space() {
        let mut rng = Rng::new(7);
        let batches: Vec<GraphBatch> = (0..4)
            .map(|_| GraphBatch::ingest(random_coo(&mut rng, 3, 2)).unwrap())
            .collect();
        let parts: Vec<&GraphBatch> = batches.iter().collect();
        let fused = FusedBatch::fuse(&parts).unwrap();
        assert_eq!(fused.num_graphs(), 4);
        let (mut node_off, mut edge_off) = (0usize, 0usize);
        for (seg, b) in fused.segments().iter().zip(&batches) {
            assert_eq!(seg.node_offset, node_off);
            assert_eq!(seg.n, b.n());
            assert_eq!(seg.edge_offset, edge_off);
            assert_eq!(seg.e, b.num_edges());
            node_off += b.n();
            edge_off += b.num_edges();
        }
        assert_eq!(fused.total_nodes(), node_off);
        assert_eq!(fused.graph().num_edges(), edge_off);
        fused.graph().validate().unwrap();
    }

    #[test]
    fn fuse_checked_consumes_analyzer_facts() {
        use crate::analysis::{FusionFact, PlanFacts, ReductionOrder, StageFacts};
        let a = GraphBatch::ingest(random_coo(&mut Rng::new(3), 2, 0)).unwrap();
        let safe = PlanFacts {
            stages: vec![StageFacts {
                fact: FusionFact::SegmentLocal,
                reduction: ReductionOrder::AscendingNodeOrder,
            }],
        };
        assert!(FusedBatch::fuse_checked(&[&a], &safe, "m").is_ok());
        let unsafe_facts = PlanFacts {
            stages: vec![StageFacts {
                fact: FusionFact::CrossSegmentUnsafe,
                reduction: ReductionOrder::None,
            }],
        };
        let err = FusedBatch::fuse_checked(&[&a], &unsafe_facts, "m")
            .unwrap_err()
            .to_string();
        assert!(err.contains("cross-segment-unsafe"), "{err}");
    }

    #[test]
    fn feature_width_mismatch_bails() {
        let a = GraphBatch::ingest(random_coo(&mut Rng::new(1), 3, 0)).unwrap();
        let b = GraphBatch::ingest(random_coo(&mut Rng::new(2), 4, 0)).unwrap();
        assert!(FusedBatch::fuse(&[&a, &b]).is_err());
        assert!(FusedBatch::fuse(&[]).is_err(), "empty fuse must bail");
    }

    /// The load-bearing property: the offset-shifted concatenation of
    /// the per-graph in-neighbor views must be **identical** to a
    /// from-scratch conversion of the fused block-diagonal COO — same
    /// rows, same order, same kept edge indices. This is the whole
    /// bit-exactness argument for fusion reduced to a data-structure
    /// equality.
    #[test]
    fn prop_shifted_concat_equals_fresh_conversion() {
        forall("fused-nbr-equivalence", 120, 0xF05E, |rng| {
            let k = rng.range(1, 6);
            let batches: Vec<GraphBatch> = (0..k)
                .map(|_| GraphBatch::ingest(random_coo(rng, 2, 1)).unwrap())
                .collect();
            let parts: Vec<&GraphBatch> = batches.iter().collect();
            let fused = FusedBatch::fuse(&parts).unwrap();
            let fresh = InNbrs::from_coo(fused.graph());
            prop_assert!(
                *fused.in_nbrs() == fresh,
                "shifted concat differs from fresh conversion of the fused COO"
            );
            Ok(())
        });
    }

    /// Cross-graph isolation: no fused in-neighbor row may reach
    /// outside its own segment's node range.
    #[test]
    fn prop_segments_stay_block_diagonal() {
        forall("fused-block-diagonal", 120, 0xB10C, |rng| {
            let k = rng.range(2, 5);
            let batches: Vec<GraphBatch> = (0..k)
                .map(|_| GraphBatch::ingest(random_coo(rng, 1, 0)).unwrap())
                .collect();
            let parts: Vec<&GraphBatch> = batches.iter().collect();
            let fused = FusedBatch::fuse(&parts).unwrap();
            for seg in fused.segments() {
                for v in seg.nodes() {
                    for &s in fused.in_nbrs().row(v) {
                        prop_assert!(
                            seg.nodes().contains(&(s as usize)),
                            "node {v} of segment at {} has out-of-segment \
                             neighbor {s}",
                            seg.node_offset
                        );
                    }
                }
            }
            Ok(())
        });
    }

    /// Each segment's rows must be its source graph's rows shifted by
    /// the segment's node offset, with edge indices shifted by the edge
    /// offset (so fused edge-feature lookups hit the same features).
    #[test]
    fn rows_are_offset_shifted_copies() {
        let mut rng = Rng::new(0x5EED);
        let batches: Vec<GraphBatch> = (0..3)
            .map(|_| GraphBatch::ingest(random_coo(&mut rng, 2, 2)).unwrap())
            .collect();
        let parts: Vec<&GraphBatch> = batches.iter().collect();
        let fused = FusedBatch::fuse(&parts).unwrap();
        for (seg, b) in fused.segments().iter().zip(&batches) {
            let own = b.in_nbrs();
            for v in 0..seg.n {
                let fused_row = fused.in_nbrs().row(seg.node_offset + v);
                let own_row = own.row(v);
                assert_eq!(fused_row.len(), own_row.len());
                for (&f, &o) in fused_row.iter().zip(own_row) {
                    assert_eq!(f as usize, o as usize + seg.node_offset);
                }
                let fused_edges = fused.in_nbrs().row_edges(seg.node_offset + v);
                let own_edges = own.row_edges(v);
                for (&f, &o) in fused_edges.iter().zip(own_edges) {
                    assert_eq!(f as usize, o as usize + seg.edge_offset);
                    // And the fused feature row equals the source's.
                    let fe = &fused.graph().edge_feat
                        [f as usize * 2..(f as usize + 1) * 2];
                    let oe = &b.graph.edge_feat[o as usize * 2..(o as usize + 1) * 2];
                    assert_eq!(fe, oe);
                }
            }
        }
    }
}

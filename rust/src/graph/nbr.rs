//! Sorted, deduplicated in-neighbor lists — the adjacency view the
//! stage-IR interpreter walks (`runtime::interp`).
//!
//! The dense densification (`graph::dense`) writes `adj[t][s] = 1.0`
//! per directed COO edge: duplicates collapse to one entry and a
//! duplicate's edge features are **last-write-wins** (the highest COO
//! index). [`InNbrs`] is the sparse image of exactly that contract:
//! per destination node, the distinct source nodes in ascending order,
//! each carrying the COO index of its *last* occurrence. Ascending
//! order is load-bearing — it makes the interpreter's float32
//! accumulation order identical to the dense reference's ascending-j
//! matmul loops, which is what the bit-exactness contract between
//! `runtime::interp` and `runtime::dense_ref` rests on (spec:
//! `python/tools/plan_replica.py`).
//!
//! Cost: one counting pass over the edges plus a per-row sort —
//! O(E log deg_max) time, O(N + E) memory. No O(n_max²) buffer exists
//! anywhere on this path.

use super::coo::CooGraph;

/// Per-destination in-neighbor lists: ascending source order,
/// duplicate edges collapsed keeping the highest COO edge index.
#[derive(Clone, Debug, PartialEq)]
pub struct InNbrs {
    n: usize,
    /// Exclusive prefix offsets (len n+1) over the deduped entries.
    offsets: Vec<u32>,
    /// Deduped in-neighbors, ascending within each row.
    nbrs: Vec<u32>,
    /// COO index of the last occurrence of each (src, dst) pair —
    /// the edge whose features densification would have kept.
    edge_idx: Vec<u32>,
}

impl InNbrs {
    /// Build from a raw COO edge list (any order, duplicates allowed).
    pub fn from_coo(g: &CooGraph) -> InNbrs {
        let n = g.n;
        let m = g.edges.len();
        // Counting sort by destination (stable: keeps COO order within
        // a row, so equal-neighbor runs are ascending in edge index).
        let mut degree = vec![0u32; n];
        for &(_, t) in &g.edges {
            degree[t as usize] += 1;
        }
        let mut offsets = vec![0u32; n + 1];
        for v in 0..n {
            offsets[v + 1] = offsets[v] + degree[v];
        }
        let mut cursor = offsets[..n].to_vec();
        let mut nbrs = vec![0u32; m];
        let mut edge_idx = vec![0u32; m];
        for (e, &(s, t)) in g.edges.iter().enumerate() {
            let slot = cursor[t as usize] as usize;
            nbrs[slot] = s;
            edge_idx[slot] = e as u32;
            cursor[t as usize] += 1;
        }
        // Per row: sort by (neighbor, edge index) and collapse each
        // neighbor run to its last (= highest-index) entry.
        let mut compact_offsets = vec![0u32; n + 1];
        let mut write = 0usize;
        let mut row: Vec<(u32, u32)> = Vec::new();
        for v in 0..n {
            let (lo, hi) = (offsets[v] as usize, offsets[v + 1] as usize);
            row.clear();
            row.extend(nbrs[lo..hi].iter().copied().zip(edge_idx[lo..hi].iter().copied()));
            row.sort_unstable();
            let mut r = 0;
            while r < row.len() {
                let mut last = r;
                while last + 1 < row.len() && row[last + 1].0 == row[r].0 {
                    last += 1;
                }
                nbrs[write] = row[r].0;
                edge_idx[write] = row[last].1;
                write += 1;
                r = last + 1;
            }
            compact_offsets[v + 1] = write as u32;
        }
        nbrs.truncate(write);
        edge_idx.truncate(write);
        InNbrs {
            n,
            offsets: compact_offsets,
            nbrs,
            edge_idx,
        }
    }

    /// Concatenate per-graph views into one block-diagonal view, each
    /// part's neighbor entries shifted by its node offset and its kept
    /// COO edge indices by its edge offset (`(view, node_offset,
    /// edge_offset)` per part, in fuse order).
    ///
    /// Because every part's rows are copied verbatim modulo a constant
    /// per-part shift, row order (ascending), deduplication, and
    /// degrees are untouched — the result is identical to
    /// [`InNbrs::from_coo`] over the fused block-diagonal COO graph
    /// (pinned by `graph::fused`'s property tests), at concat cost
    /// instead of a full re-sort.
    ///
    /// # Panics
    ///
    /// If the combined node count, source-graph edge count, or entry
    /// count would overflow the u32 index space — wrapped offsets
    /// would silently corrupt the adjacency. `FusedBatch::fuse`
    /// pre-checks and bails cleanly before calling this.
    pub fn concat_shifted(parts: &[(&InNbrs, u32, u32)]) -> InNbrs {
        let n: usize = parts.iter().map(|(p, _, _)| p.n).sum();
        let entries: usize = parts.iter().map(|(p, _, _)| p.nbrs.len()).sum();
        assert!(
            n <= u32::MAX as usize && entries <= u32::MAX as usize,
            "fused view exceeds the u32 index space"
        );
        for &(p, node_off, edge_off) in parts {
            // Shifted neighbor ids top out at node_off + p.n - 1 and
            // shifted edge indices at edge_off + max(edge_idx).
            let max_edge = p.edge_idx.iter().max().copied().unwrap_or(0);
            assert!(
                node_off as u64 + p.n as u64 <= u32::MAX as u64 + 1
                    && edge_off as u64 + max_edge as u64 <= u32::MAX as u64,
                "fused node/edge offsets exceed the u32 index space"
            );
        }
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0u32);
        let mut nbrs = Vec::with_capacity(entries);
        let mut edge_idx = Vec::with_capacity(entries);
        let mut base = 0u32;
        for &(p, node_off, edge_off) in parts {
            for v in 0..p.n {
                offsets.push(base + p.offsets[v + 1]);
            }
            nbrs.extend(p.nbrs.iter().map(|&s| s + node_off));
            edge_idx.extend(p.edge_idx.iter().map(|&e| e + edge_off));
            base += p.offsets[p.n];
        }
        InNbrs {
            n,
            offsets,
            nbrs,
            edge_idx,
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Total deduped entries (≤ the COO edge count).
    pub fn num_entries(&self) -> usize {
        self.nbrs.len()
    }

    /// Distinct in-neighbors of `v`, ascending.
    pub fn row(&self, v: usize) -> &[u32] {
        &self.nbrs[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }

    /// COO edge indices matching `row(v)` entry-for-entry (each the
    /// last occurrence of its pair).
    pub fn row_edges(&self, v: usize) -> &[u32] {
        &self.edge_idx[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }

    /// Deduplicated in-degree of `v` — bitwise equal (as f32) to the
    /// dense reference's adjacency row sum.
    pub fn deg(&self, v: usize) -> usize {
        (self.offsets[v + 1] - self.offsets[v]) as usize
    }

    pub fn has_self_loop(&self, v: usize) -> bool {
        self.row(v).binary_search(&(v as u32)).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DenseGraph;
    use crate::prop_assert;
    use crate::util::proptest::forall;
    use crate::util::rng::Rng;

    fn graph(n: usize, edges: Vec<(u32, u32)>) -> CooGraph {
        let ne = edges.len();
        CooGraph {
            n,
            edges,
            node_feat: vec![0.0; n],
            f_node: 1,
            edge_feat: (0..ne).map(|e| e as f32).collect(),
            f_edge: 1,
        }
    }

    #[test]
    fn rows_are_sorted_and_deduped_last_wins() {
        // (2,0) appears at COO indices 1 and 4 — entry keeps index 4.
        let g = graph(3, vec![(1, 0), (2, 0), (0, 2), (2, 0), (2, 0), (0, 0)]);
        let nb = InNbrs::from_coo(&g);
        assert_eq!(nb.row(0), &[0, 1, 2]);
        assert_eq!(nb.row_edges(0), &[5, 0, 4]);
        assert_eq!(nb.row(1), &[] as &[u32]);
        assert_eq!(nb.row(2), &[0]);
        assert_eq!(nb.deg(0), 3);
        assert!(nb.has_self_loop(0));
        assert!(!nb.has_self_loop(2));
    }

    #[test]
    fn empty_and_edgeless_graphs() {
        let nb = InNbrs::from_coo(&graph(0, vec![]));
        assert_eq!(nb.n(), 0);
        assert_eq!(nb.num_entries(), 0);
        let nb = InNbrs::from_coo(&graph(4, vec![]));
        for v in 0..4 {
            assert!(nb.row(v).is_empty());
            assert_eq!(nb.deg(v), 0);
        }
    }

    #[test]
    fn concat_shifted_relocates_rows_and_edges() {
        // Part A: 2 nodes, edge (1,0) at COO index 0.
        let a = InNbrs::from_coo(&graph(2, vec![(1, 0)]));
        // Part B: 3 nodes, edges (2,1)@0, (0,1)@1, duplicate (0,1)@2.
        let b = InNbrs::from_coo(&graph(3, vec![(2, 1), (0, 1), (0, 1)]));
        let fused = InNbrs::concat_shifted(&[(&a, 0, 0), (&b, 2, 1)]);
        assert_eq!(fused.n(), 5);
        assert_eq!(fused.row(0), &[1]);
        assert_eq!(fused.row_edges(0), &[0]);
        assert_eq!(fused.row(1), &[] as &[u32]);
        // B's node 1 (fused node 3): in-nbrs {0, 2} shifted to {2, 4},
        // the duplicate (0,1) keeping COO index 2, shifted to 3.
        assert_eq!(fused.row(3), &[2, 4]);
        assert_eq!(fused.row_edges(3), &[3, 1]);
        assert_eq!(fused.deg(3), 2);
        assert_eq!(fused.num_entries(), a.num_entries() + b.num_entries());
    }

    /// The sparse view must be the exact image of densification:
    /// same nonzero pattern, and each entry's edge features are the
    /// ones the last dense write would have left behind.
    #[test]
    fn prop_matches_densification_contract() {
        forall("nbr-vs-dense", 150, 0x17B2, |rng| {
            let n = rng.range(1, 16);
            let m = rng.range(0, 60);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (rng.below(n) as u32, rng.below(n) as u32))
                .collect();
            let g = graph(n, edges);
            let nb = InNbrs::from_coo(&g);
            let d = DenseGraph::from_coo(&g, n, true).unwrap();
            let mut entries = 0usize;
            for v in 0..n {
                let row = nb.row(v);
                prop_assert!(
                    row.windows(2).all(|w| w[0] < w[1]),
                    "row {v} not strictly ascending: {row:?}"
                );
                for j in 0..n {
                    let dense_set = d.adj_at(v, j) != 0.0;
                    let sparse_set = row.binary_search(&(j as u32)).is_ok();
                    prop_assert!(
                        dense_set == sparse_set,
                        "pattern mismatch at ({v},{j})"
                    );
                }
                for (&s, &ei) in row.iter().zip(nb.row_edges(v)) {
                    prop_assert!(
                        g.edges[ei as usize] == (s, v as u32),
                        "edge_idx {ei} does not point at ({s},{v})"
                    );
                    let dense_feat = d.edge_attr[v * n + s as usize];
                    let sparse_feat = g.edge_feat[ei as usize];
                    prop_assert!(
                        dense_feat == sparse_feat,
                        "({s}->{v}): dense kept feature {dense_feat}, \
                         sparse edge_idx {ei} carries {sparse_feat}"
                    );
                }
                entries += row.len();
            }
            prop_assert!(
                entries == nb.num_entries(),
                "offsets do not cover all entries"
            );
            Ok(())
        });
    }
}

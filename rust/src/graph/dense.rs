//! Sparse -> padded-dense densification: the contract with the AOT
//! artifacts (mirrors `python/compile/graphgen.densify` bit-for-bit).
//!
//! Dense tensors are what the TPU-adapted kernels consume (see
//! `python/compile/kernels/common.py`): adjacency as a routing matrix,
//! features zero-padded to the artifact's node capacity, mask marking
//! real nodes.
//!
//! **Contract shift (stage-IR redesign):** densification is
//! *reference-only*. The native serving path executes lowered
//! `ModelPlan`s over sparse in-neighbor lists ([`super::nbr::InNbrs`])
//! and never materializes these O(n_max²) tensors; they remain the
//! input layout of the AOT/PJRT artifacts (`runtime::literal`), the
//! substrate of the dense reference executor (`runtime::dense_ref`),
//! and the ground truth the sparse interpreter is property-tested
//! against bit-for-bit (`tests/plan_equivalence.rs`). Duplicate edges
//! overwrite — one adjacency entry, last edge's features win — which
//! is exactly the dedup rule `InNbrs` mirrors.

use anyhow::{bail, Result};

use super::coo::CooGraph;

/// Padded dense tensors for one graph, in artifact input layout.
#[derive(Clone, Debug)]
pub struct DenseGraph {
    pub n_max: usize,
    pub n_real: usize,
    pub f_node: usize,
    /// [n_max, f_node] row-major.
    pub x: Vec<f32>,
    /// [n_max, n_max] row-major; adj[i*n_max+j] = 1 iff edge j -> i.
    pub adj: Vec<f32>,
    /// [n_max, n_max, f_edge] row-major (empty when f_edge = 0).
    pub edge_attr: Vec<f32>,
    pub f_edge: usize,
    /// [n_max] 1.0 for real nodes.
    pub mask: Vec<f32>,
    /// [n_max] Laplacian eigenvector (zeros unless filled by spectral).
    pub eig: Vec<f32>,
}

impl DenseGraph {
    /// Densify a COO graph into `n_max`-padded tensors.
    /// `with_edge_attr` controls whether the [N, N, f_edge] tensor is
    /// materialized (GIN models only — it is the biggest buffer).
    pub fn from_coo(g: &CooGraph, n_max: usize, with_edge_attr: bool) -> Result<DenseGraph> {
        if g.n > n_max {
            bail!("graph has {} nodes, exceeds capacity {}", g.n, n_max);
        }
        g.validate()?;
        let mut d = DenseGraph {
            n_max,
            n_real: g.n,
            f_node: g.f_node,
            x: vec![0.0; n_max * g.f_node],
            adj: vec![0.0; n_max * n_max],
            edge_attr: if with_edge_attr {
                vec![0.0; n_max * n_max * g.f_edge]
            } else {
                Vec::new()
            },
            f_edge: if with_edge_attr { g.f_edge } else { 0 },
            mask: vec![0.0; n_max],
            eig: vec![0.0; n_max],
        };
        d.fill_from(g)?;
        Ok(d)
    }

    /// Re-fill in place from another graph (zero-allocation hot path for
    /// the serving pipeline — buffers are reused across requests).
    pub fn fill_from(&mut self, g: &CooGraph) -> Result<()> {
        if g.n > self.n_max {
            bail!("graph has {} nodes, exceeds capacity {}", g.n, self.n_max);
        }
        if g.f_node != self.f_node {
            bail!("node feature width {} != {}", g.f_node, self.f_node);
        }
        if self.f_edge != 0 && g.f_edge != self.f_edge {
            bail!("edge feature width {} != {}", g.f_edge, self.f_edge);
        }
        let nm = self.n_max;
        self.x.fill(0.0);
        self.adj.fill(0.0);
        self.edge_attr.fill(0.0);
        self.mask.fill(0.0);
        self.eig.fill(0.0);
        self.n_real = g.n;
        self.x[..g.n * g.f_node].copy_from_slice(&g.node_feat);
        for (ei, &(s, t)) in g.edges.iter().enumerate() {
            let (s, t) = (s as usize, t as usize);
            // Kernel convention: adj[i, j] weights message j -> i.
            self.adj[t * nm + s] = 1.0;
            if self.f_edge > 0 {
                let src = &g.edge_feat[ei * g.f_edge..(ei + 1) * g.f_edge];
                let off = (t * nm + s) * self.f_edge;
                self.edge_attr[off..off + self.f_edge].copy_from_slice(src);
            }
        }
        self.mask[..g.n].fill(1.0);
        Ok(())
    }

    pub fn adj_at(&self, i: usize, j: usize) -> f32 {
        self.adj[i * self.n_max + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::forall;

    fn sample() -> CooGraph {
        CooGraph::from_undirected(
            3,
            &[(0, 1), (1, 2)],
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            2,
            &[7.0, 8.0],
            1,
        )
        .unwrap()
    }

    #[test]
    fn pads_and_masks() {
        let d = DenseGraph::from_coo(&sample(), 5, true).unwrap();
        assert_eq!(d.mask, vec![1.0, 1.0, 1.0, 0.0, 0.0]);
        assert_eq!(&d.x[..6], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(d.x[6..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn adjacency_is_symmetric_for_undirected() {
        let d = DenseGraph::from_coo(&sample(), 4, false).unwrap();
        assert_eq!(d.adj_at(0, 1), 1.0);
        assert_eq!(d.adj_at(1, 0), 1.0);
        assert_eq!(d.adj_at(0, 2), 0.0);
        let e: f32 = d.adj.iter().sum();
        assert_eq!(e, 4.0); // 2 undirected edges -> 4 directed entries
    }

    #[test]
    fn edge_attr_mirrored() {
        let d = DenseGraph::from_coo(&sample(), 4, true).unwrap();
        let nm = 4;
        assert_eq!(d.edge_attr[(0 * nm + 1) * 1], 7.0);
        assert_eq!(d.edge_attr[(1 * nm + 0) * 1], 7.0);
        assert_eq!(d.edge_attr[(2 * nm + 1) * 1], 8.0);
    }

    #[test]
    fn rejects_oversized_graph() {
        assert!(DenseGraph::from_coo(&sample(), 2, false).is_err());
    }

    #[test]
    fn refill_equals_fresh() {
        let g1 = sample();
        let mut g2 = sample();
        g2.node_feat.iter_mut().for_each(|v| *v += 10.0);
        let mut d = DenseGraph::from_coo(&g1, 6, true).unwrap();
        d.fill_from(&g2).unwrap();
        let fresh = DenseGraph::from_coo(&g2, 6, true).unwrap();
        assert_eq!(d.x, fresh.x);
        assert_eq!(d.adj, fresh.adj);
        assert_eq!(d.edge_attr, fresh.edge_attr);
        assert_eq!(d.mask, fresh.mask);
    }

    #[test]
    fn prop_adj_entry_count_matches_edges() {
        forall("dense-edges", 100, 0xDE45E, |rng| {
            let n = rng.range(1, 20);
            let mut und = Vec::new();
            for u in 0..n {
                for v in (u + 1)..n {
                    if rng.chance(0.3) {
                        und.push((u as u32, v as u32));
                    }
                }
            }
            let g = CooGraph::from_undirected(
                n,
                &und,
                vec![0.0; n],
                1,
                &vec![0.0; und.len()],
                1,
            )
            .unwrap();
            let d = DenseGraph::from_coo(&g, n + 3, false).unwrap();
            let nnz = d.adj.iter().filter(|&&v| v != 0.0).count();
            prop_assert!(
                nnz == und.len() * 2,
                "nnz {} != 2*undirected {}",
                nnz,
                und.len() * 2
            );
            Ok(())
        });
    }
}

//! Graph data representations (paper Section 3.2): COO raw input,
//! CSR/CSC compressed adjacency with the on-chip converter, dense padded
//! tensors for the TPU-adapted kernels, and the spectral substrate DGN
//! needs for its directional aggregation.

pub mod coo;
pub mod csr;
pub mod dense;
pub mod spectral;

pub use coo::CooGraph;
pub use csr::{Csc, Csr};
pub use dense::DenseGraph;
pub use spectral::{fiedler_vector, EigResult};

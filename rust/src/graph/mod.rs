//! Graph data representations (paper Section 3.2): COO raw input,
//! CSR/CSC compressed adjacency with the on-chip converter, sorted
//! dedup in-neighbor lists for the stage-IR interpreter, dense padded
//! tensors for the AOT artifact contract, and the spectral substrate
//! DGN needs for its directional aggregation.
//!
//! [`GraphBatch`] is the single ingest entry point: every consumer that
//! needs adjacency (simulator, coordinator, baselines) goes through one
//! COO→CSR/CSC conversion — the paper's zero-preprocessing contract.
//! [`FusedBatch`] merges several ingested graphs into one
//! block-diagonal execution unit for fused micro-batch inference
//! (see `docs/ARCHITECTURE.md`), without re-converting anything.

pub mod batch;
pub mod coo;
pub mod csr;
pub mod dense;
pub mod fused;
pub mod nbr;
pub mod spectral;

pub use batch::{converter_cycles, GraphBatch, GraphStats};
pub use coo::CooGraph;
pub use csr::{Csc, Csr};
pub use dense::DenseGraph;
pub use fused::{FusedBatch, FusedSegment};
pub use nbr::InNbrs;
pub use spectral::{fiedler_vector, fiedler_vector_csr, EigResult};

//! The unified graph-ingest entry point — the paper's zero-preprocessing
//! contract (§3.1/§3.2) enforced in exactly one place.
//!
//! A producer hands over a raw COO edge list; [`GraphBatch::ingest`]
//! validates it and runs the on-chip converter model **once**, yielding
//! the CSR adjacency every downstream consumer shares (the CSC mirror
//! is derived on demand via [`GraphBatch::csc`]):
//!
//! * the cycle-level simulator (`sim::accel`, `sim::large`) walks
//!   `csr.degree` / `csr.row(..)` for the MP PE schedule;
//! * the coordinator's prep workers ingest each request once and pass
//!   the batch to the executor (no re-derivation on the hot path);
//! * the analytic CPU/GPU baselines read [`GraphStats`] off the batch;
//! * DGN's eigensolve ([`GraphBatch::fiedler`]) reuses the same CSR.
//!
//! `converter_cycles` is the cost model of that single conversion: the
//! hardware converter makes one counting pass and one placement pass
//! over the streamed edge list plus a prefix-sum over the degree table,
//! `2E + N` cycles, and "runs once when the graph is streamed into the
//! FPGA and is reused for all the GNN layers" (§3.2).

use std::sync::OnceLock;

use anyhow::Result;

use super::coo::CooGraph;
use super::csr::{Csc, Csr};
use super::nbr::InNbrs;
use super::spectral::{fiedler_vector_csr, EigResult};

/// Converter cycle cost: two passes over E edges + prefix over N nodes.
pub fn converter_cycles(n: usize, e: usize) -> u64 {
    (2 * e + n) as u64
}

/// Workload statistics the analytic baselines need about one graph.
#[derive(Clone, Copy, Debug)]
pub struct GraphStats {
    pub n: usize,
    /// Directed edge count.
    pub e: usize,
    pub f_in: usize,
}

impl GraphStats {
    pub fn of(g: &CooGraph) -> GraphStats {
        GraphStats {
            n: g.n,
            e: g.num_edges(),
            f_in: g.f_node,
        }
    }
}

/// One ingested graph: the raw COO input plus the CSR adjacency the
/// on-chip converter derives from it, converted exactly once.
#[derive(Clone, Debug)]
pub struct GraphBatch {
    /// The raw input, kept for feature access and densification.
    pub graph: CooGraph,
    /// Out-neighbors grouped by source (merged scatter-gather order).
    pub csr: Csr,
    /// Modeled cost of the one-time on-chip conversion (`2E + N`).
    pub converter_cycles: u64,
    /// Sorted dedup in-neighbor lists, built on first use and shared
    /// by every subsequent plan execution over this batch (the
    /// stage-IR interpreter's adjacency view).
    nbrs: OnceLock<InNbrs>,
}

impl GraphBatch {
    /// Validate a raw COO graph and convert it once. This is the only
    /// place in the crate where COO becomes CSR/CSC.
    pub fn ingest(graph: CooGraph) -> Result<GraphBatch> {
        graph.validate()?;
        Ok(Self::ingest_unchecked(graph))
    }

    /// Conversion without re-validating (for graphs produced by our own
    /// generators, which are valid by construction).
    pub fn ingest_unchecked(graph: CooGraph) -> GraphBatch {
        let csr = Csr::from_coo(&graph);
        let converter_cycles = converter_cycles(graph.n, graph.num_edges());
        GraphBatch {
            graph,
            csr,
            converter_cycles,
            nbrs: OnceLock::new(),
        }
    }

    /// The CSC view (gather-first execution order, §3.4), derived on
    /// demand — no current hot path consumes it, so eager construction
    /// would tax every serving request for nothing.
    pub fn csc(&self) -> Csc {
        Csc::from_coo(&self.graph)
    }

    /// Sorted dedup in-neighbor lists — the stage-IR interpreter's
    /// adjacency view, built once on first forward and reused by every
    /// later forward over this batch (one conversion per ingest, same
    /// contract as the CSR).
    pub fn in_nbrs(&self) -> &InNbrs {
        self.nbrs.get_or_init(|| InNbrs::from_coo(&self.graph))
    }

    pub fn n(&self) -> usize {
        self.graph.n
    }

    pub fn num_edges(&self) -> usize {
        self.graph.num_edges()
    }

    pub fn stats(&self) -> GraphStats {
        GraphStats::of(&self.graph)
    }

    /// First non-trivial Laplacian eigenvector over the already-built
    /// CSR (DGN's directional substrate; no re-conversion).
    pub fn fiedler(&self, max_iter: usize, tol: f64) -> EigResult {
        fiedler_vector_csr(&self.csr, max_iter, tol)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::forall;
    use crate::util::rng::Rng;
    use std::collections::BTreeSet;

    fn random_coo(rng: &mut Rng) -> CooGraph {
        let n = rng.range(1, 50);
        let m = rng.range(0, 160);
        let edges: Vec<(u32, u32)> = (0..m)
            .map(|_| (rng.below(n) as u32, rng.below(n) as u32))
            .collect();
        CooGraph {
            n,
            edges,
            node_feat: vec![0.0; n],
            f_node: 1,
            edge_feat: vec![],
            f_edge: 0,
        }
    }

    #[test]
    fn ingest_rejects_invalid_graphs() {
        let bad = CooGraph {
            n: 2,
            edges: vec![(0, 7)],
            node_feat: vec![0.0; 2],
            f_node: 1,
            edge_feat: vec![],
            f_edge: 0,
        };
        assert!(GraphBatch::ingest(bad).is_err());
    }

    #[test]
    fn converter_cost_is_two_e_plus_n() {
        let mut rng = Rng::new(3);
        let b = GraphBatch::ingest(random_coo(&mut rng)).unwrap();
        assert_eq!(
            b.converter_cycles,
            (2 * b.num_edges() + b.n()) as u64
        );
        assert_eq!(converter_cycles(4, 6), 16);
        assert_eq!(converter_cycles(0, 0), 0);
    }

    #[test]
    fn prop_roundtrip_preserves_degrees() {
        forall("batch-degrees", 150, 0xBA7C4, |rng| {
            let g = random_coo(rng);
            let (out, inn) = (g.out_degrees(), g.in_degrees());
            let b = GraphBatch::ingest(g).unwrap();
            prop_assert!(b.csr.degree == out, "CSR degree table != out-degrees");
            prop_assert!(b.csc().degree == inn, "CSC degree table != in-degrees");
            let sum: u32 = b.csr.degree.iter().sum();
            prop_assert!(
                sum as usize == b.num_edges(),
                "sum(degree) {} != E {}",
                sum,
                b.num_edges()
            );
            Ok(())
        });
    }

    #[test]
    fn prop_roundtrip_preserves_neighbor_sets() {
        forall("batch-neighbor-sets", 150, 0xBA7C5, |rng| {
            let g = random_coo(rng);
            let b = GraphBatch::ingest(g).unwrap();
            let csc = b.csc();
            for v in 0..b.n() {
                // CSR row of v == multiset of COO out-neighbors of v.
                let mut want: Vec<u32> = b
                    .graph
                    .edges
                    .iter()
                    .filter(|&&(s, _)| s as usize == v)
                    .map(|&(_, t)| t)
                    .collect();
                let mut got = b.csr.row(v).to_vec();
                want.sort_unstable();
                got.sort_unstable();
                prop_assert!(got == want, "CSR row {v} mismatch");
                // CSC column of v == multiset of COO in-neighbors of v.
                let mut want_in: Vec<u32> = b
                    .graph
                    .edges
                    .iter()
                    .filter(|&&(_, t)| t as usize == v)
                    .map(|&(s, _)| s)
                    .collect();
                let mut got_in = csc.col(v).to_vec();
                want_in.sort_unstable();
                got_in.sort_unstable();
                prop_assert!(got_in == want_in, "CSC col {v} mismatch");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_edge_idx_maps_back_exactly() {
        forall("batch-edge-idx", 100, 0xBA7C6, |rng| {
            let g = random_coo(rng);
            let b = GraphBatch::ingest(g).unwrap();
            let mut seen = BTreeSet::new();
            for v in 0..b.n() {
                for (nbr, &ei) in b.csr.row(v).iter().zip(b.csr.row_edges(v)) {
                    prop_assert!(
                        b.graph.edges[ei as usize] == (v as u32, *nbr),
                        "edge_idx {ei} does not point back to ({v},{nbr})"
                    );
                    prop_assert!(seen.insert(ei), "edge id {ei} duplicated");
                }
            }
            prop_assert!(
                seen.len() == b.num_edges(),
                "edge ids not a bijection"
            );
            Ok(())
        });
    }

    /// Adversarial generator: empty graphs (n = 0), edgeless graphs,
    /// guaranteed isolated tail nodes (edges only touch a prefix), and
    /// forced duplicate edges — the shapes real streams throw at
    /// `ingest` that a uniform generator rarely produces.
    fn adversarial_coo(rng: &mut Rng) -> CooGraph {
        let n = rng.range(0, 40);
        let mut edges = Vec::new();
        if n > 0 {
            let active = rng.range(1, n + 1);
            for _ in 0..rng.range(0, 120) {
                let e = (rng.below(active) as u32, rng.below(active) as u32);
                edges.push(e);
                if rng.chance(0.3) {
                    edges.push(e); // forced duplicate
                }
            }
        }
        CooGraph {
            n,
            edges,
            node_feat: vec![0.0; n],
            f_node: 1,
            edge_feat: vec![],
            f_edge: 0,
        }
    }

    #[test]
    fn prop_adversarial_roundtrip_csr_csc() {
        forall("batch-adversarial-roundtrip", 150, 0xADC0, |rng| {
            let g = adversarial_coo(rng);
            let e = g.edges.len();
            let b = GraphBatch::ingest(g).unwrap();
            prop_assert!(b.num_edges() == e, "ingest changed the edge count");
            let csc = b.csc();
            let out_sum: u32 = b.csr.degree.iter().sum();
            let in_sum: u32 = csc.degree.iter().sum();
            prop_assert!(out_sum as usize == e, "sum(out-deg) {out_sum} != E {e}");
            prop_assert!(in_sum as usize == e, "sum(in-deg) {in_sum} != E {e}");
            // CSR and CSC must encode exactly the COO edge multiset.
            let mut via_coo = b.graph.edges.clone();
            let mut via_csr = Vec::with_capacity(e);
            let mut via_csc = Vec::with_capacity(e);
            for v in 0..b.n() {
                for &t in b.csr.row(v) {
                    via_csr.push((v as u32, t));
                }
                for &s in csc.col(v) {
                    via_csc.push((s, v as u32));
                }
            }
            via_coo.sort_unstable();
            via_csr.sort_unstable();
            via_csc.sort_unstable();
            prop_assert!(via_csr == via_coo, "CSR lost or invented edges");
            prop_assert!(via_csc == via_coo, "CSC lost or invented edges");
            // Isolated nodes: zero degree and empty rows on both sides.
            let mut touched = vec![false; b.n()];
            for &(s, t) in &b.graph.edges {
                touched[s as usize] = true;
                touched[t as usize] = true;
            }
            for (v, &is_touched) in touched.iter().enumerate() {
                if !is_touched {
                    prop_assert!(
                        b.csr.degree[v] == 0 && csc.degree[v] == 0,
                        "isolated node {v} has nonzero degree"
                    );
                    prop_assert!(
                        b.csr.row(v).is_empty() && csc.col(v).is_empty(),
                        "isolated node {v} has neighbors"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_duplicate_edges_preserved_with_multiplicity() {
        forall("batch-duplicate-multiplicity", 100, 0xD0B1, |rng| {
            let n = rng.range(2, 20);
            let (s, t) = (rng.below(n) as u32, rng.below(n) as u32);
            let copies = rng.range(2, 6);
            let g = CooGraph {
                n,
                edges: vec![(s, t); copies],
                node_feat: vec![0.0; n],
                f_node: 1,
                edge_feat: vec![],
                f_edge: 0,
            };
            let b = GraphBatch::ingest(g).unwrap();
            let row_hits = b.csr.row(s as usize).iter().filter(|&&x| x == t).count();
            let col_hits = b.csc().col(t as usize).iter().filter(|&&x| x == s).count();
            prop_assert!(
                row_hits == copies,
                "CSR collapsed duplicates: {row_hits} != {copies}"
            );
            prop_assert!(
                col_hits == copies,
                "CSC collapsed duplicates: {col_hits} != {copies}"
            );
            prop_assert!(
                b.csr.degree[s as usize] as usize == copies,
                "degree table missed duplicates"
            );
            Ok(())
        });
    }

    #[test]
    fn deterministic_under_seeded_generation() {
        // Same seed -> same generated graph -> identical conversion.
        for seed in [1u64, 7, 0xDEAD] {
            let a = GraphBatch::ingest(random_coo(&mut Rng::new(seed))).unwrap();
            let b = GraphBatch::ingest(random_coo(&mut Rng::new(seed))).unwrap();
            assert_eq!(a.graph, b.graph);
            assert_eq!(a.csr, b.csr);
            assert_eq!(a.csc(), b.csc());
            assert_eq!(a.converter_cycles, b.converter_cycles);
        }
    }

    #[test]
    fn fiedler_over_batch_matches_direct() {
        let g = CooGraph::from_undirected(
            4,
            &[(0, 1), (1, 2), (2, 3)],
            vec![0.0; 4],
            1,
            &[],
            0,
        )
        .unwrap();
        let b = GraphBatch::ingest(g.clone()).unwrap();
        let via_batch = b.fiedler(2000, 1e-12);
        let direct = crate::graph::spectral::fiedler_vector(&g, 2000, 1e-12);
        assert_eq!(via_batch.vector, direct.vector);
        assert_eq!(via_batch.iterations, direct.iterations);
    }

    #[test]
    fn self_loops_and_empty_graphs_ingest_cleanly() {
        let empty = CooGraph {
            n: 0,
            edges: vec![],
            node_feat: vec![],
            f_node: 0,
            edge_feat: vec![],
            f_edge: 0,
        };
        let b = GraphBatch::ingest(empty).unwrap();
        assert_eq!(b.converter_cycles, 0);

        let looped = CooGraph {
            n: 2,
            edges: vec![(0, 0), (0, 1), (1, 1)],
            node_feat: vec![0.0; 2],
            f_node: 1,
            edge_feat: vec![],
            f_edge: 0,
        };
        let b = GraphBatch::ingest(looped).unwrap();
        assert_eq!(b.csr.degree, vec![2, 1]);
        assert_eq!(b.csr.row(0), &[0, 1]);
        assert_eq!(b.csc().degree, vec![1, 2]);
    }
}

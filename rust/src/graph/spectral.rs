//! Laplacian eigenvector substrate for DGN (paper Section 4.4).
//!
//! DGN "accepts the precomputed Laplacian eigenvectors as a parameter";
//! in the paper's flow they come from the host. Here the serving path
//! computes the first non-trivial eigenvector of the symmetric
//! normalized Laplacian L = I - D^-1/2 A D^-1/2 with deflated power
//! iteration on M = 2I - L (so the *smallest* Laplacian eigenvalues
//! become dominant), which is O(E) per iteration on CSR — suitable for
//! the streaming path.
//!
//! Sign convention (shared with python graphgen.laplacian_eigvec): the
//! entry of largest magnitude is positive.

use super::coo::CooGraph;
use super::csr::Csr;

/// Result of the eigensolve, with convergence diagnostics.
#[derive(Clone, Debug)]
pub struct EigResult {
    /// First non-trivial eigenvector of L_sym, unit norm, sign-fixed.
    pub vector: Vec<f32>,
    /// Rayleigh quotient v^T L v (the eigenvalue estimate, in [0, 2]).
    pub value: f64,
    pub iterations: usize,
}

/// Power iteration with deflation of the trivial kernel vector
/// v0 = D^{1/2} 1 / ||D^{1/2} 1||.
///
/// Convenience wrapper converting on the spot; the ingest path uses
/// [`fiedler_vector_csr`] over [`crate::graph::GraphBatch`]'s CSR so the
/// graph is converted exactly once.
pub fn fiedler_vector(g: &CooGraph, max_iter: usize, tol: f64) -> EigResult {
    fiedler_vector_csr(&Csr::from_coo(g), max_iter, tol)
}

/// Power iteration over an already-converted CSR adjacency.
pub fn fiedler_vector_csr(csr: &Csr, max_iter: usize, tol: f64) -> EigResult {
    let n = csr.n();
    if n == 0 {
        return EigResult {
            vector: vec![],
            value: 0.0,
            iterations: 0,
        };
    }
    let deg: Vec<f64> = csr.degree.iter().map(|&d| d as f64).collect();
    let dinv_sqrt: Vec<f64> = deg
        .iter()
        .map(|&d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 })
        .collect();

    // Trivial eigenvector of L_sym (eigenvalue 0): D^{1/2} 1, normalized.
    let mut v0: Vec<f64> = deg.iter().map(|&d| d.sqrt()).collect();
    let norm0 = l2(&v0);
    if norm0 > 0.0 {
        v0.iter_mut().for_each(|x| *x /= norm0);
    }

    // M v = 2v - L v = v + D^-1/2 A D^-1/2 v ; dominant non-deflated
    // eigenpair of M is (2 - lambda_2, v_2).
    let matvec = |v: &[f64], out: &mut [f64]| {
        for i in 0..n {
            let mut acc = 0.0;
            for (k, &j) in csr.row(i).iter().enumerate() {
                let _ = k;
                acc += dinv_sqrt[j as usize] * v[j as usize];
            }
            out[i] = v[i] + dinv_sqrt[i] * acc;
        }
    };

    // Deterministic pseudo-random start, deflated against v0.
    let mut v: Vec<f64> = (0..n)
        .map(|i| {
            let h = (i as u64)
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .rotate_left(31);
            (h as f64 / u64::MAX as f64) - 0.5
        })
        .collect();
    deflate(&mut v, &v0);
    normalize(&mut v);

    let mut tmp = vec![0.0f64; n];
    let mut iterations = 0;
    let mut prev = vec![0.0f64; n];
    for it in 0..max_iter {
        iterations = it + 1;
        matvec(&v, &mut tmp);
        deflate(&mut tmp, &v0);
        let norm = l2(&tmp);
        if norm < 1e-30 {
            // Graph with no non-trivial structure (e.g. n == 1).
            break;
        }
        tmp.iter_mut().for_each(|x| *x /= norm);
        let delta: f64 = v
            .iter()
            .zip(&tmp)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        prev.copy_from_slice(&v);
        v.copy_from_slice(&tmp);
        if delta < tol && it > 2 {
            break;
        }
    }

    // Rayleigh quotient on L: v^T L v = |v|^2 - v^T (D^-1/2 A D^-1/2) v.
    matvec(&v, &mut tmp); // tmp = v + Av'
    let vav: f64 = v.iter().zip(&tmp).map(|(a, b)| a * (b - a)).sum();
    let value = (1.0 - vav).clamp(0.0, 2.0);

    // Sign fix: largest-magnitude entry positive.
    let mut imax = 0;
    for i in 0..n {
        if v[i].abs() > v[imax].abs() {
            imax = i;
        }
    }
    if n > 0 && v[imax] < 0.0 {
        v.iter_mut().for_each(|x| *x = -*x);
    }

    EigResult {
        vector: v.iter().map(|&x| x as f32).collect(),
        value,
        iterations,
    }
}

fn l2(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

fn normalize(v: &mut [f64]) {
    let n = l2(v);
    if n > 0.0 {
        v.iter_mut().for_each(|x| *x /= n);
    }
}

fn deflate(v: &mut [f64], v0: &[f64]) {
    let dot: f64 = v.iter().zip(v0).map(|(a, b)| a * b).sum();
    for (x, &b) in v.iter_mut().zip(v0) {
        *x -= dot * b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph(n: usize, und: &[(u32, u32)]) -> CooGraph {
        CooGraph::from_undirected(n, und, vec![0.0; n], 1, &[], 0).unwrap()
    }

    fn laplacian_residual(g: &CooGraph, r: &EigResult) -> f64 {
        // || L v - lambda v ||
        let n = g.n;
        let csr = Csr::from_coo(g);
        let dinv: Vec<f64> = csr
            .degree
            .iter()
            .map(|&d| if d > 0 { 1.0 / (d as f64).sqrt() } else { 0.0 })
            .collect();
        let v: Vec<f64> = r.vector.iter().map(|&x| x as f64).collect();
        let mut res = 0.0f64;
        for i in 0..n {
            let mut av = 0.0;
            for &j in csr.row(i) {
                av += dinv[j as usize] * v[j as usize];
            }
            let lv = v[i] - dinv[i] * av;
            res += (lv - r.value * v[i]).powi(2);
        }
        res.sqrt()
    }

    #[test]
    fn path2_eigenvalue_two() {
        // P2: L_sym spectrum {0, 2}; non-trivial eigenvector (1,-1)/sqrt2.
        let g = graph(2, &[(0, 1)]);
        let r = fiedler_vector(&g, 500, 1e-12);
        assert!((r.value - 2.0).abs() < 1e-6, "lambda={}", r.value);
        assert!((r.vector[0] + r.vector[1]).abs() < 1e-5);
    }

    #[test]
    fn path3_eigenvalue_one() {
        // P3: L_sym spectrum {0, 1, 2}; power iteration on 2I-L finds
        // the *smallest* non-trivial lambda = 1.
        let g = graph(3, &[(0, 1), (1, 2)]);
        let r = fiedler_vector(&g, 2000, 1e-12);
        assert!((r.value - 1.0).abs() < 1e-5, "lambda={}", r.value);
        // Eigenvector for lambda=1 on P3: (1, 0, -1)/sqrt2 direction.
        assert!(r.vector[1].abs() < 1e-4);
    }

    #[test]
    fn eigen_residual_small_on_random_graph() {
        let und: Vec<(u32, u32)> = vec![
            (0, 1),
            (0, 2),
            (1, 3),
            (2, 3),
            (3, 4),
            (4, 5),
            (2, 5),
            (1, 6),
            (6, 7),
            (5, 7),
        ];
        let g = graph(8, &und);
        let r = fiedler_vector(&g, 5000, 1e-13);
        assert!(
            laplacian_residual(&g, &r) < 1e-4,
            "residual {}",
            laplacian_residual(&g, &r)
        );
        assert!(r.value > 0.0 && r.value < 2.0);
    }

    #[test]
    fn orthogonal_to_trivial_vector() {
        let g = graph(5, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 0)]);
        let r = fiedler_vector(&g, 2000, 1e-12);
        let csr = Csr::from_coo(&g);
        let dot: f64 = r
            .vector
            .iter()
            .zip(&csr.degree)
            .map(|(&v, &d)| v as f64 * (d as f64).sqrt())
            .sum();
        assert!(dot.abs() < 1e-5, "not deflated: {dot}");
    }

    #[test]
    fn sign_convention_largest_entry_positive() {
        let g = graph(4, &[(0, 1), (1, 2), (2, 3)]);
        let r = fiedler_vector(&g, 2000, 1e-12);
        // First index of maximum magnitude (strict-gt scan) — the same
        // tie-break as the library and as numpy's argmax in graphgen.
        let mut imax = 0;
        for i in 0..4 {
            if r.vector[i].abs() > r.vector[imax].abs() {
                imax = i;
            }
        }
        assert!(r.vector[imax] > 0.0);
    }

    #[test]
    fn unit_norm() {
        let g = graph(6, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)]);
        let r = fiedler_vector(&g, 2000, 1e-12);
        let n: f64 = r.vector.iter().map(|&x| (x as f64).powi(2)).sum();
        assert!((n - 1.0).abs() < 1e-4);
    }

    #[test]
    fn singleton_graph_does_not_crash() {
        let g = graph(1, &[]);
        let r = fiedler_vector(&g, 100, 1e-12);
        assert_eq!(r.vector.len(), 1);
    }
}

// The cluster tier sits on the serving path: degrade, don't panic.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

//! The cluster tier: `gengnn ingress` fronting N `gengnn serve`
//! backends over the existing wire protocol.
//!
//! One process was one machine until this module; the ROADMAP's
//! fleet-scale claim needs a replica pool behind a model-aware router
//! (the serving analogue of FlowGNN's multi-queue parallelism inside
//! one device). The ingress speaks v1–v4 on the client side and
//! proxies frames byte-for-byte — only the correlation id (and
//! therefore the checksum) is rewritten in each direction — so the
//! fleet inherits the single-process bit-exactness contract wholesale:
//! the same request stream through 1 backend and through N backends
//! produces identical response bytes (`rust/tests/ingress_e2e.rs`).
//!
//! * [`spec`]   — the declarative cluster spec (`cluster.toml`):
//!   backend addrs, model assignments, probe/ejection/reconcile knobs
//! * [`health`] — the per-backend probe state machine:
//!   Healthy → Ejected after K consecutive failures, Ejected →
//!   Probation on a probe success, Probation → Healthy after M
//!   consecutive successes (any probation failure relapses)
//! * [`router`] — per-model replica sets with round-robin or
//!   least-in-flight selection among healthy members
//! * [`backend`] — per-backend runtime state: the demuxing response
//!   link, the LIST_MODELS probe, the managed child process
//! * [`proxy`]  — the [`Ingress`] front: accept loop, id-rewriting
//!   frame forwarding, drain on shutdown, the prober and the
//!   node-agent-style reconciler that respawns dead managed backends
//! * [`fault`]  — the test-only [`FaultPlan`] (env/config-driven):
//!   kill a backend mid-load, black-hole probe replies, corrupt one
//!   proxied frame
//!
//! `docs/CLUSTER.md` is the operator-facing description of the
//! topology and its contracts.

pub mod backend;
pub mod fault;
pub mod health;
pub mod proxy;
pub mod router;
pub mod spec;

pub use fault::FaultPlan;
pub use health::{HealthState, ProbeTracker, Transition};
pub use proxy::{Ingress, IngressConfig};
pub use router::{Balance, Router};
pub use spec::{BackendSpec, ClusterSpec, ProbeKnobs, ReconcileKnobs};

//! Test-only fault injection for the cluster tier.
//!
//! A [`FaultPlan`] is parsed from the `GENGNN_FAULT_PLAN` environment
//! variable by the `ingress` binary (or injected programmatically via
//! `IngressConfig` in tests, which keeps parallel test runs from
//! fighting over process environment). An empty plan — the default —
//! is zero-cost on the data plane beyond one frame counter.
//!
//! Directives (`;`-separated):
//!
//! * `kill-backend=IDX@N` — after the Nth client frame arrives, SIGKILL
//!   the managed child of backend IDX (mid-load crash; exercises link
//!   failure accounting, ejection, and reconciler recovery)
//! * `drop-probes=IDX:COUNT` — black-hole the next COUNT probe
//!   attempts against backend IDX (the probe never runs; exercises
//!   probe-driven ejection while the data-plane link stays healthy)
//! * `delay-probes-ms=MS` — sleep before every probe attempt
//!   (exercises probe timeout handling without a slow backend)
//! * `corrupt-frame=N` — corrupt the Nth client frame after its id
//!   rewrite: the QoS priority byte is flipped to an invalid value and
//!   the checksum re-sealed (`proto::corrupt_request_priority`), so the
//!   backend's id salvage still works and its `BadRequest` flows back
//!   under the caller's id — loadgen accounts it as `failed`, never
//!   `lost`
//!
//! Example: `GENGNN_FAULT_PLAN="kill-backend=1@50;corrupt-frame=10"`.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

/// The declarative fault plan (immutable once parsed).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Corrupt the Nth client frame (1-based).
    pub corrupt_frame: Option<u64>,
    /// `(backend index, after Nth client frame)`: SIGKILL the managed
    /// child once the frame counter reaches N.
    pub kill_backend: Option<(usize, u64)>,
    /// `(backend index, count)`: black-hole that many probe attempts.
    pub drop_probes: Vec<(usize, u32)>,
    /// Milliseconds to sleep before every probe attempt.
    pub delay_probes_ms: u64,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        *self == FaultPlan::default()
    }

    /// Parse a plan string (see module docs for the grammar).
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for directive in s.split(';') {
            let directive = directive.trim();
            if directive.is_empty() {
                continue;
            }
            let (key, value) = directive
                .split_once('=')
                .with_context(|| format!("fault directive {directive:?} has no `=`"))?;
            match key.trim() {
                "corrupt-frame" => {
                    let n: u64 = value.trim().parse().context("corrupt-frame wants N")?;
                    if n == 0 {
                        bail!("corrupt-frame is 1-based");
                    }
                    plan.corrupt_frame = Some(n);
                }
                "kill-backend" => {
                    let (idx, after) = value
                        .split_once('@')
                        .context("kill-backend wants IDX@N")?;
                    plan.kill_backend = Some((
                        idx.trim().parse().context("kill-backend backend index")?,
                        after.trim().parse().context("kill-backend frame count")?,
                    ));
                }
                "drop-probes" => {
                    let (idx, count) = value
                        .split_once(':')
                        .context("drop-probes wants IDX:COUNT")?;
                    plan.drop_probes.push((
                        idx.trim().parse().context("drop-probes backend index")?,
                        count.trim().parse().context("drop-probes count")?,
                    ));
                }
                "delay-probes-ms" => {
                    plan.delay_probes_ms =
                        value.trim().parse().context("delay-probes-ms wants MS")?;
                }
                other => bail!("unknown fault directive {other:?}"),
            }
        }
        Ok(plan)
    }

    /// The plan carried by `GENGNN_FAULT_PLAN`, or the empty plan.
    pub fn from_env() -> Result<FaultPlan> {
        match std::env::var("GENGNN_FAULT_PLAN") {
            Ok(s) => FaultPlan::parse(&s).context("parsing GENGNN_FAULT_PLAN"),
            Err(_) => Ok(FaultPlan::default()),
        }
    }

    /// Does the plan reference a backend index outside the fleet?
    pub fn validate(&self, backend_count: usize) -> Result<()> {
        let check = |idx: usize, what: &str| -> Result<()> {
            if idx >= backend_count {
                bail!("{what} references backend {idx}, fleet has {backend_count}");
            }
            Ok(())
        };
        if let Some((idx, _)) = self.kill_backend {
            check(idx, "kill-backend")?;
        }
        for &(idx, _) in &self.drop_probes {
            check(idx, "drop-probes")?;
        }
        Ok(())
    }
}

/// Mutable consumption state for one ingress run (the plan itself
/// stays immutable; this tracks what already fired).
pub(crate) struct FaultState {
    /// Client frames seen so far (counted for every frame, parseable
    /// or not, so directive offsets are stable under error traffic).
    pub frames: AtomicU64,
    /// The kill directive fired.
    pub killed: AtomicBool,
    /// Remaining probe drops per backend.
    pub probe_drops: Vec<AtomicU32>,
}

impl FaultState {
    pub fn new(plan: &FaultPlan, backend_count: usize) -> FaultState {
        let probe_drops: Vec<AtomicU32> = (0..backend_count).map(|_| AtomicU32::new(0)).collect();
        for &(idx, count) in &plan.drop_probes {
            if let Some(slot) = probe_drops.get(idx) {
                slot.fetch_add(count, Ordering::Relaxed);
            }
        }
        FaultState {
            frames: AtomicU64::new(0),
            killed: AtomicBool::new(false),
            probe_drops,
        }
    }

    /// Consume one probe-drop token for backend `idx`; true = this
    /// probe attempt is black-holed.
    pub fn consume_probe_drop(&self, idx: usize) -> bool {
        let Some(slot) = self.probe_drops.get(idx) else {
            return false;
        };
        slot.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| n.checked_sub(1))
            .is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_directive() {
        let plan = FaultPlan::parse(
            "kill-backend=1@50; corrupt-frame=10; drop-probes=0:4; delay-probes-ms=25",
        )
        .unwrap();
        assert_eq!(plan.kill_backend, Some((1, 50)));
        assert_eq!(plan.corrupt_frame, Some(10));
        assert_eq!(plan.drop_probes, vec![(0, 4)]);
        assert_eq!(plan.delay_probes_ms, 25);
        assert!(!plan.is_empty());
        plan.validate(2).unwrap();
        assert!(plan.validate(1).is_err());
    }

    #[test]
    fn empty_and_malformed_plans() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" ; ").unwrap().is_empty());
        for bad in ["boom=1", "kill-backend=1", "drop-probes=3", "corrupt-frame=0"] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn probe_drop_tokens_deplete() {
        let plan = FaultPlan::parse("drop-probes=1:2").unwrap();
        let state = FaultState::new(&plan, 2);
        assert!(!state.consume_probe_drop(0));
        assert!(state.consume_probe_drop(1));
        assert!(state.consume_probe_drop(1));
        assert!(!state.consume_probe_drop(1));
        // Out-of-range indices never fire (validate catches them at
        // boot; this is the belt to that suspender).
        assert!(!state.consume_probe_drop(9));
    }
}

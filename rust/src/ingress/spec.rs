//! The declarative cluster spec (`cluster.toml`).
//!
//! A hand-rolled parser for the TOML subset the spec needs — plain
//! sections, one array-of-tables (`[[backend]]`), string / integer /
//! boolean / string-array values, `#` comments — because the vendor
//! set carries no TOML crate and the spec grammar is small enough to
//! own. Unknown sections and keys are hard errors: a typoed knob must
//! not silently fall back to a default in the config that decides
//! where production traffic lands.
//!
//! ```toml
//! [ingress]
//! listen = "127.0.0.1:7460"
//! balance = "least-in-flight"      # or "round-robin"
//! drain_timeout_ms = 10000
//!
//! [probe]
//! interval_ms = 500
//! timeout_ms = 1000
//! eject_after = 3
//! probation_successes = 2
//!
//! [reconcile]
//! restart_after_ms = 1000
//! max_restarts = 5
//!
//! [[backend]]
//! addr = "127.0.0.1:7461"
//! models = ["gcn", "gat"]          # empty/omitted = serves any model
//! command = ["./target/release/gengnn", "serve", "--listen", "127.0.0.1:7461"]
//! ```

use std::collections::BTreeSet;
use std::path::Path;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::router::Balance;

/// Probe/ejection knobs (the `[probe]` section).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProbeKnobs {
    /// Delay between probe rounds.
    pub interval: Duration,
    /// Per-probe connect/read deadline; a probe that outlives it
    /// counts as a failure.
    pub timeout: Duration,
    /// Consecutive probe failures before a healthy backend is ejected.
    pub eject_after: u32,
    /// Consecutive probe successes an ejected backend must show
    /// (through probation) before it takes traffic again.
    pub probation_successes: u32,
}

impl Default for ProbeKnobs {
    fn default() -> ProbeKnobs {
        ProbeKnobs {
            interval: Duration::from_millis(500),
            timeout: Duration::from_millis(1000),
            eject_after: 3,
            probation_successes: 2,
        }
    }
}

/// Reconciler knobs (the `[reconcile]` section).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ReconcileKnobs {
    /// How long a managed backend must be dead before the reconciler
    /// respawns it (a crash-loop damper, not a health judgment).
    pub restart_after: Duration,
    /// Respawn budget per backend; exhausted budget leaves the backend
    /// ejected for an operator.
    pub max_restarts: u32,
}

impl Default for ReconcileKnobs {
    fn default() -> ReconcileKnobs {
        ReconcileKnobs {
            restart_after: Duration::from_millis(1000),
            max_restarts: 5,
        }
    }
}

/// One replica in the pool (a `[[backend]]` table).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BackendSpec {
    /// Wire address of the backend's listener.
    pub addr: String,
    /// Models this replica is assigned; empty = serves any model.
    pub models: Vec<String>,
    /// Spawn command for an ingress-managed replica (argv vector; the
    /// reconciler owns the child's lifecycle). Empty = externally
    /// managed, the ingress only probes and routes.
    pub command: Vec<String>,
}

impl BackendSpec {
    /// Does this replica advertise `model` (explicitly or as a
    /// serve-anything catch-all)?
    pub fn advertises(&self, model: &str) -> bool {
        self.models.is_empty() || self.models.iter().any(|m| m == model)
    }

    /// Is the replica's process lifecycle owned by the ingress?
    pub fn managed(&self) -> bool {
        !self.command.is_empty()
    }
}

/// The whole cluster: ingress listener + knobs + replica pool.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterSpec {
    /// Ingress listen address (`[ingress] listen`).
    pub listen: String,
    /// Replica selection policy within a model's set.
    pub balance: Balance,
    /// How long shutdown waits for in-flight requests to drain.
    pub drain_timeout: Duration,
    pub probe: ProbeKnobs,
    pub reconcile: ReconcileKnobs,
    pub backends: Vec<BackendSpec>,
}

impl Default for ClusterSpec {
    fn default() -> ClusterSpec {
        ClusterSpec {
            listen: "127.0.0.1:7460".to_string(),
            balance: Balance::RoundRobin,
            drain_timeout: Duration::from_millis(10_000),
            probe: ProbeKnobs::default(),
            reconcile: ReconcileKnobs::default(),
            backends: Vec::new(),
        }
    }
}

/// One parsed right-hand side.
enum Value {
    Str(String),
    Int(i64),
    #[allow(dead_code)] // parsed for completeness; no boolean knob yet
    Bool(bool),
    List(Vec<String>),
}

impl Value {
    fn kind(&self) -> &'static str {
        match self {
            Value::Str(_) => "string",
            Value::Int(_) => "integer",
            Value::Bool(_) => "boolean",
            Value::List(_) => "array",
        }
    }

    fn str(self, key: &str) -> Result<String> {
        match self {
            Value::Str(s) => Ok(s),
            v => bail!("{key} must be a string, got {}", v.kind()),
        }
    }

    fn list(self, key: &str) -> Result<Vec<String>> {
        match self {
            Value::List(xs) => Ok(xs),
            v => bail!("{key} must be an array of strings, got {}", v.kind()),
        }
    }

    fn duration_ms(self, key: &str) -> Result<Duration> {
        match self {
            Value::Int(n) if n >= 0 => Ok(Duration::from_millis(n as u64)),
            Value::Int(n) => bail!("{key} must be non-negative, got {n}"),
            v => bail!("{key} must be an integer (milliseconds), got {}", v.kind()),
        }
    }

    fn u32(self, key: &str) -> Result<u32> {
        match self {
            Value::Int(n) if (0..=u32::MAX as i64).contains(&n) => Ok(n as u32),
            Value::Int(n) => bail!("{key} out of range: {n}"),
            v => bail!("{key} must be an integer, got {}", v.kind()),
        }
    }
}

/// Strip a `#` comment that is not inside a double-quoted string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_string(raw: &str) -> Result<String> {
    let inner = raw
        .strip_prefix('"')
        .and_then(|r| r.strip_suffix('"'))
        .with_context(|| format!("expected a double-quoted string, got {raw:?}"))?;
    if inner.contains('"') {
        bail!("embedded quotes are not supported: {raw:?}");
    }
    Ok(inner.to_string())
}

fn parse_value(raw: &str) -> Result<Value> {
    let raw = raw.trim();
    if raw.starts_with('"') {
        return Ok(Value::Str(parse_string(raw)?));
    }
    if let Some(inner) = raw.strip_prefix('[').and_then(|r| r.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::List(Vec::new()));
        }
        let items = inner
            .split(',')
            .map(|item| parse_string(item.trim()))
            .collect::<Result<Vec<_>>>()?;
        return Ok(Value::List(items));
    }
    match raw {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    raw.parse::<i64>()
        .map(Value::Int)
        .with_context(|| format!("unparseable value {raw:?}"))
}

impl ClusterSpec {
    /// Parse a `cluster.toml` document.
    pub fn parse(text: &str) -> Result<ClusterSpec> {
        let mut spec = ClusterSpec::default();
        // "" = before any section header; "backend" = inside the most
        // recently opened [[backend]] table.
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let lineno = i + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("[[").and_then(|l| l.strip_suffix("]]")) {
                if name.trim() != "backend" {
                    bail!("line {lineno}: unknown table [[{}]]", name.trim());
                }
                spec.backends.push(BackendSpec::default());
                section = "backend".to_string();
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                let name = name.trim();
                if !matches!(name, "ingress" | "probe" | "reconcile") {
                    bail!("line {lineno}: unknown section [{name}]");
                }
                section = name.to_string();
                continue;
            }
            let (key, raw_value) = line
                .split_once('=')
                .with_context(|| format!("line {lineno}: expected `key = value`, got {line:?}"))?;
            let key = key.trim();
            let value = parse_value(raw_value)
                .with_context(|| format!("line {lineno}: bad value for {key}"))?;
            spec.assign(&section, key, value)
                .with_context(|| format!("line {lineno}"))?;
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Load and parse a spec file.
    pub fn load(path: &Path) -> Result<ClusterSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading cluster spec {}", path.display()))?;
        ClusterSpec::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    fn assign(&mut self, section: &str, key: &str, value: Value) -> Result<()> {
        match (section, key) {
            ("ingress", "listen") => self.listen = value.str(key)?,
            ("ingress", "balance") => self.balance = Balance::parse(&value.str(key)?)?,
            ("ingress", "drain_timeout_ms") => self.drain_timeout = value.duration_ms(key)?,
            ("probe", "interval_ms") => self.probe.interval = value.duration_ms(key)?,
            ("probe", "timeout_ms") => self.probe.timeout = value.duration_ms(key)?,
            ("probe", "eject_after") => self.probe.eject_after = value.u32(key)?,
            ("probe", "probation_successes") => {
                self.probe.probation_successes = value.u32(key)?
            }
            ("reconcile", "restart_after_ms") => {
                self.reconcile.restart_after = value.duration_ms(key)?
            }
            ("reconcile", "max_restarts") => self.reconcile.max_restarts = value.u32(key)?,
            ("backend", _) => {
                let b = self
                    .backends
                    .last_mut()
                    .context("backend keys must follow a [[backend]] header")?;
                match key {
                    "addr" => b.addr = value.str(key)?,
                    "models" => b.models = value.list(key)?,
                    "command" => b.command = value.list(key)?,
                    _ => bail!("unknown backend key {key:?}"),
                }
            }
            ("", _) => bail!("key {key:?} before any section header"),
            _ => bail!("unknown key {key:?} in section [{section}]"),
        }
        Ok(())
    }

    /// Structural validation (independent of any model catalog).
    pub fn validate(&self) -> Result<()> {
        if self.backends.is_empty() {
            bail!("cluster spec declares no [[backend]] tables");
        }
        let mut addrs = BTreeSet::new();
        for (i, b) in self.backends.iter().enumerate() {
            if b.addr.is_empty() {
                bail!("backend {i} has no addr");
            }
            if !b.addr.contains(':') {
                bail!("backend {i} addr {:?} is not host:port", b.addr);
            }
            if !addrs.insert(&b.addr) {
                bail!("duplicate backend addr {:?}", b.addr);
            }
            if b.models.iter().any(|m| m.is_empty()) {
                bail!("backend {i} assigns an empty model name");
            }
        }
        if self.probe.eject_after == 0 {
            bail!("probe.eject_after must be at least 1");
        }
        if self.probe.probation_successes == 0 {
            bail!("probe.probation_successes must be at least 1");
        }
        if self.probe.interval.is_zero() || self.probe.timeout.is_zero() {
            bail!("probe interval and timeout must be positive");
        }
        Ok(())
    }

    /// Validate every model→replica assignment against a catalog of
    /// known model names (`registry::catalog_model_names`): routing
    /// traffic for a model no backend can serve is a spec bug worth
    /// failing at boot, not at the first misrouted request.
    pub fn validate_models(&self, catalog: &[String]) -> Result<()> {
        let known: BTreeSet<&str> = catalog.iter().map(|s| s.as_str()).collect();
        let mut unknown = BTreeSet::new();
        for b in &self.backends {
            for m in &b.models {
                if !known.contains(m.as_str()) {
                    unknown.insert(m.clone());
                }
            }
        }
        if !unknown.is_empty() {
            bail!(
                "cluster spec assigns models not in the catalog: {:?} (catalog: {:?})",
                unknown.into_iter().collect::<Vec<_>>(),
                catalog
            );
        }
        Ok(())
    }

    /// Model names with at least one assigned replica (catch-all
    /// backends serve everything and are not listed).
    pub fn assigned_models(&self) -> Vec<String> {
        let mut names = BTreeSet::new();
        for b in &self.backends {
            for m in &b.models {
                names.insert(m.clone());
            }
        }
        names.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = r#"
# Fleet of two, partitioned by model.
[ingress]
listen = "127.0.0.1:7460"
balance = "least-in-flight"
drain_timeout_ms = 2500

[probe]
interval_ms = 200          # fast probes for the test fleet
timeout_ms = 400
eject_after = 2
probation_successes = 3

[reconcile]
restart_after_ms = 300
max_restarts = 2

[[backend]]
addr = "127.0.0.1:7461"
models = ["gcn", "gat"]
command = ["./gengnn", "serve", "--listen", "127.0.0.1:7461"]

[[backend]]
addr = "127.0.0.1:7462"    # externally managed catch-all
models = []
"#;

    #[test]
    fn parses_the_full_example() {
        let spec = ClusterSpec::parse(EXAMPLE).unwrap();
        assert_eq!(spec.listen, "127.0.0.1:7460");
        assert_eq!(spec.balance, Balance::LeastInFlight);
        assert_eq!(spec.drain_timeout, Duration::from_millis(2500));
        assert_eq!(spec.probe.interval, Duration::from_millis(200));
        assert_eq!(spec.probe.eject_after, 2);
        assert_eq!(spec.probe.probation_successes, 3);
        assert_eq!(spec.reconcile.restart_after, Duration::from_millis(300));
        assert_eq!(spec.reconcile.max_restarts, 2);
        assert_eq!(spec.backends.len(), 2);
        assert_eq!(spec.backends[0].models, vec!["gcn", "gat"]);
        assert_eq!(spec.backends[0].command.len(), 4);
        assert!(spec.backends[0].managed());
        assert!(!spec.backends[1].managed());
        assert!(spec.backends[1].advertises("anything"));
        assert!(spec.backends[0].advertises("gcn"));
        assert!(!spec.backends[0].advertises("dgn"));
        assert_eq!(spec.assigned_models(), vec!["gat", "gcn"]);
    }

    #[test]
    fn defaults_fill_unset_knobs() {
        let spec = ClusterSpec::parse("[[backend]]\naddr = \"127.0.0.1:1\"\n").unwrap();
        assert_eq!(spec.probe, ProbeKnobs::default());
        assert_eq!(spec.reconcile, ReconcileKnobs::default());
        assert_eq!(spec.balance, Balance::RoundRobin);
    }

    #[test]
    fn rejects_misconfigurations() {
        // A typoed knob is an error, not a silent default.
        for bad in [
            "[ingress]\nlistn = \"x:1\"\n[[backend]]\naddr = \"x:1\"",
            "[probes]\ninterval_ms = 5",
            "addr = \"x:1\"", // key before any section
            "[[backends]]\naddr = \"x:1\"",
            "[[backend]]\naddr = \"x:1\"\n[[backend]]\naddr = \"x:1\"", // dup addr
            "[[backend]]\naddr = \"noport\"",
            "[[backend]]\naddr = \"x:1\"\nmodels = [\"\"]",
            "[[backend]]\naddr = \"x:1\"\n[probe]\neject_after = 0",
            "[ingress]\nbalance = \"fastest\"\n[[backend]]\naddr = \"x:1\"",
            "", // no backends at all
        ] {
            assert!(ClusterSpec::parse(bad).is_err(), "accepted: {bad:?}");
        }
    }

    #[test]
    fn comments_and_strings_interact_correctly() {
        let spec = ClusterSpec::parse(
            "[[backend]]\naddr = \"127.0.0.1:7461\" # trailing comment\nmodels = [\"g#n\"]\n",
        )
        .unwrap();
        // A '#' inside a quoted string is content, not a comment.
        assert_eq!(spec.backends[0].models, vec!["g#n"]);
    }

    #[test]
    fn catalog_validation_names_the_offenders() {
        let spec = ClusterSpec::parse(
            "[[backend]]\naddr = \"x:1\"\nmodels = [\"gcn\", \"bert\"]\n",
        )
        .unwrap();
        let catalog = vec!["gcn".to_string(), "gat".to_string()];
        let err = spec.validate_models(&catalog).unwrap_err().to_string();
        // The unknown list names exactly the offender, not every
        // assigned model.
        assert!(err.contains("[\"bert\"]"), "{err}");
        spec.validate_models(&["gcn".into(), "bert".into()]).unwrap();
    }
}

//! Model-aware replica routing.
//!
//! The replica sets are fixed at boot from the cluster spec (model →
//! the backends assigned that model, plus every catch-all backend);
//! what changes at runtime is which members are routable, and that
//! arrives as a per-call view (`routable` / `in_flight` slices) so the
//! router itself stays pure and property-testable under join/leave/
//! eject churn (`rust/tests/ingress_routing.rs`).
//!
//! A model no replica set covers still routes — to any healthy backend
//! — so the *backend* generates the canonical "model not served"
//! error. Self-answering at the ingress would break the fleet-scope
//! bit-exactness contract: the 1-backend and N-backend fleets must
//! produce identical bytes even for error paths.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use super::spec::BackendSpec;

/// Replica selection policy within a candidate set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Balance {
    /// Rotate through healthy candidates in order.
    RoundRobin,
    /// Pick the healthy candidate with the fewest proxied requests in
    /// flight (ties break to the lowest index, keeping replays
    /// deterministic).
    LeastInFlight,
}

impl Balance {
    pub fn parse(s: &str) -> Result<Balance> {
        Ok(match s {
            "round-robin" => Balance::RoundRobin,
            "least-in-flight" => Balance::LeastInFlight,
            _ => bail!("unknown balance policy {s:?} (round-robin | least-in-flight)"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Balance::RoundRobin => "round-robin",
            Balance::LeastInFlight => "least-in-flight",
        }
    }
}

/// The boot-time routing table: per-model candidate lists over backend
/// indices.
pub struct Router {
    /// model → sorted backend indices assigned it (incl. catch-alls).
    sets: BTreeMap<String, Vec<usize>>,
    /// Every backend index — the candidate list for model-free frames
    /// (control, resident ops) and for models outside every set.
    all: Vec<usize>,
    balance: Balance,
    /// Round-robin cursor, shared across models: rotation within any
    /// candidate list stays fair without per-model state.
    rr: AtomicU64,
}

impl Router {
    pub fn new(backends: &[BackendSpec], balance: Balance) -> Router {
        let mut sets: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (i, b) in backends.iter().enumerate() {
            for m in &b.models {
                sets.entry(m.clone()).or_default().push(i);
            }
        }
        let catch_alls: Vec<usize> = backends
            .iter()
            .enumerate()
            .filter(|(_, b)| b.models.is_empty())
            .map(|(i, _)| i)
            .collect();
        for members in sets.values_mut() {
            members.extend(catch_alls.iter().copied());
            members.sort_unstable();
            members.dedup();
        }
        Router {
            sets,
            all: (0..backends.len()).collect(),
            balance,
            rr: AtomicU64::new(0),
        }
    }

    pub fn balance(&self) -> Balance {
        self.balance
    }

    /// The backends that advertise `model` (assigned or catch-all),
    /// irrespective of health.
    pub fn candidates(&self, model: &str) -> &[usize] {
        self.sets.get(model).map(Vec::as_slice).unwrap_or(&self.all)
    }

    /// Pick a backend for one frame. `model` is `None` for control and
    /// resident frames (any backend answers those canonically);
    /// `routable[i]` / `in_flight[i]` are the caller's live view of
    /// backend `i`. Returns `None` when no routable candidate exists —
    /// the only case the ingress self-answers (`Rejected`), because no
    /// backend could have answered at all.
    pub fn route(&self, model: Option<&str>, routable: &[bool], in_flight: &[u64]) -> Option<usize> {
        let candidates = match model {
            Some(m) => self.candidates(m),
            None => &self.all,
        };
        let live: Vec<usize> = candidates
            .iter()
            .copied()
            .filter(|&i| routable.get(i).copied().unwrap_or(false))
            .collect();
        match self.balance {
            Balance::RoundRobin => {
                if live.is_empty() {
                    return None;
                }
                let turn = self.rr.fetch_add(1, Ordering::Relaxed) as usize;
                Some(live[turn % live.len()])
            }
            Balance::LeastInFlight => live
                .into_iter()
                .min_by_key(|&i| (in_flight.get(i).copied().unwrap_or(0), i)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backends(specs: &[(&str, &[&str])]) -> Vec<BackendSpec> {
        specs
            .iter()
            .map(|(addr, models)| BackendSpec {
                addr: addr.to_string(),
                models: models.iter().map(|m| m.to_string()).collect(),
                command: Vec::new(),
            })
            .collect()
    }

    #[test]
    fn candidate_sets_union_assignments_with_catch_alls() {
        let r = Router::new(
            &backends(&[("a:1", &["gcn"]), ("b:1", &["gin", "gcn"]), ("c:1", &[])]),
            Balance::RoundRobin,
        );
        assert_eq!(r.candidates("gcn"), &[0, 1, 2]);
        assert_eq!(r.candidates("gin"), &[1, 2]);
        // Unknown model → every backend (the error stays canonical).
        assert_eq!(r.candidates("bert"), &[0, 1, 2]);
    }

    #[test]
    fn round_robin_rotates_among_healthy_members_only() {
        let r = Router::new(
            &backends(&[("a:1", &["gcn"]), ("b:1", &["gcn"]), ("c:1", &["gcn"])]),
            Balance::RoundRobin,
        );
        let routable = [true, false, true];
        let picks: Vec<usize> = (0..4)
            .map(|_| r.route(Some("gcn"), &routable, &[0; 3]).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
        assert_eq!(r.route(Some("gcn"), &[false; 3], &[0; 3]), None);
    }

    #[test]
    fn least_in_flight_prefers_idle_backends_and_breaks_ties_low() {
        let r = Router::new(
            &backends(&[("a:1", &["gcn"]), ("b:1", &["gcn"]), ("c:1", &["gcn"])]),
            Balance::LeastInFlight,
        );
        assert_eq!(r.route(Some("gcn"), &[true; 3], &[5, 2, 9]), Some(1));
        assert_eq!(r.route(Some("gcn"), &[true; 3], &[4, 4, 4]), Some(0));
        assert_eq!(r.route(Some("gcn"), &[false, true, true], &[0, 7, 3]), Some(2));
    }

    #[test]
    fn model_free_frames_route_to_any_healthy_backend() {
        let r = Router::new(
            &backends(&[("a:1", &["gcn"]), ("b:1", &["gin"])]),
            Balance::LeastInFlight,
        );
        // A control/resident frame can land anywhere that's healthy.
        assert_eq!(r.route(None, &[false, true], &[0, 0]), Some(1));
        assert_eq!(r.route(None, &[false, false], &[0, 0]), None);
    }

    #[test]
    fn balance_parses_and_round_trips() {
        assert_eq!(Balance::parse("round-robin").unwrap(), Balance::RoundRobin);
        assert_eq!(
            Balance::parse(Balance::LeastInFlight.as_str()).unwrap(),
            Balance::LeastInFlight
        );
        assert!(Balance::parse("fastest").is_err());
    }
}

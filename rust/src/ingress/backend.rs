//! Per-backend runtime state: the probe round-trip, the managed child
//! process, and the shared plumbing the proxy's link threads hang off.

use std::collections::BTreeSet;
use std::io::Write;
use std::net::{TcpStream, ToSocketAddrs};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::net::proto::{self, Op, WireControl, WireFrame, WireStatus};
use crate::util::json::Json;

use super::health::ProbeTracker;
use super::spec::BackendSpec;

/// One live connection to a backend: the proxy writes proxied frames
/// through `tx` (under the mutex); a dedicated reader thread owns the
/// other half of the stream and demuxes responses by rewritten id.
pub(crate) struct Link {
    pub tx: Mutex<TcpStream>,
    /// Cleared by whichever side sees the connection die first; both
    /// the writer and the reader check it before trusting the stream.
    pub alive: AtomicBool,
    /// Monotonic link generation, so a reader that dies can tell
    /// whether the slot still holds *its* link before clearing it.
    pub generation: u64,
}

/// Everything the ingress tracks about one backend at runtime.
pub(crate) struct BackendState {
    pub spec: BackendSpec,
    pub tracker: Mutex<ProbeTracker>,
    /// Proxied frames awaiting this backend's answer (gauge; the
    /// least-in-flight balancer's input).
    pub in_flight: AtomicU64,
    pub link: Mutex<Option<Arc<Link>>>,
    /// Next link generation to assign.
    pub link_generation: AtomicU64,
    /// The managed child process (None for external backends or
    /// between death and respawn).
    pub child: Mutex<Option<Child>>,
    /// Reconciler respawns so far.
    pub restarts: AtomicU64,
    /// When the reconciler first saw the managed child dead (cleared
    /// on respawn) — the `restart_after` damper's clock.
    pub down_since: Mutex<Option<Instant>>,
}

impl BackendState {
    pub fn new(spec: BackendSpec, eject_after: u32, probation_successes: u32) -> BackendState {
        BackendState {
            spec,
            tracker: Mutex::new(ProbeTracker::new(eject_after, probation_successes)),
            in_flight: AtomicU64::new(0),
            link: Mutex::new(None),
            link_generation: AtomicU64::new(0),
            child: Mutex::new(None),
            restarts: AtomicU64::new(0),
            down_since: Mutex::new(None),
        }
    }

    /// Spawn the managed child process (quiet: a backend's stderr chat
    /// belongs to its own log, not interleaved into the ingress's).
    pub fn spawn_child(&self) -> Result<()> {
        let cmd = &self.spec.command;
        if cmd.is_empty() {
            bail!("backend {} is not ingress-managed", self.spec.addr);
        }
        let child = Command::new(&cmd[0])
            .args(&cmd[1..])
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .with_context(|| format!("spawning backend {:?}", cmd[0]))?;
        *crate::util::sync::lock(&self.child) = Some(child);
        *crate::util::sync::lock(&self.down_since) = None;
        Ok(())
    }

    /// SIGKILL the managed child (fault injection and shutdown).
    pub fn kill_child(&self) {
        if let Some(child) = crate::util::sync::lock(&self.child).as_mut() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }

    /// Has the managed child exited? (`false` for external backends.)
    pub fn child_exited(&self) -> bool {
        match crate::util::sync::lock(&self.child).as_mut() {
            Some(child) => matches!(child.try_wait(), Ok(Some(_))),
            None => self.spec.managed(),
        }
    }
}

/// Dial with a bounded connect timeout (plain `TcpStream::connect`
/// can block for the OS default, far too long for a probe tick).
pub(crate) fn dial_timeout(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let mut last: Option<std::io::Error> = None;
    for sa in addr
        .to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
    {
        match TcpStream::connect_timeout(&sa, timeout) {
            Ok(stream) => {
                stream.set_nodelay(true)?;
                return Ok(stream);
            }
            Err(e) => last = Some(e),
        }
    }
    Err(match last {
        Some(e) => anyhow::Error::from(e).context(format!("connecting to {addr}")),
        None => anyhow!("{addr} resolved to no addresses"),
    })
}

/// One LIST_MODELS probe round-trip on a fresh connection: dial,
/// send, read until the matching control response, parse the live
/// model set out of the registry JSON document. Every failure mode —
/// connect refusal, timeout, decode error, non-Ok status — surfaces
/// as `Err`, which the prober counts as one probe failure.
pub(crate) fn probe_list_models(
    addr: &str,
    timeout: Duration,
    probe_id: u64,
) -> Result<BTreeSet<String>> {
    let mut stream = dial_timeout(addr, timeout)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let frame = proto::encode_control(&WireControl {
        id: probe_id,
        op: Op::ListModels,
        model: String::new(),
        digest: String::new(),
        version: 0,
    })?;
    stream.write_all(&frame)?;
    loop {
        let payload = proto::read_frame(&mut stream)?
            .ok_or_else(|| anyhow!("EOF before the probe response"))?;
        if let WireFrame::ControlResp(resp) = proto::decode_frame(&payload)? {
            if resp.id != probe_id {
                continue;
            }
            if resp.status != WireStatus::Ok {
                bail!("probe answered {:?}: {}", resp.status, resp.message);
            }
            return parse_live_models(&resp.message);
        }
    }
}

/// Extract the live model names from a `LIST_MODELS` registry
/// document: `{"models": [{"name": ..., "live": bool}, ...], ...}`.
fn parse_live_models(doc: &str) -> Result<BTreeSet<String>> {
    let json = Json::parse(doc).context("probe response is not valid JSON")?;
    let mut live = BTreeSet::new();
    for entry in json.get("models")?.as_arr()? {
        if entry.get("live")?.as_bool()? {
            live.insert(entry.get("name")?.as_str()?.to_string());
        }
    }
    Ok(live)
}

/// Is a probe's advertised live set good enough for this backend's
/// assignment? Every spec-assigned model must be live; a catch-all
/// backend (no assignment) only needs the probe itself to succeed.
/// This is what makes "every admitted request is routed to a backend
/// advertising its model" hold even while a backend is still booting
/// or mid-deploy: not-yet-serving replicas probe as unhealthy.
pub(crate) fn advertises_assignment(spec: &BackendSpec, live: &BTreeSet<String>) -> bool {
    spec.models.iter().all(|m| live.contains(m))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn live_models_parse_from_a_registry_document() {
        let doc = r#"{"version": 3, "weight_seed": 7,
            "models": [
              {"name": "gcn", "digest": "ab", "live": true},
              {"name": "gat", "digest": "cd", "live": false},
              {"name": "gin", "digest": "ef", "live": true}
            ], "history": []}"#;
        let live = parse_live_models(doc).unwrap();
        assert_eq!(
            live.iter().cloned().collect::<Vec<_>>(),
            vec!["gcn".to_string(), "gin".to_string()]
        );
        assert!(parse_live_models("not json").is_err());
        assert!(parse_live_models("{\"nomodels\": 1}").is_err());
    }

    #[test]
    fn assignment_check_requires_every_assigned_model() {
        let live: BTreeSet<String> = ["gcn", "gin"].iter().map(|s| s.to_string()).collect();
        let spec = |models: &[&str]| BackendSpec {
            addr: "x:1".into(),
            models: models.iter().map(|s| s.to_string()).collect(),
            command: Vec::new(),
        };
        assert!(advertises_assignment(&spec(&["gcn"]), &live));
        assert!(advertises_assignment(&spec(&["gcn", "gin"]), &live));
        assert!(!advertises_assignment(&spec(&["gcn", "gat"]), &live));
        // Catch-all: any successful probe is enough.
        assert!(advertises_assignment(&spec(&[]), &live));
    }

    #[test]
    fn dial_timeout_fails_fast_on_a_closed_port() {
        // Bind then drop a listener to get a port that refuses.
        let port = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let t0 = Instant::now();
        let err = dial_timeout(&format!("127.0.0.1:{port}"), Duration::from_millis(400));
        assert!(err.is_err());
        // Refusal is immediate; the timeout is an upper bound, not a
        // sleep.
        assert!(t0.elapsed() < Duration::from_secs(5));
    }
}

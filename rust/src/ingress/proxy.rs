//! The ingress front: accept loop, id-rewriting frame proxy, drain,
//! prober, and reconciler.
//!
//! ## Data plane
//!
//! Each client connection gets a reader thread; each backend gets one
//! persistent link whose reader thread demuxes responses. A proxied
//! frame travels: client reader → peek (version/kind/id/model, with
//! the same envelope validation a backend performs) → route → rewrite
//! the correlation id to a fleet-unique ingress id → forward raw
//! bytes. The response comes back on the backend link, is matched by
//! ingress id, gets the caller's id stamped back, and is relayed —
//! every non-id byte untouched in both directions, which is what makes
//! the 1-vs-N bit-exactness proof possible.
//!
//! Thread-per-connection is a deliberate tier tradeoff (the backends
//! keep their reactor pool): the ingress holds a handful of client
//! connections and per-fleet backend links, not the per-request fan-in
//! the backends see, and blocking readers keep the proxy path free of
//! reactor state the backends' event loop couples to admission and
//! resident serving.
//!
//! ## Failure accounting
//!
//! Every admitted frame is answered exactly once — by the backend, or
//! by the ingress with `Error` if the backend link dies first, or with
//! `Rejected` if no healthy backend exists / the ingress is draining.
//! That invariant is what keeps loadgen's reconciliation
//! (`submitted = completed + rejected + failed + lost`, `lost == 0`)
//! balanced across a backend crash (`rust/tests/ingress_e2e.rs`).

use std::collections::HashMap;
use std::io::Read;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::controlplane::{response_version, IngressCounters};
use crate::net::proto::{
    self, Op, WireControlResp, WireGraphMutateResp, WireGraphQueryResp, WireResponse, WireStatus,
    KIND_CONTROL, KIND_GRAPH_MUTATE, KIND_GRAPH_QUERY,
};
use crate::util::sync::lock;

use super::backend::{advertises_assignment, dial_timeout, probe_list_models, BackendState, Link};
use super::fault::{FaultPlan, FaultState};
use super::health::{HealthState, Transition};
use super::router::Router;
use super::spec::ClusterSpec;

/// How often blocking loops check the stop/drain flags.
const POLL_TICK: Duration = Duration::from_millis(20);

/// Socket read timeout for the stop-aware frame readers.
const READ_TICK: Duration = Duration::from_millis(50);

/// Bound on a single proxied write; a backend that cannot absorb a
/// frame for this long is treated as dead rather than stalling every
/// client routed to it.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Reconciler tick.
const RECONCILE_TICK: Duration = Duration::from_millis(50);

/// Everything needed to start an ingress.
pub struct IngressConfig {
    pub spec: ClusterSpec,
    /// Test-only fault injection; `FaultPlan::default()` in production.
    pub fault: FaultPlan,
}

/// One in-flight proxied frame: how to stamp and deliver its answer.
#[derive(Clone)]
struct Route {
    /// Client connection token.
    token: u64,
    /// The caller's original correlation id.
    client_id: u64,
    version: u8,
    kind: u8,
    ctrl_op: u8,
    model: String,
    backend: usize,
}

/// The writer half of one client connection (readers own their clone).
struct ClientConn {
    tx: Mutex<TcpStream>,
}

struct Shared {
    spec: ClusterSpec,
    router: Router,
    fault: FaultPlan,
    fstate: FaultState,
    backends: Vec<BackendState>,
    /// ingress id → route, for every frame forwarded but unanswered.
    routes: Mutex<HashMap<u64, Route>>,
    next_ingress_id: AtomicU64,
    next_token: AtomicU64,
    conns: Mutex<HashMap<u64, Arc<ClientConn>>>,
    counters: Arc<IngressCounters>,
    /// Refuse new frames (answered `Rejected`), keep relaying answers.
    draining: AtomicBool,
    /// Tear everything down.
    stop: AtomicBool,
    client_threads: Mutex<Vec<JoinHandle<()>>>,
    link_threads: Mutex<Vec<JoinHandle<()>>>,
}

/// A running ingress. `shutdown` drains in-flight requests before
/// tearing the fleet down; managed children die with the ingress.
pub struct Ingress {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    prober: Option<JoinHandle<()>>,
    reconciler: Option<JoinHandle<()>>,
}

impl Ingress {
    pub fn start(cfg: IngressConfig) -> Result<Ingress> {
        cfg.spec.validate()?;
        cfg.fault.validate(cfg.spec.backends.len())?;
        let listener = TcpListener::bind(&cfg.spec.listen)
            .with_context(|| format!("binding ingress listener {}", cfg.spec.listen))?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;

        let backends: Vec<BackendState> = cfg
            .spec
            .backends
            .iter()
            .map(|b| {
                BackendState::new(
                    b.clone(),
                    cfg.spec.probe.eject_after,
                    cfg.spec.probe.probation_successes,
                )
            })
            .collect();
        let fstate = FaultState::new(&cfg.fault, backends.len());
        let router = Router::new(&cfg.spec.backends, cfg.spec.balance);
        let shared = Arc::new(Shared {
            router,
            fault: cfg.fault,
            fstate,
            backends,
            routes: Mutex::new(HashMap::new()),
            next_ingress_id: AtomicU64::new(1),
            next_token: AtomicU64::new(1),
            conns: Mutex::new(HashMap::new()),
            counters: Arc::new(IngressCounters::default()),
            draining: AtomicBool::new(false),
            stop: AtomicBool::new(false),
            client_threads: Mutex::new(Vec::new()),
            link_threads: Mutex::new(Vec::new()),
            spec: cfg.spec,
        });

        // Boot managed children, adopting any process already
        // answering on the assigned address (idempotent restarts).
        for b in &shared.backends {
            if b.spec.managed() && dial_timeout(&b.spec.addr, Duration::from_millis(200)).is_err()
            {
                b.spawn_child()
                    .with_context(|| format!("booting managed backend {}", b.spec.addr))?;
            }
        }

        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || accept_loop(&shared, listener))
        };
        let prober = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || prober_loop(&shared))
        };
        let reconciler = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || reconciler_loop(&shared))
        };
        Ok(Ingress {
            shared,
            local_addr,
            accept: Some(accept),
            prober: Some(prober),
            reconciler: Some(reconciler),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn counters(&self) -> Arc<IngressCounters> {
        Arc::clone(&self.shared.counters)
    }

    /// Routing-visible health of backend `idx`.
    pub fn backend_health(&self, idx: usize) -> HealthState {
        lock(&self.shared.backends[idx].tracker).state()
    }

    /// Reconciler respawns of backend `idx` so far.
    pub fn backend_restarts(&self, idx: usize) -> u64 {
        self.shared.backends[idx].restarts.load(Ordering::Relaxed)
    }

    /// Proxied frames currently awaiting an answer.
    pub fn in_flight(&self) -> u64 {
        self.shared.counters.requests_in_flight.load(Ordering::Relaxed)
    }

    /// Human-readable fleet status: counters plus one line per backend.
    pub fn status_report(&self) -> String {
        let mut out = self.shared.counters.render();
        for (i, b) in self.shared.backends.iter().enumerate() {
            out.push_str(&format!(
                "  backend {i} {} [{}] {:?}, {} in flight, {} restarts{}\n",
                b.spec.addr,
                if b.spec.models.is_empty() {
                    "*".to_string()
                } else {
                    b.spec.models.join(",")
                },
                lock(&b.tracker).state(),
                b.in_flight.load(Ordering::Relaxed),
                b.restarts.load(Ordering::Relaxed),
                if b.spec.managed() { " (managed)" } else { "" },
            ));
        }
        out
    }

    /// Drain and stop: refuse new frames, wait for in-flight answers
    /// (up to the spec's drain timeout), then tear down threads, close
    /// connections, and kill managed children. Returns the counter
    /// block for final reporting.
    pub fn shutdown(mut self) -> Arc<IngressCounters> {
        let shared = &self.shared;
        shared.draining.store(true, Ordering::SeqCst);
        let deadline = Instant::now() + shared.spec.drain_timeout;
        while Instant::now() < deadline {
            if lock(&shared.routes).is_empty() {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        shared.stop.store(true, Ordering::SeqCst);
        for h in [
            self.accept.take(),
            self.prober.take(),
            self.reconciler.take(),
        ]
        .into_iter()
        .flatten()
        {
            let _ = h.join();
        }
        // Close client sockets so any blocked I/O dies promptly.
        for (_, conn) in lock(&shared.conns).drain() {
            let _ = lock(&conn.tx).shutdown(Shutdown::Both);
        }
        for b in &shared.backends {
            if let Some(link) = lock(&b.link).take() {
                link.alive.store(false, Ordering::SeqCst);
                let _ = lock(&link.tx).shutdown(Shutdown::Both);
            }
            b.kill_child();
        }
        for h in std::mem::take(&mut *lock(&shared.client_threads)) {
            let _ = h.join();
        }
        for h in std::mem::take(&mut *lock(&shared.link_threads)) {
            let _ = h.join();
        }
        Arc::clone(&shared.counters)
    }
}

// ---- accept + client read path ------------------------------------------

fn accept_loop(shared: &Arc<Shared>, listener: TcpListener) {
    while !shared.stop.load(Ordering::Relaxed) && !shared.draining.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                shared
                    .counters
                    .connections_accepted
                    .fetch_add(1, Ordering::Relaxed);
                shared
                    .counters
                    .connections_open
                    .fetch_add(1, Ordering::Relaxed);
                let token = shared.next_token.fetch_add(1, Ordering::Relaxed);
                let worker = Arc::clone(shared);
                let handle = std::thread::spawn(move || client_loop(&worker, stream, token));
                lock(&shared.client_threads).push(handle);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL_TICK);
            }
            Err(_) => std::thread::sleep(POLL_TICK),
        }
    }
}

fn would_block(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Read one frame payload from a stream whose read timeout is
/// `READ_TICK`, retrying timeouts until the stop flag rises (then
/// `Ok(None)`, as on clean EOF). Mirrors `proto::read_frame` except
/// for the interruptibility.
fn read_frame_stoppable(stream: &mut TcpStream, stop: &AtomicBool) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    let mut filled = 0;
    while filled < 4 {
        if stop.load(Ordering::Relaxed) {
            return Ok(None);
        }
        match stream.read(&mut len_buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(None);
                }
                bail!("EOF inside a frame length prefix");
            }
            Ok(k) => filled += k,
            Err(e) if would_block(&e) => {}
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > proto::MAX_FRAME_BYTES {
        bail!("frame length {len} exceeds the {}-byte cap", proto::MAX_FRAME_BYTES);
    }
    let mut payload = vec![0u8; len];
    let mut got = 0;
    while got < len {
        if stop.load(Ordering::Relaxed) {
            return Ok(None);
        }
        match stream.read(&mut payload[got..]) {
            Ok(0) => bail!("EOF inside a frame body"),
            Ok(k) => got += k,
            Err(e) if would_block(&e) => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(Some(payload))
}

fn client_loop(shared: &Arc<Shared>, stream: TcpStream, token: u64) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut reader = match stream.try_clone() {
        Ok(r) => r,
        Err(_) => {
            shared
                .counters
                .connections_open
                .fetch_sub(1, Ordering::Relaxed);
            return;
        }
    };
    let _ = reader.set_read_timeout(Some(READ_TICK));
    let conn = Arc::new(ClientConn {
        tx: Mutex::new(stream),
    });
    lock(&shared.conns).insert(token, Arc::clone(&conn));
    loop {
        match read_frame_stoppable(&mut reader, &shared.stop) {
            Ok(Some(payload)) => handle_frame(shared, &conn, token, payload),
            Ok(None) => break,
            Err(_) => break,
        }
    }
    teardown_client(shared, token);
}

fn teardown_client(shared: &Arc<Shared>, token: u64) {
    lock(&shared.conns).remove(&token);
    shared
        .counters
        .connections_open
        .fetch_sub(1, Ordering::Relaxed);
    // Sweep the client's outstanding routes: its answers have nowhere
    // to go, and drain must not wait on a vanished caller.
    let mut swept = Vec::new();
    lock(&shared.routes).retain(|_, r| {
        if r.token == token {
            swept.push(r.backend);
            false
        } else {
            true
        }
    });
    for backend in swept {
        shared.backends[backend]
            .in_flight
            .fetch_sub(1, Ordering::Relaxed);
        shared
            .counters
            .requests_in_flight
            .fetch_sub(1, Ordering::Relaxed);
    }
}

// ---- the proxy hot path -------------------------------------------------

fn handle_frame(shared: &Arc<Shared>, conn: &Arc<ClientConn>, token: u64, payload: Vec<u8>) {
    let frame_no = shared.fstate.frames.fetch_add(1, Ordering::Relaxed) + 1;
    maybe_kill_backend(shared, frame_no);

    let peek = match proto::peek_frame(&payload) {
        Ok(p) => p,
        Err(e) => {
            // Unroutable: answer BadRequest here. (A backend would
            // have refused the same frame; the message differs but the
            // status and the salvage-or-BAD_FRAME_ID id rule match.)
            shared.counters.decode_errors.fetch_add(1, Ordering::Relaxed);
            let id = proto::salvage_request_id(&payload).unwrap_or(proto::BAD_FRAME_ID);
            let version = response_version(payload.first().copied());
            let kind = payload.get(1).copied().unwrap_or(0);
            send_answer(
                conn,
                version,
                kind,
                0,
                id,
                "",
                WireStatus::BadRequest,
                &format!("unroutable frame: {e:#}"),
            );
            return;
        }
    };

    if shared.draining.load(Ordering::Relaxed) {
        shared.counters.drain_rejected.fetch_add(1, Ordering::Relaxed);
        answer_peeked(conn, &peek, WireStatus::Rejected, "ingress draining");
        return;
    }

    let (routable, in_flight) = health_view(shared);
    let Some(idx) = shared
        .router
        .route(peek.model.as_deref(), &routable, &in_flight)
    else {
        shared
            .counters
            .no_backend_rejected
            .fetch_add(1, Ordering::Relaxed);
        let why = match &peek.model {
            Some(m) => format!("no healthy backend for model {m:?}"),
            None => "no healthy backend".to_string(),
        };
        answer_peeked(conn, &peek, WireStatus::Rejected, &why);
        return;
    };

    let link = match ensure_link(shared, idx) {
        Ok(link) => link,
        Err(e) => {
            // The router believed in the backend but the dial failed;
            // shed rather than stall — probes will eject it shortly.
            shared
                .counters
                .no_backend_rejected
                .fetch_add(1, Ordering::Relaxed);
            answer_peeked(
                conn,
                &peek,
                WireStatus::Rejected,
                &format!("backend unreachable: {e:#}"),
            );
            return;
        }
    };

    let ingress_id = shared.next_ingress_id.fetch_add(1, Ordering::Relaxed);
    let mut buf = payload;
    if proto::rewrite_frame_id(&mut buf, ingress_id).is_err() {
        // Unreachable after a successful peek; degrade, don't panic.
        shared.counters.decode_errors.fetch_add(1, Ordering::Relaxed);
        answer_peeked(conn, &peek, WireStatus::Error, "id rewrite failed");
        return;
    }
    if shared.fault.corrupt_frame == Some(frame_no) && proto::corrupt_request_priority(&mut buf) {
        shared
            .counters
            .frames_corrupted
            .fetch_add(1, Ordering::Relaxed);
    }

    let route = Route {
        token,
        client_id: peek.id,
        version: peek.version,
        kind: peek.kind,
        ctrl_op: peek.ctrl_op,
        model: peek.model.clone().unwrap_or_default(),
        backend: idx,
    };
    // Install the route before writing so the link reader can never
    // see a response for an id it does not know.
    lock(&shared.routes).insert(ingress_id, route);
    shared.backends[idx].in_flight.fetch_add(1, Ordering::Relaxed);
    shared
        .counters
        .requests_in_flight
        .fetch_add(1, Ordering::Relaxed);
    shared.counters.frames_proxied.fetch_add(1, Ordering::Relaxed);

    let write_ok = {
        use std::io::Write;
        let mut tx = lock(&link.tx);
        link.alive.load(Ordering::SeqCst)
            && tx.write_all(&(buf.len() as u32).to_le_bytes()).is_ok()
            && tx.write_all(&buf).is_ok()
    };
    if !write_ok || !link.alive.load(Ordering::SeqCst) {
        // Either our write failed, or the link died around it and the
        // reader's sweep may have missed our just-installed route.
        // Whoever removes the route answers it — exactly once.
        link.alive.store(false, Ordering::SeqCst);
        if let Some(route) = lock(&shared.routes).remove(&ingress_id) {
            fail_route(shared, &route, "backend connection lost");
        }
    }
}

/// The router's live view: routability and in-flight depth per backend.
fn health_view(shared: &Shared) -> (Vec<bool>, Vec<u64>) {
    let routable = shared
        .backends
        .iter()
        .map(|b| lock(&b.tracker).routable())
        .collect();
    let in_flight = shared
        .backends
        .iter()
        .map(|b| b.in_flight.load(Ordering::Relaxed))
        .collect();
    (routable, in_flight)
}

fn maybe_kill_backend(shared: &Shared, frame_no: u64) {
    let Some((idx, after)) = shared.fault.kill_backend else {
        return;
    };
    if frame_no >= after && !shared.fstate.killed.swap(true, Ordering::SeqCst) {
        shared.backends[idx].kill_child();
    }
}

// ---- answering ----------------------------------------------------------

/// Encode an ingress-originated answer in the shape the frame's kind
/// demands, stamped with the caller's version and id.
#[allow(clippy::too_many_arguments)]
fn encode_answer(
    version: u8,
    kind: u8,
    ctrl_op: u8,
    id: u64,
    model: &str,
    status: WireStatus,
    message: &str,
) -> Result<Vec<u8>> {
    match kind {
        KIND_CONTROL => proto::encode_control_resp(&WireControlResp {
            id,
            op: Op::from_byte(ctrl_op).unwrap_or(Op::ListModels),
            status,
            version: 0,
            message: message.to_string(),
        }),
        KIND_GRAPH_QUERY => {
            proto::encode_graph_query_resp(&WireGraphQueryResp::err(id, status, 0, message))
        }
        KIND_GRAPH_MUTATE => proto::encode_graph_mutate_resp(&WireGraphMutateResp {
            id,
            status,
            snapshot_version: 0,
            applied: 0,
            rejected: 0,
            message: message.to_string(),
        }),
        // KIND_REQUEST and anything unrecognized: the inference
        // response shape, which every client version decodes.
        _ => proto::encode_response_with_version(
            version,
            &WireResponse::err(id, model, status, message),
        ),
    }
}

#[allow(clippy::too_many_arguments)]
fn send_answer(
    conn: &ClientConn,
    version: u8,
    kind: u8,
    ctrl_op: u8,
    id: u64,
    model: &str,
    status: WireStatus,
    message: &str,
) -> bool {
    match encode_answer(version, kind, ctrl_op, id, model, status, message) {
        Ok(frame) => {
            use std::io::Write;
            lock(&conn.tx).write_all(&frame).is_ok()
        }
        Err(_) => false,
    }
}

fn answer_peeked(conn: &ClientConn, peek: &proto::FramePeek, status: WireStatus, message: &str) {
    send_answer(
        conn,
        peek.version,
        peek.kind,
        peek.ctrl_op,
        peek.id,
        peek.model.as_deref().unwrap_or(""),
        status,
        message,
    );
}

/// Answer one already-removed route with `Error` (its backend died
/// before responding) and settle the gauges. The caller owns the
/// route's removal, which is what makes the answer exactly-once.
fn fail_route(shared: &Shared, route: &Route, message: &str) {
    shared.backends[route.backend]
        .in_flight
        .fetch_sub(1, Ordering::Relaxed);
    shared
        .counters
        .requests_in_flight
        .fetch_sub(1, Ordering::Relaxed);
    shared
        .counters
        .backend_failed_in_flight
        .fetch_add(1, Ordering::Relaxed);
    let conn = lock(&shared.conns).get(&route.token).map(Arc::clone);
    if let Some(conn) = conn {
        send_answer(
            &conn,
            route.version,
            route.kind,
            route.ctrl_op,
            route.client_id,
            &route.model,
            WireStatus::Error,
            message,
        );
    }
}

// ---- backend links ------------------------------------------------------

fn ensure_link(shared: &Arc<Shared>, idx: usize) -> Result<Arc<Link>> {
    let backend = &shared.backends[idx];
    let mut slot = lock(&backend.link);
    if let Some(link) = slot.as_ref() {
        if link.alive.load(Ordering::SeqCst) {
            return Ok(Arc::clone(link));
        }
    }
    let stream = dial_timeout(&backend.spec.addr, shared.spec.probe.timeout)?;
    stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
    let mut reader = stream.try_clone()?;
    reader.set_read_timeout(Some(READ_TICK))?;
    let generation = backend.link_generation.fetch_add(1, Ordering::Relaxed) + 1;
    let link = Arc::new(Link {
        tx: Mutex::new(stream),
        alive: AtomicBool::new(true),
        generation,
    });
    *slot = Some(Arc::clone(&link));
    drop(slot);
    let handle = {
        let shared = Arc::clone(shared);
        let link = Arc::clone(&link);
        std::thread::spawn(move || link_loop(&shared, idx, reader, link))
    };
    lock(&shared.link_threads).push(handle);
    Ok(link)
}

fn link_loop(shared: &Arc<Shared>, idx: usize, mut reader: TcpStream, link: Arc<Link>) {
    let died = loop {
        if shared.stop.load(Ordering::Relaxed) {
            break false;
        }
        if !link.alive.load(Ordering::SeqCst) {
            break true;
        }
        match read_frame_stoppable(&mut reader, &shared.stop) {
            Ok(Some(payload)) => deliver_response(shared, payload),
            Ok(None) => break !shared.stop.load(Ordering::Relaxed),
            Err(_) => break true,
        }
    };
    if died {
        fail_backend(shared, idx, &link);
    }
}

/// Relay one backend response: match the ingress id, stamp the
/// caller's id back, forward the bytes.
fn deliver_response(shared: &Shared, payload: Vec<u8>) {
    let Some(ingress_id) = proto::frame_id(&payload) else {
        shared
            .counters
            .responses_dropped
            .fetch_add(1, Ordering::Relaxed);
        return;
    };
    let Some(route) = lock(&shared.routes).remove(&ingress_id) else {
        // Client vanished (its routes were swept) or a stray frame.
        shared
            .counters
            .responses_dropped
            .fetch_add(1, Ordering::Relaxed);
        return;
    };
    shared.backends[route.backend]
        .in_flight
        .fetch_sub(1, Ordering::Relaxed);
    shared
        .counters
        .requests_in_flight
        .fetch_sub(1, Ordering::Relaxed);
    let mut buf = payload;
    if proto::rewrite_frame_id(&mut buf, route.client_id).is_err() {
        shared
            .counters
            .responses_dropped
            .fetch_add(1, Ordering::Relaxed);
        return;
    }
    let conn = lock(&shared.conns).get(&route.token).map(Arc::clone);
    let relayed = match conn {
        Some(conn) => {
            use std::io::Write;
            let mut tx = lock(&conn.tx);
            tx.write_all(&(buf.len() as u32).to_le_bytes()).is_ok()
                && tx.write_all(&buf).is_ok()
        }
        None => false,
    };
    if relayed {
        shared
            .counters
            .responses_relayed
            .fetch_add(1, Ordering::Relaxed);
    } else {
        shared
            .counters
            .responses_dropped
            .fetch_add(1, Ordering::Relaxed);
    }
}

/// The link died: fail every in-flight route on it (each answered
/// `Error` exactly once), clear the link slot, and eject the backend
/// on data-plane evidence.
fn fail_backend(shared: &Shared, idx: usize, link: &Link) {
    link.alive.store(false, Ordering::SeqCst);
    {
        let mut slot = lock(&shared.backends[idx].link);
        if let Some(current) = slot.as_ref() {
            if current.generation == link.generation {
                *slot = None;
            }
        }
    }
    let mut failed = Vec::new();
    lock(&shared.routes).retain(|_, r| {
        if r.backend == idx {
            failed.push(r.clone());
            false
        } else {
            true
        }
    });
    for route in &failed {
        fail_route(shared, route, "backend connection lost");
    }
    if lock(&shared.backends[idx].tracker).force_eject() == Some(Transition::Ejected) {
        shared.counters.ejections.fetch_add(1, Ordering::Relaxed);
    }
}

// ---- prober -------------------------------------------------------------

fn sleep_stoppable(stop: &AtomicBool, total: Duration) {
    let deadline = Instant::now() + total;
    while Instant::now() < deadline && !stop.load(Ordering::Relaxed) {
        std::thread::sleep(POLL_TICK.min(deadline - Instant::now()));
    }
}

fn prober_loop(shared: &Arc<Shared>) {
    let mut probe_id: u64 = 0;
    while !shared.stop.load(Ordering::Relaxed) {
        sleep_stoppable(&shared.stop, shared.spec.probe.interval);
        for (idx, backend) in shared.backends.iter().enumerate() {
            if shared.stop.load(Ordering::Relaxed) {
                return;
            }
            if shared.fault.delay_probes_ms > 0 {
                sleep_stoppable(
                    &shared.stop,
                    Duration::from_millis(shared.fault.delay_probes_ms),
                );
            }
            // A black-holed probe is one the prober never hears back
            // from: it counts as a failure without touching the wire.
            let ok = if shared.fstate.consume_probe_drop(idx) {
                false
            } else {
                probe_id += 1;
                match probe_list_models(&backend.spec.addr, shared.spec.probe.timeout, probe_id) {
                    Ok(live) => advertises_assignment(&backend.spec, &live),
                    Err(_) => false,
                }
            };
            if ok {
                shared.counters.probes_ok.fetch_add(1, Ordering::Relaxed);
            } else {
                shared.counters.probes_failed.fetch_add(1, Ordering::Relaxed);
            }
            match lock(&backend.tracker).observe(ok) {
                Some(Transition::Ejected) => {
                    shared.counters.ejections.fetch_add(1, Ordering::Relaxed);
                    // Drain-on-ejection: the link (if any) stays open so
                    // in-flight requests finish; only new traffic stops.
                }
                Some(Transition::Recovered) => {
                    shared.counters.recoveries.fetch_add(1, Ordering::Relaxed);
                }
                _ => {}
            }
        }
    }
}

// ---- reconciler ---------------------------------------------------------

/// Node-agent loop: respawn managed backends whose process died, after
/// the `restart_after` damper, within the `max_restarts` budget.
/// Re-registration is implicit — the respawned process answers probes
/// on its spec'd address, walks probation, and rejoins the pool.
fn reconciler_loop(shared: &Arc<Shared>) {
    while !shared.stop.load(Ordering::Relaxed) {
        sleep_stoppable(&shared.stop, RECONCILE_TICK);
        for backend in &shared.backends {
            if !backend.spec.managed() || !backend.child_exited() {
                continue;
            }
            let eligible = {
                let mut down = lock(&backend.down_since);
                match *down {
                    None => {
                        *down = Some(Instant::now());
                        false
                    }
                    Some(t0) => t0.elapsed() >= shared.spec.reconcile.restart_after,
                }
            };
            if !eligible
                || backend.restarts.load(Ordering::Relaxed)
                    >= shared.spec.reconcile.max_restarts as u64
            {
                continue;
            }
            // Count the attempt against the budget whether or not the
            // spawn succeeds — a command that cannot spawn must not
            // retry forever.
            backend.restarts.fetch_add(1, Ordering::Relaxed);
            shared.counters.restarts.fetch_add(1, Ordering::Relaxed);
            if backend.spawn_child().is_err() {
                *lock(&backend.down_since) = Some(Instant::now());
            }
        }
    }
}

//! The per-backend probe state machine.
//!
//! Pure and synchronous — the prober thread feeds it one boolean probe
//! outcome at a time and acts on the returned [`Transition`]; nothing
//! here touches sockets or clocks, which is what makes the
//! healthy→ejected→probation→healthy ladder pinnable against a table
//! of outcome sequences (`rust/tests/ingress_routing.rs`).
//!
//! The ladder:
//!
//! ```text
//! Healthy ──(K consecutive failures)──▶ Ejected
//! Ejected ──(1 success)──▶ Probation          (no traffic yet)
//! Probation ──(M consecutive successes total)──▶ Healthy
//! Probation ──(any failure)──▶ Ejected        (relapse, count resets)
//! ```
//!
//! Probation receives no traffic: a backend that just came back (or
//! was just restarted by the reconciler) must prove itself for M
//! consecutive probes before the router sees it again. With M = 1 the
//! first success graduates immediately (probation collapses to an
//! instant).

/// Routing-visible health of one backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    /// Takes traffic.
    Healthy,
    /// Takes no traffic; probes keep running so it can come back.
    Ejected,
    /// Probes are succeeding but the success streak is still short of
    /// the recovery threshold; takes no traffic.
    Probation,
}

/// A state change worth counting or logging.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transition {
    /// Entered `Ejected` (threshold reached, probation relapse, or a
    /// forced ejection from the data plane).
    Ejected,
    /// Entered `Probation` (first success while ejected).
    Probation,
    /// Entered `Healthy` (success streak reached the threshold).
    Recovered,
}

/// One backend's probe bookkeeping.
#[derive(Clone, Debug)]
pub struct ProbeTracker {
    state: HealthState,
    eject_after: u32,
    probation_successes: u32,
    /// Consecutive failures while `Healthy`.
    failures: u32,
    /// Consecutive successes since leaving `Healthy`.
    successes: u32,
}

impl ProbeTracker {
    /// A tracker that starts `Healthy` (the spec declares the backend;
    /// the first K failed probes demote it). Zero thresholds are
    /// clamped to 1 — `validate` rejects them upstream, but a tracker
    /// must never be unable to transition.
    pub fn new(eject_after: u32, probation_successes: u32) -> ProbeTracker {
        ProbeTracker {
            state: HealthState::Healthy,
            eject_after: eject_after.max(1),
            probation_successes: probation_successes.max(1),
            failures: 0,
            successes: 0,
        }
    }

    pub fn state(&self) -> HealthState {
        self.state
    }

    /// May the router send this backend traffic?
    pub fn routable(&self) -> bool {
        self.state == HealthState::Healthy
    }

    /// Feed one probe outcome; returns the transition it caused, if
    /// any.
    pub fn observe(&mut self, ok: bool) -> Option<Transition> {
        match (self.state, ok) {
            (HealthState::Healthy, true) => {
                self.failures = 0;
                None
            }
            (HealthState::Healthy, false) => {
                self.failures += 1;
                (self.failures >= self.eject_after).then(|| {
                    self.state = HealthState::Ejected;
                    self.successes = 0;
                    Transition::Ejected
                })
            }
            (HealthState::Ejected, true) => {
                self.successes = 1;
                Some(if self.successes >= self.probation_successes {
                    self.state = HealthState::Healthy;
                    self.failures = 0;
                    Transition::Recovered
                } else {
                    self.state = HealthState::Probation;
                    Transition::Probation
                })
            }
            (HealthState::Ejected, false) => None,
            (HealthState::Probation, true) => {
                self.successes += 1;
                (self.successes >= self.probation_successes).then(|| {
                    self.state = HealthState::Healthy;
                    self.failures = 0;
                    Transition::Recovered
                })
            }
            (HealthState::Probation, false) => {
                self.state = HealthState::Ejected;
                self.successes = 0;
                Some(Transition::Ejected)
            }
        }
    }

    /// Eject immediately, bypassing the failure threshold — the data
    /// plane saw the backend die mid-frame (link EOF/reset), which is
    /// stronger evidence than any probe. No-op when already ejected;
    /// from probation it counts as a relapse.
    pub fn force_eject(&mut self) -> Option<Transition> {
        if self.state == HealthState::Ejected {
            return None;
        }
        self.state = HealthState::Ejected;
        self.failures = 0;
        self.successes = 0;
        Some(Transition::Ejected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Replay a probe outcome sequence, returning (final state, all
    /// transitions in order).
    fn replay(k: u32, m: u32, outcomes: &[bool]) -> (HealthState, Vec<Transition>) {
        let mut t = ProbeTracker::new(k, m);
        let transitions = outcomes.iter().filter_map(|&ok| t.observe(ok)).collect();
        (t.state(), transitions)
    }

    #[test]
    fn full_ladder_healthy_ejected_probation_healthy() {
        use Transition::*;
        let (state, trans) = replay(2, 2, &[true, false, false, false, true, true]);
        assert_eq!(state, HealthState::Healthy);
        assert_eq!(trans, vec![Ejected, Probation, Recovered]);
    }

    #[test]
    fn single_failure_below_threshold_does_not_eject() {
        let (state, trans) = replay(3, 1, &[false, false, true, false, false]);
        // Failure streaks of 2 against a threshold of 3, broken by a
        // success: never ejected.
        assert_eq!(state, HealthState::Healthy);
        assert!(trans.is_empty());
    }

    #[test]
    fn probation_relapse_resets_the_success_streak() {
        use Transition::*;
        let (state, trans) = replay(1, 3, &[false, true, true, false, true, true, true]);
        assert_eq!(state, HealthState::Healthy);
        assert_eq!(trans, vec![Ejected, Probation, Ejected, Probation, Recovered]);
    }

    #[test]
    fn probation_takes_no_traffic() {
        let mut t = ProbeTracker::new(1, 2);
        assert!(t.routable());
        t.observe(false);
        assert!(!t.routable());
        t.observe(true);
        assert_eq!(t.state(), HealthState::Probation);
        assert!(!t.routable());
        t.observe(true);
        assert!(t.routable());
    }

    #[test]
    fn m_equals_one_collapses_probation() {
        use Transition::*;
        let (state, trans) = replay(1, 1, &[false, true]);
        assert_eq!(state, HealthState::Healthy);
        assert_eq!(trans, vec![Ejected, Recovered]);
    }

    #[test]
    fn force_eject_is_idempotent_and_requires_full_recovery() {
        let mut t = ProbeTracker::new(5, 2);
        assert_eq!(t.force_eject(), Some(Transition::Ejected));
        assert_eq!(t.force_eject(), None);
        assert_eq!(t.observe(true), Some(Transition::Probation));
        assert_eq!(t.observe(true), Some(Transition::Recovered));
        assert!(t.routable());
    }
}

//! Virtual-node augmentation (paper Sections 4.5 / Fig. 6): append an
//! artificial node connected to every real node. The VN is the
//! highest-degree node by construction, which is exactly the imbalance
//! the streaming pipeline absorbs (Fig. 9(c)).

use crate::graph::CooGraph;

/// Id assigned to the virtual node after augmentation = original n.
pub fn augment_with_virtual_node(g: &CooGraph) -> CooGraph {
    let vn = g.n as u32;
    let mut edges = g.edges.clone();
    let mut edge_feat = g.edge_feat.clone();
    // Bidirectional connection to every real node (Fig. 6 left), with
    // zero edge features (the VN carries no bond semantics).
    for v in 0..g.n as u32 {
        edges.push((vn, v));
        edges.push((v, vn));
        edge_feat.extend(std::iter::repeat(0.0).take(2 * g.f_edge));
    }
    let mut node_feat = g.node_feat.clone();
    node_feat.extend(std::iter::repeat(0.0).take(g.f_node));
    CooGraph {
        n: g.n + 1,
        edges,
        node_feat,
        f_node: g.f_node,
        edge_feat,
        f_edge: g.f_edge,
    }
}

/// Position the virtual node *first* in the processing order instead of
/// last. Paper Section 4.5: the VN's long message-passing phase fully
/// overlaps with other nodes' embedding computation "as long as it is
/// processed early enough (depending on the node ID numbering and
/// processing order, which is adjustable)".
pub fn augment_with_virtual_node_first(g: &CooGraph) -> CooGraph {
    // Relabel: new id 0 = VN, real node v -> v + 1.
    let mut edges: Vec<(u32, u32)> =
        g.edges.iter().map(|&(s, t)| (s + 1, t + 1)).collect();
    let mut edge_feat = g.edge_feat.clone();
    for v in 1..=g.n as u32 {
        edges.push((0, v));
        edges.push((v, 0));
        edge_feat.extend(std::iter::repeat(0.0).take(2 * g.f_edge));
    }
    let mut node_feat = vec![0.0; g.f_node];
    node_feat.extend_from_slice(&g.node_feat);
    CooGraph {
        n: g.n + 1,
        edges,
        node_feat,
        f_node: g.f_node,
        edge_feat,
        f_edge: g.f_edge,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> CooGraph {
        CooGraph::from_undirected(
            3,
            &[(0, 1), (1, 2)],
            vec![1.0; 3 * 2],
            2,
            &[5.0, 6.0],
            1,
        )
        .unwrap()
    }

    #[test]
    fn vn_connects_to_all_nodes() {
        let g = augment_with_virtual_node(&base());
        assert_eq!(g.n, 4);
        let deg = g.out_degrees();
        assert_eq!(deg[3], 3, "VN out-degree must equal original n");
        // Every real node gained exactly one out-edge (to the VN):
        // path 0-1-2 had out-degrees [1, 2, 1].
        assert_eq!(&deg[..3], &[2, 3, 2]);
        g.validate().unwrap();
    }

    #[test]
    fn vn_first_is_relabelled_isomorph() {
        let last = augment_with_virtual_node(&base());
        let first = augment_with_virtual_node_first(&base());
        assert_eq!(first.n, last.n);
        assert_eq!(first.num_edges(), last.num_edges());
        // VN (id 0) is the max-degree node.
        let deg = first.out_degrees();
        assert_eq!(deg[0], 3);
        first.validate().unwrap();
    }

    #[test]
    fn vn_edge_features_are_zero() {
        let g = augment_with_virtual_node(&base());
        // Last 6 directed edges are VN edges with 0-features.
        let m = g.num_edges();
        for ei in (m - 6)..m {
            assert_eq!(g.edge_feat[ei], 0.0);
        }
    }

    #[test]
    fn vn_is_highest_degree() {
        let g = augment_with_virtual_node(&base());
        let deg = g.out_degrees();
        let max = *deg.iter().max().unwrap();
        assert_eq!(deg[3], max);
    }
}

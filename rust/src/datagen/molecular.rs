//! Synthetic molecular-graph workload generator (MolHIV / MolPCBA
//! substitute — see rust/README.md § Backends).
//!
//! OGB molecular graphs are small (MolHIV mean ≈ 25.5 nodes, ≈ 27.5
//! undirected bonds), tree-like with a few rings, with 9 integer-coded
//! atom features and 3 integer-coded bond features. The generator
//! produces a random spanning tree plus ~8% extra ring-closing bonds,
//! which matches those statistics distributionally — the only graph
//! properties the latency experiments (Figs. 7, 9) depend on.

use crate::graph::CooGraph;
use crate::util::rng::Rng;

pub const ATOM_F: usize = 9;
pub const BOND_F: usize = 3;
pub const MOLHIV_MEAN_NODES: f64 = 25.5;
pub const MOLPCBA_MEAN_NODES: f64 = 26.0;

/// Configuration for the molecular generator.
#[derive(Clone, Copy, Debug)]
pub struct MolConfig {
    pub mean_nodes: f64,
    pub std_nodes: f64,
    pub ring_fraction: f64,
    pub max_nodes: usize,
}

impl Default for MolConfig {
    fn default() -> Self {
        MolConfig {
            mean_nodes: MOLHIV_MEAN_NODES,
            std_nodes: 6.0,
            ring_fraction: 0.08,
            max_nodes: 64,
        }
    }
}

impl MolConfig {
    pub fn molhiv() -> Self {
        Self::default()
    }

    pub fn molpcba() -> Self {
        MolConfig {
            mean_nodes: MOLPCBA_MEAN_NODES,
            ..Self::default()
        }
    }
}

/// Generate one molecule-like graph.
pub fn molecular_graph(rng: &mut Rng, cfg: &MolConfig) -> CooGraph {
    let n = (rng.normal_with(cfg.mean_nodes, cfg.std_nodes).round() as isize)
        .clamp(2, cfg.max_nodes as isize) as usize;

    // Random spanning tree: node v attaches to a uniform prior node.
    let mut und: Vec<(u32, u32)> = Vec::with_capacity(n + 4);
    for v in 1..n {
        let u = rng.below(v) as u32;
        und.push((u, v as u32));
    }
    // Ring bonds: ~ring_fraction * n extra closures.
    let extra = ((n as f64 * cfg.ring_fraction).round() as usize) + rng.below(3);
    for _ in 0..extra {
        let u = rng.below(n) as u32;
        let v = rng.below(n) as u32;
        if u == v {
            continue;
        }
        let e = (u.min(v), u.max(v));
        if !und.contains(&e) {
            und.push(e);
        }
    }

    let node_feat: Vec<f32> = (0..n * ATOM_F)
        .map(|_| rng.below(6) as f32)
        .collect();
    let edge_feat: Vec<f32> = (0..und.len() * BOND_F)
        .map(|_| rng.below(4) as f32)
        .collect();

    CooGraph::from_undirected(n, &und, node_feat, ATOM_F, &edge_feat, BOND_F)
        .expect("generator produces valid graphs")
}

/// Generate a dataset of `count` graphs (the streaming workload).
pub fn dataset(seed: u64, count: usize, cfg: &MolConfig) -> Vec<CooGraph> {
    let mut root = Rng::new(seed);
    (0..count)
        .map(|i| molecular_graph(&mut root.fork(i as u64), cfg))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn graphs_are_connected_trees_plus_rings() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let g = molecular_graph(&mut rng, &MolConfig::default());
            g.validate().unwrap();
            // Spanning tree guarantees connectivity: BFS covers all nodes.
            let csr = crate::graph::Csr::from_coo(&g);
            let mut seen = vec![false; g.n];
            let mut stack = vec![0usize];
            seen[0] = true;
            while let Some(v) = stack.pop() {
                for &w in csr.row(v) {
                    if !seen[w as usize] {
                        seen[w as usize] = true;
                        stack.push(w as usize);
                    }
                }
            }
            assert!(seen.iter().all(|&s| s), "disconnected molecule");
        }
    }

    #[test]
    fn dataset_statistics_match_molhiv() {
        let graphs = dataset(7, 500, &MolConfig::molhiv());
        let mean_n: f64 =
            graphs.iter().map(|g| g.n as f64).sum::<f64>() / graphs.len() as f64;
        let mean_e: f64 = graphs
            .iter()
            .map(|g| g.num_edges() as f64 / 2.0)
            .sum::<f64>()
            / graphs.len() as f64;
        assert!(
            (mean_n - MOLHIV_MEAN_NODES).abs() < 2.0,
            "mean nodes {mean_n}"
        );
        // MolHIV: ~27.5 undirected edges per graph.
        assert!((mean_e - 27.5).abs() < 4.0, "mean edges {mean_e}");
    }

    #[test]
    fn respects_max_nodes() {
        let graphs = dataset(3, 200, &MolConfig::default());
        assert!(graphs.iter().all(|g| g.n <= 64));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = dataset(42, 10, &MolConfig::default());
        let b = dataset(42, 10, &MolConfig::default());
        assert_eq!(a, b);
    }

    #[test]
    fn feature_ranges_are_integer_codes() {
        let g = molecular_graph(&mut Rng::new(5), &MolConfig::default());
        assert!(g
            .node_feat
            .iter()
            .all(|&v| v >= 0.0 && v < 6.0 && v.fract() == 0.0));
        assert!(g
            .edge_feat
            .iter()
            .all(|&v| v >= 0.0 && v < 4.0 && v.fract() == 0.0));
    }
}

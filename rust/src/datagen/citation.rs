//! Synthetic citation-network generator (Cora / CiteSeer / PubMed
//! substitute for the Large Graph Extension, paper Table 5 / Fig. 8).
//!
//! Preferential attachment yields the power-law degree distribution of
//! real citation graphs; node/edge counts and feature widths match
//! Table 5 exactly, which is what the DRAM-traffic model (sim/large.rs)
//! and the baselines depend on.

use crate::graph::CooGraph;
use crate::util::rng::Rng;

/// Table 5 rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CitationDataset {
    Cora,
    CiteSeer,
    PubMed,
}

impl CitationDataset {
    pub fn name(&self) -> &'static str {
        match self {
            CitationDataset::Cora => "Cora",
            CitationDataset::CiteSeer => "CiteSeer",
            CitationDataset::PubMed => "PubMed",
        }
    }

    /// (nodes, directed edges, feature dim) exactly as in Table 5.
    pub fn stats(&self) -> (usize, usize, usize) {
        match self {
            CitationDataset::Cora => (2708, 10_556, 1433),
            CitationDataset::CiteSeer => (3327, 9104, 3703),
            CitationDataset::PubMed => (19_717, 88_648, 500),
        }
    }

    /// Label-space size of the real dataset — the resident model's
    /// output width (Cora 7, CiteSeer 6, PubMed 3).
    pub fn num_classes(&self) -> usize {
        match self {
            CitationDataset::Cora => 7,
            CitationDataset::CiteSeer => 6,
            CitationDataset::PubMed => 3,
        }
    }

    /// Parse a CLI spelling, case-insensitively.
    pub fn parse(s: &str) -> anyhow::Result<CitationDataset> {
        match s.to_ascii_lowercase().as_str() {
            "cora" => Ok(CitationDataset::Cora),
            "citeseer" => Ok(CitationDataset::CiteSeer),
            "pubmed" => Ok(CitationDataset::PubMed),
            _ => anyhow::bail!("unknown citation dataset {s:?} (cora|citeseer|pubmed)"),
        }
    }

    pub fn all() -> [CitationDataset; 3] {
        [
            CitationDataset::Cora,
            CitationDataset::CiteSeer,
            CitationDataset::PubMed,
        ]
    }
}

/// Generate a citation-style graph with `n` nodes and ~`m_directed/2`
/// undirected edges via preferential attachment.
pub fn citation_graph(seed: u64, n: usize, m_directed: usize, f: usize) -> CooGraph {
    let mut rng = Rng::new(seed);
    let target_und = m_directed / 2;
    let m_per = (target_und as f64 / n.max(1) as f64).round().max(1.0) as usize;

    let mut und: Vec<(u32, u32)> = Vec::with_capacity(target_und + n);
    let mut seen = std::collections::HashSet::with_capacity(target_und * 2);
    // `repeated` holds every endpoint once per incident edge: sampling it
    // uniformly == degree-proportional attachment.
    let mut repeated: Vec<u32> = Vec::with_capacity(target_und * 2 + n);
    repeated.push(0);

    for v in 1..n {
        let k = m_per.min(v);
        let mut attached = 0usize;
        let mut attempts = 0usize;
        while attached < k && attempts < 20 * k {
            attempts += 1;
            let u = if rng.chance(0.9) {
                repeated[rng.below(repeated.len())]
            } else {
                rng.below(v) as u32
            };
            if u as usize == v {
                continue;
            }
            let e = (u.min(v as u32), u.max(v as u32));
            if seen.insert(e) {
                und.push(e);
                repeated.push(e.0);
                repeated.push(e.1);
                attached += 1;
            }
        }
    }
    // Top up or trim to hit the exact edge budget.
    let mut guard = 0usize;
    while und.len() < target_und && guard < 50 * target_und {
        guard += 1;
        let u = repeated[rng.below(repeated.len())];
        let v = rng.below(n) as u32;
        if u == v {
            continue;
        }
        let e = (u.min(v), u.max(v));
        if seen.insert(e) {
            und.push(e);
            repeated.push(e.0);
            repeated.push(e.1);
        }
    }
    // Deterministic lexicographic fill: on dense graphs the stochastic
    // top-up can exhaust its guard budget in collisions, leaving the
    // count short of Table 5. Walking (u, v) pairs in order closes the
    // gap exactly whenever target_und <= n*(n-1)/2.
    'fill: for u in 0..n as u32 {
        if und.len() >= target_und {
            break;
        }
        for v in (u + 1)..n as u32 {
            if und.len() >= target_und {
                break 'fill;
            }
            if seen.insert((u, v)) {
                und.push((u, v));
            }
        }
    }
    und.truncate(target_und);

    // Sparse bag-of-words features: ~1% nonzero, like the real datasets.
    let nnz_per_node = (f as f64 * 0.01).ceil() as usize;
    let mut node_feat = vec![0.0f32; n * f];
    for v in 0..n {
        for _ in 0..nnz_per_node {
            node_feat[v * f + rng.below(f)] = 1.0;
        }
    }

    CooGraph::from_undirected(n, &und, node_feat, f, &[], 0)
        .expect("generator produces valid graphs")
}

/// Generate the named Table 5 dataset (full size).
pub fn dataset(which: CitationDataset, seed: u64) -> CooGraph {
    let (n, m, f) = which.stats();
    citation_graph(seed, n, m, f)
}

/// Scaled-down version preserving density/feature ratios — used by the
/// numeric (PJRT) path, where the full graphs exceed the artifact's
/// padded capacity (rust/README.md § Backends).
pub fn dataset_scaled(which: CitationDataset, seed: u64, n: usize, f: usize) -> CooGraph {
    let (n0, m0, _) = which.stats();
    let m = (m0 as f64 * n as f64 / n0 as f64).round() as usize;
    citation_graph(seed, n, m, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_table5_counts_exactly() {
        for which in CitationDataset::all() {
            let (n, m, f) = which.stats();
            let g = dataset(which, 1);
            assert_eq!(g.n, n);
            assert_eq!(g.f_node, f);
            assert_eq!(
                g.num_edges(),
                m,
                "{}: edges {} vs Table 5's {}",
                which.name(),
                g.num_edges(),
                m
            );
        }
    }

    #[test]
    fn no_self_loops_or_duplicate_edges() {
        for seed in [1, 5, 11] {
            let g = citation_graph(seed, 800, 3200, 8);
            let mut seen = std::collections::HashSet::new();
            for &(s, d) in &g.edges {
                assert_ne!(s, d, "seed {seed}: self-loop at {s}");
                assert!(seen.insert((s, d)), "seed {seed}: duplicate edge {s}->{d}");
            }
        }
    }

    #[test]
    fn distinct_seeds_give_distinct_graphs() {
        let a = citation_graph(9, 500, 2000, 8);
        let b = citation_graph(10, 500, 2000, 8);
        assert_ne!(a, b);
        assert_eq!(a.num_edges(), b.num_edges());
    }

    #[test]
    fn degree_distribution_is_heavy_tailed() {
        let g = citation_graph(3, 2000, 8000, 16);
        let mut deg = g.out_degrees();
        deg.sort_unstable_by(|a, b| b.cmp(a));
        let top1pct: u32 = deg[..20].iter().sum();
        let total: u32 = deg.iter().sum();
        // Top 1% of nodes should hold well above 1% of the edges.
        assert!(
            top1pct as f64 / total as f64 > 0.05,
            "top1% share {}",
            top1pct as f64 / total as f64
        );
    }

    #[test]
    fn deterministic() {
        let a = citation_graph(9, 500, 2000, 8);
        let b = citation_graph(9, 500, 2000, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn scaled_preserves_density() {
        let g = dataset_scaled(CitationDataset::PubMed, 2, 400, 32);
        let (n0, m0, _) = CitationDataset::PubMed.stats();
        let want = m0 as f64 / n0 as f64;
        let got = g.num_edges() as f64 / g.n as f64;
        assert!((got - want).abs() / want < 0.25, "density {got} vs {want}");
    }

    #[test]
    fn parse_accepts_case_insensitive_names() {
        assert_eq!(CitationDataset::parse("cora").unwrap(), CitationDataset::Cora);
        assert_eq!(
            CitationDataset::parse("CiteSeer").unwrap(),
            CitationDataset::CiteSeer
        );
        assert_eq!(CitationDataset::parse("PUBMED").unwrap(), CitationDataset::PubMed);
        assert!(CitationDataset::parse("reddit").is_err());
    }

    #[test]
    fn features_are_sparse_binary() {
        let g = citation_graph(4, 100, 400, 64);
        let nnz = g.node_feat.iter().filter(|&&v| v != 0.0).count();
        assert!(nnz > 0 && nnz < g.node_feat.len() / 10);
        assert!(g.node_feat.iter().all(|&v| v == 0.0 || v == 1.0));
    }
}

//! Synthetic citation-network generator (Cora / CiteSeer / PubMed
//! substitute for the Large Graph Extension, paper Table 5 / Fig. 8).
//!
//! Preferential attachment yields the power-law degree distribution of
//! real citation graphs; node/edge counts and feature widths match
//! Table 5 exactly, which is what the DRAM-traffic model (sim/large.rs)
//! and the baselines depend on.

use crate::graph::CooGraph;
use crate::util::rng::Rng;

/// Table 5 rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CitationDataset {
    Cora,
    CiteSeer,
    PubMed,
}

impl CitationDataset {
    pub fn name(&self) -> &'static str {
        match self {
            CitationDataset::Cora => "Cora",
            CitationDataset::CiteSeer => "CiteSeer",
            CitationDataset::PubMed => "PubMed",
        }
    }

    /// (nodes, directed edges, feature dim) exactly as in Table 5.
    pub fn stats(&self) -> (usize, usize, usize) {
        match self {
            CitationDataset::Cora => (2708, 10_556, 1433),
            CitationDataset::CiteSeer => (3327, 9104, 3703),
            CitationDataset::PubMed => (19_717, 88_648, 500),
        }
    }

    pub fn all() -> [CitationDataset; 3] {
        [
            CitationDataset::Cora,
            CitationDataset::CiteSeer,
            CitationDataset::PubMed,
        ]
    }
}

/// Generate a citation-style graph with `n` nodes and ~`m_directed/2`
/// undirected edges via preferential attachment.
pub fn citation_graph(seed: u64, n: usize, m_directed: usize, f: usize) -> CooGraph {
    let mut rng = Rng::new(seed);
    let target_und = m_directed / 2;
    let m_per = (target_und as f64 / n.max(1) as f64).round().max(1.0) as usize;

    let mut und: Vec<(u32, u32)> = Vec::with_capacity(target_und + n);
    let mut seen = std::collections::HashSet::with_capacity(target_und * 2);
    // `repeated` holds every endpoint once per incident edge: sampling it
    // uniformly == degree-proportional attachment.
    let mut repeated: Vec<u32> = Vec::with_capacity(target_und * 2 + n);
    repeated.push(0);

    for v in 1..n {
        let k = m_per.min(v);
        let mut attached = 0usize;
        let mut attempts = 0usize;
        while attached < k && attempts < 20 * k {
            attempts += 1;
            let u = if rng.chance(0.9) {
                repeated[rng.below(repeated.len())]
            } else {
                rng.below(v) as u32
            };
            if u as usize == v {
                continue;
            }
            let e = (u.min(v as u32), u.max(v as u32));
            if seen.insert(e) {
                und.push(e);
                repeated.push(e.0);
                repeated.push(e.1);
                attached += 1;
            }
        }
    }
    // Top up or trim to hit the exact edge budget.
    let mut guard = 0usize;
    while und.len() < target_und && guard < 50 * target_und {
        guard += 1;
        let u = repeated[rng.below(repeated.len())];
        let v = rng.below(n) as u32;
        if u == v {
            continue;
        }
        let e = (u.min(v), u.max(v));
        if seen.insert(e) {
            und.push(e);
            repeated.push(e.0);
            repeated.push(e.1);
        }
    }
    und.truncate(target_und);

    // Sparse bag-of-words features: ~1% nonzero, like the real datasets.
    let nnz_per_node = (f as f64 * 0.01).ceil() as usize;
    let mut node_feat = vec![0.0f32; n * f];
    for v in 0..n {
        for _ in 0..nnz_per_node {
            node_feat[v * f + rng.below(f)] = 1.0;
        }
    }

    CooGraph::from_undirected(n, &und, node_feat, f, &[], 0)
        .expect("generator produces valid graphs")
}

/// Generate the named Table 5 dataset (full size).
pub fn dataset(which: CitationDataset, seed: u64) -> CooGraph {
    let (n, m, f) = which.stats();
    citation_graph(seed, n, m, f)
}

/// Scaled-down version preserving density/feature ratios — used by the
/// numeric (PJRT) path, where the full graphs exceed the artifact's
/// padded capacity (rust/README.md § Backends).
pub fn dataset_scaled(which: CitationDataset, seed: u64, n: usize, f: usize) -> CooGraph {
    let (n0, m0, _) = which.stats();
    let m = (m0 as f64 * n as f64 / n0 as f64).round() as usize;
    citation_graph(seed, n, m, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_table5_counts() {
        for which in CitationDataset::all() {
            let (n, m, f) = which.stats();
            let g = dataset(which, 1);
            assert_eq!(g.n, n);
            assert_eq!(g.f_node, f);
            let err = (g.num_edges() as f64 - m as f64).abs() / m as f64;
            assert!(err < 0.02, "{}: edges {} vs {}", which.name(), g.num_edges(), m);
        }
    }

    #[test]
    fn degree_distribution_is_heavy_tailed() {
        let g = citation_graph(3, 2000, 8000, 16);
        let mut deg = g.out_degrees();
        deg.sort_unstable_by(|a, b| b.cmp(a));
        let top1pct: u32 = deg[..20].iter().sum();
        let total: u32 = deg.iter().sum();
        // Top 1% of nodes should hold well above 1% of the edges.
        assert!(
            top1pct as f64 / total as f64 > 0.05,
            "top1% share {}",
            top1pct as f64 / total as f64
        );
    }

    #[test]
    fn deterministic() {
        let a = citation_graph(9, 500, 2000, 8);
        let b = citation_graph(9, 500, 2000, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn scaled_preserves_density() {
        let g = dataset_scaled(CitationDataset::PubMed, 2, 400, 32);
        let (n0, m0, _) = CitationDataset::PubMed.stats();
        let want = m0 as f64 / n0 as f64;
        let got = g.num_edges() as f64 / g.n as f64;
        assert!((got - want).abs() / want < 0.25, "density {got} vs {want}");
    }

    #[test]
    fn features_are_sparse_binary() {
        let g = citation_graph(4, 100, 400, 64);
        let nnz = g.node_feat.iter().filter(|&&v| v != 0.0).count();
        assert!(nnz > 0 && nnz < g.node_feat.len() / 10);
        assert!(g.node_feat.iter().all(|&v| v == 0.0 || v == 1.0));
    }
}

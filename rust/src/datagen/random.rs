//! Controlled-statistics random graphs for the Fig. 9(a) sweep: the
//! paper evaluates the pipelining strategies on "100k random graphs
//! with various statistics, including average node degree (x-axis) and
//! the percentage of large-degree nodes (y-axis)".

use crate::graph::CooGraph;
use crate::util::rng::Rng;

/// Parameters of one Fig. 9(a) grid cell.
#[derive(Clone, Copy, Debug)]
pub struct RandomGraphConfig {
    pub nodes: usize,
    /// Target average (directed) degree of ordinary nodes.
    pub avg_degree: f64,
    /// Fraction of nodes that are "large-degree" hubs.
    pub high_degree_fraction: f64,
    /// Hub degree multiplier relative to avg_degree.
    pub hub_multiplier: f64,
    pub f_node: usize,
}

impl Default for RandomGraphConfig {
    fn default() -> Self {
        RandomGraphConfig {
            nodes: 32,
            avg_degree: 4.0,
            high_degree_fraction: 0.0,
            hub_multiplier: 6.0,
            f_node: 9,
        }
    }
}

/// Directed random graph with the requested degree profile.
/// Hubs receive `hub_multiplier * avg_degree` out-edges; ordinary nodes
/// `avg_degree` (rounded stochastically), so the *imbalance* knob of
/// Fig. 9(a) is controlled independently of the mean.
pub fn random_graph(rng: &mut Rng, cfg: &RandomGraphConfig) -> CooGraph {
    let n = cfg.nodes;
    let n_hubs = (n as f64 * cfg.high_degree_fraction).round() as usize;
    let hubs: Vec<usize> = rng.permutation(n).into_iter().take(n_hubs).collect();
    let mut is_hub = vec![false; n];
    for &h in &hubs {
        is_hub[h] = true;
    }

    let mut edges: Vec<(u32, u32)> = Vec::new();
    for v in 0..n {
        let target = if is_hub[v] {
            cfg.avg_degree * cfg.hub_multiplier
        } else {
            cfg.avg_degree
        };
        // Stochastic rounding preserves the exact expected mean.
        let mut k = target.floor() as usize;
        if rng.chance(target - target.floor()) {
            k += 1;
        }
        let k = k.min(n.saturating_sub(1));
        for _ in 0..k {
            let mut w = rng.below(n);
            if w == v {
                w = (w + 1) % n;
            }
            edges.push((v as u32, w as u32));
        }
    }

    let node_feat: Vec<f32> = (0..n * cfg.f_node).map(|_| rng.f32()).collect();
    CooGraph {
        n,
        edges,
        node_feat,
        f_node: cfg.f_node,
        edge_feat: vec![],
        f_edge: 0,
    }
}

/// Generate a batch for one grid cell.
pub fn batch(seed: u64, count: usize, cfg: &RandomGraphConfig) -> Vec<CooGraph> {
    let mut root = Rng::new(seed);
    (0..count)
        .map(|i| random_graph(&mut root.fork(i as u64), cfg))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hits_target_average_degree() {
        for &d in &[2.0, 4.0, 8.0, 16.0] {
            let cfg = RandomGraphConfig {
                avg_degree: d,
                nodes: 64,
                ..Default::default()
            };
            let gs = batch(11, 200, &cfg);
            let mean: f64 = gs.iter().map(|g| g.avg_degree()).sum::<f64>()
                / gs.len() as f64;
            assert!(
                (mean - d).abs() / d < 0.1,
                "target {d}, measured {mean}"
            );
        }
    }

    #[test]
    fn hub_fraction_creates_imbalance() {
        let flat = RandomGraphConfig {
            nodes: 100,
            avg_degree: 4.0,
            high_degree_fraction: 0.0,
            ..Default::default()
        };
        let hubby = RandomGraphConfig {
            high_degree_fraction: 0.2,
            ..flat
        };
        let var = |gs: &[CooGraph]| {
            let mut all: Vec<f64> = Vec::new();
            for g in gs {
                all.extend(g.out_degrees().iter().map(|&d| d as f64));
            }
            let m = all.iter().sum::<f64>() / all.len() as f64;
            all.iter().map(|d| (d - m) * (d - m)).sum::<f64>() / all.len() as f64
        };
        let v_flat = var(&batch(5, 50, &flat));
        let v_hub = var(&batch(5, 50, &hubby));
        assert!(
            v_hub > 2.0 * v_flat,
            "hub variance {v_hub} vs flat {v_flat}"
        );
    }

    #[test]
    fn no_self_loops() {
        let g = random_graph(&mut Rng::new(3), &RandomGraphConfig::default());
        assert!(g.edges.iter().all(|&(s, t)| s != t));
    }

    #[test]
    fn deterministic() {
        let cfg = RandomGraphConfig::default();
        assert_eq!(batch(1, 5, &cfg), batch(1, 5, &cfg));
    }
}

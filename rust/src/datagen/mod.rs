//! Workload generators substituting for the paper's datasets
//! (rust/README.md § Backends): synthetic molecules (MolHIV/MolPCBA),
//! preferential-attachment citation graphs (Cora/CiteSeer/PubMed), the
//! Fig. 9(a) controlled random graphs, and virtual-node augmentation.

pub mod citation;
pub mod molecular;
pub mod random;
pub mod virtual_node;

pub use citation::{citation_graph, CitationDataset};
pub use molecular::{molecular_graph, MolConfig};
pub use random::{random_graph, RandomGraphConfig};
pub use virtual_node::{augment_with_virtual_node, augment_with_virtual_node_first};

//! Fig. 9 regeneration: the NE/MP pipelining ablation.
//!
//! (a) a grid of random-graph populations swept over average node
//!     degree (x-axis) and the share of large-degree nodes (y-axis),
//!     GIN model, reporting the three speed-up ratios per cell;
//! (b) the MolHIV benchmark with GIN;
//! (c) MolHIV with GIN + virtual node.
//!
//! Paper ranges: fixed/non 1.2–1.5; streaming/fixed 1.15–1.37;
//! streaming/non 1.53–1.92; benefit shrinks as degree grows; MolHIV
//! (1.38, 1.63); with VN (1.40, 1.61).

use crate::datagen::{molecular, random, MolConfig, RandomGraphConfig};
use crate::graph::{CooGraph, GraphBatch};
use crate::models::ModelConfig;
use crate::sim::cycles::CostParams;
use crate::sim::mp_pe::mp_profile;
use crate::sim::ne_pe::{embed_cycles, ne_cycles};
use crate::sim::pipeline::{schedule_cycles, PipelineMode};

/// Speed-up triple for one workload population.
#[derive(Clone, Copy, Debug, Default)]
pub struct Speedups {
    pub fixed_over_non: f64,
    pub streaming_over_fixed: f64,
    pub streaming_over_non: f64,
}

/// One grid cell of Fig. 9(a).
#[derive(Clone, Debug)]
pub struct Fig9Cell {
    pub avg_degree: f64,
    pub high_fraction: f64,
    pub speedups: Speedups,
}

/// Aggregate pipeline cycles (all layers of `cfg`) across a population,
/// per mode, then form ratios — mirroring the paper's per-population
/// aggregation over 100k graphs.
pub fn population_speedups(cfg: &ModelConfig, graphs: &[CooGraph]) -> Speedups {
    let p = CostParams::default();
    let mut totals = [0u64; 3];
    let ne_base = ne_cycles(&p, cfg);
    let embed = embed_cycles(&p, cfg);
    let mut ne0: Vec<u64> = Vec::new();
    let mut ne: Vec<u64> = Vec::new();
    for g in graphs {
        let batch = GraphBatch::ingest_unchecked(g.clone());
        let mp = mp_profile(&p, cfg, &batch.csr.degree);
        // Layer 0 carries the input embedding; layers 1..L are
        // identical, so schedule once and multiply (§Perf).
        ne0.clear();
        ne0.resize(g.n, embed + ne_base);
        ne.clear();
        ne.resize(g.n, ne_base);
        for (mi, mode) in PipelineMode::all().into_iter().enumerate() {
            totals[mi] += schedule_cycles(mode, &ne0, &mp, p.fifo_depth)
                + (cfg.layers as u64 - 1)
                    * schedule_cycles(mode, &ne, &mp, p.fifo_depth);
        }
    }
    let [non, fixed, streaming] = totals.map(|t| t as f64);
    Speedups {
        fixed_over_non: non / fixed,
        streaming_over_fixed: fixed / streaming,
        streaming_over_non: non / streaming,
    }
}

/// Fig. 9(a): the sweep grid (GIN, like the paper's evaluation).
pub fn compute_grid(
    degrees: &[f64],
    high_fractions: &[f64],
    graphs_per_cell: usize,
    seed: u64,
) -> Vec<Fig9Cell> {
    let gin = ModelConfig::by_name("gin").unwrap();
    let mut cells = Vec::new();
    for (di, &avg_degree) in degrees.iter().enumerate() {
        for (hi, &high_fraction) in high_fractions.iter().enumerate() {
            let cfg = RandomGraphConfig {
                nodes: 32,
                avg_degree,
                high_degree_fraction: high_fraction,
                ..RandomGraphConfig::default()
            };
            let graphs = random::batch(
                seed ^ ((di as u64) << 32) ^ (hi as u64),
                graphs_per_cell,
                &cfg,
            );
            cells.push(Fig9Cell {
                avg_degree,
                high_fraction,
                speedups: population_speedups(&gin, &graphs),
            });
        }
    }
    cells
}

/// Default paper-like sweep axes. The degree axis covers the regime
/// where NE and MP latencies are comparable (molecular graphs sit near
/// degree ~2); past ~2x the balance point both pipelined schedules
/// degenerate to the MP-bound critical path and the streaming/fixed
/// ratio flattens to 1 — the same "degrade to fixed-pipeline" limit the
/// paper describes for large degrees.
pub fn default_grid(graphs_per_cell: usize, seed: u64) -> Vec<Fig9Cell> {
    compute_grid(
        &[1.0, 2.0, 3.0, 4.0, 6.0],
        &[0.02, 0.05, 0.10, 0.20],
        graphs_per_cell,
        seed,
    )
}

/// Fig. 9(b): MolHIV + GIN. Fig. 9(c): MolHIV + GIN with virtual node.
pub fn molhiv(count: usize, seed: u64, virtual_node: bool) -> Speedups {
    let graphs: Vec<CooGraph> = molecular::dataset(seed, count, &MolConfig::molhiv())
        .into_iter()
        .map(|g| {
            if virtual_node {
                crate::datagen::augment_with_virtual_node_first(&g)
            } else {
                g
            }
        })
        .collect();
    let name = if virtual_node { "gin_vn" } else { "gin" };
    // The VN is materialized in the graph, so simulate with plain GIN
    // costs (gin_vn would re-augment).
    let mut cfg = ModelConfig::by_name(name).unwrap();
    cfg.kind = crate::models::GnnKind::Gin;
    population_speedups(&cfg, &graphs)
}

pub fn render_grid(cells: &[Fig9Cell]) -> String {
    let mut out = format!(
        "Fig. 9(a): pipelining speed-ups on random graphs (GIN)\n{:>7} {:>6} {:>9} {:>11} {:>9}\n",
        "avg-deg", "%high", "fix/non", "stream/fix", "str/non"
    );
    for c in cells {
        out.push_str(&format!(
            "{:>7.0} {:>5.0}% {:>9.2} {:>11.2} {:>9.2}\n",
            c.avg_degree,
            c.high_fraction * 100.0,
            c.speedups.fixed_over_non,
            c.speedups.streaming_over_fixed,
            c.speedups.streaming_over_non,
        ));
    }
    out
}

pub fn render_mol(label: &str, s: &Speedups) -> String {
    format!(
        "Fig. 9 ({label}): fixed/non {:.2}x, streaming/fixed {:.2}x, streaming/non {:.2}x\n",
        s.fixed_over_non, s.streaming_over_fixed, s.streaming_over_non
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_always_at_least_one() {
        for c in default_grid(40, 0xF19A) {
            assert!(c.speedups.fixed_over_non >= 1.0, "{c:?}");
            assert!(c.speedups.streaming_over_fixed >= 1.0, "{c:?}");
            assert!(c.speedups.streaming_over_non >= 1.0, "{c:?}");
        }
    }

    #[test]
    fn grid_ratios_in_paper_ballpark() {
        // Paper ranges (1.2-1.5, 1.15-1.37, 1.53-1.92) with absolute
        // slack for the simulator's cost constants; orderings and
        // trends are checked exactly in the other tests.
        for c in default_grid(60, 0xF19B) {
            let s = &c.speedups;
            assert!(
                (1.0..=1.85).contains(&s.fixed_over_non),
                "fixed/non {:.2} at {c:?}",
                s.fixed_over_non
            );
            assert!(
                (1.0..=1.65).contains(&s.streaming_over_fixed),
                "st/fix {:.2} at {c:?}",
                s.streaming_over_fixed
            );
            assert!(
                (1.0..=2.25).contains(&s.streaming_over_non),
                "st/non {:.2} at {c:?}",
                s.streaming_over_non
            );
        }
    }

    #[test]
    fn streaming_benefit_shrinks_with_degree() {
        // Paper trend: higher average degree -> streaming degenerates
        // toward fixed. Compare deg=2 vs deg=32 at the same hub share.
        let cells = compute_grid(&[2.0, 32.0], &[0.05], 100, 7);
        let lo = &cells[0].speedups;
        let hi = &cells[1].speedups;
        assert!(
            lo.streaming_over_non > hi.streaming_over_non,
            "deg2 {:.2} !> deg32 {:.2}",
            lo.streaming_over_non,
            hi.streaming_over_non
        );
    }

    #[test]
    fn molhiv_speedups_in_ballpark() {
        let s = molhiv(150, 0xB0B, false);
        // Paper: (1.38, 1.63). Simulator tolerance: +-0.35 absolute.
        assert!((1.0..=1.9).contains(&s.fixed_over_non), "{s:?}");
        assert!((1.2..=2.1).contains(&s.streaming_over_non), "{s:?}");
    }

    #[test]
    fn virtual_node_keeps_streaming_gain() {
        let plain = molhiv(100, 0xC0C, false);
        let vn = molhiv(100, 0xC0C, true);
        // Paper: VN speedups (1.40, 1.61) stay close to plain (1.38,
        // 1.63) *because* streaming absorbs the VN hub; the VN graph is
        // strictly more imbalanced, so fixed/non must not collapse.
        assert!(vn.fixed_over_non >= plain.fixed_over_non * 0.85, "{vn:?}");
        assert!(
            vn.streaming_over_non >= plain.streaming_over_non * 0.85,
            "{vn:?}"
        );
    }
}

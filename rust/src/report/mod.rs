//! Paper-artifact regeneration: one module per table/figure of the
//! evaluation section (one module per figure/table).

pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod table4;
pub mod table5;

//! Paper-artifact regeneration: one module per table/figure of the
//! evaluation section (DESIGN.md §6 per-experiment index).

pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod table4;
pub mod table5;

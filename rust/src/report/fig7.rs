//! Fig. 7 regeneration: average end-to-end latency per model on the
//! molecular datasets, GenGNN (simulated U50) vs the CPU/GPU baselines.
//!
//! Paper envelopes (§5.3): on MolHIV GenGNN is 1.77–13.84× faster than
//! CPU and 2.05–25.96× than GPU; on MolPCBA 1.64–9.69× / 1.92–17.66×;
//! DGN shows the largest GPU speedup.

use crate::baselines::{cpu, gpu, MOLPCBA_WARM_FACTOR};
use crate::datagen::{molecular, MolConfig};
use crate::graph::GraphBatch;
use crate::models::ModelConfig;
use crate::sim::{Accelerator, PipelineMode};

/// One bar triple of Fig. 7.
#[derive(Clone, Debug)]
pub struct Fig7Row {
    pub model: String,
    pub fpga_secs: f64,
    pub cpu_secs: f64,
    pub gpu_secs: f64,
}

impl Fig7Row {
    pub fn cpu_speedup(&self) -> f64 {
        self.cpu_secs / self.fpga_secs
    }
    pub fn gpu_speedup(&self) -> f64 {
        self.gpu_secs / self.fpga_secs
    }
}

/// Which half of Fig. 7.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MolDataset {
    MolHiv,
    MolPcba,
}

impl MolDataset {
    pub fn config(&self) -> MolConfig {
        match self {
            MolDataset::MolHiv => MolConfig::molhiv(),
            MolDataset::MolPcba => MolConfig::molpcba(),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            MolDataset::MolHiv => "MolHIV",
            MolDataset::MolPcba => "MolPCBA",
        }
    }

    /// Baseline warm factor (steady-state over the larger stream).
    fn warm(&self) -> f64 {
        match self {
            MolDataset::MolHiv => 1.0,
            MolDataset::MolPcba => MOLPCBA_WARM_FACTOR,
        }
    }
}

/// Compute all six rows over `count` generated graphs. Each graph is
/// ingested once ([`GraphBatch`]); the simulator and both baselines
/// read the same converted batch.
pub fn compute(dataset: MolDataset, count: usize, seed: u64) -> Vec<Fig7Row> {
    let batches: Vec<GraphBatch> = molecular::dataset(seed, count, &dataset.config())
        .into_iter()
        .map(GraphBatch::ingest_unchecked)
        .collect();
    ModelConfig::fig7_models()
        .into_iter()
        .map(|cfg| {
            let acc = Accelerator::new(cfg.clone(), PipelineMode::Streaming);
            let fpga = acc.mean_latency_batches(&batches);
            let (mut c, mut g) = (0.0, 0.0);
            for b in &batches {
                let s = b.stats();
                c += cpu::latency(&cfg, s);
                g += gpu::latency(&cfg, s);
            }
            let n = batches.len() as f64;
            Fig7Row {
                model: cfg.kind.paper_name().to_string(),
                fpga_secs: fpga,
                cpu_secs: c / n * dataset.warm(),
                gpu_secs: g / n * dataset.warm(),
            }
        })
        .collect()
}

/// Render the figure as the series the paper plots.
pub fn render(dataset: MolDataset, rows: &[Fig7Row]) -> String {
    let mut out = format!(
        "Fig. 7 ({}): average latency over test graphs\n{:<8} {:>12} {:>12} {:>12} {:>9} {:>9}\n",
        dataset.name(),
        "model",
        "GenGNN",
        "CPU",
        "GPU",
        "vs CPU",
        "vs GPU"
    );
    for r in rows {
        out.push_str(&format!(
            "{:<8} {:>11.1}µs {:>11.1}µs {:>11.1}µs {:>8.2}x {:>8.2}x\n",
            r.model,
            r.fpga_secs * 1e6,
            r.cpu_secs * 1e6,
            r.gpu_secs * 1e6,
            r.cpu_speedup(),
            r.gpu_speedup(),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn molhiv_speedups_inside_paper_envelope() {
        let rows = compute(MolDataset::MolHiv, 120, 0xF16_7);
        for r in &rows {
            assert!(
                (1.5..=16.0).contains(&r.cpu_speedup()),
                "{}: cpu speedup {:.2}",
                r.model,
                r.cpu_speedup()
            );
            assert!(
                (1.8..=28.0).contains(&r.gpu_speedup()),
                "{}: gpu speedup {:.2}",
                r.model,
                r.gpu_speedup()
            );
        }
    }

    #[test]
    fn dgn_has_largest_gpu_speedup() {
        let rows = compute(MolDataset::MolHiv, 120, 0xF16_7);
        let dgn = rows.iter().find(|r| r.model == "DGN").unwrap();
        for r in &rows {
            assert!(
                dgn.gpu_speedup() >= r.gpu_speedup(),
                "DGN {:.2} vs {} {:.2}",
                dgn.gpu_speedup(),
                r.model,
                r.gpu_speedup()
            );
        }
    }

    #[test]
    fn molpcba_envelope_compresses() {
        let hiv = compute(MolDataset::MolHiv, 120, 1);
        let pcba = compute(MolDataset::MolPcba, 120, 1);
        let max = |rows: &[Fig7Row]| {
            rows.iter().map(|r| r.cpu_speedup()).fold(0.0, f64::max)
        };
        assert!(max(&pcba) < max(&hiv), "MolPCBA speedups compress");
    }

    #[test]
    fn fpga_always_wins_on_molecules() {
        for r in compute(MolDataset::MolHiv, 60, 3) {
            assert!(r.fpga_secs < r.cpu_secs && r.fpga_secs < r.gpu_secs, "{}", r.model);
        }
    }

    #[test]
    fn render_has_six_rows() {
        let rows = compute(MolDataset::MolHiv, 20, 5);
        let s = render(MolDataset::MolHiv, &rows);
        assert_eq!(rows.len(), 6);
        for m in ["GIN", "GIN+VN", "GCN", "PNA", "GAT", "DGN"] {
            assert!(s.contains(m), "missing {m}");
        }
    }
}

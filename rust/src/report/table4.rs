//! Table 4 regeneration: per-model resource utilization on the U50.

use crate::models::ModelConfig;
use crate::resources::hls::{estimate, Estimate};
use crate::resources::table::render_table4;

/// Compute estimates for the six Table 4 models in paper order.
pub fn compute() -> Vec<Estimate> {
    ["gin", "gin_vn", "gcn", "pna", "gat", "dgn"]
        .iter()
        .map(|n| estimate(&ModelConfig::by_name(n).unwrap()).unwrap())
        .collect()
}

pub fn render() -> String {
    let mut s = String::from("Table 4: resource utilization (Alveo U50, 300 MHz)\n");
    s.push_str(&render_table4(&compute()));
    s
}

/// Verbose variant: per-component inventory per model.
pub fn render_detailed() -> String {
    let mut out = render();
    for e in compute() {
        out.push_str(&format!("\n[{}]\n", e.model));
        for c in &e.components {
            out.push_str(&format!(
                "  {:<45} dsp {:>5} lut {:>7} ff {:>7} bram {:>4} uram {:>4}\n",
                c.name, c.res.dsp, c.res.lut, c.res.ff, c.res.bram, c.res.uram
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_models_in_paper_order() {
        let e = compute();
        let names: Vec<&str> = e.iter().map(|x| x.model.as_str()).collect();
        assert_eq!(names, vec!["gin", "gin_vn", "gcn", "pna", "gat", "dgn"]);
    }

    #[test]
    fn render_contains_header_and_detail() {
        assert!(render().contains("Available"));
        let d = render_detailed();
        assert!(d.contains("MAC"));
        assert!(d.contains("[dgn]"));
    }
}

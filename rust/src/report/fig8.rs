//! Fig. 8 regeneration: DGN with the Large Graph Extension on
//! Cora / CiteSeer / PubMed vs CPU and GPU.
//!
//! Paper shape (§5.3): GenGNN beats the CPU 1.49–1.95× on all three;
//! beats the GPU 2.44× on Cora and 1.32× on CiteSeer, but is 1.04×
//! *slower* than the GPU on PubMed — the crossover where arithmetic
//! intensity finally pays for the GPU's launch overhead.

use crate::baselines::{cpu, gpu};
use crate::datagen::citation::{dataset, CitationDataset};
use crate::graph::GraphBatch;
use crate::models::ModelConfig;
use crate::sim::LargeGraphSim;

/// One dataset row of Fig. 8.
#[derive(Clone, Debug)]
pub struct Fig8Row {
    pub dataset: String,
    pub nodes: usize,
    pub edges: usize,
    pub fpga_secs: f64,
    pub cpu_secs: f64,
    pub gpu_secs: f64,
}

impl Fig8Row {
    pub fn cpu_speedup(&self) -> f64 {
        self.cpu_secs / self.fpga_secs
    }
    pub fn gpu_speedup(&self) -> f64 {
        self.gpu_secs / self.fpga_secs
    }
}

/// Compute the three rows (graphs generated at the Table 5 N/E/F).
pub fn compute(seed: u64) -> Vec<Fig8Row> {
    let model = ModelConfig::by_name("dgn_large").unwrap();
    CitationDataset::all()
        .into_iter()
        .map(|which| {
            let b = GraphBatch::ingest_unchecked(dataset(which, seed));
            let sim = LargeGraphSim::default();
            // dgn_large's padded capacity (512) is a scaled-down golden
            // artifact; the simulator models the real Table 5 sizes.
            let r = sim.simulate_batch(&b, &model);
            let s = b.stats();
            Fig8Row {
                dataset: which.name().to_string(),
                nodes: b.n(),
                edges: b.num_edges(),
                fpga_secs: r.secs,
                cpu_secs: cpu::latency(&model, s),
                gpu_secs: gpu::latency(&model, s),
            }
        })
        .collect()
}

pub fn render(rows: &[Fig8Row]) -> String {
    let mut out = format!(
        "Fig. 8: DGN + Large Graph Extension latency\n{:<10} {:>8} {:>8} {:>10} {:>10} {:>10} {:>8} {:>8}\n",
        "dataset", "nodes", "edges", "GenGNN", "CPU", "GPU", "vs CPU", "vs GPU"
    );
    for r in rows {
        out.push_str(&format!(
            "{:<10} {:>8} {:>8} {:>8.2}ms {:>8.2}ms {:>8.2}ms {:>7.2}x {:>7.2}x\n",
            r.dataset,
            r.nodes,
            r.edges,
            r.fpga_secs * 1e3,
            r.cpu_secs * 1e3,
            r.gpu_secs * 1e3,
            r.cpu_speedup(),
            r.gpu_speedup(),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows() -> Vec<Fig8Row> {
        compute(0xF18)
    }

    #[test]
    fn cpu_speedup_between_1_4_and_2_1_everywhere() {
        for r in rows() {
            let s = r.cpu_speedup();
            assert!((1.3..=2.2).contains(&s), "{}: {s:.2}", r.dataset);
        }
    }

    #[test]
    fn gpu_wins_only_on_pubmed() {
        let rows = rows();
        let by = |n: &str| rows.iter().find(|r| r.dataset == n).unwrap().gpu_speedup();
        assert!(by("Cora") > 1.5, "Cora gpu speedup {:.2}", by("Cora"));
        assert!(by("CiteSeer") > 1.0, "CiteSeer {:.2}", by("CiteSeer"));
        assert!(by("PubMed") < 1.0, "PubMed must flip: {:.2}", by("PubMed"));
        assert!(by("PubMed") > 0.8, "but only just: {:.2}", by("PubMed"));
        // Ordering: Cora > CiteSeer > PubMed.
        assert!(by("Cora") > by("CiteSeer") && by("CiteSeer") > by("PubMed"));
    }

    #[test]
    fn sizes_match_table5() {
        let rows = rows();
        let by = |n: &str| rows.iter().find(|r| r.dataset == n).unwrap();
        assert_eq!(by("Cora").nodes, 2708);
        assert_eq!(by("CiteSeer").nodes, 3327);
        assert_eq!(by("PubMed").nodes, 19717);
        // Directed edge counts match Table 5.
        assert!((by("Cora").edges as i64 - 10556).abs() < 600, "{}", by("Cora").edges);
        assert!((by("PubMed").edges as i64 - 88648).abs() < 4500, "{}", by("PubMed").edges);
    }

    #[test]
    fn render_mentions_all_datasets() {
        let s = render(&rows());
        for d in ["Cora", "CiteSeer", "PubMed"] {
            assert!(s.contains(d));
        }
    }
}

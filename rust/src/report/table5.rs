//! Table 5 regeneration: the Large Graph Extension — dataset sizes and
//! per-dataset resource utilization.

use crate::datagen::citation::CitationDataset;
use crate::resources::hls::{estimate_large, Resources};
use crate::resources::table::render_table5;

/// (name, nodes, directed edges, feature dim, resources) per dataset.
pub fn compute() -> Vec<(String, usize, usize, usize, Resources)> {
    CitationDataset::all()
        .into_iter()
        .map(|d| {
            let (n, e, f) = d.stats();
            let est = estimate_large(d.name(), n, f);
            (d.name().to_string(), n, e, f, est.total)
        })
        .collect()
}

pub fn render() -> String {
    let rows = compute();
    let mut s = String::from("Table 5: Large Graph Extension datasets + resources\n");
    s.push_str(&render_table5(&rows));
    s.push_str(&format!(
        "common: {} DSPs, {} BRAMs, {} URAMs for all three datasets\n",
        rows[0].4.dsp, rows[0].4.bram, rows[0].4.uram
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_match_paper_exactly() {
        let rows = compute();
        assert_eq!(rows[0].1, 2708); // Cora nodes
        assert_eq!(rows[1].3, 3703); // CiteSeer feature dim
        assert_eq!(rows[2].2, 88_648); // PubMed edges
    }

    #[test]
    fn common_resources_constant_across_datasets() {
        let rows = compute();
        assert!(rows.windows(2).all(|w| w[0].4.dsp == w[1].4.dsp
            && w[0].4.bram == w[1].4.bram
            && w[0].4.uram == w[1].4.uram));
    }

    #[test]
    fn render_has_three_rows() {
        let s = render();
        assert!(s.contains("Cora") && s.contains("CiteSeer") && s.contains("PubMed"));
    }
}

//! The composable message-passing stage IR (the paper's §3.1 claim made
//! executable): every model in the zoo is an ordered sequence of stages
//! drawn from one component library, instead of a hand-written
//! monolithic forward pass.
//!
//! A [`ModelPlan`] is lowered from a manifest entry by the per-kind
//! registry in [`super::lower`] and executed by the generic sparse
//! interpreter in `runtime::interp`, which walks sorted in-neighbor
//! lists ([`crate::graph::InNbrs`]) — O(edges) per request, no padded
//! adjacency anywhere. The legacy dense-matmul forwards survive as
//! `runtime::dense_ref`, the bit-exactness reference the interpreter is
//! property-tested against.
//!
//! The interpreter is a two-register machine: `h` holds the live node
//! (or pooled graph) features, `m` holds the latest sparse-aggregation
//! result until a combine stage consumes it, plus optional virtual-node
//! state seeded from [`ModelPlan::vn_init`]. The same stage sequence
//! also executes *fused micro-batches* (several graphs merged
//! block-diagonally, one interpreter pass, per-graph readout segments
//! — see [`crate::graph::FusedBatch`]) without any plan-level change:
//! stages are defined per node or per graph, never per batch.

use anyhow::{bail, Result};

use crate::util::json::{self, Json};

use super::params::Dense;

/// Elementwise activation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    None,
    Relu,
    /// `v <= 0 → exp(v) - 1` (GAT inter-layer).
    Elu,
}

impl Act {
    /// Stable identifier used by the `gengnn plan` dumps.
    pub fn name(&self) -> &'static str {
        match self {
            Act::None => "none",
            Act::Relu => "relu",
            Act::Elu => "elu",
        }
    }
}

/// Sparse neighborhood aggregation — the component library's gather/
/// aggregate building blocks. All walk in-neighbors in ascending node
/// order (the bit-exactness contract with the dense reference).
#[derive(Clone, Debug)]
pub enum Aggregate {
    /// Plain neighbor sum.
    Sum,
    /// Neighbor mean, degree clamped to ≥ 1 (GraphSAGE).
    Mean,
    /// Elementwise neighbor max (0 for isolated nodes).
    Max,
    /// Elementwise neighbor min (0 for isolated nodes).
    Min,
    /// Symmetric GCN normalization `D^-1/2 (A + I) D^-1/2 · h`, with
    /// the self-loop diagonal merged at its sorted position.
    GcnNorm,
    /// `Σ relu(h_j + bond(e_ij))` — GIN's edge-embedding message sum.
    EdgeReluSum { bond: Dense },
    /// PNA multi-aggregator tower: [mean, std, max, min] × scalers
    /// [identity, amplification, attenuation] → width 12·d.
    PnaTower,
    /// DGN directional pair: [mean ‖ |B·h − b_row∘h|] along the
    /// Laplacian eigenvector → width 2·d. Needs the `eig` input.
    DgnDirectional,
}

impl Aggregate {
    /// Stable identifier used by the `gengnn plan` dumps (validated by
    /// `python/tools/check_plan_schema.py`).
    pub fn name(&self) -> &'static str {
        match self {
            Aggregate::Sum => "sum",
            Aggregate::Mean => "mean",
            Aggregate::Max => "max",
            Aggregate::Min => "min",
            Aggregate::GcnNorm => "gcn_norm",
            Aggregate::EdgeReluSum { .. } => "edge_relu_sum",
            Aggregate::PnaTower => "pna_tower",
            Aggregate::DgnDirectional => "dgn_directional",
        }
    }

    /// Output width of the aggregation register for input width `d`.
    pub fn out_width(&self, d: usize) -> usize {
        match self {
            Aggregate::PnaTower => 12 * d,
            Aggregate::DgnDirectional => 2 * d,
            _ => d,
        }
    }

    /// Trained parameters carried by this aggregation (the GIN bond
    /// embedding; every other aggregate is parameter-free).
    pub fn params(&self) -> usize {
        match self {
            Aggregate::EdgeReluSum { bond } => bond.params(),
            _ => 0,
        }
    }
}

/// Graph-level vs node-level readout.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Readout {
    /// Mean over real nodes → one `[1, d]` graph row.
    MaskedMeanPool,
    /// Keep per-node rows; the interpreter zero-pads them to the
    /// artifact capacity after the head.
    NodeHead,
}

/// One stage of a model plan. `h` is the live feature register, `m`
/// the aggregation register.
#[derive(Clone, Debug)]
pub enum Stage {
    /// `h ← act(h·W + b)`
    Linear { w: Dense, act: Act },
    /// `m ← aggregate(h)` over the sparse in-neighborhoods.
    SparseAggregate(Aggregate),
    /// `h ← m` (adopt the aggregation result — GCN/SGC convolutions).
    TakeAggregate,
    /// `h ← (1 + ε)·h + m` (GIN combine).
    EpsCombine { eps: f32 },
    /// `h ← act(m·W + b) + h` (PNA/DGN residual update).
    ResidualLinear { w: Dense, act: Act },
    /// `h ← h·W_self + m·W_nbr` (GraphSAGE combine).
    DualLinear { w_self: Dense, w_nbr: Dense },
    /// Multi-head softmax attention over neighbors ∪ {self} applied to
    /// the already-projected `h` (GAT). Per-head logit vectors.
    EdgeAttention {
        heads: usize,
        a_src: Vec<f32>,
        a_dst: Vec<f32>,
    },
    /// `h ← act(h)` elementwise.
    Activation(Act),
    /// Row-wise L2 normalization (GraphSAGE).
    L2Normalize,
    /// `h ← h + vn` broadcast of the virtual-node state.
    VirtualNodeAdd,
    /// `vn ← mlp(vn + Σ_i h_i)` (between GIN+VN layers).
    VirtualNodeUpdate { w1: Dense, w2: Dense },
    /// Collapse to the output shape.
    Readout(Readout),
}

impl Stage {
    /// Stable identifier used by the `gengnn plan` dumps.
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Linear { .. } => "linear",
            Stage::SparseAggregate(_) => "sparse_aggregate",
            Stage::TakeAggregate => "take_aggregate",
            Stage::EpsCombine { .. } => "eps_combine",
            Stage::ResidualLinear { .. } => "residual_linear",
            Stage::DualLinear { .. } => "dual_linear",
            Stage::EdgeAttention { .. } => "edge_attention",
            Stage::Activation(_) => "activation",
            Stage::L2Normalize => "l2_normalize",
            Stage::VirtualNodeAdd => "virtual_node_add",
            Stage::VirtualNodeUpdate { .. } => "virtual_node_update",
            Stage::Readout(_) => "readout",
        }
    }

    /// Human-readable parameterization for `gengnn plan`.
    pub fn detail(&self) -> String {
        match self {
            Stage::Linear { w, act } => format!("{}x{} act={}", w.fin, w.fout, act.name()),
            Stage::SparseAggregate(a) => match a {
                Aggregate::EdgeReluSum { bond } => {
                    format!("{} bond={}x{}", a.name(), bond.fin, bond.fout)
                }
                _ => a.name().to_string(),
            },
            Stage::TakeAggregate => String::new(),
            Stage::EpsCombine { eps } => format!("eps={eps}"),
            Stage::ResidualLinear { w, act } => {
                format!("{}x{} act={}", w.fin, w.fout, act.name())
            }
            Stage::DualLinear { w_self, w_nbr } => format!(
                "self={}x{} nbr={}x{}",
                w_self.fin, w_self.fout, w_nbr.fin, w_nbr.fout
            ),
            Stage::EdgeAttention { heads, a_src, .. } => {
                let fh = a_src.len() / (*heads).max(1);
                format!("heads={heads} fh={fh}")
            }
            Stage::Activation(a) => a.name().to_string(),
            Stage::L2Normalize => String::new(),
            Stage::VirtualNodeAdd => String::new(),
            Stage::VirtualNodeUpdate { w1, w2 } => {
                format!("mlp={}x{}x{}", w1.fin, w1.fout, w2.fout)
            }
            Stage::Readout(r) => match r {
                Readout::MaskedMeanPool => "masked_mean_pool".to_string(),
                Readout::NodeHead => "node_head".to_string(),
            },
        }
    }

    /// Trained parameters this stage carries.
    pub fn params(&self) -> usize {
        match self {
            Stage::Linear { w, .. } | Stage::ResidualLinear { w, .. } => w.params(),
            Stage::SparseAggregate(a) => a.params(),
            Stage::DualLinear { w_self, w_nbr } => w_self.params() + w_nbr.params(),
            Stage::EdgeAttention { a_src, a_dst, .. } => a_src.len() + a_dst.len(),
            Stage::VirtualNodeUpdate { w1, w2 } => w1.params() + w2.params(),
            _ => 0,
        }
    }
}

/// Shape/param summary of one stage, produced by the plan walk.
#[derive(Clone, Debug)]
pub struct StageSummary {
    pub index: usize,
    pub name: &'static str,
    pub detail: String,
    pub in_width: usize,
    pub out_width: usize,
    pub params: usize,
}

/// A lowered model: metadata + the executable stage sequence.
#[derive(Clone, Debug)]
pub struct ModelPlan {
    pub model: String,
    pub n_max: usize,
    pub in_dim: usize,
    pub out_dim: usize,
    /// Edge feature width consumed by `EdgeReluSum` stages (0 if none).
    pub edge_dim: usize,
    pub node_level: bool,
    /// Initial virtual-node state (GIN+VN).
    pub vn_init: Option<Vec<f32>>,
    pub stages: Vec<Stage>,
}

impl ModelPlan {
    /// Whether execution needs a Laplacian eigenvector input.
    pub fn needs_eig(&self) -> bool {
        self.stages
            .iter()
            .any(|s| matches!(s, Stage::SparseAggregate(Aggregate::DgnDirectional)))
    }

    /// Whether execution consumes per-edge features (GIN models).
    pub fn needs_edge_attr(&self) -> bool {
        self.edge_dim > 0
    }

    /// Parameters carried by the virtual-node initial state.
    pub fn vn_params(&self) -> usize {
        self.vn_init.as_ref().map_or(0, |v| v.len())
    }

    /// Total trained parameters (stages + virtual-node state).
    pub fn param_count(&self) -> usize {
        self.vn_params() + self.stages.iter().map(|s| s.params()).sum::<usize>()
    }

    /// Walk the stage sequence, checking that widths chain and that
    /// register/state use is well-formed, producing per-stage shape
    /// summaries. This is the schema the `gengnn plan` dump exposes.
    pub fn summaries(&self) -> Result<Vec<StageSummary>> {
        let mut out = Vec::with_capacity(self.stages.len());
        let mut h = self.in_dim;
        // Width of the pending aggregation register, if any.
        let mut m: Option<usize> = None;
        let mut pooled = false;
        let take_m = |m: &mut Option<usize>, what: &str| -> Result<usize> {
            m.take()
                .ok_or_else(|| anyhow::anyhow!("{what} with no pending SparseAggregate"))
        };
        for (index, stage) in self.stages.iter().enumerate() {
            let in_width = h;
            // After a pooling readout only head stages make sense:
            // everything that walks node rows or touches per-node
            // state would misalign with the single pooled row (and
            // the interpreter would index out of bounds).
            if pooled && !matches!(stage, Stage::Linear { .. } | Stage::Activation(_)) {
                bail!("stage {index}: {} after readout", stage.name());
            }
            match stage {
                Stage::Linear { w, .. } => {
                    if w.fin != h {
                        bail!("stage {index}: linear expects width {}, h is {h}", w.fin);
                    }
                    h = w.fout;
                }
                Stage::SparseAggregate(a) => {
                    if m.is_some() {
                        bail!(
                            "stage {index}: aggregation would overwrite an \
                             unconsumed aggregation register"
                        );
                    }
                    if let Aggregate::EdgeReluSum { bond } = a {
                        if self.edge_dim == 0 {
                            bail!("stage {index}: edge aggregation without edge features");
                        }
                        if bond.fin != self.edge_dim || bond.fout != h {
                            bail!(
                                "stage {index}: bond {}x{} does not map edge_dim {} \
                                 onto h({h})",
                                bond.fin,
                                bond.fout,
                                self.edge_dim
                            );
                        }
                    }
                    m = Some(a.out_width(h));
                }
                Stage::TakeAggregate => {
                    h = take_m(&mut m, "take_aggregate")?;
                }
                Stage::EpsCombine { .. } => {
                    let mw = take_m(&mut m, "eps_combine")?;
                    if mw != h {
                        bail!("stage {index}: eps_combine widths differ ({mw} vs {h})");
                    }
                }
                Stage::ResidualLinear { w, .. } => {
                    let mw = take_m(&mut m, "residual_linear")?;
                    if w.fin != mw || w.fout != h {
                        bail!(
                            "stage {index}: residual {}x{} does not map m({mw}) onto h({h})",
                            w.fin,
                            w.fout
                        );
                    }
                }
                Stage::DualLinear { w_self, w_nbr } => {
                    let mw = take_m(&mut m, "dual_linear")?;
                    if w_self.fin != h || w_nbr.fin != mw || w_self.fout != w_nbr.fout {
                        bail!("stage {index}: dual_linear width mismatch");
                    }
                    h = w_self.fout;
                }
                Stage::EdgeAttention { heads, a_src, a_dst } => {
                    if *heads == 0 || h % heads != 0 {
                        bail!("stage {index}: width {h} not divisible by {heads} heads");
                    }
                    if a_src.len() != h || a_dst.len() != h {
                        bail!("stage {index}: attention logit vectors must have width {h}");
                    }
                }
                Stage::Activation(_) | Stage::L2Normalize => {}
                Stage::VirtualNodeAdd | Stage::VirtualNodeUpdate { .. } => {
                    let vn = self
                        .vn_init
                        .as_ref()
                        .ok_or_else(|| anyhow::anyhow!("stage {index}: no vn_init state"))?;
                    if vn.len() != h {
                        bail!("stage {index}: vn width {} vs h {h}", vn.len());
                    }
                    if let Stage::VirtualNodeUpdate { w1, w2 } = stage {
                        if w1.fin != h || w2.fout != h {
                            bail!("stage {index}: vn mlp must map {h} -> {h}");
                        }
                    }
                }
                Stage::Readout(r) => {
                    if m.is_some() {
                        bail!(
                            "stage {index}: readout with an unconsumed \
                             aggregation register"
                        );
                    }
                    pooled = true;
                    if *r == Readout::NodeHead && !self.node_level {
                        bail!("stage {index}: node_head readout in a graph-level plan");
                    }
                    if *r == Readout::MaskedMeanPool && self.node_level {
                        bail!("stage {index}: pooled readout in a node-level plan");
                    }
                }
            }
            out.push(StageSummary {
                index,
                name: stage.name(),
                detail: stage.detail(),
                in_width,
                out_width: h,
                params: stage.params(),
            });
        }
        if m.is_some() {
            bail!("plan ends with an unconsumed aggregation register");
        }
        if !pooled {
            bail!("plan has no readout stage");
        }
        if h != self.out_dim {
            bail!("plan ends at width {h}, artifact wants {}", self.out_dim);
        }
        Ok(out)
    }

    /// Shape-check the stage chain.
    pub fn validate(&self) -> Result<()> {
        self.summaries().map(|_| ())
    }

    /// Render the `gengnn plan` text dump.
    pub fn render_text(&self) -> Result<String> {
        use std::fmt::Write as _;
        let summaries = self.summaries()?;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "model {} (n_max {}, in {}, out {}, {} level{})",
            self.model,
            self.n_max,
            self.in_dim,
            self.out_dim,
            if self.node_level { "node" } else { "graph" },
            if self.edge_dim > 0 {
                format!(", edge_dim {}", self.edge_dim)
            } else {
                String::new()
            }
        );
        let _ = writeln!(
            s,
            "{:>3}  {:<18} {:<28} {:>5} {:>6} {:>9}",
            "#", "stage", "detail", "in", "out", "params"
        );
        for sum in &summaries {
            let _ = writeln!(
                s,
                "{:>3}  {:<18} {:<28} {:>5} {:>6} {:>9}",
                sum.index, sum.name, sum.detail, sum.in_width, sum.out_width, sum.params
            );
        }
        let _ = writeln!(
            s,
            "{} stages, {} params ({} in virtual-node state)",
            summaries.len(),
            self.param_count(),
            self.vn_params()
        );
        Ok(s)
    }

    /// The machine-readable dump `gengnn plan --json` emits, validated
    /// by `python/tools/check_plan_schema.py` in CI.
    pub fn to_json(&self) -> Result<Json> {
        let stages: Vec<Json> = self
            .summaries()?
            .iter()
            .map(|s| {
                json::obj(vec![
                    ("index", json::num(s.index as f64)),
                    ("stage", Json::Str(s.name.to_string())),
                    ("detail", Json::Str(s.detail.clone())),
                    ("in_width", json::num(s.in_width as f64)),
                    ("out_width", json::num(s.out_width as f64)),
                    ("params", json::num(s.params as f64)),
                ])
            })
            .collect();
        Ok(json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("n_max", json::num(self.n_max as f64)),
            ("in_dim", json::num(self.in_dim as f64)),
            ("out_dim", json::num(self.out_dim as f64)),
            ("edge_dim", json::num(self.edge_dim as f64)),
            ("node_level", Json::Bool(self.node_level)),
            ("vn_params", json::num(self.vn_params() as f64)),
            ("total_params", json::num(self.param_count() as f64)),
            ("stages", Json::Arr(stages)),
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::params::WInit;

    fn tiny_plan() -> ModelPlan {
        let mut wi = WInit::new(0);
        ModelPlan {
            model: "tiny".into(),
            n_max: 8,
            in_dim: 4,
            out_dim: 1,
            edge_dim: 0,
            node_level: false,
            vn_init: None,
            stages: vec![
                Stage::Linear {
                    w: wi.dense(4, 8),
                    act: Act::Relu,
                },
                Stage::SparseAggregate(Aggregate::GcnNorm),
                Stage::TakeAggregate,
                Stage::Readout(Readout::MaskedMeanPool),
                Stage::Linear {
                    w: wi.dense(8, 1),
                    act: Act::None,
                },
            ],
        }
    }

    #[test]
    fn summaries_chain_widths() {
        let p = tiny_plan();
        let s = p.summaries().unwrap();
        assert_eq!(s.len(), 5);
        for pair in s.windows(2) {
            assert_eq!(pair[0].out_width, pair[1].in_width);
        }
        assert_eq!(s[0].in_width, 4);
        assert_eq!(s.last().unwrap().out_width, 1);
        assert_eq!(p.param_count(), (4 * 8 + 8) + (8 + 1));
    }

    #[test]
    fn unconsumed_aggregate_is_rejected() {
        let mut p = tiny_plan();
        p.stages.remove(2); // drop TakeAggregate
        assert!(p.validate().is_err());
    }

    #[test]
    fn width_mismatch_is_rejected() {
        let mut p = tiny_plan();
        if let Stage::Linear { w, .. } = &mut p.stages[4] {
            w.fin = 5;
        }
        assert!(p.validate().is_err());
    }

    #[test]
    fn missing_readout_is_rejected() {
        let mut p = tiny_plan();
        p.stages.remove(3);
        assert!(p.validate().is_err());
    }

    #[test]
    fn consecutive_aggregations_are_rejected() {
        // A second aggregation would silently discard the first.
        let mut p = tiny_plan();
        p.stages.insert(2, Stage::SparseAggregate(Aggregate::Sum));
        let err = p.validate().unwrap_err().to_string();
        assert!(err.contains("overwrite"), "{err}");
    }

    #[test]
    fn node_stages_after_readout_are_rejected() {
        // Post-readout, only head Linear/Activation stages are legal —
        // node-topology stages would misalign with the pooled row.
        let mut p = tiny_plan();
        p.stages.insert(4, Stage::L2Normalize);
        let err = p.validate().unwrap_err().to_string();
        assert!(err.contains("after readout"), "{err}");
        let mut p = tiny_plan();
        p.stages.push(Stage::Readout(Readout::MaskedMeanPool));
        assert!(p.validate().is_err(), "second readout must be rejected");
    }

    #[test]
    fn json_dump_round_trips() {
        let p = tiny_plan();
        let text = p.to_json().unwrap().to_string_pretty();
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.get("model").unwrap().as_str().unwrap(), "tiny");
        assert_eq!(
            v.get("stages").unwrap().as_arr().unwrap().len(),
            p.stages.len()
        );
        assert_eq!(
            v.get("total_params").unwrap().as_usize().unwrap(),
            p.param_count()
        );
    }
}

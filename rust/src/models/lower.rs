//! The per-kind lowering registry: manifest entry ([`ModelMeta`]) →
//! executable [`ModelPlan`].
//!
//! Each registry entry owns a set of manifest model names and a
//! lowering function that draws the model's weights from the seeded
//! [`WInit`] stream **in the exact order `python/compile/model.py`'s
//! builders do** (that order is the contract with the AOT artifacts —
//! reshuffling it silently changes every weight) and then composes the
//! stage sequence from the component library in [`super::plan`].
//!
//! Adding a model to the zoo is now a registry entry plus a stage
//! composition — no new forward pass, no new executor code.

use anyhow::{bail, Result};

use crate::runtime::artifact::ModelMeta;

use super::params::{Dense, WInit};
use super::plan::{Act, Aggregate, ModelPlan, Readout, Stage};

const EPS_GIN: f32 = 0.1;

/// A lowering function: manifest entry + seeded weight stream →
/// (stage sequence, optional virtual-node initial state).
type LowerFn = fn(&ModelMeta, &mut WInit) -> Result<(Vec<Stage>, Option<Vec<f32>>)>;

/// One registry entry: the model kind, the manifest names it lowers,
/// and its lowering function.
pub struct Lowering {
    pub kind: &'static str,
    pub models: &'static [&'static str],
    lower: LowerFn,
}

/// The component registry — one entry per GNN kind in the zoo.
pub fn registry() -> &'static [Lowering] {
    REGISTRY
}

const REGISTRY: &[Lowering] = &[
    Lowering {
        kind: "gcn",
        models: &["gcn"],
        lower: lower_gcn,
    },
    Lowering {
        kind: "gin",
        models: &["gin"],
        lower: lower_gin,
    },
    Lowering {
        kind: "gin_vn",
        models: &["gin_vn"],
        lower: lower_gin_vn,
    },
    Lowering {
        kind: "gat",
        models: &["gat"],
        lower: lower_gat,
    },
    Lowering {
        kind: "pna",
        models: &["pna"],
        lower: lower_pna,
    },
    Lowering {
        kind: "sgc",
        models: &["sgc"],
        lower: lower_sgc,
    },
    Lowering {
        kind: "sage",
        models: &["sage"],
        lower: lower_sage,
    },
    Lowering {
        kind: "dgn",
        models: &["dgn", "dgn_large", "dgn_resident"],
        lower: lower_dgn,
    },
];

/// Lower a manifest entry to its stage-IR plan, regenerating the
/// baked-in weights from the artifact seed. The static analyzer
/// ([`crate::analysis`]) is a mandatory gate here: any `Error`-level
/// finding rejects the plan before it can serve traffic, which covers
/// `Engine` construction and the coordinator's `LOAD` path (both lower
/// through this function).
pub fn lower(meta: &ModelMeta, weight_seed: u64) -> Result<ModelPlan> {
    let (plan, report) = lower_with_report(meta, weight_seed)?;
    crate::analysis::require_clean(&report)?;
    Ok(plan)
}

/// Lower and return the full analyzer report alongside the plan —
/// `gengnn lint-plan` wants every finding (warnings and notes
/// included), not just the pass/fail verdict [`lower`] enforces.
pub fn lower_with_report(
    meta: &ModelMeta,
    weight_seed: u64,
) -> Result<(ModelPlan, crate::analysis::Report)> {
    if weight_seed > u32::MAX as u64 {
        bail!("weight_seed {weight_seed} exceeds the scalar MT19937 seeding range");
    }
    if meta.dim == 0 || meta.layers == 0 {
        bail!("model {:?} has degenerate dims", meta.name);
    }
    // Node-level output is defined only for DGN (mask applied *after*
    // the head, so padding is exactly zero — the plan contract). The
    // other kinds either pool unconditionally or, in the dense
    // reference, leak head bias into padded rows; lowering them
    // node-level would break the bit-exactness contract silently.
    if meta.node_level && !meta.name.starts_with("dgn") {
        bail!(
            "model {:?}: node-level lowering is only defined for dgn",
            meta.name
        );
    }
    let entry = registry()
        .iter()
        .find(|l| l.models.contains(&meta.name.as_str()))
        .ok_or_else(|| {
            anyhow::anyhow!("no lowering registered for model {:?}", meta.name)
        })?;
    let mut wi = WInit::new(weight_seed as u32);
    let (stages, vn_init) = (entry.lower)(meta, &mut wi)?;
    let plan = ModelPlan {
        model: meta.name.clone(),
        n_max: meta.n_max,
        in_dim: meta.in_dim,
        out_dim: meta.out_dim,
        edge_dim: edge_dim_of(meta),
        node_level: meta.node_level,
        vn_init,
        stages,
    };
    let report = crate::analysis::analyze_lowered(&plan, wi.drawn());
    Ok((plan, report))
}

fn edge_dim_of(meta: &ModelMeta) -> usize {
    meta.inputs
        .iter()
        .find(|i| i.name == "edge_attr")
        .map(|i| *i.shape.last().unwrap_or(&0))
        .unwrap_or(0)
}

fn readout_of(meta: &ModelMeta) -> Stage {
    Stage::Readout(if meta.node_level {
        Readout::NodeHead
    } else {
        Readout::MaskedMeanPool
    })
}

fn linear(w: Dense, act: Act) -> Stage {
    Stage::Linear { w, act }
}

fn lower_gcn(meta: &ModelMeta, wi: &mut WInit) -> Result<(Vec<Stage>, Option<Vec<f32>>)> {
    let d = meta.dim;
    let embed = wi.dense(meta.in_dim, d);
    let convs: Vec<Dense> = (0..meta.layers).map(|_| wi.dense(d, d)).collect();
    let head = wi.dense(d, meta.out_dim);
    let mut stages = vec![linear(embed, Act::Relu)];
    let layers = convs.len();
    for (li, conv) in convs.into_iter().enumerate() {
        stages.push(linear(conv, Act::None));
        stages.push(Stage::SparseAggregate(Aggregate::GcnNorm));
        stages.push(Stage::TakeAggregate);
        if li + 1 < layers {
            stages.push(Stage::Activation(Act::Relu));
        }
    }
    stages.push(readout_of(meta));
    stages.push(linear(head, Act::None));
    Ok((stages, None))
}

fn lower_sgc(meta: &ModelMeta, wi: &mut WInit) -> Result<(Vec<Stage>, Option<Vec<f32>>)> {
    let w = wi.dense(meta.in_dim, meta.dim);
    let head = wi.dense(meta.dim, meta.out_dim);
    let mut stages = Vec::new();
    for _ in 0..meta.layers {
        stages.push(Stage::SparseAggregate(Aggregate::GcnNorm));
        stages.push(Stage::TakeAggregate);
    }
    stages.push(linear(w, Act::Relu));
    stages.push(readout_of(meta));
    stages.push(linear(head, Act::None));
    Ok((stages, None))
}

fn gin_stages(
    meta: &ModelMeta,
    wi: &mut WInit,
    virtual_node: bool,
) -> Result<(Vec<Stage>, Option<Vec<f32>>)> {
    let d = meta.dim;
    let edge_dim = edge_dim_of(meta);
    if edge_dim == 0 {
        bail!("GIN artifact {:?} lists no edge_attr input", meta.name);
    }
    let embed = wi.dense(meta.in_dim, d);
    let bond: Vec<Dense> = (0..meta.layers).map(|_| wi.dense(edge_dim, d)).collect();
    let mlps: Vec<(Dense, Dense)> = (0..meta.layers)
        .map(|_| (wi.dense(d, 2 * d), wi.dense(2 * d, d)))
        .collect();
    let head = wi.dense(d, meta.out_dim);
    let (vn_init, vn_mlps) = if virtual_node {
        let vn0 = wi.vec(d);
        let vn_mlps: Vec<(Dense, Dense)> = (0..meta.layers - 1)
            .map(|_| (wi.dense(d, 2 * d), wi.dense(2 * d, d)))
            .collect();
        (Some(vn0), vn_mlps)
    } else {
        (None, Vec::new())
    };
    let mut vn_mlps = vn_mlps.into_iter();
    let layers = meta.layers;
    let mut stages = vec![linear(embed, Act::Relu)];
    for (li, (bond_l, (w1, w2))) in bond.into_iter().zip(mlps).enumerate() {
        if virtual_node {
            stages.push(Stage::VirtualNodeAdd);
        }
        stages.push(Stage::SparseAggregate(Aggregate::EdgeReluSum { bond: bond_l }));
        stages.push(Stage::EpsCombine { eps: EPS_GIN });
        stages.push(linear(w1, Act::Relu));
        stages.push(linear(w2, Act::Relu));
        if virtual_node && li + 1 < layers {
            let (w1, w2) = vn_mlps.next().expect("one vn mlp per inner layer");
            stages.push(Stage::VirtualNodeUpdate { w1, w2 });
        }
    }
    stages.push(readout_of(meta));
    stages.push(linear(head, Act::None));
    Ok((stages, vn_init))
}

fn lower_gin(meta: &ModelMeta, wi: &mut WInit) -> Result<(Vec<Stage>, Option<Vec<f32>>)> {
    gin_stages(meta, wi, false)
}

fn lower_gin_vn(meta: &ModelMeta, wi: &mut WInit) -> Result<(Vec<Stage>, Option<Vec<f32>>)> {
    gin_stages(meta, wi, true)
}

fn lower_gat(meta: &ModelMeta, wi: &mut WInit) -> Result<(Vec<Stage>, Option<Vec<f32>>)> {
    let d = meta.dim;
    if meta.heads == 0 || d % meta.heads != 0 {
        bail!(
            "GAT artifact {:?}: dim {} not divisible by heads {}",
            meta.name,
            d,
            meta.heads
        );
    }
    let embed = wi.dense(meta.in_dim, d);
    let convs: Vec<(Dense, Vec<f32>, Vec<f32>)> = (0..meta.layers)
        .map(|_| {
            let w = wi.dense(d, d);
            let a_src = wi.vec(d);
            let a_dst = wi.vec(d);
            (w, a_src, a_dst)
        })
        .collect();
    let head = wi.dense(d, meta.out_dim);
    let mut stages = vec![linear(embed, Act::Relu)];
    let layers = convs.len();
    for (li, (w, a_src, a_dst)) in convs.into_iter().enumerate() {
        stages.push(linear(w, Act::None));
        stages.push(Stage::EdgeAttention {
            heads: meta.heads,
            a_src,
            a_dst,
        });
        if li + 1 < layers {
            stages.push(Stage::Activation(Act::Elu));
        }
    }
    stages.push(readout_of(meta));
    stages.push(linear(head, Act::None));
    Ok((stages, None))
}

fn lower_pna(meta: &ModelMeta, wi: &mut WInit) -> Result<(Vec<Stage>, Option<Vec<f32>>)> {
    let d = meta.dim;
    let embed = wi.dense(meta.in_dim, d);
    let convs: Vec<Dense> = (0..meta.layers).map(|_| wi.dense(12 * d, d)).collect();
    let head = [
        wi.dense(d, d / 2),
        wi.dense(d / 2, d / 4),
        wi.dense(d / 4, meta.out_dim),
    ];
    let mut stages = vec![linear(embed, Act::Relu)];
    for conv in convs {
        stages.push(Stage::SparseAggregate(Aggregate::PnaTower));
        stages.push(Stage::ResidualLinear {
            w: conv,
            act: Act::Relu,
        });
    }
    stages.push(readout_of(meta));
    let [h0, h1, h2] = head;
    stages.push(linear(h0, Act::Relu));
    stages.push(linear(h1, Act::Relu));
    stages.push(linear(h2, Act::None));
    Ok((stages, None))
}

fn lower_sage(meta: &ModelMeta, wi: &mut WInit) -> Result<(Vec<Stage>, Option<Vec<f32>>)> {
    let d = meta.dim;
    let embed = wi.dense(meta.in_dim, d);
    let convs: Vec<(Dense, Dense)> = (0..meta.layers)
        .map(|_| (wi.dense(d, d), wi.dense(d, d)))
        .collect();
    let head = wi.dense(d, meta.out_dim);
    let mut stages = vec![linear(embed, Act::Relu)];
    let layers = convs.len();
    for (li, (w_self, w_nbr)) in convs.into_iter().enumerate() {
        stages.push(Stage::SparseAggregate(Aggregate::Mean));
        stages.push(Stage::DualLinear { w_self, w_nbr });
        if li + 1 < layers {
            stages.push(Stage::Activation(Act::Relu));
        }
        stages.push(Stage::L2Normalize);
    }
    stages.push(readout_of(meta));
    stages.push(linear(head, Act::None));
    Ok((stages, None))
}

fn lower_dgn(meta: &ModelMeta, wi: &mut WInit) -> Result<(Vec<Stage>, Option<Vec<f32>>)> {
    let d = meta.dim;
    let embed = wi.dense(meta.in_dim, d);
    let convs: Vec<Dense> = (0..meta.layers).map(|_| wi.dense(2 * d, d)).collect();
    let head = [
        wi.dense(d, d / 2),
        wi.dense(d / 2, d / 4),
        wi.dense(d / 4, meta.out_dim),
    ];
    let mut stages = vec![linear(embed, Act::Relu)];
    for conv in convs {
        stages.push(Stage::SparseAggregate(Aggregate::DgnDirectional));
        stages.push(Stage::ResidualLinear {
            w: conv,
            act: Act::Relu,
        });
    }
    stages.push(readout_of(meta));
    let [h0, h1, h2] = head;
    stages.push(linear(h0, Act::Relu));
    stages.push(linear(h1, Act::Relu));
    stages.push(linear(h2, Act::None));
    Ok((stages, None))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::InputSpec;

    fn tiny_meta(name: &str) -> ModelMeta {
        let n_max = 8;
        let in_dim = 4;
        let mut inputs = vec![
            InputSpec {
                name: "x".into(),
                shape: vec![n_max, in_dim],
            },
            InputSpec {
                name: "adj".into(),
                shape: vec![n_max, n_max],
            },
        ];
        if name.starts_with("gin") {
            inputs.push(InputSpec {
                name: "edge_attr".into(),
                shape: vec![n_max, n_max, 3],
            });
        }
        if name.starts_with("dgn") {
            inputs.push(InputSpec {
                name: "eig".into(),
                shape: vec![n_max],
            });
        }
        inputs.push(InputSpec {
            name: "mask".into(),
            shape: vec![n_max],
        });
        ModelMeta {
            name: name.to_string(),
            layers: 2,
            dim: 8,
            heads: if name == "gat" { 2 } else { 0 },
            n_max,
            in_dim,
            out_dim: 1,
            node_level: false,
            inputs,
            hlo_path: "unused.hlo.txt".into(),
            golden_path: "unused.golden.json".into(),
        }
    }

    #[test]
    fn every_kind_lowers_and_validates() {
        for name in ["gcn", "gin", "gin_vn", "gat", "pna", "sgc", "sage", "dgn"] {
            let plan = lower(&tiny_meta(name), 0).unwrap();
            assert_eq!(plan.model, name);
            plan.validate().unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert!(plan.param_count() > 0, "{name} has no params");
            assert!(!plan.render_text().unwrap().is_empty());
        }
    }

    #[test]
    fn every_kind_passes_the_analyzer_gate() {
        // lower() must agree with the full report: zero errors, all
        // stages fusable, and a weight stream that exactly covers the
        // params the plan carries.
        for name in ["gcn", "gin", "gin_vn", "gat", "pna", "sgc", "sage", "dgn"] {
            let (plan, report) = lower_with_report(&tiny_meta(name), 0).unwrap();
            assert!(
                report.ok(),
                "{name}: {:?}",
                report.first_error().map(|d| d.to_string())
            );
            assert!(report.fusable, "{name} must be fusable");
            assert!(
                !report.has_code(crate::analysis::Code::WeightStreamMismatch),
                "{name}: weight stream must cover the plan exactly"
            );
            assert_eq!(report.stages.len(), plan.stages.len());
        }
    }

    #[test]
    fn registry_covers_the_zoo_disjointly() {
        let mut seen = std::collections::BTreeSet::new();
        for entry in registry() {
            for m in entry.models {
                assert!(seen.insert(*m), "model {m} claimed twice");
            }
        }
        for name in ["gcn", "gin", "gin_vn", "gat", "pna", "sgc", "sage", "dgn", "dgn_large"]
        {
            assert!(seen.contains(name), "registry misses {name}");
        }
    }

    #[test]
    fn unknown_model_is_a_clean_error() {
        let mut meta = tiny_meta("gcn");
        meta.name = "transformer".into();
        let err = lower(&meta, 0).unwrap_err().to_string();
        assert!(err.contains("no lowering registered"), "{err}");
    }

    #[test]
    fn gat_dim_must_divide_heads() {
        let mut meta = tiny_meta("gat");
        meta.heads = 3;
        assert!(lower(&meta, 0).is_err());
    }

    #[test]
    fn gin_requires_edge_attr_input() {
        let mut meta = tiny_meta("gin");
        meta.inputs.retain(|i| i.name != "edge_attr");
        assert!(lower(&meta, 0).is_err());
    }

    #[test]
    fn oversized_seed_is_rejected() {
        assert!(lower(&tiny_meta("gcn"), u64::MAX).is_err());
    }

    #[test]
    fn node_level_is_dgn_only() {
        let mut meta = tiny_meta("dgn");
        meta.node_level = true;
        meta.out_dim = 3;
        lower(&meta, 0).unwrap();
        for name in ["gcn", "sgc", "gat", "gin", "pna", "sage"] {
            let mut meta = tiny_meta(name);
            meta.node_level = true;
            let err = lower(&meta, 0).unwrap_err().to_string();
            assert!(err.contains("node-level"), "{name}: {err}");
        }
    }

    #[test]
    fn gin_vn_carries_state_and_eps() {
        let plan = lower(&tiny_meta("gin_vn"), 0).unwrap();
        assert_eq!(plan.vn_params(), 8);
        assert!(plan
            .stages
            .iter()
            .any(|s| matches!(s, Stage::VirtualNodeUpdate { .. })));
        assert!(plan
            .stages
            .iter()
            .any(|s| matches!(s, Stage::EpsCombine { eps } if *eps == EPS_GIN)));
    }

    #[test]
    fn dgn_needs_eig_and_gin_needs_edges() {
        assert!(lower(&tiny_meta("dgn"), 0).unwrap().needs_eig());
        assert!(!lower(&tiny_meta("gcn"), 0).unwrap().needs_eig());
        assert!(lower(&tiny_meta("gin"), 0).unwrap().needs_edge_attr());
    }
}

//! Seeded weight substrate shared by the stage-IR lowering registry
//! (`models::lower`) and the dense reference executor
//! (`runtime::dense_ref`).
//!
//! [`Mt19937`] is a port of numpy's legacy `RandomState` stream
//! (scalar-int seeding, two 32-bit draws per 53-bit double), so
//! [`WInit`] reproduces `model.py`'s `WInit(seed)` draw order
//! bit-for-bit — the same baked-in constants the AOT artifacts carry.
//! Every lowering must draw its [`Dense`] layers in the exact order the
//! JAX model builders do, or the regenerated weights stop matching the
//! golden files.

/// Classic MT19937 matching numpy's legacy `RandomState` stream.
pub struct Mt19937 {
    mt: [u32; 624],
    idx: usize,
}

impl Mt19937 {
    pub fn new(seed: u32) -> Mt19937 {
        let mut mt = [0u32; 624];
        mt[0] = seed;
        for i in 1..624 {
            mt[i] = 1_812_433_253u32
                .wrapping_mul(mt[i - 1] ^ (mt[i - 1] >> 30))
                .wrapping_add(i as u32);
        }
        Mt19937 { mt, idx: 624 }
    }

    fn next_u32(&mut self) -> u32 {
        if self.idx >= 624 {
            for i in 0..624 {
                let y = (self.mt[i] & 0x8000_0000) | (self.mt[(i + 1) % 624] & 0x7fff_ffff);
                let mut next = self.mt[(i + 397) % 624] ^ (y >> 1);
                if y & 1 == 1 {
                    next ^= 0x9908_b0df;
                }
                self.mt[i] = next;
            }
            self.idx = 0;
        }
        let mut y = self.mt[self.idx];
        self.idx += 1;
        y ^= y >> 11;
        y ^= (y << 7) & 0x9d2c_5680;
        y ^= (y << 15) & 0xefc6_0000;
        y ^= y >> 18;
        y
    }

    /// numpy `random_sample`: two 32-bit draws into a 53-bit double.
    pub fn next_double(&mut self) -> f64 {
        let a = (self.next_u32() >> 5) as f64;
        let b = (self.next_u32() >> 6) as f64;
        (a * 67_108_864.0 + b) / 9_007_199_254_740_992.0
    }

    /// `RandomState.uniform(lo, hi, count).astype(float32)`.
    pub fn uniform_f32(&mut self, lo: f64, hi: f64, count: usize) -> Vec<f32> {
        (0..count)
            .map(|_| (lo + (hi - lo) * self.next_double()) as f32)
            .collect()
    }
}

/// One dense layer's weights: `w` is `[fin, fout]` row-major.
#[derive(Clone, Debug)]
pub struct Dense {
    pub fin: usize,
    pub fout: usize,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl Dense {
    /// Trained-parameter count (weights + biases).
    pub fn params(&self) -> usize {
        self.w.len() + self.b.len()
    }
}

/// Mirror of `model.WInit`: the exact draw order of the AOT weights.
pub struct WInit {
    mt: Mt19937,
    drawn: usize,
}

impl WInit {
    pub fn new(seed: u32) -> WInit {
        WInit {
            mt: Mt19937::new(seed),
            drawn: 0,
        }
    }

    pub fn dense(&mut self, fin: usize, fout: usize) -> Dense {
        let s = 1.0 / (fin as f64).sqrt();
        self.drawn += fin * fout + fout;
        Dense {
            fin,
            fout,
            w: self.mt.uniform_f32(-s, s, fin * fout),
            b: self.mt.uniform_f32(-s, s, fout),
        }
    }

    pub fn vec(&mut self, f: usize) -> Vec<f32> {
        let s = 1.0 / (f as f64).sqrt();
        self.drawn += f;
        self.mt.uniform_f32(-s, s, f)
    }

    /// Scalars drawn from the stream so far. The static analyzer's
    /// weight-coverage pass compares this against the lowered plan's
    /// [`crate::models::ModelPlan::param_count`]: a lowering that draws
    /// parameters its stage sequence never carries (or vice versa) has
    /// silently broken the AOT draw-order contract.
    pub fn drawn(&self) -> usize {
        self.drawn
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// numpy `RandomState(0).uniform(-0.5, 0.5, 6)` reference values.
    #[test]
    fn mt19937_matches_numpy_randomstate_stream() {
        let mut mt = Mt19937::new(0);
        let want = [
            0.04881350392732475,
            0.21518936637241948,
            0.10276337607164387,
            0.044883182996896864,
            -0.07634520066109529,
            0.14589411306665612,
        ];
        for w in want {
            let got = -0.5 + (0.5 - (-0.5)) * mt.next_double();
            assert!((got - w).abs() < 1e-16, "got {got}, want {w}");
        }
        let mut mt2 = Mt19937::new(12345);
        let want2 = [
            0.8592321856342957,
            -0.3672488908364282,
            -0.6321623766458111,
            -0.5908794428939206,
        ];
        for w in want2 {
            let got = -1.0 + 2.0 * mt2.next_double();
            assert!((got - w).abs() < 1e-15, "got {got}, want {w}");
        }
    }

    /// `WInit(0).dense(9, d)` first f32 weights, as numpy casts them.
    #[test]
    fn winit_f32_cast_matches_numpy() {
        let mut wi = WInit::new(0);
        let dense = wi.dense(9, 4);
        let want: [f32; 3] = [0.032542337, 0.14345957, 0.068508916];
        for (g, w) in dense.w.iter().zip(&want) {
            assert_eq!(*g, *w, "weight cast mismatch");
        }
        assert_eq!(dense.params(), 9 * 4 + 4);
    }

    #[test]
    fn drawn_counter_tracks_every_scalar() {
        let mut wi = WInit::new(0);
        assert_eq!(wi.drawn(), 0);
        let d = wi.dense(3, 5);
        assert_eq!(wi.drawn(), d.params());
        wi.vec(7);
        assert_eq!(wi.drawn(), d.params() + 7);
    }
}

//! Model zoo: configurations for the paper's six representative GNNs
//! (Table 2, hyperparameters of Section 5.1).

pub mod config;

pub use config::{GnnKind, ModelConfig};

//! Model zoo: configurations for the paper's representative GNNs
//! (Table 2, hyperparameters of Section 5.1) plus the composable
//! message-passing stage IR they all lower to:
//!
//! * [`config`] — the static hyperparameter registry (simulator /
//!   resource-estimator consumers)
//! * [`params`] — seeded weight substrate (MT19937 numpy port)
//! * [`plan`]   — the stage IR: [`ModelPlan`], the component library
//! * [`lower`]  — the per-kind registry lowering `ModelMeta` → plan

pub mod config;
pub mod lower;
pub mod params;
pub mod plan;

pub use config::{GnnKind, ModelConfig};
pub use lower::{lower, lower_with_report};
pub use params::{Dense, Mt19937, WInit};
pub use plan::{Act, Aggregate, ModelPlan, Readout, Stage, StageSummary};

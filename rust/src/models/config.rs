//! Model zoo configuration — the six representative GNNs of paper
//! Table 2 with the exact hyperparameters of Section 5.1. These configs
//! drive three independent consumers that must agree: the cycle-level
//! simulator, the resource estimator, and the PJRT runtime (which
//! cross-checks them against artifacts/manifest.json).

use anyhow::{bail, Result};

/// GNN family (paper Table 2, one representative per family).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GnnKind {
    /// SpMM-style convolution.
    Gcn,
    /// Edge embeddings + MLP transform, SpMM does not apply.
    Gin,
    /// GIN plus a virtual node connected to all nodes.
    GinVn,
    /// Multi-head self-attention.
    Gat,
    /// Multiple simultaneous aggregators with degree scalers.
    Pna,
    /// Directional aggregation along Laplacian eigenvectors.
    Dgn,
}

impl GnnKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            GnnKind::Gcn => "gcn",
            GnnKind::Gin => "gin",
            GnnKind::GinVn => "gin_vn",
            GnnKind::Gat => "gat",
            GnnKind::Pna => "pna",
            GnnKind::Dgn => "dgn",
        }
    }

    pub fn parse(s: &str) -> Result<GnnKind> {
        Ok(match s {
            "gcn" => GnnKind::Gcn,
            "gin" => GnnKind::Gin,
            "gin_vn" | "gin+vn" | "ginvn" => GnnKind::GinVn,
            "gat" => GnnKind::Gat,
            "pna" => GnnKind::Pna,
            "dgn" | "dgn_large" => GnnKind::Dgn,
            _ => bail!("unknown model {s:?}"),
        })
    }

    /// Display name used in the paper's tables/figures.
    pub fn paper_name(&self) -> &'static str {
        match self {
            GnnKind::Gcn => "GCN",
            GnnKind::Gin => "GIN",
            GnnKind::GinVn => "GIN+VN",
            GnnKind::Gat => "GAT",
            GnnKind::Pna => "PNA",
            GnnKind::Dgn => "DGN",
        }
    }
}

/// Full configuration of one deployable model.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    /// Registry key (matches the artifact name).
    pub name: &'static str,
    pub kind: GnnKind,
    pub layers: usize,
    /// Node embedding dimension per layer.
    pub dim: usize,
    /// Attention heads (GAT only, 0 otherwise).
    pub heads: usize,
    /// Padded node capacity of the AOT artifact.
    pub n_max: usize,
    /// Raw input feature width.
    pub in_dim: usize,
    /// Raw edge feature width (0 when unused).
    pub edge_dim: usize,
    pub out_dim: usize,
    pub needs_eig: bool,
    pub needs_edge_attr: bool,
    pub node_level: bool,
    /// Hidden sizes of the prediction head MLP (paper Section 5.1).
    pub head_dims: Vec<usize>,
}

impl ModelConfig {
    /// The on-chip registry: paper Section 5.1 hyperparameters.
    pub fn registry() -> Vec<ModelConfig> {
        vec![
            ModelConfig {
                name: "gcn",
                kind: GnnKind::Gcn,
                layers: 5,
                dim: 100,
                heads: 0,
                n_max: 64,
                in_dim: 9,
                edge_dim: 0,
                out_dim: 1,
                needs_eig: false,
                needs_edge_attr: false,
                node_level: false,
                head_dims: vec![1],
            },
            ModelConfig {
                name: "gin",
                kind: GnnKind::Gin,
                layers: 5,
                dim: 100,
                heads: 0,
                n_max: 64,
                in_dim: 9,
                edge_dim: 3,
                out_dim: 1,
                needs_eig: false,
                needs_edge_attr: true,
                node_level: false,
                head_dims: vec![1],
            },
            ModelConfig {
                name: "gin_vn",
                kind: GnnKind::GinVn,
                layers: 5,
                dim: 100,
                heads: 0,
                n_max: 64,
                in_dim: 9,
                edge_dim: 3,
                out_dim: 1,
                needs_eig: false,
                needs_edge_attr: true,
                node_level: false,
                head_dims: vec![1],
            },
            ModelConfig {
                name: "gat",
                kind: GnnKind::Gat,
                layers: 5,
                dim: 64,
                heads: 4,
                n_max: 64,
                in_dim: 9,
                edge_dim: 0,
                out_dim: 1,
                needs_eig: false,
                needs_edge_attr: false,
                node_level: false,
                head_dims: vec![1],
            },
            ModelConfig {
                name: "pna",
                kind: GnnKind::Pna,
                layers: 4,
                dim: 80,
                heads: 0,
                n_max: 64,
                in_dim: 9,
                edge_dim: 0,
                out_dim: 1,
                needs_eig: false,
                needs_edge_attr: false,
                node_level: false,
                head_dims: vec![40, 20, 1],
            },
            ModelConfig {
                name: "dgn",
                kind: GnnKind::Dgn,
                layers: 4,
                dim: 100,
                heads: 0,
                n_max: 64,
                in_dim: 9,
                edge_dim: 0,
                out_dim: 1,
                needs_eig: true,
                needs_edge_attr: false,
                node_level: false,
                head_dims: vec![50, 25, 1],
            },
            ModelConfig {
                name: "dgn_large",
                kind: GnnKind::Dgn,
                layers: 4,
                dim: 100,
                heads: 0,
                n_max: 512,
                in_dim: 500,
                edge_dim: 0,
                out_dim: 3,
                needs_eig: true,
                needs_edge_attr: false,
                node_level: true,
                head_dims: vec![50, 25, 3],
            },
        ]
    }

    pub fn by_name(name: &str) -> Result<ModelConfig> {
        Self::registry()
            .into_iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow::anyhow!("unknown model {name:?}"))
    }

    /// The six molecular (Fig. 7) models in paper order.
    pub fn fig7_models() -> Vec<ModelConfig> {
        ["gin", "gin_vn", "gcn", "pna", "gat", "dgn"]
            .iter()
            .map(|n| Self::by_name(n).unwrap())
            .collect()
    }

    /// Approximate trained-parameter count (weights + biases), used by
    /// the resource estimator for BRAM sizing.
    pub fn param_count(&self) -> usize {
        let d = self.dim;
        let embed = self.in_dim * d + d;
        let head: usize = {
            let mut dims = vec![d];
            dims.extend(&self.head_dims);
            dims.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
        };
        let per_layer = match self.kind {
            GnnKind::Gcn => d * d + d,
            GnnKind::Gin => self.edge_dim * d + d + (d * 2 * d + 2 * d) + (2 * d * d + d),
            GnnKind::GinVn => {
                // GIN layer + virtual-node MLP.
                self.edge_dim * d + d
                    + (d * 2 * d + 2 * d)
                    + (2 * d * d + d)
                    + (d * 2 * d + 2 * d)
                    + (2 * d * d + d)
            }
            GnnKind::Gat => d * d + d + 2 * d,
            GnnKind::Pna => 12 * d * d + d,
            GnnKind::Dgn => 2 * d * d + d,
        };
        embed + self.layers * per_layer + head
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_all_paper_models() {
        let names: Vec<&str> =
            ModelConfig::registry().iter().map(|m| m.name).collect();
        for want in ["gcn", "gin", "gin_vn", "gat", "pna", "dgn", "dgn_large"] {
            assert!(names.contains(&want), "missing {want}");
        }
    }

    #[test]
    fn paper_hyperparameters() {
        // Section 5.1: GCN/GIN 5 layers dim 100; PNA 4 layers dim 80
        // head (40,20,1); DGN 4 layers dim 100 head (50,25,1); GAT 5
        // layers 4 heads x 16.
        let gcn = ModelConfig::by_name("gcn").unwrap();
        assert_eq!((gcn.layers, gcn.dim), (5, 100));
        let pna = ModelConfig::by_name("pna").unwrap();
        assert_eq!((pna.layers, pna.dim), (4, 80));
        assert_eq!(pna.head_dims, vec![40, 20, 1]);
        let dgn = ModelConfig::by_name("dgn").unwrap();
        assert_eq!(dgn.head_dims, vec![50, 25, 1]);
        let gat = ModelConfig::by_name("gat").unwrap();
        assert_eq!(gat.dim / gat.heads, 16);
    }

    #[test]
    fn parse_kind_aliases() {
        assert_eq!(GnnKind::parse("gin+vn").unwrap(), GnnKind::GinVn);
        assert_eq!(GnnKind::parse("dgn_large").unwrap(), GnnKind::Dgn);
        assert!(GnnKind::parse("transformer").is_err());
    }

    #[test]
    fn param_counts_are_plausible() {
        // 5-layer d=100 GIN: ~310k params (2 MLP layers of ~20k each x5).
        let gin = ModelConfig::by_name("gin").unwrap().param_count();
        assert!((150_000..600_000).contains(&gin), "gin params {gin}");
        let vn = ModelConfig::by_name("gin_vn").unwrap().param_count();
        assert!(vn > gin, "VN adds parameters");
    }

    #[test]
    fn fig7_order_matches_paper() {
        let names: Vec<&str> =
            ModelConfig::fig7_models().iter().map(|m| m.name).collect();
        assert_eq!(names, vec!["gin", "gin_vn", "gcn", "pna", "gat", "dgn"]);
    }
}

//! Table formatting for the resource reports (paper Tables 4 and 5).

use super::hls::{Estimate, Resources, U50};

/// Render a Table-4-style utilization table for a set of estimates.
pub fn render_table4(estimates: &[Estimate]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<10} {:>6} {:>9} {:>9} {:>6} {:>6}\n",
        "Model", "DSP", "LUT", "FF", "BRAM", "URAM"
    ));
    out.push_str(&format!(
        "{:<10} {:>6} {:>9} {:>9} {:>6} {:>6}\n",
        "Available", U50.dsp, U50.lut, U50.ff, U50.bram, U50.uram
    ));
    for e in estimates {
        out.push_str(&row(&e.model, &e.total));
    }
    out
}

/// Render one Table-5-style row (large-graph extension, per dataset).
pub fn render_table5(rows: &[(String, usize, usize, usize, Resources)]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<10} {:>7} {:>7} {:>10} {:>9} {:>9}\n",
        "Dataset", "Nodes", "Edges", "Feat. Dim.", "LUT", "FF"
    ));
    for (name, n, e, f, res) in rows {
        out.push_str(&format!(
            "{:<10} {:>7} {:>7} {:>10} {:>9} {:>9}\n",
            name, n, e, f, res.lut, res.ff
        ));
    }
    out
}

fn row(name: &str, r: &Resources) -> String {
    format!(
        "{:<10} {:>6} {:>9} {:>9} {:>6} {:>6}\n",
        name, r.dsp, r.lut, r.ff, r.bram, r.uram
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelConfig;
    use crate::resources::hls::estimate;

    #[test]
    fn table4_renders_all_models() {
        let ests: Vec<Estimate> = ["gin", "gcn"]
            .iter()
            .map(|n| estimate(&ModelConfig::by_name(n).unwrap()).unwrap())
            .collect();
        let t = render_table4(&ests);
        assert!(t.contains("Available"));
        assert!(t.contains("gin") && t.contains("gcn"));
        assert!(t.lines().count() >= 4);
    }

    #[test]
    fn table5_renders_rows() {
        let r = crate::resources::hls::estimate_large("Cora", 2708, 1433);
        let t = render_table5(&[("Cora".into(), 2708, 10556, 1433, r.total)]);
        assert!(t.contains("Cora") && t.contains("10556"));
    }
}

//! HLS-style FPGA resource estimator (paper Tables 4 and 5).
//!
//! The paper reports post-synthesis DSP/LUT/FF/BRAM/URAM utilization on
//! the Alveo U50 for each model. Without Vitis we reproduce the numbers
//! the way an HLS engineer budgets them: a component inventory per model
//! (MAC arrays with a DSP-or-fabric binding, partitioned on-chip
//! buffers with a BRAM/URAM binding, register files, PE control) priced
//! with per-unit costs, calibrated once against Table 4
//! (rust/README.md § Backends; Table 4's own PNA row is "estimates from
//! the Vitis HLS tool", so estimate-vs-estimate is the fair comparison).

pub mod hls;
pub mod table;

pub use hls::{estimate, estimate_large, estimate_scaled, Estimate, Resources, U50};

//! Component-inventory resource model.
//!
//! Pricing rules (per unit, 32-bit fixed-point arithmetic as in the
//! paper's small-graph models):
//!
//! * a DSP-bound 32-bit MAC costs 4 DSP48 slices + glue LUT/FF;
//! * a fabric-bound 32-bit MAC costs no DSPs but ~300 LUT / ~350 FF;
//! * an on-chip buffer bank costs 1 BRAM18 (or 1 URAM) + port muxing;
//! * a fully-partitioned register file costs 1 FF/bit + mux LUTs;
//! * each processing element carries control/FSM overhead;
//! * a fixed base covers the AXI shell, COO converter, and I/O FIFOs.
//!
//! Per-model inventories encode the implementation *choices* visible in
//! Table 4: GCN binds its node-parallel SpMM accumulators to fabric and
//! registers (huge LUT/FF, few DSPs), GIN/DGN bind their MLP arrays to
//! DSPs, PNA (an HLS estimate in the paper) is a narrow design holding
//! its aggregator state in URAM.

use anyhow::{bail, Result};

use crate::models::{GnnKind, ModelConfig};

/// One resource vector (same columns as paper Table 4).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Resources {
    pub dsp: u64,
    pub lut: u64,
    pub ff: u64,
    pub bram: u64,
    pub uram: u64,
}

impl std::ops::Add for Resources {
    type Output = Resources;
    fn add(self, o: Resources) -> Resources {
        Resources {
            dsp: self.dsp + o.dsp,
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            bram: self.bram + o.bram,
            uram: self.uram + o.uram,
        }
    }
}

impl Resources {
    /// Utilization fraction against a device, per column (max over cols).
    pub fn max_utilization(&self, dev: &Resources) -> f64 {
        [
            self.dsp as f64 / dev.dsp as f64,
            self.lut as f64 / dev.lut as f64,
            self.ff as f64 / dev.ff as f64,
            self.bram as f64 / dev.bram as f64,
            self.uram as f64 / dev.uram as f64,
        ]
        .into_iter()
        .fold(0.0, f64::max)
    }
}

/// Alveo U50 availability (paper Table 4 header row).
pub const U50: Resources = Resources {
    dsp: 5952,
    lut: 872_000,
    ff: 1_743_000,
    bram: 1344,
    uram: 640,
};

// ---- per-unit pricing constants (calibrated once against Table 4) ----
const DSP_PER_MAC32: u64 = 4;
const DSPMAC_LUT: u64 = 60;
const DSPMAC_FF: u64 = 90;
const FABMAC_LUT: u64 = 300;
const FABMAC_FF: u64 = 350;
const BANK_LUT: u64 = 40;
const BANK_FF: u64 = 40;
const URAM_LUT: u64 = 5;
const URAM_FF: u64 = 5;
const PE_LUT: u64 = 3000;
const PE_FF: u64 = 3000;
const REG_LUT_PER_BIT: f64 = 0.15;
const BASE_LUT: u64 = 15_000;
const BASE_FF: u64 = 10_000;
const BASE_BRAM: u64 = 3;

/// One priced inventory line.
#[derive(Clone, Debug)]
pub struct Component {
    pub name: &'static str,
    pub res: Resources,
    /// True for arithmetic components that scale with the PE lane
    /// widths (used by DSE's `estimate_scaled`).
    pub compute: bool,
}

/// A full estimate: the inventory plus its total.
#[derive(Clone, Debug)]
pub struct Estimate {
    pub model: String,
    pub components: Vec<Component>,
    pub total: Resources,
}

fn dsp_macs(name: &'static str, n: u64) -> Component {
    Component {
        name,
        res: Resources {
            dsp: n * DSP_PER_MAC32,
            lut: n * DSPMAC_LUT,
            ff: n * DSPMAC_FF,
            ..Resources::default()
        },
        compute: true,
    }
}

fn fabric_macs(name: &'static str, n: u64) -> Component {
    Component {
        name,
        res: Resources {
            lut: n * FABMAC_LUT,
            ff: n * FABMAC_FF,
            ..Resources::default()
        },
        compute: true,
    }
}

fn bram_banks(name: &'static str, n: u64) -> Component {
    Component {
        name,
        compute: false,
        res: Resources {
            bram: n,
            lut: n * BANK_LUT,
            ff: n * BANK_FF,
            ..Resources::default()
        },
    }
}

fn uram_banks(name: &'static str, n: u64) -> Component {
    Component {
        name,
        compute: false,
        res: Resources {
            uram: n,
            lut: n * URAM_LUT,
            ff: n * URAM_FF,
            ..Resources::default()
        },
    }
}

fn reg_file(name: &'static str, words: u64, bits: u64) -> Component {
    let total_bits = words * bits;
    Component {
        name,
        compute: false,
        res: Resources {
            ff: total_bits,
            lut: (total_bits as f64 * REG_LUT_PER_BIT) as u64,
            ..Resources::default()
        },
    }
}

fn pe_control(name: &'static str, pes: u64) -> Component {
    Component {
        name,
        compute: false,
        res: Resources {
            lut: pes * PE_LUT,
            ff: pes * PE_FF,
            ..Resources::default()
        },
    }
}

fn base_shell() -> Component {
    Component {
        name: "AXI shell + COO converter + I/O FIFOs",
        compute: false,
        res: Resources {
            lut: BASE_LUT,
            ff: BASE_FF,
            bram: BASE_BRAM,
            ..Resources::default()
        },
    }
}

/// Inventory for one small-graph model (Table 4 rows).
fn inventory(m: &ModelConfig) -> Vec<Component> {
    match m.kind {
        GnnKind::Gin => vec![
            base_shell(),
            // MLP PE: 8x8 lanes over two pipelined linear stages.
            dsp_macs("MLP PE MAC array (DSP)", 128),
            // Bond-embedding linear in the MP PE.
            dsp_macs("edge-embedding MACs (DSP)", 64),
            // eps-combine + pooling adders.
            dsp_macs("combine/pool MACs (DSP)", 12),
            fabric_macs("elementwise units (fabric)", 35),
            reg_file("MLP ping-pong local buffers", 430, 32),
            // node buffer + 2 message buffers, partitioned by feature.
            bram_banks("node/message buffers (3 x 100 banks)", 300),
            bram_banks("weight cache + misc", 40),
            bram_banks("I/O + stream FIFOs", 22),
            uram_banks("layer weight ping-pong (URAM)", 10),
            pe_control("NE/MP/converter/head control", 4),
        ],
        GnnKind::GinVn => {
            let mut v = inventory(&ModelConfig::by_name("gin").unwrap());
            v.push(Component {
                name: "virtual-node unit",
                compute: false,
                res: Resources {
                    lut: 1900,
                    ff: 1350,
                    bram: 2,
                    ..Resources::default()
                },
            });
            v
        }
        GnnKind::Gcn => vec![
            base_shell(),
            // GCN exploits node- AND feature-level parallelism (SpMM
            // formulation): accumulators bound to fabric + registers.
            dsp_macs("feature-transform MACs (DSP)", 106),
            fabric_macs("node-parallel SpMM MACs (fabric)", 332),
            reg_file("fully-partitioned accumulator rows", 6875, 32),
            bram_banks("node/message buffers (2 x 100 banks)", 200),
            pe_control("NE/MP/converter/head control", 4),
        ],
        GnnKind::Gat => vec![
            base_shell(),
            // 4 heads x 16 features, parallelized along heads.
            dsp_macs("projection + attention MACs (DSP)", 85),
            fabric_macs("logit/softmax units (fabric)", 97),
            // Per-head attention score + z buffers: 4 heads x many banks.
            bram_banks("per-head z/score buffers", 420),
            bram_banks("node/message buffers", 61),
            pe_control("NE/MP/converter/head control", 4),
        ],
        GnnKind::Pna => vec![
            base_shell(),
            // Paper marks PNA as a Vitis estimate: narrow MAC array.
            dsp_macs("linear MACs (DSP)", 12),
            fabric_macs("scaler units (fabric)", 10),
            bram_banks("node buffer + stream FIFOs", 230),
            // 4 aggregator buffers + 12d-wide weights live in URAM.
            uram_banks("aggregator state + weights (URAM)", 144),
            pe_control("NE/MP/converter/head control", 4),
        ],
        GnnKind::Dgn => vec![
            base_shell(),
            // Two concurrent aggregation streams + MLP with skip.
            dsp_macs("MLP + directional MACs (DSP)", 260),
            fabric_macs("directional weight units (fabric)", 14),
            reg_file("aggregation staging registers", 606, 32),
            bram_banks("node/message/eig buffers", 470),
            bram_banks("directional matrices cache", 50),
            pe_control("NE/MP(x2 streams)/converter/head control", 5),
        ],
    }
}

/// Estimate the resource vector of one registered model (Table 4 row).
pub fn estimate(m: &ModelConfig) -> Result<Estimate> {
    if m.n_max > 64 {
        bail!("{} is a large-graph config; use estimate_large", m.name);
    }
    let components = inventory(m);
    let total = components
        .iter()
        .fold(Resources::default(), |acc, c| acc + c.res);
    Ok(Estimate {
        model: m.name.to_string(),
        components,
        total,
    })
}

/// Estimate under non-default PE lane widths (the DSE knobs): compute
/// components scale with the MAC-array area `p_in x p_out` relative to
/// the calibrated 8x8 baseline; buffers, register files, and control
/// are lane-independent. `p_msg` contributes linearly through the MP
/// datapath share (weighted 1/4 of the compute inventory).
pub fn estimate_scaled(m: &ModelConfig, p: &crate::sim::cycles::CostParams) -> Result<Estimate> {
    let base = estimate(m)?;
    let mlp_factor = (p.p_in * p.p_out) as f64 / 64.0;
    let msg_factor = p.p_msg as f64 / 2.0;
    let scale = 0.75 * mlp_factor + 0.25 * msg_factor;
    let components: Vec<Component> = base
        .components
        .into_iter()
        .map(|c| {
            if c.compute {
                Component {
                    res: Resources {
                        dsp: (c.res.dsp as f64 * scale).round() as u64,
                        lut: (c.res.lut as f64 * scale).round() as u64,
                        ff: (c.res.ff as f64 * scale).round() as u64,
                        bram: c.res.bram,
                        uram: c.res.uram,
                    },
                    ..c
                }
            } else {
                c
            }
        })
        .collect();
    let total = components
        .iter()
        .fold(Resources::default(), |acc, c| acc + c.res);
    Ok(Estimate {
        model: base.model,
        components,
        total,
    })
}

/// Estimate for the Large Graph Extension on a dataset of `n` nodes and
/// `f` input features (Table 5: "1,344 DSPs, 494 BRAMs, and 0 URAMs for
/// all three datasets", LUT/FF varying mildly with the dataset).
pub fn estimate_large(dataset: &str, n: usize, f: usize) -> Estimate {
    let _ = dataset;
    let dsp_macs_n = 336u64; // 336 MACs x 4 DSP = 1,344
    let addr_bits = (usize::BITS - n.leading_zeros()) as u64;
    let lut = 109_500 + 150 * addr_bits + f as u64 / 2;
    let ff = 99_000 + 3 * f as u64;
    let total = Resources {
        dsp: dsp_macs_n * DSP_PER_MAC32,
        lut,
        ff,
        bram: 494,
        uram: 0,
    };
    Estimate {
        model: format!("dgn_large[{dataset}]"),
        components: vec![Component {
            name: "large-graph extension datapath",
            res: total,
            compute: true,
        }],
        total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelConfig;

    /// Paper Table 4 reference rows (DSP, LUT, FF, BRAM, URAM).
    pub const TABLE4: [(&str, [u64; 5]); 6] = [
        ("gin", [817, 66_326, 81_144, 365, 10]),
        ("gin_vn", [817, 68_204, 82_498, 367, 10]),
        ("gcn", [424, 173_899, 375_882, 203, 0]),
        ("pna", [50, 40_951, 34_533, 233, 144]),
        ("gat", [341, 80_545, 82_829, 484, 0]),
        ("dgn", [1042, 73_735, 93_579, 523, 0]),
    ];

    fn within(ours: u64, paper: u64, tol: f64) -> bool {
        if paper == 0 {
            return ours == 0;
        }
        let r = ours as f64 / paper as f64;
        (1.0 - tol..=1.0 + tol).contains(&r)
    }

    #[test]
    fn table4_within_25_percent_per_cell() {
        for (name, row) in TABLE4 {
            let e = estimate(&ModelConfig::by_name(name).unwrap()).unwrap();
            let got = [e.total.dsp, e.total.lut, e.total.ff, e.total.bram, e.total.uram];
            for (col, (&g, &want)) in got.iter().zip(&row).enumerate() {
                assert!(
                    within(g, want, 0.25),
                    "{name} col {col}: got {g}, paper {want}"
                );
            }
        }
    }

    #[test]
    fn orderings_match_paper() {
        let t = |n: &str| estimate(&ModelConfig::by_name(n).unwrap()).unwrap().total;
        // DGN uses the most DSPs; GCN the most LUT+FF; PNA the most URAM.
        let names = ["gin", "gcn", "pna", "gat", "dgn"];
        assert!(names.iter().all(|&n| t("dgn").dsp >= t(n).dsp));
        assert!(names.iter().all(|&n| t("gcn").lut >= t(n).lut));
        assert!(names.iter().all(|&n| t("gcn").ff >= t(n).ff));
        assert!(names.iter().all(|&n| t("pna").uram >= t(n).uram));
        // VN adds a small delta over GIN on LUT/FF/BRAM, same DSPs.
        assert_eq!(t("gin_vn").dsp, t("gin").dsp);
        assert!(t("gin_vn").lut > t("gin").lut);
        assert!(t("gin_vn").bram > t("gin").bram);
    }

    #[test]
    fn everything_fits_on_u50() {
        for (name, _) in TABLE4 {
            let e = estimate(&ModelConfig::by_name(name).unwrap()).unwrap();
            let u = e.total.max_utilization(&U50);
            assert!(u < 1.0, "{name} exceeds the U50: {u:.2}");
        }
    }

    #[test]
    fn large_extension_matches_table5() {
        // (name, nodes, feat, LUT, FF)
        let rows = [
            ("Cora", 2708, 1433, 111_456u64, 110_508u64),
            ("CiteSeer", 3327, 3703, 116_442, 109_765),
            ("PubMed", 19717, 500, 119_329, 100_699),
        ];
        for (name, n, f, lut, ff) in rows {
            let e = estimate_large(name, n, f);
            assert_eq!(e.total.dsp, 1344);
            assert_eq!(e.total.bram, 494);
            assert_eq!(e.total.uram, 0);
            assert!(within(e.total.lut, lut, 0.25), "{name} lut {}", e.total.lut);
            assert!(within(e.total.ff, ff, 0.25), "{name} ff {}", e.total.ff);
        }
    }

    #[test]
    fn components_sum_to_total() {
        let e = estimate(&ModelConfig::by_name("dgn").unwrap()).unwrap();
        let sum = e
            .components
            .iter()
            .fold(Resources::default(), |a, c| a + c.res);
        assert_eq!(sum, e.total);
    }

    #[test]
    fn rejects_large_config() {
        assert!(estimate(&ModelConfig::by_name("dgn_large").unwrap()).is_err());
    }
}

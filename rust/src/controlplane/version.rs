//! The wire protocol version table and the negotiation rule.
//!
//! Version negotiation is per-frame and one-sided: a server (or the
//! ingress answering on a server's behalf) simply echoes whatever
//! version byte the request frame carried, so a v1 client never sees a
//! version byte it does not understand. The table lives here — not in
//! `net::proto` — because the ingress proxy and the reactor both need
//! it without pulling in the frame codec's request/response types;
//! `net::proto` re-exports the constants for wire-level callers.

/// The QoS protocol version; inference frames are still encoded at
/// this version by default (v3 changed nothing about inference).
pub const PROTO_VERSION: u8 = 2;

/// The legacy pre-QoS version; still accepted by the decoder.
pub const PROTO_V1: u8 = 1;

/// The control-plane version: inference bodies identical to v2, plus
/// the control frame kinds carrying registry ops.
pub const PROTO_V3: u8 = 3;

/// The resident-graph version: inference and control bodies identical
/// to v3, plus the resident frame kinds (`GRAPH_QUERY` /
/// `GRAPH_MUTATE`) against a server-hosted graph.
pub const PROTO_V4: u8 = 4;

/// Is `version` one the decoder understands?
pub fn known_version(version: u8) -> bool {
    version == PROTO_V1 || version == PROTO_VERSION || version == PROTO_V3 || version == PROTO_V4
}

/// The version a response frame should be stamped with, given the
/// first byte of the request payload it answers: responses echo the
/// version of the frame they answer; frames whose version byte is
/// itself unknown (or missing entirely) get the current version.
pub fn response_version(first_byte: Option<u8>) -> u8 {
    match first_byte {
        Some(v) if known_version(v) => v,
        _ => PROTO_VERSION,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_versions_are_exactly_1_through_4() {
        for v in 0u8..=255 {
            assert_eq!(known_version(v), (1..=4).contains(&v), "version {v}");
        }
    }

    #[test]
    fn responses_echo_known_versions_and_default_otherwise() {
        assert_eq!(response_version(Some(PROTO_V1)), PROTO_V1);
        assert_eq!(response_version(Some(PROTO_VERSION)), PROTO_VERSION);
        assert_eq!(response_version(Some(PROTO_V3)), PROTO_V3);
        assert_eq!(response_version(Some(PROTO_V4)), PROTO_V4);
        assert_eq!(response_version(Some(0)), PROTO_VERSION);
        assert_eq!(response_version(Some(99)), PROTO_VERSION);
        assert_eq!(response_version(None), PROTO_VERSION);
    }
}

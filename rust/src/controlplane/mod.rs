// Shared by both serving binaries; the same degrade-don't-panic rule
// as the wire front-end applies.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

//! Control-plane building blocks shared by the `serve` and `ingress`
//! front-ends.
//!
//! Before the cluster tier, everything front-end-shaped lived inside
//! `net/` + `coordinator/` and was reachable only from the single
//! serving process. `gengnn ingress` fronts N `gengnn serve` backends
//! over the same wire protocol, so the pieces both binaries need are
//! lifted here, where neither depends on the other's internals:
//!
//! * [`version`] — the wire protocol version table and the
//!   echo-the-caller's-version negotiation rule (one copy, consumed by
//!   the frame codec, the reactor, and the ingress proxy)
//! * [`metrics`] — the lock-free counter blocks: [`NetCounters`]
//!   (wire front-end, embedded in `coordinator::Metrics`) and
//!   [`IngressCounters`] (proxy/probe/reconciler, owned by the ingress)
//! * [`options`] — [`FrontendOptions`], the `--listen/--reactors/
//!   --duration` flag triple both subcommands parse the same way
//!
//! `docs/CLUSTER.md` describes the fleet topology this enables.

pub mod metrics;
pub mod options;
pub mod version;

pub use metrics::{IngressCounters, NetCounters};
pub use options::FrontendOptions;
pub use version::{known_version, response_version, PROTO_V1, PROTO_V3, PROTO_V4, PROTO_VERSION};

//! Lock-free counter blocks shared by the serving front-ends.
//!
//! [`NetCounters`] is the wire front-end block every `serve` process
//! embeds in its `coordinator::Metrics`; it moved here so the ingress
//! (which has no coordinator) and the server register the same wire
//! counters from the same definition. [`IngressCounters`] is the
//! cluster-tier block: proxy data plane, health probes, ejections, and
//! reconciler restarts.

use std::sync::atomic::{AtomicU64, Ordering};

/// Wire front-end counters, updated lock-free by the accept loop,
/// connection readers, and the response demux. `connections_open` and
/// `requests_in_flight` are gauges (incremented and decremented);
/// everything else is monotonic.
#[derive(Default)]
pub struct NetCounters {
    /// Completed `accept(2)` calls — counted before connection setup,
    /// so this includes connections later dropped during setup under
    /// resource pressure (`connections_open` is rolled back for those).
    pub connections_accepted: AtomicU64,
    /// Currently-open connections (gauge).
    pub connections_open: AtomicU64,
    /// Frames that failed to decode (bad version, checksum, truncation).
    pub decode_errors: AtomicU64,
    /// Wire requests admitted but not yet answered (gauge).
    pub requests_in_flight: AtomicU64,
    /// Responses dropped because a connection's outbox was full (the
    /// client stopped reading) — the demux never blocks on one stalled
    /// connection at the expense of the others.
    pub responses_dropped: AtomicU64,
}

/// Cluster-tier counters, updated lock-free by the ingress proxy's
/// client readers, backend link readers, prober, and reconciler.
/// `connections_open` and `requests_in_flight` are gauges; everything
/// else is monotonic.
#[derive(Default)]
pub struct IngressCounters {
    /// Client connections accepted by the ingress front.
    pub connections_accepted: AtomicU64,
    /// Currently-open client connections (gauge).
    pub connections_open: AtomicU64,
    /// Client frames forwarded to a backend (after id rewrite).
    pub frames_proxied: AtomicU64,
    /// Backend responses relayed back to a client.
    pub responses_relayed: AtomicU64,
    /// Proxied frames not yet answered (gauge).
    pub requests_in_flight: AtomicU64,
    /// Client frames the ingress could not parse far enough to route
    /// (bad version, checksum, truncation) — answered `BadRequest` at
    /// the ingress, never forwarded.
    pub decode_errors: AtomicU64,
    /// Frames answered `Rejected` because no healthy backend covers
    /// the requested model.
    pub no_backend_rejected: AtomicU64,
    /// Frames answered `Rejected` because the ingress was draining.
    pub drain_rejected: AtomicU64,
    /// In-flight requests answered `Error` because their backend link
    /// died before responding (the crash-accounting path: these land
    /// in loadgen's `failed` bucket, never in `lost`).
    pub backend_failed_in_flight: AtomicU64,
    /// Backend responses with no live route (client disconnected
    /// before its answer arrived).
    pub responses_dropped: AtomicU64,
    /// Successful health probes.
    pub probes_ok: AtomicU64,
    /// Failed health probes (connect/timeout/decode failures, error
    /// statuses, and probes missing a spec-assigned model).
    pub probes_failed: AtomicU64,
    /// Healthy→Ejected transitions (probe threshold or link death).
    pub ejections: AtomicU64,
    /// Probation→Healthy transitions.
    pub recoveries: AtomicU64,
    /// Dead managed backends respawned by the reconciler.
    pub restarts: AtomicU64,
    /// Proxied frames deliberately corrupted by the fault-injection
    /// plan (test harness only; zero in production).
    pub frames_corrupted: AtomicU64,
}

impl IngressCounters {
    /// Human-readable counter table (the ingress analogue of
    /// `coordinator::Metrics::render`).
    pub fn render(&self) -> String {
        let g = |c: &AtomicU64| c.load(Ordering::Relaxed);
        let mut out = format!(
            "ingress: {} conns accepted ({} open), {} proxied, {} relayed, {} in flight\n",
            g(&self.connections_accepted),
            g(&self.connections_open),
            g(&self.frames_proxied),
            g(&self.responses_relayed),
            g(&self.requests_in_flight),
        );
        out.push_str(&format!(
            "  rejected: {} no-backend, {} draining; {} decode errors, \
             {} failed in flight, {} responses dropped\n",
            g(&self.no_backend_rejected),
            g(&self.drain_rejected),
            g(&self.decode_errors),
            g(&self.backend_failed_in_flight),
            g(&self.responses_dropped),
        ));
        out.push_str(&format!(
            "  health: {} probes ok / {} failed, {} ejections, {} recoveries, {} restarts\n",
            g(&self.probes_ok),
            g(&self.probes_failed),
            g(&self.ejections),
            g(&self.recoveries),
            g(&self.restarts),
        ));
        let corrupted = g(&self.frames_corrupted);
        if corrupted > 0 {
            out.push_str(&format!("  fault injection: {corrupted} frames corrupted\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ingress_render_covers_every_section() {
        let c = IngressCounters::default();
        c.connections_accepted.store(4, Ordering::Relaxed);
        c.frames_proxied.store(100, Ordering::Relaxed);
        c.ejections.store(2, Ordering::Relaxed);
        let text = c.render();
        assert!(text.contains("4 conns accepted"));
        assert!(text.contains("100 proxied"));
        assert!(text.contains("2 ejections"));
        // The fault-injection line only appears when faults fired.
        assert!(!text.contains("fault injection"));
        c.frames_corrupted.store(1, Ordering::Relaxed);
        assert!(c.render().contains("fault injection: 1 frames corrupted"));
    }
}

//! Front-end flag parsing shared by the `serve` and `ingress`
//! subcommands: both expose a TCP listener whose lifetime is governed
//! by `--duration`, and both size a worker pool — so the flag triple
//! parses in exactly one place instead of drifting apart per binary.

use std::time::Duration;

use anyhow::Result;

use crate::util::cli::Args;

/// The `--listen / --reactors / --duration` triple of a serving
/// front-end.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FrontendOptions {
    /// Listen address (`None` when the subcommand's default applies —
    /// in-process streaming for `serve`, the cluster spec's address
    /// for `ingress`).
    pub listen: Option<String>,
    /// Worker pool size (reactor threads for `serve`; accepted and
    /// ignored by `ingress`, which is thread-per-connection).
    pub workers: usize,
    /// Seconds to serve before a clean shutdown; 0 = run until killed.
    pub duration_secs: u64,
}

impl FrontendOptions {
    /// Parse the triple from already-parsed CLI args. `default_workers`
    /// is the subcommand's pool size when `--reactors` is absent.
    pub fn from_args(a: &Args, default_workers: usize) -> Result<FrontendOptions> {
        Ok(FrontendOptions {
            listen: a.str_opt("listen").map(|s| s.to_string()),
            workers: a.usize_or("reactors", default_workers)?,
            duration_secs: a.u64_or("duration", 0)?,
        })
    }

    /// The bounded run window, or `None` to serve until killed.
    pub fn run_for(&self) -> Option<Duration> {
        (self.duration_secs > 0).then(|| Duration::from_secs(self.duration_secs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(argv: &[&str]) -> Args {
        let owned: Vec<String> = argv.iter().map(|s| s.to_string()).collect();
        Args::parse(&owned, &[]).unwrap()
    }

    #[test]
    fn defaults_apply_when_flags_absent() {
        let fo = FrontendOptions::from_args(&args(&[]), 2).unwrap();
        assert_eq!(fo.listen, None);
        assert_eq!(fo.workers, 2);
        assert_eq!(fo.duration_secs, 0);
        assert_eq!(fo.run_for(), None);
    }

    #[test]
    fn flags_override_defaults() {
        let fo = FrontendOptions::from_args(
            &args(&["--listen", "127.0.0.1:9", "--reactors", "4", "--duration", "30"]),
            2,
        )
        .unwrap();
        assert_eq!(fo.listen.as_deref(), Some("127.0.0.1:9"));
        assert_eq!(fo.workers, 4);
        assert_eq!(fo.run_for(), Some(Duration::from_secs(30)));
    }
}

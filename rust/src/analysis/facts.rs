//! Fusion-safety facts: per-stage classification of why (or whether)
//! a stage may run over a block-diagonal fused batch, plus the f32
//! reduction-order tag the determinism audit reports.
//!
//! Before this pass existed, `runtime::interp::execute_fused` *assumed*
//! every stage kind was safe to evaluate over merged segments — true
//! for the current component library, but nothing enforced it for the
//! next stage somebody adds. Now the safety argument is explicit: an
//! exhaustive `match` (no wildcard arm) classifies every stage, so a
//! new `Stage` or `Aggregate` variant fails to compile until its
//! author states a fact, and the fused execution path refuses any plan
//! containing a [`FusionFact::CrossSegmentUnsafe`] stage instead of
//! silently miscomputing it.

use anyhow::{bail, Result};

use crate::models::plan::{Aggregate, ModelPlan, Readout, Stage};

/// Why one stage is safe (or not) under fused block-diagonal
/// execution. Ordered from the strongest safety argument to none.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum FusionFact {
    /// Pure per-row computation — cannot observe fusion at all.
    RowIndependent,
    /// Reads other rows only through the in-neighbor view, which is
    /// block-diagonal under fusion: neighborhoods never cross a
    /// segment boundary, so each segment sees exactly its own graph.
    NeighborhoodLocal,
    /// Touches per-graph state (readout rows, virtual-node vectors)
    /// and therefore needs the segment table — safe because the fused
    /// interpreter materializes one state slot per segment.
    SegmentLocal,
    /// No safety argument. Fused execution must refuse the plan.
    CrossSegmentUnsafe,
}

impl FusionFact {
    /// Stable identifier used by the `lint-plan` JSON report.
    pub fn name(&self) -> &'static str {
        match self {
            FusionFact::RowIndependent => "row_independent",
            FusionFact::NeighborhoodLocal => "neighborhood_local",
            FusionFact::SegmentLocal => "segment_local",
            FusionFact::CrossSegmentUnsafe => "cross_segment_unsafe",
        }
    }
}

/// How a stage's f32 reduction visits its operands — the determinism
/// audit compares this order between per-request and fused execution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReductionOrder {
    /// No floating-point reduction at all.
    None,
    /// Order-insensitive reduction (elementwise max/min).
    OrderInsensitive,
    /// f32 accumulation walking rows in ascending node order — the
    /// bit-exactness contract shared by the per-request and fused
    /// paths (segment-relative order equals whole-graph order because
    /// fused node ids are a shifted, order-preserving renumbering).
    AscendingNodeOrder,
}

impl ReductionOrder {
    /// Stable identifier used by the `lint-plan` JSON report.
    pub fn name(&self) -> &'static str {
        match self {
            ReductionOrder::None => "none",
            ReductionOrder::OrderInsensitive => "order_insensitive",
            ReductionOrder::AscendingNodeOrder => "ascending_node_order",
        }
    }

    pub fn is_order_sensitive(&self) -> bool {
        matches!(self, ReductionOrder::AscendingNodeOrder)
    }
}

/// Classify one stage. Exhaustive on purpose: adding a `Stage` (or
/// `Aggregate`) variant without classifying it is a compile error, not
/// a silently-wrong fused batch.
pub fn stage_fact(stage: &Stage) -> FusionFact {
    match stage {
        // Register ops that touch only the current row of `h`/`m`.
        Stage::Linear { .. }
        | Stage::TakeAggregate
        | Stage::EpsCombine { .. }
        | Stage::ResidualLinear { .. }
        | Stage::DualLinear { .. }
        | Stage::Activation(_)
        | Stage::L2Normalize => FusionFact::RowIndependent,
        // Neighborhood walks over the block-diagonal in-neighbor view.
        Stage::SparseAggregate(a) => match a {
            Aggregate::Sum
            | Aggregate::Mean
            | Aggregate::Max
            | Aggregate::Min
            | Aggregate::GcnNorm
            | Aggregate::EdgeReluSum { .. }
            | Aggregate::PnaTower
            | Aggregate::DgnDirectional => FusionFact::NeighborhoodLocal,
        },
        Stage::EdgeAttention { .. } => FusionFact::NeighborhoodLocal,
        // Per-graph state: one slot per fused segment.
        Stage::VirtualNodeAdd | Stage::VirtualNodeUpdate { .. } => FusionFact::SegmentLocal,
        Stage::Readout(r) => match r {
            Readout::MaskedMeanPool | Readout::NodeHead => FusionFact::SegmentLocal,
        },
    }
}

/// Tag the f32 reduction order of one stage (exhaustive, like
/// [`stage_fact`]).
pub fn stage_reduction(stage: &Stage) -> ReductionOrder {
    match stage {
        Stage::Linear { .. }
        | Stage::TakeAggregate
        | Stage::EpsCombine { .. }
        | Stage::ResidualLinear { .. }
        | Stage::DualLinear { .. }
        | Stage::Activation(_)
        // Row-local dot/norm sums have a fixed within-row order that
        // fusion cannot perturb, so they carry no cross-path hazard.
        | Stage::L2Normalize
        | Stage::VirtualNodeAdd => ReductionOrder::None,
        Stage::SparseAggregate(a) => match a {
            Aggregate::Max | Aggregate::Min => ReductionOrder::OrderInsensitive,
            Aggregate::Sum
            | Aggregate::Mean
            | Aggregate::GcnNorm
            | Aggregate::EdgeReluSum { .. }
            | Aggregate::PnaTower
            | Aggregate::DgnDirectional => ReductionOrder::AscendingNodeOrder,
        },
        // Softmax max/denominator and the weighted sum walk the merged
        // neighborhood (self included) in ascending node order.
        Stage::EdgeAttention { .. } => ReductionOrder::AscendingNodeOrder,
        // Σ_i h_i over the segment's real nodes, ascending.
        Stage::VirtualNodeUpdate { .. } => ReductionOrder::AscendingNodeOrder,
        Stage::Readout(r) => match r {
            Readout::MaskedMeanPool => ReductionOrder::AscendingNodeOrder,
            Readout::NodeHead => ReductionOrder::None,
        },
    }
}

/// The derived facts for one stage.
#[derive(Clone, Copy, Debug)]
pub struct StageFacts {
    pub fact: FusionFact,
    pub reduction: ReductionOrder,
}

/// Facts for a whole plan, index-aligned with `plan.stages`. Derived
/// once at lowering time and cached by the native executor; the fused
/// paths (`graph::FusedBatch::fuse_checked`,
/// `runtime::interp::execute_fused`) consume these instead of assuming
/// fusability.
#[derive(Clone, Debug)]
pub struct PlanFacts {
    pub stages: Vec<StageFacts>,
}

impl PlanFacts {
    pub fn derive(plan: &ModelPlan) -> PlanFacts {
        PlanFacts {
            stages: plan
                .stages
                .iter()
                .map(|s| StageFacts {
                    fact: stage_fact(s),
                    reduction: stage_reduction(s),
                })
                .collect(),
        }
    }

    /// Index of the first stage with no fusion-safety argument.
    pub fn first_unfusable(&self) -> Option<usize> {
        self.stages
            .iter()
            .position(|s| s.fact == FusionFact::CrossSegmentUnsafe)
    }

    /// Whether every stage carries a fusion-safety argument.
    pub fn fusable(&self) -> bool {
        self.first_unfusable().is_none()
    }

    /// Hard gate used by the fused execution path: error (naming the
    /// offending stage) when the facts do not justify fusion.
    pub fn require_fusable(&self, model: &str) -> Result<()> {
        if let Some(i) = self.first_unfusable() {
            bail!(
                "model {model:?}: stage {i} is cross-segment-unsafe — \
                 fused execution refused (run per-request instead)"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::params::WInit;
    use crate::models::plan::Act;

    #[test]
    fn component_library_is_entirely_fusable() {
        let mut wi = WInit::new(0);
        let stages = vec![
            Stage::Linear {
                w: wi.dense(4, 8),
                act: Act::Relu,
            },
            Stage::SparseAggregate(Aggregate::GcnNorm),
            Stage::SparseAggregate(Aggregate::Max),
            Stage::SparseAggregate(Aggregate::EdgeReluSum { bond: wi.dense(3, 8) }),
            Stage::TakeAggregate,
            Stage::EpsCombine { eps: 0.1 },
            Stage::EdgeAttention {
                heads: 2,
                a_src: vec![0.0; 8],
                a_dst: vec![0.0; 8],
            },
            Stage::VirtualNodeAdd,
            Stage::VirtualNodeUpdate {
                w1: wi.dense(8, 16),
                w2: wi.dense(16, 8),
            },
            Stage::Readout(Readout::MaskedMeanPool),
            Stage::Readout(Readout::NodeHead),
        ];
        for s in &stages {
            assert_ne!(
                stage_fact(s),
                FusionFact::CrossSegmentUnsafe,
                "{} must carry a safety argument",
                s.name()
            );
        }
    }

    #[test]
    fn reduction_tags_match_the_interpreter_contract() {
        assert_eq!(
            stage_reduction(&Stage::SparseAggregate(Aggregate::Max)),
            ReductionOrder::OrderInsensitive
        );
        assert_eq!(
            stage_reduction(&Stage::SparseAggregate(Aggregate::Sum)),
            ReductionOrder::AscendingNodeOrder
        );
        assert_eq!(
            stage_reduction(&Stage::Readout(Readout::MaskedMeanPool)),
            ReductionOrder::AscendingNodeOrder
        );
        assert_eq!(
            stage_reduction(&Stage::Readout(Readout::NodeHead)),
            ReductionOrder::None
        );
        assert!(ReductionOrder::AscendingNodeOrder.is_order_sensitive());
        assert!(!ReductionOrder::OrderInsensitive.is_order_sensitive());
    }

    #[test]
    fn unfusable_facts_fail_the_gate_with_the_stage_index() {
        let facts = PlanFacts {
            stages: vec![
                StageFacts {
                    fact: FusionFact::RowIndependent,
                    reduction: ReductionOrder::None,
                },
                StageFacts {
                    fact: FusionFact::CrossSegmentUnsafe,
                    reduction: ReductionOrder::AscendingNodeOrder,
                },
            ],
        };
        assert!(!facts.fusable());
        assert_eq!(facts.first_unfusable(), Some(1));
        let err = facts.require_fusable("hypothetical").unwrap_err().to_string();
        assert!(err.contains("stage 1"), "{err}");
        assert!(err.contains("cross-segment-unsafe"), "{err}");
    }

    #[test]
    fn fact_lattice_orders_weakest_last() {
        assert!(FusionFact::RowIndependent < FusionFact::NeighborhoodLocal);
        assert!(FusionFact::NeighborhoodLocal < FusionFact::SegmentLocal);
        assert!(FusionFact::SegmentLocal < FusionFact::CrossSegmentUnsafe);
    }
}

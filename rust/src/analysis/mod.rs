//! Static analysis over the stage IR: every lowered [`ModelPlan`] is
//! abstract-interpreted **before** it may serve traffic.
//!
//! Three passes (see `docs/STATIC_ANALYSIS.md` for the full catalog):
//!
//! 1. **Shape/dataflow** ([`shape`]) — symbolic width chaining,
//!    write-before-read discipline on the aggregation register and
//!    virtual-node state, readout compatibility, parameter audit, and
//!    weight-stream coverage (unused or doubly-consumed params).
//! 2. **Fusion-safety facts** ([`facts`]) — classifies every stage on
//!    the `row_independent ⊑ neighborhood_local ⊑ segment_local ⊑
//!    cross_segment_unsafe` lattice. The fused execution path
//!    (`graph::FusedBatch::fuse_checked`,
//!    `runtime::interp::execute_fused`) consumes these derived facts
//!    instead of assuming every stage kind is safe to merge.
//! 3. **Determinism audit** — tags each stage's f32 reduction order
//!    and flags any stage whose fused evaluation order could diverge
//!    from per-request order.
//!
//! Entry points: [`analyze`] / [`analyze_lowered`] produce a
//! [`Report`]; [`require_clean`] is the mandatory gate
//! `models::lower::lower` (and therefore `Engine` construction and the
//! coordinator's `LOAD` path) applies; `gengnn lint-plan` renders the
//! report for humans and CI.

pub mod diag;
pub mod facts;
pub mod shape;

use anyhow::{bail, Result};

use crate::models::plan::ModelPlan;
use crate::util::json::{self, Json};

pub use diag::{Code, Diagnostic, Severity};
pub use facts::{FusionFact, PlanFacts, ReductionOrder, StageFacts};

/// Per-stage row of the findings report: the derived facts, keyed by
/// stage index and name.
#[derive(Clone, Debug)]
pub struct StageRow {
    pub index: usize,
    pub name: &'static str,
    pub fact: FusionFact,
    pub reduction: ReductionOrder,
}

/// The analyzer's structured verdict on one plan.
#[derive(Clone, Debug)]
pub struct Report {
    pub model: String,
    pub stages: Vec<StageRow>,
    pub findings: Vec<Diagnostic>,
    /// Whether every stage carries a fusion-safety argument (derived
    /// from the facts pass, not from the findings).
    pub fusable: bool,
}

impl Report {
    pub fn count(&self, s: Severity) -> usize {
        self.findings.iter().filter(|f| f.severity() == s).count()
    }

    /// No `Error`-severity findings: the plan may be deployed.
    pub fn ok(&self) -> bool {
        self.count(Severity::Error) == 0
    }

    pub fn first_error(&self) -> Option<&Diagnostic> {
        self.findings
            .iter()
            .find(|f| f.severity() == Severity::Error)
    }

    pub fn has_code(&self, code: Code) -> bool {
        self.findings.iter().any(|f| f.code == code)
    }

    /// The `gengnn lint-plan --json` schema, validated by
    /// `python/tools/check_plan_schema.py --lint`.
    pub fn to_json(&self) -> Json {
        let stages: Vec<Json> = self
            .stages
            .iter()
            .map(|s| {
                json::obj(vec![
                    ("index", json::num(s.index as f64)),
                    ("stage", Json::Str(s.name.to_string())),
                    ("fusion", Json::Str(s.fact.name().to_string())),
                    ("reduction", Json::Str(s.reduction.name().to_string())),
                ])
            })
            .collect();
        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                json::obj(vec![
                    ("code", Json::Str(f.code.id().to_string())),
                    ("severity", Json::Str(f.severity().name().to_string())),
                    (
                        "stage",
                        f.stage.map_or(Json::Null, |i| json::num(i as f64)),
                    ),
                    ("message", Json::Str(f.message.clone())),
                ])
            })
            .collect();
        json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("ok", Json::Bool(self.ok())),
            ("fusable", Json::Bool(self.fusable)),
            ("errors", json::num(self.count(Severity::Error) as f64)),
            ("warnings", json::num(self.count(Severity::Warning) as f64)),
            ("infos", json::num(self.count(Severity::Info) as f64)),
            ("stages", Json::Arr(stages)),
            ("findings", Json::Arr(findings)),
        ])
    }

    /// Human-readable rendering for `gengnn lint-plan`.
    pub fn render_text(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "{}: {} ({} stages, fusable: {})",
            self.model,
            if self.ok() { "PASS" } else { "FAIL" },
            self.stages.len(),
            if self.fusable { "yes" } else { "no" },
        );
        let _ = writeln!(
            s,
            "{:>3}  {:<18} {:<22} {}",
            "#", "stage", "fusion", "reduction"
        );
        for row in &self.stages {
            let _ = writeln!(
                s,
                "{:>3}  {:<18} {:<22} {}",
                row.index,
                row.name,
                row.fact.name(),
                row.reduction.name()
            );
        }
        for f in &self.findings {
            let _ = writeln!(s, "  {f}");
        }
        let _ = writeln!(
            s,
            "{} error(s), {} warning(s), {} note(s)",
            self.count(Severity::Error),
            self.count(Severity::Warning),
            self.count(Severity::Info)
        );
        s
    }
}

/// Analyze a plan assembled without a seeded weight stream (tests,
/// hand-built plans). Skips the weight-coverage pass.
pub fn analyze(plan: &ModelPlan) -> Report {
    analyze_inner(plan, None)
}

/// Analyze a freshly-lowered plan whose weights came from a counted
/// [`crate::models::params::WInit`] stream; `drawn_params` enables the
/// weight-coverage check.
pub fn analyze_lowered(plan: &ModelPlan, drawn_params: usize) -> Report {
    analyze_inner(plan, Some(drawn_params))
}

fn analyze_inner(plan: &ModelPlan, drawn_params: Option<usize>) -> Report {
    let mut findings = shape::check(plan, drawn_params);
    let facts = PlanFacts::derive(plan);
    let stages: Vec<StageRow> = plan
        .stages
        .iter()
        .zip(&facts.stages)
        .enumerate()
        .map(|(index, (stage, f))| StageRow {
            index,
            name: stage.name(),
            fact: f.fact,
            reduction: f.reduction,
        })
        .collect();
    audit_determinism(&stages, &mut findings);
    Report {
        model: plan.model.clone(),
        stages,
        findings,
        fusable: facts.fusable(),
    }
}

/// The determinism audit: a stage's fused evaluation order can only
/// diverge from per-request order when the stage has no fusion-safety
/// argument (every classified fact preserves segment-relative node
/// order — the bit-exactness property the fused_equivalence suite
/// pins). Flag exactly those, distinguishing the actively dangerous
/// case (order-sensitive f32 reduction) from the merely unproven one.
fn audit_determinism(stages: &[StageRow], findings: &mut Vec<Diagnostic>) {
    let mut order_sensitive = 0usize;
    let mut all_safe = true;
    for row in stages {
        if row.reduction.is_order_sensitive() {
            order_sensitive += 1;
        }
        if row.fact == FusionFact::CrossSegmentUnsafe {
            all_safe = false;
            let (code, what) = if row.reduction.is_order_sensitive() {
                (
                    Code::FusedOrderDivergence,
                    "order-sensitive f32 reduction with no fusion-safety argument: \
                     fused evaluation order could diverge from per-request order",
                )
            } else {
                (
                    Code::FusionUnsafeStage,
                    "no fusion-safety argument: the fused path will refuse this plan",
                )
            };
            findings.push(Diagnostic::at(code, row.index, format!("{}: {what}", row.name)));
        }
    }
    if all_safe && order_sensitive > 0 {
        findings.push(Diagnostic::plan(
            Code::ReductionOrderNote,
            format!(
                "{order_sensitive} order-sensitive f32 reduction stage(s); per-request \
                 and fused execution both walk ascending node order, so outputs are \
                 bit-identical"
            ),
        ));
    }
}

/// The mandatory lowering gate: reject any plan with `Error` findings.
pub fn require_clean(report: &Report) -> Result<()> {
    if let Some(first) = report.first_error() {
        bail!(
            "plan analysis rejected model {:?}: {} error(s), first: {first}",
            report.model,
            report.count(Severity::Error)
        );
    }
    Ok(())
}

/// Derive the fusion-safety facts for a plan (cached by the native
/// executor at build time).
pub fn plan_facts(plan: &ModelPlan) -> PlanFacts {
    PlanFacts::derive(plan)
}

/// Gate used by the fused execution path: error unless every stage of
/// the plan carries a fusion-safety argument.
pub fn assert_fusable(plan: &ModelPlan) -> Result<()> {
    PlanFacts::derive(plan).require_fusable(&plan.model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::params::WInit;
    use crate::models::plan::{Act, Aggregate, Readout, Stage};

    fn tiny_plan() -> ModelPlan {
        let mut wi = WInit::new(0);
        ModelPlan {
            model: "tiny".into(),
            n_max: 8,
            in_dim: 4,
            out_dim: 1,
            edge_dim: 0,
            node_level: false,
            vn_init: None,
            stages: vec![
                Stage::Linear {
                    w: wi.dense(4, 8),
                    act: Act::Relu,
                },
                Stage::SparseAggregate(Aggregate::GcnNorm),
                Stage::TakeAggregate,
                Stage::Readout(Readout::MaskedMeanPool),
                Stage::Linear {
                    w: wi.dense(8, 1),
                    act: Act::None,
                },
            ],
        }
    }

    #[test]
    fn clean_plan_passes_with_a_determinism_note() {
        let p = tiny_plan();
        let r = analyze(&p);
        assert!(r.ok(), "{:?}", r.findings);
        assert!(r.fusable);
        assert!(require_clean(&r).is_ok());
        assert!(r.has_code(Code::ReductionOrderNote));
        assert_eq!(r.stages.len(), p.stages.len());
        assert!(assert_fusable(&p).is_ok());
    }

    #[test]
    fn weight_coverage_flags_both_directions() {
        let p = tiny_plan();
        let carried = p.param_count();
        assert!(analyze_lowered(&p, carried).ok());
        let over = analyze_lowered(&p, carried + 8);
        assert!(over.has_code(Code::WeightStreamMismatch));
        assert!(over.findings.iter().any(|f| f.message.contains("unused")));
        let under = analyze_lowered(&p, carried - 1);
        assert!(under.has_code(Code::WeightStreamMismatch));
        assert!(under
            .findings
            .iter()
            .any(|f| f.message.contains("doubly-consumed")));
        assert!(require_clean(&over).is_err());
    }

    #[test]
    fn recovery_reports_multiple_independent_defects() {
        let mut p = tiny_plan();
        // Defect 1: head expects the wrong width.
        if let Stage::Linear { w, .. } = &mut p.stages[4] {
            w.fin = 5;
            w.w = vec![0.0; 5];
        }
        // Defect 2: a NaN weight in the embed layer.
        if let Stage::Linear { w, .. } = &mut p.stages[0] {
            w.w[0] = f32::NAN;
        }
        let r = analyze(&p);
        assert!(r.has_code(Code::StageWidthMismatch));
        assert!(r.has_code(Code::NonFiniteParam));
        assert!(r.count(Severity::Error) >= 2, "{:?}", r.findings);
    }

    #[test]
    fn unused_inputs_warn_without_failing_the_gate() {
        let mut p = tiny_plan();
        p.edge_dim = 3;
        p.vn_init = Some(vec![0.0; 8]);
        let r = analyze(&p);
        assert!(r.has_code(Code::UnusedEdgeInput));
        assert!(r.has_code(Code::UnusedVnState));
        assert!(r.ok(), "warnings must not reject: {:?}", r.findings);
    }

    #[test]
    fn json_report_round_trips() {
        let r = analyze(&tiny_plan());
        let v = Json::parse(&r.to_json().to_string_pretty()).unwrap();
        assert_eq!(v.get("model").unwrap().as_str().unwrap(), "tiny");
        assert!(v.get("ok").unwrap().as_bool().unwrap());
        assert!(v.get("fusable").unwrap().as_bool().unwrap());
        assert_eq!(v.get("stages").unwrap().as_arr().unwrap().len(), 5);
        let findings = v.get("findings").unwrap().as_arr().unwrap();
        assert_eq!(findings.len(), r.findings.len());
        for f in findings {
            assert!(f.get("code").unwrap().as_str().unwrap().starts_with("GN-"));
        }
        assert!(r.render_text().contains("PASS"));
    }

    #[test]
    fn analyzer_subsumes_validate_on_simple_mutations() {
        // Every summaries() rejection must map to at least one Error
        // finding (the full matrix lives in rust/tests/plan_lint.rs).
        let mutations: Vec<(&str, Box<dyn Fn(&mut ModelPlan)>)> = vec![
            ("drop take", Box::new(|p| drop(p.stages.remove(2)))),
            ("drop readout", Box::new(|p| drop(p.stages.remove(3)))),
            (
                "double aggregate",
                Box::new(|p| p.stages.insert(2, Stage::SparseAggregate(Aggregate::Sum))),
            ),
            (
                "post-readout node stage",
                Box::new(|p| p.stages.insert(4, Stage::L2Normalize)),
            ),
        ];
        for (name, mutate) in mutations {
            let mut p = tiny_plan();
            mutate(&mut p);
            assert!(p.validate().is_err(), "{name}: validate must reject");
            let r = analyze(&p);
            assert!(!r.ok(), "{name}: analyzer must also reject");
        }
    }
}

//! Diagnostic vocabulary of the plan analyzer: stable codes, severity
//! levels, and the [`Diagnostic`] record the passes emit.
//!
//! Codes are part of the tool contract — `gengnn lint-plan --json`
//! emits them verbatim, `python/tools/check_plan_schema.py` validates
//! their format, the mutation harness in `rust/tests/plan_lint.rs`
//! asserts one specific code per corruption class, and
//! `docs/STATIC_ANALYSIS.md` documents them. Renaming a code is a
//! breaking change to all four.

use std::fmt;

/// How bad a finding is. `Error` findings fail the lowering gate and
/// give `lint-plan` a nonzero exit; `Warning`/`Info` findings are
/// reported but do not reject the plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warning,
    Error,
}

impl Severity {
    /// Stable identifier used in the JSON findings report.
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// Every distinct defect class the analyzer can report. The letter
/// groups the pass that finds it: `P` plan metadata, `S` shape chain,
/// `D` register dataflow, `R` readout, `E` edge-input contract,
/// `V` virtual-node state, `W` weight audit, `F` fusion safety,
/// `I` informational notes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Code {
    /// `GN-P01` — degenerate plan metadata (zero `n_max`/`in_dim`/`out_dim`).
    DegeneratePlan,
    /// `GN-S01` — a stage's weight shape does not chain with the live width.
    StageWidthMismatch,
    /// `GN-S02` — the terminal width differs from the artifact `out_dim`.
    TerminalWidthMismatch,
    /// `GN-S03` — attention heads/logit vectors inconsistent with the width.
    AttentionShapeMismatch,
    /// `GN-S04` — virtual-node state or MLP widths inconsistent with `h`.
    VirtualNodeShapeMismatch,
    /// `GN-D01` — an aggregation would overwrite an unconsumed register.
    AggregateOverwrite,
    /// `GN-D02` — a combine stage reads the register before any write.
    CombineWithoutAggregate,
    /// `GN-D03` — the plan ends with an unconsumed aggregation register.
    DanglingAggregate,
    /// `GN-D04` — readout fires while an aggregation is still pending.
    ReadoutOverPendingAggregate,
    /// `GN-R01` — the plan never collapses to the output shape.
    MissingReadout,
    /// `GN-R02` — a non-head stage appears after the readout.
    StageAfterReadout,
    /// `GN-R03` — readout kind contradicts the plan's output level.
    ReadoutLevelMismatch,
    /// `GN-E01` — edge aggregation without (or mismatching) edge features.
    EdgeDataContract,
    /// `GN-E02` — declared edge features are never consumed.
    UnusedEdgeInput,
    /// `GN-V01` — a virtual-node stage with no `vn_init` state.
    MissingVnState,
    /// `GN-V02` — `vn_init` state that no stage ever touches.
    UnusedVnState,
    /// `GN-W01` — drawn weight scalars differ from the params the plan carries.
    WeightStreamMismatch,
    /// `GN-W02` — a parameter value is NaN or infinite.
    NonFiniteParam,
    /// `GN-W03` — a parameter tensor is malformed (zero dims / wrong length).
    MalformedParam,
    /// `GN-F01` — a stage carries no fusion-safety argument; the fused
    /// path must refuse this plan.
    FusionUnsafeStage,
    /// `GN-F02` — an order-sensitive f32 reduction whose fused
    /// evaluation order could diverge from per-request order.
    FusedOrderDivergence,
    /// `GN-I01` — note: order-sensitive reductions present, all walked
    /// in ascending node order on both execution paths.
    ReductionOrderNote,
}

impl Code {
    /// The stable wire identifier (`GN-<pass letter><2 digits>`).
    pub fn id(&self) -> &'static str {
        match self {
            Code::DegeneratePlan => "GN-P01",
            Code::StageWidthMismatch => "GN-S01",
            Code::TerminalWidthMismatch => "GN-S02",
            Code::AttentionShapeMismatch => "GN-S03",
            Code::VirtualNodeShapeMismatch => "GN-S04",
            Code::AggregateOverwrite => "GN-D01",
            Code::CombineWithoutAggregate => "GN-D02",
            Code::DanglingAggregate => "GN-D03",
            Code::ReadoutOverPendingAggregate => "GN-D04",
            Code::MissingReadout => "GN-R01",
            Code::StageAfterReadout => "GN-R02",
            Code::ReadoutLevelMismatch => "GN-R03",
            Code::EdgeDataContract => "GN-E01",
            Code::UnusedEdgeInput => "GN-E02",
            Code::MissingVnState => "GN-V01",
            Code::UnusedVnState => "GN-V02",
            Code::WeightStreamMismatch => "GN-W01",
            Code::NonFiniteParam => "GN-W02",
            Code::MalformedParam => "GN-W03",
            Code::FusionUnsafeStage => "GN-F01",
            Code::FusedOrderDivergence => "GN-F02",
            Code::ReductionOrderNote => "GN-I01",
        }
    }

    /// Default severity of this code. Individual findings never
    /// override this: one code, one severity, so downstream tooling
    /// can triage on the code alone.
    pub fn severity(&self) -> Severity {
        match self {
            Code::UnusedEdgeInput
            | Code::UnusedVnState
            | Code::FusionUnsafeStage
            | Code::FusedOrderDivergence => Severity::Warning,
            Code::ReductionOrderNote => Severity::Info,
            _ => Severity::Error,
        }
    }
}

/// One analyzer finding: a code, the stage it anchors to (or `None`
/// for plan-level findings), and a human-readable message.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub code: Code,
    pub stage: Option<usize>,
    pub message: String,
}

impl Diagnostic {
    pub fn plan(code: Code, message: impl Into<String>) -> Diagnostic {
        Diagnostic {
            code,
            stage: None,
            message: message.into(),
        }
    }

    pub fn at(code: Code, stage: usize, message: impl Into<String>) -> Diagnostic {
        Diagnostic {
            code,
            stage: Some(stage),
            message: message.into(),
        }
    }

    pub fn severity(&self) -> Severity {
        self.code.severity()
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.stage {
            Some(i) => write!(
                f,
                "{} [{}] stage {i}: {}",
                self.code.id(),
                self.severity().name(),
                self.message
            ),
            None => write!(
                f,
                "{} [{}] plan: {}",
                self.code.id(),
                self.severity().name(),
                self.message
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL: &[Code] = &[
        Code::DegeneratePlan,
        Code::StageWidthMismatch,
        Code::TerminalWidthMismatch,
        Code::AttentionShapeMismatch,
        Code::VirtualNodeShapeMismatch,
        Code::AggregateOverwrite,
        Code::CombineWithoutAggregate,
        Code::DanglingAggregate,
        Code::ReadoutOverPendingAggregate,
        Code::MissingReadout,
        Code::StageAfterReadout,
        Code::ReadoutLevelMismatch,
        Code::EdgeDataContract,
        Code::UnusedEdgeInput,
        Code::MissingVnState,
        Code::UnusedVnState,
        Code::WeightStreamMismatch,
        Code::NonFiniteParam,
        Code::MalformedParam,
        Code::FusionUnsafeStage,
        Code::FusedOrderDivergence,
        Code::ReductionOrderNote,
    ];

    #[test]
    fn code_ids_are_unique_and_well_formed() {
        let mut seen = std::collections::BTreeSet::new();
        for c in ALL {
            let id = c.id();
            assert!(seen.insert(id), "duplicate id {id}");
            let b = id.as_bytes();
            // GN-<letter><digit><digit>, the format the schema checker pins.
            assert_eq!(b.len(), 6, "{id}");
            assert_eq!(&id[..3], "GN-", "{id}");
            assert!(b[3].is_ascii_uppercase(), "{id}");
            assert!(b[4].is_ascii_digit() && b[5].is_ascii_digit(), "{id}");
        }
    }

    #[test]
    fn severity_orders_error_highest() {
        assert!(Severity::Error > Severity::Warning);
        assert!(Severity::Warning > Severity::Info);
        let d = Diagnostic::at(Code::StageWidthMismatch, 3, "w");
        assert_eq!(d.severity(), Severity::Error);
        assert!(d.to_string().contains("GN-S01"));
        assert!(d.to_string().contains("stage 3"));
    }
}

//! The shape/dataflow pass: an abstract interpreter over the stage
//! sequence that tracks the symbolic machine state — live width `h`,
//! pending aggregation register `m`, pooled flag — plus the plan-level
//! input contracts (edge features, virtual-node state, weight stream).
//!
//! Unlike `ModelPlan::summaries`, which bails on the first defect (the
//! right behavior for an execution gate), this pass **recovers**: a
//! width mismatch is recorded and the walk continues with the stage's
//! declared output width, so one lint run surfaces every independent
//! defect in a corrupted plan instead of the first. The analyzer is a
//! strict superset of `summaries`: every plan `summaries` rejects
//! yields at least one `Error` finding here (pinned by the mutation
//! harness in `rust/tests/plan_lint.rs`).

use crate::models::params::Dense;
use crate::models::plan::{Aggregate, ModelPlan, Readout, Stage};

use super::diag::{Code, Diagnostic};

/// Run the shape/dataflow pass. `drawn_params` is the number of
/// scalars the lowering drew from the seeded weight stream
/// ([`crate::models::params::WInit::drawn`]) when known; `None` skips
/// the weight-coverage check (plans assembled by hand in tests).
pub fn check(plan: &ModelPlan, drawn_params: Option<usize>) -> Vec<Diagnostic> {
    let mut d = Vec::new();
    check_metadata(plan, &mut d);
    check_stage_chain(plan, &mut d);
    check_input_consumption(plan, &mut d);
    check_weights(plan, &mut d);
    if let Some(drawn) = drawn_params {
        check_weight_coverage(plan, drawn, &mut d);
    }
    d
}

fn check_metadata(plan: &ModelPlan, d: &mut Vec<Diagnostic>) {
    if plan.n_max == 0 || plan.in_dim == 0 || plan.out_dim == 0 {
        d.push(Diagnostic::plan(
            Code::DegeneratePlan,
            format!(
                "degenerate dims (n_max {}, in_dim {}, out_dim {})",
                plan.n_max, plan.in_dim, plan.out_dim
            ),
        ));
    }
}

/// The abstract stage walk. Mirrors the interpreter's two-register
/// machine symbolically; recovery rule on a width defect is "trust the
/// stage's declared output shape and keep walking".
fn check_stage_chain(plan: &ModelPlan, d: &mut Vec<Diagnostic>) {
    let mut h = plan.in_dim;
    // Width of the pending aggregation register, if any write reached it.
    let mut m: Option<usize> = None;
    let mut pooled = false;
    for (i, stage) in plan.stages.iter().enumerate() {
        if pooled && !matches!(stage, Stage::Linear { .. } | Stage::Activation(_)) {
            d.push(Diagnostic::at(
                Code::StageAfterReadout,
                i,
                format!("{} after readout (only head linear/activation is legal)", stage.name()),
            ));
        }
        match stage {
            Stage::Linear { w, .. } => {
                if w.fin != h {
                    d.push(Diagnostic::at(
                        Code::StageWidthMismatch,
                        i,
                        format!("linear expects width {}, h is {h}", w.fin),
                    ));
                }
                h = w.fout;
            }
            Stage::SparseAggregate(a) => {
                if m.is_some() {
                    d.push(Diagnostic::at(
                        Code::AggregateOverwrite,
                        i,
                        "aggregation would overwrite an unconsumed aggregation register",
                    ));
                }
                if let Aggregate::EdgeReluSum { bond } = a {
                    if plan.edge_dim == 0 {
                        d.push(Diagnostic::at(
                            Code::EdgeDataContract,
                            i,
                            "edge aggregation in a plan that declares no edge features",
                        ));
                    } else if bond.fin != plan.edge_dim || bond.fout != h {
                        d.push(Diagnostic::at(
                            Code::EdgeDataContract,
                            i,
                            format!(
                                "bond {}x{} does not map edge_dim {} onto h({h})",
                                bond.fin, bond.fout, plan.edge_dim
                            ),
                        ));
                    }
                }
                m = Some(a.out_width(h));
            }
            Stage::TakeAggregate => match m.take() {
                Some(mw) => h = mw,
                None => d.push(no_pending(i, "take_aggregate")),
            },
            Stage::EpsCombine { .. } => match m.take() {
                Some(mw) if mw != h => d.push(Diagnostic::at(
                    Code::StageWidthMismatch,
                    i,
                    format!("eps_combine widths differ (m {mw} vs h {h})"),
                )),
                Some(_) => {}
                None => d.push(no_pending(i, "eps_combine")),
            },
            Stage::ResidualLinear { w, .. } => match m.take() {
                Some(mw) => {
                    if w.fin != mw || w.fout != h {
                        d.push(Diagnostic::at(
                            Code::StageWidthMismatch,
                            i,
                            format!(
                                "residual {}x{} does not map m({mw}) onto h({h})",
                                w.fin, w.fout
                            ),
                        ));
                    }
                }
                None => d.push(no_pending(i, "residual_linear")),
            },
            Stage::DualLinear { w_self, w_nbr } => {
                match m.take() {
                    Some(mw) => {
                        if w_self.fin != h || w_nbr.fin != mw || w_self.fout != w_nbr.fout {
                            d.push(Diagnostic::at(
                                Code::StageWidthMismatch,
                                i,
                                format!(
                                    "dual_linear self {}x{} / nbr {}x{} does not combine \
                                     h({h}) with m({mw})",
                                    w_self.fin, w_self.fout, w_nbr.fin, w_nbr.fout
                                ),
                            ));
                        }
                    }
                    None => d.push(no_pending(i, "dual_linear")),
                }
                h = w_self.fout;
            }
            Stage::EdgeAttention { heads, a_src, a_dst } => {
                if *heads == 0 || h % heads != 0 {
                    d.push(Diagnostic::at(
                        Code::AttentionShapeMismatch,
                        i,
                        format!("width {h} not divisible into {heads} heads"),
                    ));
                }
                if a_src.len() != h || a_dst.len() != h {
                    d.push(Diagnostic::at(
                        Code::AttentionShapeMismatch,
                        i,
                        format!(
                            "attention logit vectors ({}, {}) must both have width {h}",
                            a_src.len(),
                            a_dst.len()
                        ),
                    ));
                }
            }
            Stage::Activation(_) | Stage::L2Normalize => {}
            Stage::VirtualNodeAdd | Stage::VirtualNodeUpdate { .. } => {
                match plan.vn_init.as_ref() {
                    None => d.push(Diagnostic::at(
                        Code::MissingVnState,
                        i,
                        format!("{} in a plan with no vn_init state", stage.name()),
                    )),
                    Some(vn) if vn.len() != h => d.push(Diagnostic::at(
                        Code::VirtualNodeShapeMismatch,
                        i,
                        format!("vn state width {} vs h {h}", vn.len()),
                    )),
                    Some(_) => {}
                }
                if let Stage::VirtualNodeUpdate { w1, w2 } = stage {
                    if w1.fin != h || w2.fout != h || w1.fout != w2.fin {
                        d.push(Diagnostic::at(
                            Code::VirtualNodeShapeMismatch,
                            i,
                            format!(
                                "vn mlp {}x{} -> {}x{} must chain and map {h} -> {h}",
                                w1.fin, w1.fout, w2.fin, w2.fout
                            ),
                        ));
                    }
                }
            }
            Stage::Readout(r) => {
                if m.is_some() {
                    d.push(Diagnostic::at(
                        Code::ReadoutOverPendingAggregate,
                        i,
                        "readout with an unconsumed aggregation register",
                    ));
                    m = None;
                }
                if !pooled {
                    match r {
                        Readout::NodeHead if !plan.node_level => d.push(Diagnostic::at(
                            Code::ReadoutLevelMismatch,
                            i,
                            "node_head readout in a graph-level plan",
                        )),
                        Readout::MaskedMeanPool if plan.node_level => d.push(Diagnostic::at(
                            Code::ReadoutLevelMismatch,
                            i,
                            "pooled readout in a node-level plan",
                        )),
                        _ => {}
                    }
                }
                pooled = true;
            }
        }
    }
    if m.is_some() {
        d.push(Diagnostic::plan(
            Code::DanglingAggregate,
            "plan ends with an unconsumed aggregation register",
        ));
    }
    if !pooled {
        d.push(Diagnostic::plan(
            Code::MissingReadout,
            "plan never collapses to the output shape (no readout stage)",
        ));
    } else if h != plan.out_dim {
        d.push(Diagnostic::plan(
            Code::TerminalWidthMismatch,
            format!("plan ends at width {h}, artifact wants {}", plan.out_dim),
        ));
    }
}

/// Declared inputs that no stage reads are latent bugs in a lowering —
/// the dual of the read-before-write register checks above.
fn check_input_consumption(plan: &ModelPlan, d: &mut Vec<Diagnostic>) {
    let consumes_edges = plan
        .stages
        .iter()
        .any(|s| matches!(s, Stage::SparseAggregate(Aggregate::EdgeReluSum { .. })));
    if plan.edge_dim > 0 && !consumes_edges {
        d.push(Diagnostic::plan(
            Code::UnusedEdgeInput,
            format!("edge_dim {} declared but no stage consumes edge features", plan.edge_dim),
        ));
    }
    let touches_vn = plan
        .stages
        .iter()
        .any(|s| matches!(s, Stage::VirtualNodeAdd | Stage::VirtualNodeUpdate { .. }));
    if plan.vn_init.is_some() && !touches_vn {
        d.push(Diagnostic::plan(
            Code::UnusedVnState,
            "vn_init state present but no stage touches the virtual node",
        ));
    }
}

/// Parameter audit: every tensor well-formed and every value finite.
/// A NaN weight is legal f32 and would propagate silently through the
/// whole forward pass; it can only come from a corrupted lowering.
fn check_weights(plan: &ModelPlan, d: &mut Vec<Diagnostic>) {
    for (i, stage) in plan.stages.iter().enumerate() {
        match stage {
            Stage::Linear { w, .. } | Stage::ResidualLinear { w, .. } => {
                check_dense(i, "w", w, d);
            }
            Stage::SparseAggregate(Aggregate::EdgeReluSum { bond }) => {
                check_dense(i, "bond", bond, d);
            }
            Stage::SparseAggregate(_) => {}
            Stage::DualLinear { w_self, w_nbr } => {
                check_dense(i, "w_self", w_self, d);
                check_dense(i, "w_nbr", w_nbr, d);
            }
            Stage::EdgeAttention { a_src, a_dst, .. } => {
                check_finite(i, "a_src", a_src, d);
                check_finite(i, "a_dst", a_dst, d);
            }
            Stage::VirtualNodeUpdate { w1, w2 } => {
                check_dense(i, "w1", w1, d);
                check_dense(i, "w2", w2, d);
            }
            Stage::EpsCombine { eps } => {
                if !eps.is_finite() {
                    d.push(Diagnostic::at(
                        Code::NonFiniteParam,
                        i,
                        format!("eps is {eps}"),
                    ));
                }
            }
            Stage::TakeAggregate
            | Stage::Activation(_)
            | Stage::L2Normalize
            | Stage::VirtualNodeAdd
            | Stage::Readout(_) => {}
        }
    }
    if let Some(vn) = plan.vn_init.as_ref() {
        if vn.iter().any(|v| !v.is_finite()) {
            d.push(Diagnostic::plan(
                Code::NonFiniteParam,
                "vn_init contains a non-finite value",
            ));
        }
    }
}

fn check_dense(stage: usize, label: &str, w: &Dense, d: &mut Vec<Diagnostic>) {
    if w.fin == 0 || w.fout == 0 || w.w.len() != w.fin * w.fout || w.b.len() != w.fout {
        d.push(Diagnostic::at(
            Code::MalformedParam,
            stage,
            format!(
                "{label} declares {}x{} but carries {} weights / {} biases",
                w.fin,
                w.fout,
                w.w.len(),
                w.b.len()
            ),
        ));
        return;
    }
    check_finite(stage, label, &w.w, d);
    check_finite(stage, label, &w.b, d);
}

fn check_finite(stage: usize, label: &str, v: &[f32], d: &mut Vec<Diagnostic>) {
    if let Some(j) = v.iter().position(|x| !x.is_finite()) {
        d.push(Diagnostic::at(
            Code::NonFiniteParam,
            stage,
            format!("{label}[{j}] is {}", v[j]),
        ));
    }
}

/// Weight-stream coverage: the lowering drew `drawn` scalars from the
/// seeded stream; the plan carries `param_count()` of them. Any gap
/// means parameters were drawn and dropped (stream position silently
/// shifted — every later tensor is wrong vs the AOT artifacts) or a
/// tensor is consumed twice.
fn check_weight_coverage(plan: &ModelPlan, drawn: usize, d: &mut Vec<Diagnostic>) {
    let carried = plan.param_count();
    if drawn != carried {
        let what = if drawn > carried {
            "drawn but never carried by a stage (unused parameters)"
        } else {
            "carried by stages but never drawn (doubly-consumed parameters)"
        };
        d.push(Diagnostic::plan(
            Code::WeightStreamMismatch,
            format!(
                "weight stream drew {drawn} scalars, plan carries {carried}: \
                 {} scalars {what}",
                drawn.abs_diff(carried)
            ),
        ));
    }
}

fn no_pending(stage: usize, what: &str) -> Diagnostic {
    Diagnostic::at(
        Code::CombineWithoutAggregate,
        stage,
        format!("{what} reads the aggregation register before any aggregation wrote it"),
    )
}

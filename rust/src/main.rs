//! `gengnn` — command-line entrypoint for the GenGNN reproduction.
//!
//! Subcommands map one-to-one onto the paper's artifacts:
//!
//! ```text
//! gengnn serve          stream synthetic molecular graphs through the
//!                       serving stack (--lanes N parallel executor
//!                       lanes, --fuse N fused micro-batch size, 1 to
//!                       disable) and print latency + per-lane/fused
//!                       metrics; with --listen ADDR, expose the wire
//!                       protocol over TCP instead (--reactors N
//!                       event-loop threads, --duration S to exit);
//!                       --resident DATASET (cora/citeseer/pubmed)
//!                       additionally hosts a resident citation graph
//!                       serving v4 GRAPH_QUERY / GRAPH_MUTATE ops
//! gengnn ingress        front a replica pool of `gengnn serve`
//!                       backends behind one address: model-aware
//!                       routing from a declarative cluster spec
//!                       (`--spec cluster.toml`), LIST_MODELS health
//!                       probes with ejection/probation, a node-agent
//!                       reconciler restarting managed backends, and
//!                       connection drain on shutdown; --duration S to
//!                       exit, --listen ADDR overrides the spec
//! gengnn loadgen        open-loop load generator against a serving
//!                       front-end: --addr, --rps, --count, model mix,
//!                       --ttl-ms / --priority-mix QoS profile;
//!                       --scenario molecular:N,query:N,mutate:N mixes
//!                       resident traffic in (--query-hops/--query-fanout
//!                       /--resident-nodes shape it), --diurnal bends
//!                       the schedule along a sinusoidal rate curve;
//!                       reports p50/p95/p99 + throughput
//! gengnn deploy         drive the v3 control plane of a running
//!                       server: `deploy <model> [--digest D]` makes a
//!                       model live (digest pins the exact catalog
//!                       bytes), `--unload MODEL` retires one,
//!                       `--rollback N` restores version N's serving
//!                       set (0 = previous)
//! gengnn models         list a running server's catalog, live set,
//!                       and version history (--json for the raw
//!                       registry document)
//! gengnn infer          run one model on one generated graph
//! gengnn plan           dump the lowered stage IR of a manifest model
//!                       (stage names, shapes, parameter counts;
//!                       --json for the schema-checked dump)
//! gengnn lint-plan      run the static plan analyzer on one manifest
//!                       model (or --all): shape/dataflow findings,
//!                       fusion-safety facts, determinism audit;
//!                       --json for the schema-checked findings report;
//!                       nonzero exit on any error-level finding
//! gengnn simulate       cycle-level simulation of one model/graph
//! gengnn resources      Table 4 (+ --detailed component inventory)
//! gengnn report-fig7    Fig. 7  (MolHIV / MolPCBA latency bars)
//! gengnn report-fig8    Fig. 8  (large-graph DGN latency)
//! gengnn report-fig9    Fig. 9  (pipelining ablation, parts a/b/c)
//! gengnn report-table4  Table 4 (resource utilization)
//! gengnn report-table5  Table 5 (large-graph datasets + resources)
//! gengnn selftest       golden cross-check of every artifact
//! ```

use anyhow::{bail, Result};

use gengnn::coordinator::{Admission, AdmissionPolicy, BatchPolicy, Server, ServerConfig};
use gengnn::datagen::{molecular, CitationDataset, MolConfig};
use gengnn::models::ModelConfig;
use gengnn::net::{loadgen, LoadGenConfig, NetClient, NetServer, NetServerConfig};
use gengnn::report::{fig7, fig8, fig9, table4, table5};
use gengnn::resident::ResidentState;
use gengnn::runtime::{Artifacts, Engine, Golden};
use gengnn::sim::{Accelerator, PipelineMode};
use gengnn::util::cli::Args;
use gengnn::util::pool::{Channel, RecvTimeout};
use gengnn::util::rng::Rng;
use gengnn::util::stats::fmt_secs;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    let cmd = argv[0].clone();
    let rest = &argv[1..];
    if let Err(e) = dispatch(&cmd, rest) {
        eprintln!("gengnn {cmd}: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    eprintln!(
        "usage: gengnn <serve|ingress|loadgen|deploy|models|infer|plan|lint-plan|simulate|\
         resources|dse|report-fig7|report-fig8|report-fig9|report-table4|\
         report-table5|selftest> [--flags]"
    );
}

fn dispatch(cmd: &str, rest: &[String]) -> Result<()> {
    match cmd {
        "serve" => cmd_serve(Args::parse(rest, &["reject"])?),
        "ingress" => cmd_ingress(Args::parse(rest, &[])?),
        "loadgen" => cmd_loadgen(Args::parse(rest, &["diurnal"])?),
        "deploy" => cmd_deploy(Args::parse(rest, &[])?),
        "models" => cmd_models(Args::parse(rest, &["json"])?),
        "infer" => cmd_infer(Args::parse(rest, &[])?),
        "plan" => cmd_plan(Args::parse(rest, &["json"])?),
        "lint-plan" => cmd_lint_plan(Args::parse(rest, &["json", "all"])?),
        "simulate" => cmd_simulate(Args::parse(rest, &[])?),
        "resources" | "report-table4" => {
            cmd_table4(Args::parse(rest, &["detailed"])?)
        }
        "report-table5" => {
            println!("{}", table5::render());
            Ok(())
        }
        "report-fig7" => cmd_fig7(Args::parse(rest, &[])?),
        "report-fig8" => {
            let a = Args::parse(rest, &[])?;
            println!("{}", fig8::render(&fig8::compute(a.u64_or("seed", 2)?)));
            Ok(())
        }
        "report-fig9" => cmd_fig9(Args::parse(rest, &[])?),
        "dse" => cmd_dse(Args::parse(rest, &[])?),
        "selftest" => cmd_selftest(Args::parse(rest, &[])?),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        _ => bail!("unknown subcommand {cmd:?}"),
    }
}

fn cmd_serve(a: Args) -> Result<()> {
    let models = a.list_or("models", &["gcn", "gat", "dgn"]);
    let count = a.usize_or("count", 500)?;
    let seed = a.u64_or("seed", 7)?;
    let lanes = a.usize_or("lanes", 2)?;
    // Resident mode: host a citation-scale graph in-process and serve
    // k-hop `GRAPH_QUERY` extractions against it. The synthesized
    // model entry rides into the registry in-memory, never on disk.
    let resident = match a.str_opt("resident") {
        Some(name) => {
            let dataset = CitationDataset::parse(name)?;
            let arts = Artifacts::load(Artifacts::default_dir())?;
            // Any cataloged DGN entry works as the shape donor; prefer
            // the large-graph one when the manifest carries it.
            let base = arts.model("dgn_large").or_else(|_| arts.model("dgn"))?;
            eprintln!(
                "[serve] booting resident store from {} (seed {seed}) ...",
                dataset.name()
            );
            Some(std::sync::Arc::new(ResidentState::boot(dataset, seed, base)?))
        }
        None => None,
    };
    let mut builder = ServerConfig::builder()
        .models(models.iter().cloned())
        .prep_workers(a.usize_or("prep-workers", 2)?)
        .executor_lanes(lanes)
        .queue_capacity(a.usize_or("queue", 256)?)
        .admission(if a.has("reject") {
            AdmissionPolicy::Reject
        } else {
            AdmissionPolicy::Block
        })
        .batch(BatchPolicy {
            max_batch: a.usize_or("max-batch", 8)?,
            sticky: true,
        })
        // Fused micro-batching: lanes merge up to N same-model requests
        // into one block-diagonal interpreter pass (1 disables).
        .fuse_max_graphs(a.usize_or("fuse", 8)?);
    if let Some(rs) = &resident {
        builder = builder.synthetic_models(vec![rs.meta.clone()]);
    }
    let cfg = builder.build()?;
    // Wire-serving mode: expose the protocol over TCP instead of
    // streaming synthetic graphs in-process.
    if let Some(listen) = a.str_opt("listen") {
        let duration = a.u64_or("duration", 0)?;
        eprintln!("[serve] compiling {models:?} on {lanes} executor lane(s) ...");
        let net = NetServer::start(NetServerConfig {
            listen: listen.to_string(),
            reactors: a.usize_or("reactors", 2)?,
            server: cfg,
            resident,
        })?;
        eprintln!(
            "[serve] listening on {} ({}); drive it with `gengnn loadgen --addr {}`",
            net.local_addr(),
            if duration == 0 {
                "until killed".to_string()
            } else {
                format!("for {duration}s")
            },
            net.local_addr(),
        );
        if duration == 0 {
            loop {
                std::thread::sleep(std::time::Duration::from_secs(60));
                eprintln!("{}", net.metrics().render());
            }
        }
        std::thread::sleep(std::time::Duration::from_secs(duration));
        let metrics = net.shutdown();
        println!("{}", metrics.render());
        return Ok(());
    }
    if resident.is_some() {
        bail!("--resident requires --listen (resident mode is wire-serving only)");
    }

    eprintln!("[serve] compiling {models:?} on {lanes} executor lane(s) ...");
    let server = Server::start(cfg)?;
    let responses = server.responses();
    eprintln!("[serve] streaming {count} molecular graphs ...");

    // Admission-rejected requests never produce a response, so the
    // drain target is the *accepted* count — delivered once submission
    // finishes; until then the drainer polls.
    let target_ch: Channel<u64> = Channel::bounded(1);
    let target_rx = target_ch.clone();
    let drain = std::thread::spawn(move || {
        let mut ok = 0u64;
        let mut err = 0u64;
        let mut target: Option<u64> = None;
        loop {
            if target.is_none() {
                target = target_rx.try_recv();
            }
            if let Some(t) = target {
                if ok + err >= t {
                    break;
                }
            }
            match responses.recv_timeout(std::time::Duration::from_millis(10)) {
                RecvTimeout::Item(r) => {
                    if r.is_ok() {
                        ok += 1;
                    } else {
                        err += 1;
                    }
                }
                RecvTimeout::TimedOut => {}
                RecvTimeout::Closed => break,
            }
        }
        (ok, err)
    });

    let mut rng = Rng::new(seed);
    let t0 = std::time::Instant::now();
    let mut accepted = 0u64;
    for i in 0..count {
        let g = molecular::molecular_graph(&mut rng, &MolConfig::molhiv());
        let model = &models[i % models.len()];
        if server.submit(model, g).0 == Admission::Accepted {
            accepted += 1;
        }
    }
    let _ = target_ch.send(accepted);
    let (ok, err) = drain.join().unwrap();
    let wall = t0.elapsed().as_secs_f64();
    let metrics = server.shutdown();
    println!("{}", metrics.render());
    println!(
        "accepted {accepted}/{count}, ok {ok}, err {err}, wall {} ({:.0} graphs/s)",
        fmt_secs(wall),
        ok as f64 / wall
    );
    Ok(())
}

/// `gengnn ingress --spec cluster.toml` — the cluster tier's front
/// door. Loads the declarative fleet spec, sanity-checks its model
/// assignments against the artifacts catalog when one is present,
/// boots any ingress-managed backends, and proxies v1–v4 client
/// traffic with model-aware routing, health-probe ejection, and
/// reconciler-driven restarts (see `docs/CLUSTER.md`).
fn cmd_ingress(a: Args) -> Result<()> {
    use gengnn::ingress::{FaultPlan, Ingress, IngressConfig};
    let spec_path = match (a.positional.first(), a.str_opt("spec")) {
        (Some(p), _) => p.clone(),
        (None, Some(s)) => s.to_string(),
        (None, None) => bail!(
            "usage: gengnn ingress <cluster.toml> [--listen ADDR] [--duration S] \
             [--artifacts DIR]"
        ),
    };
    let mut spec = gengnn::ingress::ClusterSpec::load(std::path::Path::new(&spec_path))?;
    if let Some(listen) = a.str_opt("listen") {
        spec.listen = listen.to_string();
    }
    let duration = a.u64_or("duration", 0)?;
    // Catch model-name typos at boot when the catalog is on disk; a
    // spec-only host (no artifacts checkout) still runs — the backends
    // are the authority on what they actually serve.
    let artifacts_dir = a
        .str_opt("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Artifacts::default_dir);
    match gengnn::registry::catalog_model_names(&artifacts_dir) {
        Ok(catalog) => spec.validate_models(&catalog)?,
        Err(e) => eprintln!("[ingress] model assignments unchecked (no catalog: {e:#})"),
    }
    let fault = FaultPlan::from_env()?;
    if !fault.is_empty() {
        eprintln!("[ingress] FAULT INJECTION ACTIVE (GENGNN_FAULT_PLAN): {fault:?}");
    }
    let backends = spec.backends.len();
    let balance = spec.balance.as_str();
    let ingress = Ingress::start(IngressConfig { spec, fault })?;
    eprintln!(
        "[ingress] fronting {backends} backend(s) ({balance}) on {} ({}); drive it with \
         `gengnn loadgen --addr {}`",
        ingress.local_addr(),
        if duration == 0 {
            "until killed".to_string()
        } else {
            format!("for {duration}s")
        },
        ingress.local_addr(),
    );
    if duration == 0 {
        loop {
            std::thread::sleep(std::time::Duration::from_secs(60));
            eprintln!("{}", ingress.status_report());
        }
    }
    std::thread::sleep(std::time::Duration::from_secs(duration));
    let counters = ingress.shutdown();
    println!("{}", counters.render());
    Ok(())
}

fn cmd_loadgen(a: Args) -> Result<()> {
    let cfg = LoadGenConfig {
        addr: a.str_or("addr", "127.0.0.1:7447").to_string(),
        rps: a.f64_or("rps", 200.0)?,
        count: a.usize_or("count", 1000)?,
        connections: a.usize_or("connections", 2)?,
        models: a.list_or("models", &["gcn", "gat", "dgn"]),
        seed: a.u64_or("seed", 7)?,
        graph_pool: a.usize_or("graph-pool", 32)?,
        drain_timeout: std::time::Duration::from_secs(a.u64_or("drain-timeout", 30)?),
        // QoS profile: a nonzero TTL lets the server shed requests
        // whose deadline lapses (`Expired`); the mix assigns priority
        // classes round-robin, e.g. "high:1,normal:8,low:1".
        ttl_ms: a.u64_or("ttl-ms", 0)? as u32,
        priority_mix: a.str_or("priority-mix", "").to_string(),
        // Mixed-scenario traffic against a resident server, e.g.
        // `--scenario molecular:2,query:6,mutate:1`; `--diurnal` bends
        // the open-loop schedule along a sinusoidal rate curve.
        scenario: a.str_or("scenario", "").to_string(),
        diurnal: a.has("diurnal"),
        query_hops: a.u64_or("query-hops", 2)? as u8,
        query_fanout: a.u64_or("query-fanout", 0)? as u16,
        resident_nodes: a.u64_or("resident-nodes", 2708)? as u32,
    };
    eprintln!(
        "[loadgen] {} requests @ {} rps over {} connection(s) → {}",
        cfg.count, cfg.rps, cfg.connections, cfg.addr
    );
    let report = loadgen::run(&cfg)?;
    print!("{}", report.render());
    if !report.reconciles() {
        bail!(
            "accounting mismatch: {} submitted vs {} completed + {} rejected + {} failed + {} lost",
            report.submitted,
            report.completed,
            report.rejected,
            report.failed,
            report.lost
        );
    }
    // Export only after reconciliation: a broken run must not leave a
    // schema-valid "measured" point on the perf trajectory.
    if let Some(path) = std::env::var_os("GENGNN_BENCH_JSON") {
        let json = gengnn::util::bench::results_to_json(
            "loadgen",
            &report.to_bench_results(),
        );
        std::fs::write(&path, json)?;
        eprintln!("[loadgen] wrote bench snapshot to {path:?}");
    }
    Ok(())
}

/// `gengnn deploy` — the operator's side of the v3 control plane:
/// `deploy <model> [--digest D]` loads a model into the live serving
/// set (the server byte-verifies blobs and re-runs the plan analyzer
/// before the cutover; a pinned digest additionally insists on the
/// exact catalog bytes the operator audited), `--unload MODEL` retires
/// one, `--rollback N` restores version N's serving set (0 = the
/// previous set). Exits nonzero on a rejected op, with the server's
/// reason on stderr.
fn cmd_deploy(a: Args) -> Result<()> {
    let addr = a.str_or("addr", "127.0.0.1:7447").to_string();
    let client = NetClient::connect(&addr, 1)?;
    let resp = if let Some(v) = a.str_opt("rollback") {
        let version: u64 = v
            .parse()
            .map_err(|_| anyhow::anyhow!("--rollback takes a registry version, got {v:?}"))?;
        client.rollback(version)?
    } else if let Some(model) = a.str_opt("unload") {
        client.undeploy(model)?
    } else {
        let model = match (a.positional.first(), a.str_opt("model")) {
            (Some(p), _) => p.clone(),
            (None, Some(m)) => m.to_string(),
            (None, None) => bail!(
                "usage: gengnn deploy <model> [--digest D] | --unload MODEL | --rollback N \
                 [--addr HOST:PORT]"
            ),
        };
        client.deploy(&model, a.str_opt("digest"))?
    };
    if resp.is_ok() {
        println!(
            "{} ok: registry at version {}{}",
            resp.op.as_str(),
            resp.version,
            if resp.message.is_empty() {
                String::new()
            } else {
                format!(" ({})", resp.message)
            }
        );
        Ok(())
    } else {
        bail!("{} rejected: {}", resp.op.as_str(), resp.message);
    }
}

/// `gengnn models` — ask a running server for its catalog, live
/// serving set, and version history (`LIST_MODELS`). `--json` prints
/// the raw registry document for scripting.
fn cmd_models(a: Args) -> Result<()> {
    let addr = a.str_or("addr", "127.0.0.1:7447").to_string();
    let client = NetClient::connect(&addr, 1)?;
    let resp = client.models()?;
    if !resp.is_ok() {
        bail!("LIST_MODELS rejected: {}", resp.message);
    }
    if a.has("json") {
        println!("{}", resp.message);
        return Ok(());
    }
    let doc = gengnn::util::json::Json::parse(&resp.message)
        .map_err(|e| anyhow::anyhow!("unparseable registry document: {e}"))?;
    println!("registry version {}", resp.version);
    if let Ok(models) = doc.get("models").and_then(|m| m.as_arr()) {
        for m in models {
            let name = m.get("name").and_then(|v| v.as_str()).unwrap_or("?");
            let live = m.get("live").and_then(|v| v.as_bool()).unwrap_or(false);
            let digest = m.get("digest").and_then(|v| v.as_str()).unwrap_or("");
            println!(
                "  {name:<10} {} {}",
                if live { "live  " } else { "staged" },
                &digest[..digest.len().min(12)]
            );
        }
    }
    Ok(())
}

fn cmd_infer(a: Args) -> Result<()> {
    let model = a.str_or("model", "gcn").to_string();
    let seed = a.u64_or("seed", 1)?;
    let artifacts = Artifacts::load(a.str_or(
        "artifacts",
        Artifacts::default_dir().to_str().unwrap(),
    ))?;
    let mut engine = Engine::load(&artifacts, &[&model])?;
    let g = molecular::molecular_graph(&mut Rng::new(seed), &MolConfig::molhiv());
    let t0 = std::time::Instant::now();
    let out = engine.infer(&model, &g)?;
    println!(
        "model={model} n={} e={} out={:?} ({})",
        g.n,
        g.num_edges(),
        &out[..out.len().min(8)],
        fmt_secs(t0.elapsed().as_secs_f64())
    );
    Ok(())
}

/// `gengnn plan <model> [--json]` — dump the lowered stage IR for any
/// manifest model: the ordered component sequence the generic sparse
/// interpreter executes, with per-stage shapes and parameter counts.
fn cmd_plan(a: Args) -> Result<()> {
    let model = match (a.positional.first(), a.str_opt("model")) {
        (Some(p), _) => p.clone(),
        (None, Some(m)) => m.to_string(),
        (None, None) => bail!("usage: gengnn plan <model> [--json] [--artifacts DIR]"),
    };
    let artifacts = Artifacts::load(a.str_or(
        "artifacts",
        Artifacts::default_dir().to_str().unwrap(),
    ))?;
    let meta = artifacts.model(&model)?;
    let plan = gengnn::models::lower(meta, artifacts.weight_seed)?;
    if a.has("json") {
        println!("{}", plan.to_json()?.to_string_pretty());
    } else {
        print!("{}", plan.render_text()?);
    }
    Ok(())
}

/// `gengnn lint-plan <model|--all> [--json]` — run the static plan
/// analyzer (`gengnn::analysis`) on lowered manifest models and print
/// the structured findings report: shape/dataflow diagnostics, the
/// per-stage fusion-safety facts, and the determinism audit. Exits
/// nonzero if any model has an error-level finding, which makes the
/// `make lint-plans` CI step a hard gate.
fn cmd_lint_plan(a: Args) -> Result<()> {
    use gengnn::analysis::Severity;
    use gengnn::util::json::{self, Json};
    let artifacts = Artifacts::load(a.str_or(
        "artifacts",
        Artifacts::default_dir().to_str().unwrap(),
    ))?;
    let models: Vec<String> = if a.has("all") {
        artifacts.model_names().iter().map(|s| s.to_string()).collect()
    } else {
        match (a.positional.first(), a.str_opt("model")) {
            (Some(p), _) => vec![p.clone()],
            (None, Some(m)) => vec![m.to_string()],
            (None, None) => {
                bail!("usage: gengnn lint-plan <model|--all> [--json] [--artifacts DIR]")
            }
        }
    };
    let mut reports = Vec::new();
    let mut errors = 0usize;
    for name in &models {
        let meta = artifacts.model(name)?;
        let (_plan, report) =
            gengnn::models::lower_with_report(meta, artifacts.weight_seed)?;
        errors += report.count(Severity::Error);
        reports.push(report);
    }
    if a.has("json") {
        if reports.len() == 1 && !a.has("all") {
            println!("{}", reports[0].to_json().to_string_pretty());
        } else {
            let arr: Vec<Json> = reports.iter().map(|r| r.to_json()).collect();
            let wrapper = json::obj(vec![
                ("ok", Json::Bool(errors == 0)),
                ("models", json::num(reports.len() as f64)),
                ("reports", Json::Arr(arr)),
            ]);
            println!("{}", wrapper.to_string_pretty());
        }
    } else {
        for r in &reports {
            print!("{}", r.render_text());
        }
    }
    if errors > 0 {
        bail!(
            "plan analysis found {errors} error(s) across {} model(s)",
            reports.len()
        );
    }
    Ok(())
}

fn cmd_simulate(a: Args) -> Result<()> {
    let model = ModelConfig::by_name(a.str_or("model", "gin"))?;
    let seed = a.u64_or("seed", 1)?;
    let count = a.usize_or("count", 100)?;
    let graphs = molecular::dataset(seed, count, &MolConfig::molhiv());
    println!(
        "{:<14} {:>12} {:>14}",
        "pipeline", "avg cycles", "avg latency"
    );
    for mode in PipelineMode::all() {
        let acc = Accelerator::new(model.clone(), mode);
        let mean_cycles: f64 = graphs
            .iter()
            .map(|g| acc.simulate(g).cycles as f64)
            .sum::<f64>()
            / graphs.len() as f64;
        let mean_secs = acc.mean_latency(&graphs);
        println!(
            "{:<14} {:>12.0} {:>14}",
            mode.as_str(),
            mean_cycles,
            fmt_secs(mean_secs)
        );
    }
    Ok(())
}

fn cmd_table4(a: Args) -> Result<()> {
    if a.has("detailed") {
        println!("{}", table4::render_detailed());
    } else {
        println!("{}", table4::render());
    }
    Ok(())
}

fn cmd_fig7(a: Args) -> Result<()> {
    let count = a.usize_or("count", 300)?;
    let seed = a.u64_or("seed", 1)?;
    for ds in [fig7::MolDataset::MolHiv, fig7::MolDataset::MolPcba] {
        let rows = fig7::compute(ds, count, seed);
        println!("{}", fig7::render(ds, &rows));
    }
    Ok(())
}

fn cmd_fig9(a: Args) -> Result<()> {
    let part = a.str_or("part", "all").to_string();
    let count = a.usize_or("count", 200)?;
    let seed = a.u64_or("seed", 3)?;
    if part == "a" || part == "all" {
        println!("{}", fig9::render_grid(&fig9::default_grid(count, seed)));
    }
    if part == "b" || part == "all" {
        let s = fig9::molhiv(count, seed, false);
        print!("{}", fig9::render_mol("b: MolHIV, GIN", &s));
    }
    if part == "c" || part == "all" {
        let s = fig9::molhiv(count, seed, true);
        print!("{}", fig9::render_mol("c: MolHIV, GIN+VN", &s));
    }
    Ok(())
}

fn cmd_dse(a: Args) -> Result<()> {
    let model = ModelConfig::by_name(a.str_or("model", "gin"))?;
    let count = a.usize_or("count", 80)?;
    let seed = a.u64_or("seed", 3)?;
    let graphs = molecular::dataset(seed, count, &MolConfig::molhiv());
    let evals = gengnn::dse::sweep(&model, &graphs, &gengnn::dse::default_space());
    let front = gengnn::dse::pareto(&evals);
    println!(
        "swept {} design points over {count} graphs; {} on the frontier\n",
        evals.len(),
        front.len()
    );
    println!("{}", gengnn::dse::render(&model, &front));
    Ok(())
}

fn cmd_selftest(a: Args) -> Result<()> {
    let artifacts = Artifacts::load(a.str_or(
        "artifacts",
        Artifacts::default_dir().to_str().unwrap(),
    ))?;
    let mut failures = 0;
    for meta in artifacts.models.clone() {
        let mut engine = Engine::load(&artifacts, &[&meta.name])?;
        let tol = engine.golden_tolerance();
        let golden = Golden::load(&meta)?;
        let t0 = std::time::Instant::now();
        let out = engine.infer_with_eig(&meta.name, &golden.graph, golden.eig.as_deref())?;
        let ok = out.len() == golden.output.len()
            && out
                .iter()
                .zip(&golden.output)
                .all(|(x, y)| (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())));
        println!(
            "{:<10} {} ({} outputs, {})",
            meta.name,
            if ok { "OK" } else { "MISMATCH" },
            out.len(),
            fmt_secs(t0.elapsed().as_secs_f64())
        );
        if !ok {
            failures += 1;
        }
    }
    if failures > 0 {
        bail!("{failures} golden mismatches");
    }
    println!("all artifacts match their goldens");
    Ok(())
}

//! Design-space exploration (paper §6: "Future work includes design
//! automation [and] design space exploration for GenGNN").
//!
//! The cycle-level simulator prices a candidate microarchitecture's
//! *latency* on a workload; the HLS resource model prices its *area*.
//! DSE sweeps the HLS design knobs — MLP-PE lane widths, MP-PE message
//! lanes, FIFO depth — and returns the latency/utilization Pareto
//! frontier for a model + workload, i.e. the automation loop a GenGNN
//! user would run before synthesis.

use crate::graph::CooGraph;
use crate::models::ModelConfig;
use crate::resources::hls::{estimate_scaled, Resources, U50};
use crate::sim::cycles::CostParams;
use crate::sim::{Accelerator, PipelineMode};

/// One candidate configuration of the design knobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DesignPoint {
    pub p_in: usize,
    pub p_out: usize,
    pub p_msg: usize,
    pub fifo_depth: usize,
}

impl DesignPoint {
    pub fn params(&self) -> CostParams {
        CostParams {
            p_in: self.p_in,
            p_out: self.p_out,
            p_msg: self.p_msg,
            fifo_depth: self.fifo_depth,
            ..CostParams::default()
        }
    }
}

/// A priced candidate.
#[derive(Clone, Debug)]
pub struct Evaluated {
    pub point: DesignPoint,
    /// Mean per-graph latency on the workload, seconds at 300 MHz.
    pub latency: f64,
    pub resources: Resources,
    /// Worst per-column device utilization on the U50.
    pub utilization: f64,
    /// Candidates exceeding the device are kept but flagged.
    pub fits: bool,
}

/// The default sweep grid (powers of two around the paper's design).
pub fn default_space() -> Vec<DesignPoint> {
    let mut pts = Vec::new();
    for &p_in in &[4usize, 8, 16, 32] {
        for &p_out in &[4usize, 8, 16, 32] {
            for &p_msg in &[1usize, 2, 4, 8] {
                for &fifo_depth in &[2usize, 10, 32] {
                    pts.push(DesignPoint {
                        p_in,
                        p_out,
                        p_msg,
                        fifo_depth,
                    });
                }
            }
        }
    }
    pts
}

/// Evaluate every candidate on `graphs` for `model`.
pub fn sweep(model: &ModelConfig, graphs: &[CooGraph], points: &[DesignPoint]) -> Vec<Evaluated> {
    points
        .iter()
        .map(|&point| {
            let mut acc = Accelerator::new(model.clone(), PipelineMode::Streaming);
            acc.params = point.params();
            let latency = acc.mean_latency(graphs);
            let resources = estimate_scaled(model, &point.params())
                .map(|e| e.total)
                .unwrap_or_default();
            let utilization = resources.max_utilization(&U50);
            Evaluated {
                point,
                latency,
                resources,
                utilization,
                fits: utilization <= 1.0,
            }
        })
        .collect()
}

/// Keep the (latency, utilization) Pareto-optimal candidates among
/// those that fit, sorted by latency.
pub fn pareto(evals: &[Evaluated]) -> Vec<Evaluated> {
    let mut fitting: Vec<&Evaluated> = evals.iter().filter(|e| e.fits).collect();
    fitting.sort_by(|a, b| a.latency.total_cmp(&b.latency));
    let mut front: Vec<Evaluated> = Vec::new();
    let mut best_util = f64::INFINITY;
    for e in fitting {
        if e.utilization < best_util - 1e-12 {
            front.push(e.clone());
            best_util = e.utilization;
        }
    }
    front
}

/// Render the frontier as a report table.
pub fn render(model: &ModelConfig, front: &[Evaluated]) -> String {
    let mut out = format!(
        "DSE Pareto frontier for {} (streaming pipeline, U50 budget)\n{:>5} {:>5} {:>5} {:>5} {:>12} {:>6} {:>6} {:>8}\n",
        model.name, "p_in", "p_out", "p_msg", "fifo", "latency", "DSP", "BRAM", "util"
    );
    for e in front {
        out.push_str(&format!(
            "{:>5} {:>5} {:>5} {:>5} {:>11.1}µs {:>6} {:>6} {:>7.1}%\n",
            e.point.p_in,
            e.point.p_out,
            e.point.p_msg,
            e.point.fifo_depth,
            e.latency * 1e6,
            e.resources.dsp,
            e.resources.bram,
            e.utilization * 100.0
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{molecular, MolConfig};

    fn workload() -> Vec<CooGraph> {
        molecular::dataset(3, 40, &MolConfig::molhiv())
    }

    #[test]
    fn wider_lanes_are_faster_but_bigger() {
        let gin = ModelConfig::by_name("gin").unwrap();
        let graphs = workload();
        let narrow = DesignPoint {
            p_in: 4,
            p_out: 4,
            p_msg: 2,
            fifo_depth: 10,
        };
        let wide = DesignPoint {
            p_in: 32,
            p_out: 32,
            p_msg: 8,
            fifo_depth: 10,
        };
        let evals = sweep(&gin, &graphs, &[narrow, wide]);
        assert!(evals[1].latency < evals[0].latency);
        assert!(evals[1].resources.dsp > evals[0].resources.dsp);
    }

    #[test]
    fn pareto_front_is_monotone() {
        let gin = ModelConfig::by_name("gin").unwrap();
        let graphs = workload();
        let evals = sweep(&gin, &graphs, &default_space());
        let front = pareto(&evals);
        assert!(!front.is_empty());
        // Sorted by latency ascending -> utilization strictly descending.
        for w in front.windows(2) {
            assert!(w[0].latency <= w[1].latency);
            assert!(w[0].utilization > w[1].utilization);
        }
        // Every front point must dominate or tie any non-front point in
        // at least one dimension.
        for e in &evals {
            if !e.fits {
                continue;
            }
            for f in &front {
                assert!(
                    f.latency <= e.latency + 1e-12 || f.utilization <= e.utilization + 1e-12,
                    "front point dominated"
                );
            }
        }
    }

    #[test]
    fn fit_boundary_is_meaningful_for_gcn() {
        // GCN's fabric-bound MACs blow past the U50's FF budget at wide
        // lane configs — DSE must find both sides of the boundary.
        let gcn = ModelConfig::by_name("gcn").unwrap();
        let graphs = workload();
        let evals = sweep(&gcn, &graphs, &default_space());
        let narrow_fit = evals
            .iter()
            .filter(|e| e.point.p_in * e.point.p_out <= 64)
            .all(|e| e.fits);
        assert!(narrow_fit, "baseline-width designs must fit the U50");
        assert!(
            evals.iter().any(|e| !e.fits),
            "the sweep should reach configs that exceed the device"
        );
        assert!(!pareto(&evals).is_empty());
    }

    #[test]
    fn render_mentions_knobs() {
        let gin = ModelConfig::by_name("gin").unwrap();
        let graphs = workload();
        let front = pareto(&sweep(&gin, &graphs, &default_space()[..8]));
        let s = render(&gin, &front);
        assert!(s.contains("Pareto"));
        assert!(s.contains("p_msg"));
    }
}

//! The resident graph store: one server-hosted citation-scale graph
//! behind `Arc`-swapped immutable snapshots.
//!
//! Mirrors the PR-8 registry's publication discipline exactly: writers
//! serialize on a mutation lock, build a **new** [`GraphSnapshot`] off
//! to the side, and publish it with a single `RwLock` write — readers
//! clone an `Arc` and keep computing against the snapshot they
//! resolved, however long their query takes. No reader ever observes a
//! half-applied mutation batch, and the monotone version counter is
//! the cutover observable (wire `GRAPH_QUERY` responses echo it).
//!
//! The graph itself is **undirected** — the convention of the citation
//! datasets, whose COO form mirrors every edge ([`CooGraph`]'s
//! `from_undirected`). The snapshot therefore stores each edge once in
//! canonical `(min, max)` form and materializes the mirrored directed
//! view on demand ([`GraphSnapshot::to_coo`]); adjacency rows are kept
//! sorted ascending because that is the accumulation order the
//! stage-IR interpreter's bit-exactness contract rests on
//! (`graph::nbr`).

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use anyhow::{bail, Result};

use crate::graph::{fiedler_vector_csr, CooGraph, Csr};

/// Iteration budget of the snapshot eigensolve — the same budget the
/// coordinator's prep workers use, so a query-attached eigenvector is
/// bit-identical to what the prep stage would have computed.
pub const EIG_MAX_ITER: usize = 400;
/// Convergence tolerance matching the prep workers' eigensolve.
pub const EIG_TOL: f64 = 1e-9;

/// One immutable published state of the resident graph.
#[derive(Debug)]
pub struct GraphSnapshot {
    /// Monotone publication counter (seed snapshot = 1).
    pub version: u64,
    n: usize,
    f: usize,
    /// Undirected edge set, canonical `(u, v)` with `u < v`.
    edges: BTreeSet<(u32, u32)>,
    /// Row-major `[n, f]` node features, shared across snapshots that
    /// did not touch them (edge mutations clone the `Arc`, not the
    /// buffer).
    features: Arc<Vec<f32>>,
    /// Per-node sorted ascending neighbor lists (undirected, so
    /// in-neighbors == out-neighbors == neighbors).
    nbrs: Vec<Vec<u32>>,
    /// Full-graph Fiedler vector, solved lazily once per snapshot and
    /// shared by every query that resolves this snapshot.
    eig: OnceLock<Arc<Vec<f32>>>,
}

impl GraphSnapshot {
    /// Build a snapshot from a directed COO graph whose edges are
    /// mirrored undirected pairs (the citation generator's output).
    /// Self-loops are rejected: the resident store's mutation ops
    /// forbid them, so the seed must be loop-free too.
    pub fn from_coo(version: u64, g: &CooGraph) -> Result<GraphSnapshot> {
        let mut edges = BTreeSet::new();
        for &(u, v) in &g.edges {
            if u == v {
                bail!("resident seed graph has self-loop at node {u}");
            }
            if u as usize >= g.n || v as usize >= g.n {
                bail!("resident seed edge ({u},{v}) out of range");
            }
            edges.insert((u.min(v), u.max(v)));
        }
        Ok(Self::assemble(
            version,
            g.n,
            g.f_node,
            edges,
            Arc::new(g.node_feat.clone()),
        ))
    }

    fn assemble(
        version: u64,
        n: usize,
        f: usize,
        edges: BTreeSet<(u32, u32)>,
        features: Arc<Vec<f32>>,
    ) -> GraphSnapshot {
        let mut nbrs: Vec<Vec<u32>> = vec![Vec::new(); n];
        // BTreeSet iteration is ascending (u, v): pushing v onto row u
        // keeps row u sorted; row v gets u in ascending-u order too.
        for &(u, v) in &edges {
            nbrs[u as usize].push(v);
            nbrs[v as usize].push(u);
        }
        for row in &mut nbrs {
            row.sort_unstable();
        }
        GraphSnapshot {
            version,
            n,
            f,
            edges,
            features,
            nbrs,
            eig: OnceLock::new(),
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Node feature width.
    pub fn f(&self) -> usize {
        self.f
    }

    /// Undirected edge count (directed COO count is twice this).
    pub fn num_undirected(&self) -> usize {
        self.edges.len()
    }

    pub fn has_edge(&self, u: u32, v: u32) -> bool {
        self.edges.contains(&(u.min(v), u.max(v)))
    }

    /// Sorted ascending neighbors of `v`.
    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.nbrs[v]
    }

    pub fn feature_row(&self, v: usize) -> &[f32] {
        &self.features[v * self.f..(v + 1) * self.f]
    }

    /// The full directed COO view (each undirected edge mirrored, set
    /// order) with the snapshot's features — what the full-graph
    /// reference forward ingests.
    pub fn to_coo(&self) -> CooGraph {
        let mut directed = Vec::with_capacity(self.edges.len() * 2);
        for &(u, v) in &self.edges {
            directed.push((u, v));
            directed.push((v, u));
        }
        CooGraph {
            n: self.n,
            edges: directed,
            node_feat: self.features.as_ref().clone(),
            f_node: self.f,
            edge_feat: Vec::new(),
            f_edge: 0,
        }
    }

    /// The snapshot's full-graph Fiedler vector (length `n`), solved
    /// on first use with the prep workers' iteration budget and cached
    /// for the snapshot's lifetime. Every query against this snapshot
    /// shares the same vector — the substrate of the k-hop
    /// bit-exactness contract (a fresh per-subgraph eigensolve would
    /// produce a *different* directional field than the full graph).
    pub fn eig(&self) -> &Arc<Vec<f32>> {
        self.eig.get_or_init(|| {
            // Feature-free shadow graph: the CSR conversion reads only
            // `n` and `edges`, so skip cloning the feature matrix.
            let mut directed = Vec::with_capacity(self.edges.len() * 2);
            for &(u, v) in &self.edges {
                directed.push((u, v));
                directed.push((v, u));
            }
            let shadow = CooGraph {
                n: self.n,
                edges: directed,
                node_feat: Vec::new(),
                f_node: 0,
                edge_feat: Vec::new(),
                f_edge: 0,
            };
            let r = fiedler_vector_csr(&Csr::from_coo(&shadow), EIG_MAX_ITER, EIG_TOL);
            Arc::new(r.vector)
        })
    }
}

/// One live graph mutation.
#[derive(Clone, Debug, PartialEq)]
pub enum MutateOp {
    /// Insert undirected edge {u, v}. Rejected: self-loop,
    /// out-of-range endpoint, edge already present.
    AddEdge(u32, u32),
    /// Remove undirected edge {u, v}. Rejected: edge not present.
    RemoveEdge(u32, u32),
    /// Append one node carrying these features (len must equal the
    /// snapshot's feature width).
    AddNode(Vec<f32>),
}

/// What one mutation batch did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MutateOutcome {
    /// Ops applied into the published snapshot.
    pub applied: u32,
    /// Ops rejected (per-op validation); the rest of the batch still
    /// applies.
    pub rejected: u32,
    /// Version of the snapshot holding the batch's effects (unchanged
    /// when every op was rejected — nothing was published).
    pub version: u64,
}

/// The mutable holder: a mutation lock serializing writers and an
/// `RwLock<Arc<_>>` publishing immutable snapshots to readers.
pub struct ResidentStore {
    /// Serializes mutation batches (the `RwLock` write is held only
    /// for the pointer swap).
    mutate: Mutex<()>,
    live: RwLock<Arc<GraphSnapshot>>,
    version: AtomicU64,
}

impl ResidentStore {
    /// Boot the store from a seed graph (version 1).
    pub fn new(seed: &CooGraph) -> Result<ResidentStore> {
        let snap = Arc::new(GraphSnapshot::from_coo(1, seed)?);
        Ok(ResidentStore {
            mutate: Mutex::new(()),
            live: RwLock::new(snap),
            version: AtomicU64::new(1),
        })
    }

    /// Resolve the current snapshot. The caller keeps computing
    /// against it even if mutations publish newer versions meanwhile.
    pub fn snapshot(&self) -> Arc<GraphSnapshot> {
        Arc::clone(&crate::util::sync::read(&self.live))
    }

    /// Lock-free read of the latest published version.
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Apply one mutation batch copy-on-write: validate each op
    /// against the batch's evolving state, build a fresh snapshot, and
    /// publish it in one swap. Per-op rejections do not abort the
    /// batch; a batch whose every op is rejected publishes nothing.
    pub fn apply(&self, ops: &[MutateOp]) -> MutateOutcome {
        let _guard = crate::util::sync::lock(&self.mutate);
        let cur = self.snapshot();
        let mut edges = cur.edges.clone();
        let mut n = cur.n;
        let mut features: Option<Vec<f32>> = None; // cloned only if AddNode lands
        let mut applied = 0u32;
        let mut rejected = 0u32;
        for op in ops {
            let ok = match op {
                MutateOp::AddEdge(u, v) => {
                    let (u, v) = (*u, *v);
                    u != v
                        && (u as usize) < n
                        && (v as usize) < n
                        && edges.insert((u.min(v), u.max(v)))
                }
                MutateOp::RemoveEdge(u, v) => {
                    let (u, v) = (*u, *v);
                    edges.remove(&(u.min(v), u.max(v)))
                }
                MutateOp::AddNode(feat) => {
                    if feat.len() == cur.f && cur.f > 0 {
                        features
                            .get_or_insert_with(|| cur.features.as_ref().clone())
                            .extend_from_slice(feat);
                        n += 1;
                        true
                    } else {
                        false
                    }
                }
            };
            if ok {
                applied += 1;
            } else {
                rejected += 1;
            }
        }
        if applied == 0 {
            return MutateOutcome {
                applied,
                rejected,
                version: cur.version,
            };
        }
        let features = features.map(Arc::new).unwrap_or_else(|| Arc::clone(&cur.features));
        let next = Arc::new(GraphSnapshot::assemble(
            cur.version + 1,
            n,
            cur.f,
            edges,
            features,
        ));
        let version = next.version;
        *crate::util::sync::write(&self.live) = next;
        self.version.store(version, Ordering::Release);
        MutateOutcome {
            applied,
            rejected,
            version,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seed_graph() -> CooGraph {
        // 0-1-2-3 path plus 0-3, features = node id per column.
        CooGraph::from_undirected(
            4,
            &[(0, 1), (1, 2), (2, 3), (0, 3)],
            (0..4 * 2).map(|i| i as f32).collect(),
            2,
            &[],
            0,
        )
        .unwrap()
    }

    #[test]
    fn snapshot_round_trips_the_seed() {
        let store = ResidentStore::new(&seed_graph()).unwrap();
        let s = store.snapshot();
        assert_eq!(s.version, 1);
        assert_eq!(s.n(), 4);
        assert_eq!(s.num_undirected(), 4);
        assert_eq!(s.neighbors(0), &[1, 3]);
        assert_eq!(s.neighbors(2), &[1, 3]);
        assert!(s.has_edge(3, 0) && !s.has_edge(0, 2));
        let coo = s.to_coo();
        assert_eq!(coo.num_edges(), 8);
        coo.validate().unwrap();
        // Directed view mirrors each undirected edge.
        assert!(coo.edges.contains(&(0, 1)) && coo.edges.contains(&(1, 0)));
    }

    #[test]
    fn seed_with_self_loop_is_rejected() {
        let mut g = seed_graph();
        g.edges.push((2, 2));
        assert!(ResidentStore::new(&g).is_err());
    }

    #[test]
    fn mutations_publish_cow_snapshots() {
        let store = ResidentStore::new(&seed_graph()).unwrap();
        let before = store.snapshot();
        let out = store.apply(&[
            MutateOp::AddEdge(0, 2),
            MutateOp::RemoveEdge(2, 3),
            MutateOp::AddEdge(1, 1),  // self-loop: rejected
            MutateOp::AddEdge(0, 1),  // duplicate: rejected
            MutateOp::RemoveEdge(0, 2), // just added above: applied
        ]);
        assert_eq!(out.applied, 3);
        assert_eq!(out.rejected, 2);
        assert_eq!(out.version, 2);
        assert_eq!(store.version(), 2);
        let after = store.snapshot();
        assert!(!after.has_edge(2, 3) && !after.has_edge(0, 2));
        // The snapshot resolved before the batch is untouched.
        assert!(before.has_edge(2, 3));
        assert_eq!(before.version, 1);
        // Edge-only batch shares the feature buffer.
        assert!(Arc::ptr_eq(&before.features, &after.features));
    }

    #[test]
    fn add_node_extends_features_and_range() {
        let store = ResidentStore::new(&seed_graph()).unwrap();
        let out = store.apply(&[
            MutateOp::AddNode(vec![9.0, 8.0]),
            MutateOp::AddNode(vec![1.0]), // wrong width: rejected
            MutateOp::AddEdge(0, 4),      // new node is attachable in-batch
        ]);
        assert_eq!((out.applied, out.rejected), (2, 1));
        let s = store.snapshot();
        assert_eq!(s.n(), 5);
        assert_eq!(s.feature_row(4), &[9.0, 8.0]);
        assert!(s.has_edge(0, 4));
        assert_eq!(s.neighbors(4), &[0]);
    }

    #[test]
    fn all_rejected_batch_publishes_nothing() {
        let store = ResidentStore::new(&seed_graph()).unwrap();
        let out = store.apply(&[MutateOp::AddEdge(0, 1), MutateOp::RemoveEdge(0, 2)]);
        assert_eq!((out.applied, out.rejected), (0, 2));
        assert_eq!(out.version, 1);
        assert_eq!(store.version(), 1);
    }

    #[test]
    fn eig_is_cached_per_snapshot_and_refreshed_by_mutation() {
        let store = ResidentStore::new(&seed_graph()).unwrap();
        let s1 = store.snapshot();
        let e1a = Arc::clone(s1.eig());
        let e1b = Arc::clone(s1.eig());
        assert!(Arc::ptr_eq(&e1a, &e1b), "snapshot eig must be cached");
        assert_eq!(e1a.len(), 4);
        store.apply(&[MutateOp::AddEdge(0, 2)]);
        let s2 = store.snapshot();
        let e2 = Arc::clone(s2.eig());
        assert_ne!(*e1a, *e2, "a structural mutation must change the field");
        // And the snapshot eig matches a direct solve over the same COO.
        let direct =
            crate::graph::spectral::fiedler_vector(&s2.to_coo(), EIG_MAX_ITER, EIG_TOL);
        assert_eq!(*e2, direct.vector);
    }

    #[test]
    fn neighbor_rows_stay_sorted_under_mutation() {
        let store = ResidentStore::new(&seed_graph()).unwrap();
        store.apply(&[MutateOp::AddEdge(2, 0)]);
        let s = store.snapshot();
        assert_eq!(s.neighbors(0), &[1, 2, 3]);
        for v in 0..s.n() {
            assert!(s.neighbors(v).windows(2).all(|w| w[0] < w[1]));
        }
    }
}

//! Resident large-graph serving (the paper's §4.6 Large Graph
//! Extension as a *serving mode*, not a batch benchmark).
//!
//! The molecular path ships each graph whole inside the request. The
//! resident path instead keeps one citation-scale graph **hosted by
//! the server** ([`ResidentStore`]: CSR-style sorted adjacency + node
//! features behind an `Arc`-swapped snapshot, same publish discipline
//! as the PR-8 model registry) and serves two new wire-v4 operations:
//!
//! - `GRAPH_QUERY`: a seed-node set plus hop count / fanout. The
//!   reactor extracts the deterministic k-hop closure
//!   ([`extract::extract_khop`]) into an ordinary [`CooGraph`] and
//!   feeds it down the *existing* ingest path — prep, stage-IR
//!   interpreter, fusion, QoS admission all unchanged — under the
//!   synthesized [`RESIDENT_MODEL`] entry. Per-seed output rows are
//!   sliced from the node-level forward.
//! - `GRAPH_MUTATE`: add/remove edges, add nodes. Copy-on-write: a
//!   batch builds a successor snapshot and publishes it atomically,
//!   so in-flight queries finish on the snapshot they resolved.
//!
//! Correctness contract (pinned by `rust/tests/resident_e2e.rs`, the
//! unit test below, and `python/tools/resident_replica.py`): with
//! full expansion and `hops >= layers`, the forward on an extracted
//! neighborhood is **bit-identical** on the seed rows to the
//! full-graph forward restricted to those seeds, across interleaved
//! mutation sequences. See `docs/SCENARIOS.md`.

pub mod extract;
pub mod store;

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::datagen::citation::{self, CitationDataset};
use crate::runtime::{InputSpec, ModelMeta};

pub use extract::{extract_khop, ExtractError, Extraction};
pub use store::{GraphSnapshot, MutateOp, MutateOutcome, ResidentStore};

/// Catalog name of the synthesized resident model. It is injected
/// into the registry in-memory (never persisted to the artifact
/// store) and lowers through the stock DGN path.
pub const RESIDENT_MODEL: &str = "dgn_resident";
/// Padded node capacity of the resident plan — the extraction cap.
pub const RESIDENT_N_MAX: usize = 512;
/// Message-passing depth. Queries must carry `hops >= RESIDENT_LAYERS`
/// for the exactness contract; shallower queries are rejected.
pub const RESIDENT_LAYERS: usize = 2;
/// Hidden width of the resident model.
pub const RESIDENT_DIM: usize = 64;

/// The canonical DGN-style input slots for a given capacity/width.
fn dgn_inputs(n_max: usize, in_dim: usize) -> Vec<InputSpec> {
    vec![
        InputSpec {
            name: "x".into(),
            shape: vec![n_max, in_dim],
        },
        InputSpec {
            name: "adj".into(),
            shape: vec![n_max, n_max],
        },
        InputSpec {
            name: "eig".into(),
            shape: vec![n_max],
        },
        InputSpec {
            name: "mask".into(),
            shape: vec![n_max],
        },
    ]
}

/// Synthesize the resident model's metadata from a cataloged DGN base
/// entry. The base contributes only its artifact paths (kept valid so
/// catalog listing and client-side compile checks still resolve); all
/// shape-bearing fields are overridden for the dataset.
pub fn resident_meta(base: &ModelMeta, dataset: CitationDataset) -> ModelMeta {
    let (_, _, f) = dataset.stats();
    let out_dim = dataset.num_classes();
    ModelMeta {
        name: RESIDENT_MODEL.to_string(),
        layers: RESIDENT_LAYERS,
        dim: RESIDENT_DIM,
        heads: 0,
        n_max: RESIDENT_N_MAX,
        in_dim: f,
        out_dim,
        node_level: true,
        inputs: dgn_inputs(RESIDENT_N_MAX, f),
        hlo_path: base.hlo_path.clone(),
        golden_path: base.golden_path.clone(),
    }
}

/// The same model re-padded to hold the *entire* resident graph —
/// used only by reference forwards in tests and the replica, never by
/// the serving path. Weight generation depends on widths and layer
/// count alone, so this shares bit-exact weights with the query plan.
pub fn full_graph_meta(meta: &ModelMeta, n: usize) -> ModelMeta {
    let mut full = meta.clone();
    full.n_max = n;
    full.inputs = dgn_inputs(n, meta.in_dim);
    full
}

/// Book-keeping for one in-flight resident query: enough to carve the
/// per-seed rows out of the node-level output when the coordinator
/// completes it.
#[derive(Clone, Debug)]
pub struct QueryPending {
    pub seed_locals: Vec<u32>,
    pub out_dim: usize,
    pub snapshot_version: u64,
}

/// Shared serving state for resident mode, threaded through the
/// reactors (dispatch) and the response pump (completion).
pub struct ResidentState {
    pub store: ResidentStore,
    /// The synthesized catalog entry queries execute under.
    pub meta: ModelMeta,
    pub dataset: CitationDataset,
    pending: Mutex<HashMap<u64, QueryPending>>,
}

impl std::fmt::Debug for ResidentState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ResidentState")
            .field("dataset", &self.dataset)
            .field("snapshot_version", &self.store.version())
            .finish_non_exhaustive()
    }
}

impl ResidentState {
    /// Seed the resident store from a generated citation dataset and
    /// synthesize its model entry from `base` (any cataloged DGN meta).
    pub fn boot(dataset: CitationDataset, seed: u64, base: &ModelMeta) -> Result<ResidentState> {
        let graph = citation::dataset(dataset, seed);
        let store = ResidentStore::new(&graph)
            .with_context(|| format!("seeding resident store from {}", dataset.name()))?;
        Ok(ResidentState {
            store,
            meta: resident_meta(base, dataset),
            dataset,
            pending: Mutex::new(HashMap::new()),
        })
    }

    /// Build directly from a graph (tests; avoids full-size datasets).
    pub fn from_graph(
        graph: &crate::graph::CooGraph,
        dataset: CitationDataset,
        base: &ModelMeta,
    ) -> Result<ResidentState> {
        let store = ResidentStore::new(graph)?;
        let mut meta = resident_meta(base, dataset);
        meta.in_dim = graph.f_node;
        meta.inputs = dgn_inputs(meta.n_max, meta.in_dim);
        Ok(ResidentState {
            store,
            meta,
            dataset,
            pending: Mutex::new(HashMap::new()),
        })
    }

    pub fn register_pending(&self, id: u64, entry: QueryPending) {
        crate::util::sync::lock(&self.pending).insert(id, entry);
    }

    pub fn take_pending(&self, id: u64) -> Option<QueryPending> {
        crate::util::sync::lock(&self.pending).remove(&id)
    }

    pub fn pending_len(&self) -> usize {
        crate::util::sync::lock(&self.pending).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CooGraph, GraphBatch};
    use crate::runtime::NativeModel;

    /// A deterministic 40-node "toy citation" graph: a ring plus
    /// distance-7 chords, 8 binary-ish features per node.
    fn toy_graph() -> CooGraph {
        let n = 40u32;
        let f = 8usize;
        let mut und = Vec::new();
        for i in 0..n {
            und.push((i, (i + 1) % n));
            und.push((i, (i + 7) % n));
        }
        let feat: Vec<f32> = (0..n as usize * f)
            .map(|k| if (k * 2654435761) % 7 < 3 { 1.0 } else { 0.0 })
            .collect();
        CooGraph::from_undirected(n as usize, &und, feat, f, &[], 0).unwrap()
    }

    fn toy_meta(in_dim: usize) -> ModelMeta {
        ModelMeta {
            name: RESIDENT_MODEL.to_string(),
            layers: RESIDENT_LAYERS,
            dim: RESIDENT_DIM,
            heads: 0,
            n_max: 64,
            in_dim,
            out_dim: 3,
            node_level: true,
            inputs: dgn_inputs(64, in_dim),
            hlo_path: "unused.hlo.txt".into(),
            golden_path: "unused.golden.json".into(),
        }
    }

    fn pad(eig: &[f32], n_max: usize) -> Vec<f32> {
        let mut v = eig.to_vec();
        v.resize(n_max, 0.0);
        v
    }

    /// Forward the full resident graph through a re-padded plan and
    /// return the node-level output rows (`n * out_dim`).
    fn full_forward(snap: &GraphSnapshot, meta: &ModelMeta, seed: u64) -> Vec<f32> {
        let full = full_graph_meta(meta, snap.n());
        let model = NativeModel::build(&full, seed).unwrap();
        let batch = GraphBatch::ingest_unchecked(snap.to_coo());
        let eig = snap.eig();
        model.forward_batch(&batch, Some(&eig)).unwrap()
    }

    /// The tentpole's correctness pin, at unit scope: extracted k-hop
    /// forwards are bit-identical to full-graph forwards on the seed
    /// rows, across an interleaved mutation sequence.
    #[test]
    fn khop_forward_matches_full_graph_bitwise_across_mutations() {
        let g = toy_graph();
        let meta = toy_meta(g.f_node);
        let store = ResidentStore::new(&g).unwrap();
        let weight_seed = 20180414;
        let model = NativeModel::build(&meta, weight_seed).unwrap();
        let seeds = [3u32, 17, 30];

        let mutations: [&[MutateOp]; 3] = [
            &[],
            &[MutateOp::AddEdge(3, 20), MutateOp::RemoveEdge(17, 18)],
            &[
                MutateOp::AddNode(vec![1.0, 0.0, 1.0, 0.0, 0.0, 1.0, 1.0, 0.0]),
                MutateOp::AddEdge(30, 40),
            ],
        ];
        for ops in mutations {
            if !ops.is_empty() {
                let out = store.apply(ops);
                assert_eq!(out.rejected, 0);
            }
            let snap = store.snapshot();
            let full = full_forward(&snap, &meta, weight_seed);
            let ex = extract_khop(&snap, &seeds, RESIDENT_LAYERS as u8, 0, meta.n_max).unwrap();
            let batch = GraphBatch::ingest_unchecked(ex.graph.clone());
            let out = model
                .forward_batch(&batch, Some(&pad(&ex.eig, meta.n_max)))
                .unwrap();
            for (si, &s) in seeds.iter().enumerate() {
                let li = ex.seed_locals[si] as usize;
                let got = &out[li * meta.out_dim..(li + 1) * meta.out_dim];
                let want = &full[s as usize * meta.out_dim..(s as usize + 1) * meta.out_dim];
                let got_bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                assert_eq!(
                    got_bits, want_bits,
                    "seed {s} diverged on snapshot v{}",
                    snap.version
                );
            }
        }
    }

    /// Shallow queries cannot honor the contract: a 1-hop closure of a
    /// 2-layer model really does diverge (the rejection rule exists
    /// for a reason, not out of caution).
    #[test]
    fn one_hop_closure_diverges_for_two_layer_model() {
        let g = toy_graph();
        let meta = toy_meta(g.f_node);
        let store = ResidentStore::new(&g).unwrap();
        let weight_seed = 20180414;
        let model = NativeModel::build(&meta, weight_seed).unwrap();
        let snap = store.snapshot();
        let full = full_forward(&snap, &meta, weight_seed);
        let ex = extract_khop(&snap, &[3], 1, 0, meta.n_max).unwrap();
        let batch = GraphBatch::ingest_unchecked(ex.graph.clone());
        let out = model
            .forward_batch(&batch, Some(&pad(&ex.eig, meta.n_max)))
            .unwrap();
        let li = ex.seed_locals[0] as usize;
        assert_ne!(
            out[li * meta.out_dim..(li + 1) * meta.out_dim],
            full[3 * meta.out_dim..4 * meta.out_dim]
        );
    }

    #[test]
    fn resident_meta_reshapes_the_base_entry() {
        let base = toy_meta(9);
        let meta = resident_meta(&base, CitationDataset::Cora);
        assert_eq!(meta.name, RESIDENT_MODEL);
        assert_eq!(meta.in_dim, 1433);
        assert_eq!(meta.out_dim, 7);
        assert_eq!(meta.n_max, RESIDENT_N_MAX);
        assert!(meta.node_level);
        assert!(meta.needs_eig());
        assert_eq!(meta.inputs[0].shape, vec![RESIDENT_N_MAX, 1433]);
    }

    #[test]
    fn pending_table_round_trips() {
        let g = toy_graph();
        let st = ResidentState::from_graph(&g, CitationDataset::Cora, &toy_meta(g.f_node)).unwrap();
        st.register_pending(
            7,
            QueryPending {
                seed_locals: vec![1],
                out_dim: 3,
                snapshot_version: 1,
            },
        );
        assert_eq!(st.pending_len(), 1);
        let got = st.take_pending(7).unwrap();
        assert_eq!(got.seed_locals, vec![1]);
        assert!(st.take_pending(7).is_none());
    }
}

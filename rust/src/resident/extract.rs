//! Deterministic k-hop neighborhood extraction: a resident-graph
//! query becomes an ordinary [`CooGraph`] that flows through the
//! existing `GraphBatch` ingest path unchanged.
//!
//! The bit-exactness contract (pinned by `rust/tests/resident_e2e.rs`
//! and `python/tools/resident_replica.py`): with full expansion
//! (`fanout = 0`) and `hops >= layers`, the DGN forward over the
//! extracted subgraph is **bit-identical** on the seed rows to the
//! full-graph forward restricted to those seeds. Three properties
//! carry it:
//!
//! 1. **Closure**: every node within `hops` of a seed is included, so
//!    any node whose layer-`l` state reaches a seed (depth ≤
//!    `layers - 1 < hops`) has its *complete* neighborhood in the
//!    subgraph — its aggregation weights (degrees, normalized
//!    eig-differences) are exactly the full-graph ones. Boundary
//!    nodes at depth == `hops` contribute only their raw features.
//! 2. **Monotone relabeling**: closure nodes are assigned local ids in
//!    ascending global order, so every sorted in-neighbor walk — the
//!    interpreter's f32 accumulation order — visits neighbors in the
//!    same relative order as the full graph.
//! 3. **Shared spectral field**: the attached eigenvector is the
//!    *snapshot's* full-graph Fiedler vector restricted to the
//!    closure, not a per-subgraph re-solve (which would be a
//!    different directional field entirely).
//!
//! `fanout > 0` caps expansion at the first `fanout` ascending
//! neighbors per node — a deterministic capacity-bounded
//! approximation that deliberately trades the exactness contract for
//! bounded extraction size (documented in `docs/SCENARIOS.md`).

use std::collections::BTreeSet;

use crate::graph::CooGraph;

use super::store::GraphSnapshot;

/// Why an extraction was refused. `SeedOutOfRange` / `DuplicateSeed` /
/// `NoSeeds` are malformed requests (wire `BadRequest`); `TooLarge` is
/// a capacity rejection (wire `Rejected` — the client may retry with
/// fewer hops or a fanout cap).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ExtractError {
    SeedOutOfRange(u32),
    DuplicateSeed(u32),
    NoSeeds,
    TooLarge { nodes: usize, cap: usize },
}

impl std::fmt::Display for ExtractError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExtractError::SeedOutOfRange(s) => write!(f, "seed {s} out of range"),
            ExtractError::DuplicateSeed(s) => write!(f, "duplicate seed {s}"),
            ExtractError::NoSeeds => write!(f, "query carries no seeds"),
            ExtractError::TooLarge { nodes, cap } => {
                write!(f, "extraction spans {nodes}+ nodes, capacity {cap}")
            }
        }
    }
}

impl std::error::Error for ExtractError {}

/// Whether this error is a malformed request (vs a capacity refusal).
impl ExtractError {
    pub fn is_bad_request(&self) -> bool {
        !matches!(self, ExtractError::TooLarge { .. })
    }
}

/// One extracted k-hop neighborhood, ready for `GraphBatch` ingest.
#[derive(Clone, Debug)]
pub struct Extraction {
    /// Global ids of the closure, ascending — index in this vec is the
    /// node's local id (monotone relabeling).
    pub nodes: Vec<u32>,
    /// Local id of each requested seed, in request order.
    pub seed_locals: Vec<u32>,
    /// The induced subgraph: locally relabeled directed edges (both
    /// mirror directions present), gathered feature rows.
    pub graph: CooGraph,
    /// The snapshot's full-graph Fiedler vector restricted to
    /// `nodes` (same order; length `nodes.len()`).
    pub eig: Vec<f32>,
    /// Version of the snapshot this extraction resolved.
    pub snapshot_version: u64,
}

/// Extract the k-hop in-neighbor closure of `seeds` from a snapshot.
///
/// `fanout = 0` expands every neighbor (the exactness contract);
/// `fanout > 0` expands only the first `fanout` ascending neighbors
/// per node. `cap` bounds the closure size (the resident model's
/// padded capacity); crossing it rejects the query instead of
/// truncating it silently.
pub fn extract_khop(
    snap: &GraphSnapshot,
    seeds: &[u32],
    hops: u8,
    fanout: u16,
    cap: usize,
) -> Result<Extraction, ExtractError> {
    if seeds.is_empty() {
        return Err(ExtractError::NoSeeds);
    }
    let n = snap.n();
    let mut closure: BTreeSet<u32> = BTreeSet::new();
    for &s in seeds {
        if s as usize >= n {
            return Err(ExtractError::SeedOutOfRange(s));
        }
        if !closure.insert(s) {
            return Err(ExtractError::DuplicateSeed(s));
        }
    }
    if closure.len() > cap {
        return Err(ExtractError::TooLarge {
            nodes: closure.len(),
            cap,
        });
    }
    let mut frontier: Vec<u32> = closure.iter().copied().collect();
    for _ in 0..hops {
        if frontier.is_empty() {
            break;
        }
        let mut next = Vec::new();
        for &v in &frontier {
            let nbrs = snap.neighbors(v as usize);
            let take = if fanout == 0 {
                nbrs.len()
            } else {
                (fanout as usize).min(nbrs.len())
            };
            for &u in &nbrs[..take] {
                if closure.insert(u) {
                    if closure.len() > cap {
                        return Err(ExtractError::TooLarge {
                            nodes: closure.len(),
                            cap,
                        });
                    }
                    next.push(u);
                }
            }
        }
        next.sort_unstable();
        frontier = next;
    }

    // Ascending global order IS the local relabeling.
    let nodes: Vec<u32> = closure.into_iter().collect();
    let local = |g: u32| -> u32 {
        nodes
            .binary_search(&g)
            .expect("closure member has a local id") as u32
    };
    let seed_locals: Vec<u32> = seeds.iter().map(|&s| local(s)).collect();

    let f = snap.f();
    let mut node_feat = Vec::with_capacity(nodes.len() * f);
    for &g in &nodes {
        node_feat.extend_from_slice(snap.feature_row(g as usize));
    }
    // Induced directed edges, grouped by destination ascending with
    // ascending sources inside each group (deterministic order; the
    // interpreter re-sorts per row anyway).
    let mut edges = Vec::new();
    for (li, &g) in nodes.iter().enumerate() {
        for &u in snap.neighbors(g as usize) {
            if let Ok(lu) = nodes.binary_search(&u) {
                edges.push((lu as u32, li as u32));
            }
        }
    }
    let eig_full = snap.eig();
    let eig: Vec<f32> = nodes.iter().map(|&g| eig_full[g as usize]).collect();
    Ok(Extraction {
        graph: CooGraph {
            n: nodes.len(),
            edges,
            node_feat,
            f_node: f,
            edge_feat: Vec::new(),
            f_edge: 0,
        },
        nodes,
        seed_locals,
        eig,
        snapshot_version: snap.version,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resident::store::ResidentStore;

    /// Path 0-1-2-3-4-5 with a branch 2-6.
    fn store() -> ResidentStore {
        let g = CooGraph::from_undirected(
            7,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (2, 6)],
            (0..7).map(|i| i as f32).collect(),
            1,
            &[],
            0,
        )
        .unwrap();
        ResidentStore::new(&g).unwrap()
    }

    #[test]
    fn closure_follows_hop_count() {
        let s = store().snapshot();
        let e1 = extract_khop(&s, &[2], 1, 0, 64).unwrap();
        assert_eq!(e1.nodes, vec![1, 2, 3, 6]);
        let e2 = extract_khop(&s, &[2], 2, 0, 64).unwrap();
        assert_eq!(e2.nodes, vec![0, 1, 2, 3, 4, 6]);
        let e3 = extract_khop(&s, &[2], 3, 0, 64).unwrap();
        assert_eq!(e3.nodes, vec![0, 1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn relabeling_is_monotone_and_features_follow() {
        let s = store().snapshot();
        let e = extract_khop(&s, &[2], 2, 0, 64).unwrap();
        // nodes = [0,1,2,3,4,6]: local ids ascend with global ids.
        assert!(e.nodes.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(e.seed_locals, vec![2]);
        // Feature row k carries global node e.nodes[k]'s features.
        for (li, &g) in e.nodes.iter().enumerate() {
            assert_eq!(e.graph.node_feat[li], g as f32);
        }
        // eig restriction picks the same positions.
        let full = s.eig();
        for (li, &g) in e.nodes.iter().enumerate() {
            assert_eq!(e.eig[li], full[g as usize]);
        }
    }

    #[test]
    fn induced_edges_are_exactly_the_closure_pairs() {
        let s = store().snapshot();
        let e = extract_khop(&s, &[2], 1, 0, 64).unwrap();
        // closure {1,2,3,6} → locals {0,1,2,3}; undirected edges
        // inside: {1,2},{2,3},{2,6} → 6 directed entries.
        let mut got = e.graph.edges.clone();
        got.sort_unstable();
        assert_eq!(
            got,
            vec![(0, 1), (1, 0), (1, 2), (1, 3), (2, 1), (3, 1)]
        );
        e.graph.validate().unwrap();
        // Edge 0-1 of the full graph is cut: node 1 is a boundary node.
        assert!(!got.contains(&(0, 0)));
    }

    #[test]
    fn multi_seed_union_and_seed_locals_in_request_order() {
        let s = store().snapshot();
        let e = extract_khop(&s, &[5, 0], 1, 0, 64).unwrap();
        assert_eq!(e.nodes, vec![0, 1, 4, 5]);
        assert_eq!(e.seed_locals, vec![3, 0]);
    }

    #[test]
    fn fanout_takes_lowest_id_neighbors() {
        let s = store().snapshot();
        // Node 2's neighbors are [1, 3, 6]; fanout 2 keeps {1, 3}.
        let e = extract_khop(&s, &[2], 1, 2, 64).unwrap();
        assert_eq!(e.nodes, vec![1, 2, 3]);
    }

    #[test]
    fn extraction_is_deterministic() {
        let st = store();
        let s = st.snapshot();
        let a = extract_khop(&s, &[2, 5], 2, 0, 64).unwrap();
        let b = extract_khop(&s, &[2, 5], 2, 0, 64).unwrap();
        assert_eq!(a.nodes, b.nodes);
        assert_eq!(a.graph, b.graph);
        assert_eq!(a.eig, b.eig);
        assert_eq!(a.snapshot_version, 1);
    }

    #[test]
    fn rejections_are_typed() {
        let s = store().snapshot();
        assert_eq!(
            extract_khop(&s, &[], 1, 0, 64),
            Err(ExtractError::NoSeeds)
        );
        assert_eq!(
            extract_khop(&s, &[9], 1, 0, 64),
            Err(ExtractError::SeedOutOfRange(9))
        );
        assert_eq!(
            extract_khop(&s, &[1, 1], 1, 0, 64),
            Err(ExtractError::DuplicateSeed(1))
        );
        let too_big = extract_khop(&s, &[2], 2, 0, 3);
        assert!(matches!(too_big, Err(ExtractError::TooLarge { cap: 3, .. })));
        assert!(!ExtractError::TooLarge { nodes: 9, cap: 3 }.is_bad_request());
        assert!(ExtractError::NoSeeds.is_bad_request());
    }

    #[test]
    fn extraction_tracks_mutations_through_new_snapshots() {
        use crate::resident::store::MutateOp;
        let st = store();
        let before = st.snapshot();
        st.apply(&[MutateOp::AddEdge(0, 6)]);
        let after = st.snapshot();
        let e_before = extract_khop(&before, &[0], 1, 0, 64).unwrap();
        let e_after = extract_khop(&after, &[0], 1, 0, 64).unwrap();
        assert_eq!(e_before.nodes, vec![0, 1]);
        assert_eq!(e_after.nodes, vec![0, 1, 6]);
        assert_eq!(e_before.snapshot_version, 1);
        assert_eq!(e_after.snapshot_version, 2);
    }
}

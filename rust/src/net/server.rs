//! The threaded TCP front-end: the wire-level ingress that puts real
//! traffic on the executor pool.
//!
//! ```text
//! conn 0 ─ reader ─┐                                   ┌─ writer ─ conn 0
//! conn 1 ─ reader ─┼─► Server::submit_with_id ─► lanes ─► responses
//! conn … ─ reader ─┘        (ingest queue,              │
//!                            Block | Reject)     demux ─┴─► per-conn
//!                                                            outboxes
//! ```
//!
//! One reader and one writer thread per connection, plus a single
//! **demux** thread draining the coordinator's response channel and
//! routing each response to its connection's outbox by request id.
//! Readers register the route *before* admission (via
//! [`Server::reserve_id`]), so a response can never race past its
//! routing entry.
//!
//! Backpressure is inherited from the coordinator: under
//! `AdmissionPolicy::Block` a full ingest queue blocks the reader,
//! which stops draining the socket, which backs TCP up to the client —
//! the paper's full-FIFO stall propagated all the way to the producer.
//! Under `Reject` a shed request is answered immediately with a
//! `Rejected` wire status on the same connection; the connection
//! stays up.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::coordinator::{Admission, Metrics, Server, ServerConfig};
use crate::util::pool::Channel;

use super::proto::{self, WireFrame, WireResponse, WireStatus};

/// Routing entry for one in-flight wire request: which connection to
/// answer on, under which client-side id.
struct RouteEntry {
    outbox: Channel<WireResponse>,
    client_id: u64,
}

/// Stripe count of the routing table. Requests hash to a shard by id,
/// so N connection readers and the demux contend per-stripe, not on
/// one global lock — the same sharding story as the per-model metrics.
const ROUTE_SHARDS: usize = 16;

/// Sharded routing table for in-flight wire requests, keyed by the
/// reserved coordinator id.
struct RouteTable {
    shards: Vec<Mutex<HashMap<u64, RouteEntry>>>,
}

impl RouteTable {
    fn new() -> RouteTable {
        RouteTable {
            shards: (0..ROUTE_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    fn insert(&self, id: u64, entry: RouteEntry) {
        crate::util::sync::lock(&self.shards[id as usize % ROUTE_SHARDS]).insert(id, entry);
    }

    fn remove(&self, id: u64) -> Option<RouteEntry> {
        crate::util::sync::lock(&self.shards[id as usize % ROUTE_SHARDS]).remove(&id)
    }
}

type RouteMap = Arc<RouteTable>;

/// Live-connection socket registry, keyed by connection number so a
/// closing reader can deregister itself — long-running servers must
/// not pin a dead connection's file descriptor until shutdown.
type SockRegistry = Arc<Mutex<HashMap<usize, TcpStream>>>;

/// Construction parameters of the TCP front-end.
#[derive(Clone, Debug)]
pub struct NetServerConfig {
    /// Listen address, e.g. `127.0.0.1:7447` (port 0 for ephemeral).
    pub listen: String,
    /// The wrapped coordinator's configuration (models, lanes, queue
    /// capacity, admission policy).
    pub server: ServerConfig,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig {
            listen: "127.0.0.1:0".to_string(),
            server: ServerConfig::default(),
        }
    }
}

/// A running TCP front-end over a coordinator [`Server`].
pub struct NetServer {
    local_addr: SocketAddr,
    server: Arc<Server>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
    demux_handle: Option<JoinHandle<()>>,
    conn_handles: Arc<Mutex<Vec<JoinHandle<()>>>>,
    conn_socks: SockRegistry,
}

impl NetServer {
    /// Compile the coordinator, bind the listener, and start serving.
    pub fn start(cfg: NetServerConfig) -> Result<NetServer> {
        let server = Arc::new(Server::start(cfg.server)?);
        let metrics = server.metrics();
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding {}", cfg.listen))?;
        // Nonblocking accept + a short poll keeps shutdown deterministic:
        // the accept thread re-checks the stop flag every tick instead of
        // parking in accept(2) until a wake connection that might never
        // land (wildcard binds, full backlogs).
        listener
            .set_nonblocking(true)
            .context("setting listener nonblocking")?;
        let local_addr = listener.local_addr()?;

        let stop = Arc::new(AtomicBool::new(false));
        let routes: RouteMap = Arc::new(RouteTable::new());
        let conn_handles: Arc<Mutex<Vec<JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let conn_socks: SockRegistry = Arc::new(Mutex::new(HashMap::new()));

        // Demux: the coordinator's single response stream fans back out
        // to per-connection outboxes. Also the one place end-to-end
        // latency lands in the histogram.
        let demux_handle = {
            let responses = server.responses();
            let routes = Arc::clone(&routes);
            let metrics = Arc::clone(&metrics);
            std::thread::Builder::new()
                .name("gengnn-net-demux".to_string())
                .spawn(move || {
                    while let Some(r) = responses.recv() {
                        metrics.record_e2e_latency(r.latency());
                        let Some(entry) = routes.remove(r.id) else {
                            // Connection closed while the request was in
                            // flight; the result has nowhere to go.
                            continue;
                        };
                        metrics
                            .net()
                            .requests_in_flight
                            .fetch_sub(1, Ordering::Relaxed);
                        let wire = match r.output {
                            Ok(output) => {
                                WireResponse::ok(entry.client_id, r.model, output)
                            }
                            Err(msg) => WireResponse::err(
                                entry.client_id,
                                r.model,
                                WireStatus::Error,
                                msg,
                            ),
                        };
                        // Never block the demux on one connection: a
                        // full outbox means the client stopped reading
                        // (its writer is wedged against TCP), and a
                        // closed one means the connection is gone —
                        // drop the response either way so every other
                        // connection keeps receiving.
                        if entry.outbox.try_send(wire).is_err() {
                            metrics
                                .net()
                                .responses_dropped
                                .fetch_add(1, Ordering::Relaxed);
                        }
                    }
                })
                .expect("spawn net demux")
        };

        // Accept loop: one reader + one writer thread per connection.
        let accept_handle = {
            let server = Arc::clone(&server);
            let metrics = Arc::clone(&metrics);
            let stop = Arc::clone(&stop);
            let routes = Arc::clone(&routes);
            let conn_handles = Arc::clone(&conn_handles);
            let conn_socks = Arc::clone(&conn_socks);
            std::thread::Builder::new()
                .name("gengnn-net-accept".to_string())
                .spawn(move || {
                    let mut conn_no = 0usize;
                    loop {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        let sock = match listener.accept() {
                            Ok((s, _)) => s,
                            Err(e)
                                if e.kind() == std::io::ErrorKind::WouldBlock =>
                            {
                                // Idle: nothing pending; poll the stop
                                // flag again shortly.
                                std::thread::sleep(
                                    std::time::Duration::from_millis(20),
                                );
                                continue;
                            }
                            Err(_) => {
                                // Persistent accept errors (e.g. fd
                                // exhaustion) repeat immediately; back
                                // off instead of spinning a core.
                                std::thread::sleep(
                                    std::time::Duration::from_millis(10),
                                );
                                continue;
                            }
                        };
                        conn_no += 1;
                        // Whether an accepted socket inherits the
                        // listener's nonblocking mode is
                        // platform-dependent; connection threads use
                        // blocking I/O.
                        if sock.set_nonblocking(false).is_err() {
                            continue;
                        }
                        let _ = sock.set_nodelay(true);
                        metrics
                            .net()
                            .connections_accepted
                            .fetch_add(1, Ordering::Relaxed);
                        metrics
                            .net()
                            .connections_open
                            .fetch_add(1, Ordering::Relaxed);
                        // The registry entry is what shutdown uses to
                        // force this connection closed; serving an
                        // untracked socket could hang the reader join,
                        // so a failed clone drops the connection.
                        match sock.try_clone() {
                            Ok(clone) => {
                                crate::util::sync::lock(&conn_socks).insert(conn_no, clone);
                            }
                            Err(e) => {
                                eprintln!(
                                    "[net] dropping connection {conn_no}: {e}"
                                );
                                metrics
                                    .net()
                                    .connections_open
                                    .fetch_sub(1, Ordering::Relaxed);
                                continue;
                            }
                        }
                        match spawn_connection(
                            conn_no,
                            sock,
                            Arc::clone(&server),
                            Arc::clone(&metrics),
                            Arc::clone(&routes),
                            Arc::clone(&conn_socks),
                        ) {
                            Ok((rh, wh)) => {
                                // Reap finished connection threads so the
                                // handle list tracks live connections,
                                // not history.
                                let mut handles = crate::util::sync::lock(&conn_handles);
                                let mut i = 0;
                                while i < handles.len() {
                                    if handles[i].is_finished() {
                                        let _ = handles.swap_remove(i).join();
                                    } else {
                                        i += 1;
                                    }
                                }
                                handles.push(rh);
                                handles.push(wh);
                            }
                            Err(e) => {
                                // Resource exhaustion (clone or thread
                                // spawn failed): drop this connection and
                                // keep accepting — the listener must
                                // outlive transient pressure.
                                eprintln!(
                                    "[net] dropping connection {conn_no}: {e}"
                                );
                                crate::util::sync::lock(&conn_socks).remove(&conn_no);
                                metrics
                                    .net()
                                    .connections_open
                                    .fetch_sub(1, Ordering::Relaxed);
                            }
                        }
                    }
                })
                .expect("spawn net accept loop")
        };

        Ok(NetServer {
            local_addr,
            server,
            metrics,
            stop,
            accept_handle: Some(accept_handle),
            demux_handle: Some(demux_handle),
            conn_handles,
            conn_socks,
        })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Models the wrapped coordinator serves.
    pub fn served_models(&self) -> &[String] {
        self.server.served_models()
    }

    /// Stop accepting, close every connection, drain the coordinator,
    /// and return the final metrics.
    pub fn shutdown(mut self) -> Arc<Metrics> {
        // The accept loop polls this flag between nonblocking accepts,
        // so it exits within one tick — no wake connection required.
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        // Force every connection closed so readers and writers unwind.
        for (_, s) in crate::util::sync::lock(&self.conn_socks).drain() {
            let _ = s.shutdown(Shutdown::Both);
        }
        let handles: Vec<JoinHandle<()>> =
            crate::util::sync::lock(&self.conn_handles).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        // All reader clones of the coordinator are joined; unwrap the
        // sole remaining Arc and drain it. Closing the response channel
        // (inside Server::shutdown) releases the demux thread.
        let server = Arc::try_unwrap(self.server)
            .unwrap_or_else(|_| panic!("coordinator still shared at shutdown"));
        let metrics = server.shutdown();
        if let Some(h) = self.demux_handle.take() {
            let _ = h.join();
        }
        metrics
    }
}

/// Spawn the reader/writer pair for one accepted connection. Errors
/// (socket clone or thread spawn failing under resource exhaustion)
/// are returned, not panicked — the accept loop drops the connection
/// and keeps serving.
fn spawn_connection(
    conn_no: usize,
    sock: TcpStream,
    server: Arc<Server>,
    metrics: Arc<Metrics>,
    routes: RouteMap,
    socks: SockRegistry,
) -> Result<(JoinHandle<()>, JoinHandle<()>)> {
    // Outbox sized generously; if a client stops reading long enough
    // to fill it anyway, the demux drops that connection's responses
    // (`responses_dropped`) rather than stalling everyone else.
    let outbox: Channel<WireResponse> = Channel::bounded(1024);

    let writer_handle = {
        let outbox = outbox.clone();
        let sock = sock.try_clone().context("cloning connection for writer")?;
        std::thread::Builder::new()
            .name(format!("gengnn-net-writer-{conn_no}"))
            .spawn(move || {
                let mut w = BufWriter::new(sock);
                while let Some(resp) = outbox.recv() {
                    let Ok(frame) = proto::encode_response(&resp) else {
                        continue;
                    };
                    if w.write_all(&frame).is_err() {
                        break;
                    }
                    // Batch flushes under load: only hit the socket
                    // when no further response is already queued.
                    if outbox.is_empty() && w.flush().is_err() {
                        break;
                    }
                }
                // Whatever ended this writer (closed outbox or a dead
                // socket), close the outbox: a reader parked in a
                // blocking outbox.send would otherwise wait forever on
                // a channel nothing will ever drain again.
                outbox.close();
            })
            .context("spawning net writer")?
    };

    let outbox_on_err = outbox.clone();
    let reader_handle = {
        match std::thread::Builder::new()
            .name(format!("gengnn-net-reader-{conn_no}"))
            .spawn(move || {
                let mut r = BufReader::new(sock);
                loop {
                    let payload = match proto::read_frame(&mut r) {
                        Ok(Some(p)) => p,
                        // Clean EOF or socket error: unwind the connection.
                        Ok(None) | Err(_) => break,
                    };
                    let req = match proto::decode_frame(&payload) {
                        Ok(WireFrame::Request(req)) => req,
                        Ok(WireFrame::Response(_)) => {
                            // A response frame on the server's ingress is
                            // a protocol violation; answer and move on.
                            metrics.net().decode_errors.fetch_add(1, Ordering::Relaxed);
                            let _ = outbox.send(WireResponse::err(
                                proto::BAD_FRAME_ID,
                                "",
                                WireStatus::BadRequest,
                                "response frame sent to server",
                            ));
                            continue;
                        }
                        Err(e) => {
                            // Framing is intact (read_frame succeeded) but
                            // the payload is bad: report it on this
                            // connection — under the caller's own id when
                            // the envelope checksum vouches for it — and
                            // keep serving.
                            metrics.net().decode_errors.fetch_add(1, Ordering::Relaxed);
                            let id = proto::salvage_request_id(&payload)
                                .unwrap_or(proto::BAD_FRAME_ID);
                            let _ = outbox.send(WireResponse::err(
                                id,
                                "",
                                WireStatus::BadRequest,
                                format!("{e}"),
                            ));
                            continue;
                        }
                    };
                    // Route registration precedes admission (see module
                    // docs): reserve, install, then submit.
                    let server_id = server.reserve_id();
                    routes.insert(
                        server_id,
                        RouteEntry {
                            outbox: outbox.clone(),
                            client_id: req.id,
                        },
                    );
                    metrics
                        .net()
                        .requests_in_flight
                        .fetch_add(1, Ordering::Relaxed);
                    match server.submit_with_id(server_id, &req.model, req.graph) {
                        Admission::Accepted => {}
                        Admission::Rejected => {
                            // Shed: unregister and answer immediately with
                            // the Rejected wire status.
                            routes.remove(server_id);
                            metrics
                                .net()
                                .requests_in_flight
                                .fetch_sub(1, Ordering::Relaxed);
                            let _ = outbox.send(WireResponse::err(
                                req.id,
                                req.model,
                                WireStatus::Rejected,
                                "ingest queue full",
                            ));
                        }
                    }
                }
                // Reader gone: close the outbox so the writer drains
                // what is queued and exits, deregister the socket (the
                // fd must not outlive the connection), and drop the
                // open-connections gauge; late demux sends fail soft.
                outbox.close();
                crate::util::sync::lock(&socks).remove(&conn_no);
                metrics
                    .net()
                    .connections_open
                    .fetch_sub(1, Ordering::Relaxed);
            }) {
            Ok(h) => h,
            Err(e) => {
                // The writer is already running: close its outbox so it
                // exits, join it, then report the spawn failure.
                outbox_on_err.close();
                let _ = writer_handle.join();
                return Err(anyhow::Error::from(e).context("spawning net reader"));
            }
        }
    };

    Ok((reader_handle, writer_handle))
}

/// Dial helper shared by the client and the load generator.
pub(crate) fn dial(addr: &str) -> Result<TcpStream> {
    let mut last_err = None;
    for a in addr
        .to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
    {
        match TcpStream::connect(a) {
            Ok(s) => {
                let _ = s.set_nodelay(true);
                return Ok(s);
            }
            Err(e) => last_err = Some(e),
        }
    }
    Err(match last_err {
        Some(e) => anyhow::Error::from(e).context(format!("connecting to {addr}")),
        None => anyhow::anyhow!("{addr} resolved to no addresses"),
    })
}

//! The TCP front-end: nonblocking reactor ingress that puts real
//! traffic on the executor pool.
//!
//! ```text
//! conn 0 ─┐                  ┌────────────┐   try_submit
//! conn 1 ─┼─► accept (rr) ─► │ reactor 0… │ ─────────────► lanes
//! conn … ─┘                  │ reactor N-1│ ◄─ Deliver ─┐     │
//!                            └────────────┘             │     ▼
//!                                         response pump ◄─ responses
//! ```
//!
//! One accept thread hands each connection to a fixed pool of
//! [`super::reactor`] event loops (no per-connection threads: a
//! reactor multiplexes thousands of sockets through one
//! `polly::Poller`), and one **response pump** drains the
//! coordinator's response channel, settles the routing table, and
//! posts each encoded frame back to the owning reactor.
//!
//! Reactors register the route *before* admission (via
//! [`Server::reserve_id`]), so a response can never race past its
//! routing entry. The `requests_in_flight` gauge is symmetric around
//! that table: incremented once per insert, decremented by whichever
//! path removes the entry — pump delivery, rejection, deadline
//! expiry, or connection teardown sweeping its in-flight ids (a
//! connection that dies mid-request no longer strands the gauge).
//!
//! Backpressure is inherited from the coordinator: under
//! `AdmissionPolicy::Block` a full ingest queue parks the decoded
//! request on its connection and drops read interest, which backs TCP
//! up to the client — the paper's full-FIFO stall propagated all the
//! way to the producer, without a blocked thread. Under `Reject` a
//! shed request is answered immediately with a `Rejected` wire status
//! on the same connection; requests whose TTL lapses while parked or
//! queued come back `Expired` (shed-by-deadline).

use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::coordinator::{Metrics, Server, ServerConfig};
use crate::resident::ResidentState;

use super::proto::{self, WireGraphQueryResp, WireResponse, WireStatus};
use super::reactor::{self, ReactorMsg, ReactorQueue, RouteTable};

/// Construction parameters of the TCP front-end.
#[derive(Clone, Debug)]
pub struct NetServerConfig {
    /// Listen address, e.g. `127.0.0.1:7447` (port 0 for ephemeral).
    pub listen: String,
    /// Reactor (event-loop) threads. Every connection is pinned to
    /// one reactor for its lifetime; 2 keeps accept/drain work off a
    /// single core without competing with the executor lanes.
    pub reactors: usize,
    /// The wrapped coordinator's configuration (models, lanes, queue
    /// capacity, admission policy).
    pub server: ServerConfig,
    /// Resident graph-serving state (wire-v4 `GRAPH_QUERY` /
    /// `GRAPH_MUTATE`). `None` = molecular-only serving; the caller
    /// boots the state ([`ResidentState::boot`]) and must also inject
    /// its synthesized model via `ServerConfig::synthetic_models`.
    pub resident: Option<Arc<ResidentState>>,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig {
            listen: "127.0.0.1:0".to_string(),
            reactors: 2,
            server: ServerConfig::default(),
            resident: None,
        }
    }
}

/// A running TCP front-end over a coordinator [`Server`].
pub struct NetServer {
    local_addr: SocketAddr,
    server: Arc<Server>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
    pump_handle: Option<JoinHandle<()>>,
    reactor_queues: Vec<Arc<ReactorQueue>>,
    reactor_handles: Vec<JoinHandle<()>>,
}

impl NetServer {
    /// Compile the coordinator, bind the listener, and start serving.
    pub fn start(cfg: NetServerConfig) -> Result<NetServer> {
        // Thousands of multiplexed connections need thousands of fds;
        // lift a conservative soft limit up front (best effort — the
        // hard limit still caps us, and failure is not fatal here).
        let _ = polly::raise_nofile_limit(8192);

        let server = Arc::new(Server::start(cfg.server)?);
        let metrics = server.metrics();
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding {}", cfg.listen))?;
        // Nonblocking accept + a short poll keeps shutdown deterministic:
        // the accept thread re-checks the stop flag every tick instead of
        // parking in accept(2) until a wake connection that might never
        // land (wildcard binds, full backlogs).
        listener
            .set_nonblocking(true)
            .context("setting listener nonblocking")?;
        let local_addr = listener.local_addr()?;

        let stop = Arc::new(AtomicBool::new(false));
        let routes = Arc::new(RouteTable::new());
        let (reactor_queues, reactor_handles) =
            reactor::spawn_reactors(cfg.reactors, &server, &metrics, &routes, cfg.resident.as_ref())?;

        // Response pump: the coordinator's single response stream fans
        // back out to the reactors as pre-encoded frames. Also the one
        // place end-to-end latency lands in the histogram, and one of
        // the two sides of the route-table accounting (see module docs).
        let pump_handle = {
            let responses = server.responses();
            let routes = Arc::clone(&routes);
            let metrics = Arc::clone(&metrics);
            let queues = reactor_queues.clone();
            let resident = cfg.resident.clone();
            std::thread::Builder::new()
                .name("gengnn-net-pump".to_string())
                .spawn(move || {
                    while let Some(r) = responses.recv() {
                        metrics.record_e2e_latency(r.latency());
                        let Some(entry) = routes.remove(r.id) else {
                            // Connection closed while the request was
                            // in flight; its teardown already settled
                            // the gauge, so only count the loss.
                            metrics
                                .net()
                                .responses_dropped
                                .fetch_add(1, Ordering::Relaxed);
                            continue;
                        };
                        metrics
                            .net()
                            .requests_in_flight
                            .fetch_sub(1, Ordering::Relaxed);
                        // A resident k-hop query (identified by its
                        // pending entry): carve the per-seed rows out
                        // of the node-level output and answer as a v4
                        // GRAPH_QUERY_RESP instead of a plain response.
                        if let Some(p) = resident.as_ref().and_then(|rs| rs.take_pending(r.id)) {
                            let wire = if r.expired {
                                WireGraphQueryResp::err(
                                    entry.client_id,
                                    WireStatus::Expired,
                                    p.snapshot_version,
                                    r.output.err().unwrap_or_default(),
                                )
                            } else {
                                match r.output {
                                    Ok(output) => seed_rows(&output, &p.seed_locals, p.out_dim)
                                        .map(|rows| {
                                            WireGraphQueryResp::ok(
                                                entry.client_id,
                                                p.snapshot_version,
                                                p.out_dim,
                                                rows,
                                            )
                                        })
                                        .unwrap_or_else(|| {
                                            WireGraphQueryResp::err(
                                                entry.client_id,
                                                WireStatus::Error,
                                                p.snapshot_version,
                                                "node-level output shorter than the closure",
                                            )
                                        }),
                                    Err(msg) => WireGraphQueryResp::err(
                                        entry.client_id,
                                        WireStatus::Error,
                                        p.snapshot_version,
                                        msg,
                                    ),
                                }
                            };
                            match proto::encode_graph_query_resp(&wire) {
                                Ok(frame) => queues[entry.reactor].send(ReactorMsg::Deliver {
                                    token: entry.token,
                                    id: r.id,
                                    frame,
                                }),
                                Err(_) => {
                                    metrics
                                        .net()
                                        .responses_dropped
                                        .fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            continue;
                        }
                        let wire = if r.expired {
                            WireResponse::err(
                                entry.client_id,
                                r.model,
                                WireStatus::Expired,
                                r.output.err().unwrap_or_default(),
                            )
                        } else {
                            match r.output {
                                Ok(output) => {
                                    WireResponse::ok(entry.client_id, r.model, output)
                                }
                                Err(msg) => WireResponse::err(
                                    entry.client_id,
                                    r.model,
                                    WireStatus::Error,
                                    msg,
                                ),
                            }
                        };
                        // Responses echo the version of the request
                        // frame they answer (see proto module docs).
                        match proto::encode_response_with_version(entry.version, &wire) {
                            Ok(frame) => queues[entry.reactor].send(ReactorMsg::Deliver {
                                token: entry.token,
                                id: r.id,
                                frame,
                            }),
                            Err(_) => {
                                metrics
                                    .net()
                                    .responses_dropped
                                    .fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                })
                .expect("spawn net response pump")
        };

        // Accept loop: adopt each connection into a reactor,
        // round-robin. No per-connection threads are spawned.
        let accept_handle = {
            let metrics = Arc::clone(&metrics);
            let stop = Arc::clone(&stop);
            let queues = reactor_queues.clone();
            std::thread::Builder::new()
                .name("gengnn-net-accept".to_string())
                .spawn(move || {
                    let mut conn_no = 0usize;
                    loop {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        let sock = match listener.accept() {
                            Ok((s, _)) => s,
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                // Idle: nothing pending; poll the stop
                                // flag again shortly.
                                std::thread::sleep(std::time::Duration::from_millis(20));
                                continue;
                            }
                            Err(_) => {
                                // Persistent accept errors (e.g. fd
                                // exhaustion) repeat immediately; back
                                // off instead of spinning a core.
                                std::thread::sleep(std::time::Duration::from_millis(10));
                                continue;
                            }
                        };
                        // The reactors drive every socket through the
                        // poller; a connection that cannot enter
                        // nonblocking mode cannot be served.
                        if sock.set_nonblocking(true).is_err() {
                            continue;
                        }
                        let _ = sock.set_nodelay(true);
                        metrics
                            .net()
                            .connections_accepted
                            .fetch_add(1, Ordering::Relaxed);
                        metrics
                            .net()
                            .connections_open
                            .fetch_add(1, Ordering::Relaxed);
                        queues[conn_no % queues.len()].send(ReactorMsg::NewConn(sock));
                        conn_no += 1;
                    }
                })
                .expect("spawn net accept loop")
        };

        Ok(NetServer {
            local_addr,
            server,
            metrics,
            stop,
            accept_handle: Some(accept_handle),
            pump_handle: Some(pump_handle),
            reactor_queues,
            reactor_handles,
        })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Models the wrapped coordinator currently serves (live — follows
    /// control-plane deploys).
    pub fn served_models(&self) -> Vec<String> {
        self.server.served_models()
    }

    /// Stop accepting, tear down the reactors (closing every
    /// connection), drain the coordinator, and return the final
    /// metrics.
    pub fn shutdown(mut self) -> Arc<Metrics> {
        // The accept loop polls this flag between nonblocking accepts,
        // so it exits within one tick — no wake connection required.
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        // Reactors close their connections on the way out (sweeping
        // in-flight routes, so the gauge lands at zero) and drop their
        // coordinator handles.
        for q in &self.reactor_queues {
            q.send(ReactorMsg::Shutdown);
        }
        for h in self.reactor_handles.drain(..) {
            let _ = h.join();
        }
        // Every other holder of the coordinator Arc is joined; unwrap
        // the sole remaining one and drain it. Closing the response
        // channel (inside Server::shutdown) releases the pump thread,
        // whose late route lookups all miss (counted as drops).
        let server = Arc::try_unwrap(self.server)
            .unwrap_or_else(|_| panic!("coordinator still shared at shutdown"));
        let metrics = server.shutdown();
        if let Some(h) = self.pump_handle.take() {
            let _ = h.join();
        }
        metrics
    }
}

/// Gather the seed rows (request order) out of a node-level output;
/// `None` if the output is too short for any requested local index.
fn seed_rows(output: &[f32], seed_locals: &[u32], out_dim: usize) -> Option<Vec<f32>> {
    let mut rows = Vec::with_capacity(seed_locals.len() * out_dim);
    for &li in seed_locals {
        let li = li as usize;
        rows.extend_from_slice(output.get(li * out_dim..(li + 1) * out_dim)?);
    }
    Some(rows)
}

/// Dial helper shared by the client and the load generator.
pub(crate) fn dial(addr: &str) -> Result<TcpStream> {
    let mut last_err = None;
    for a in addr
        .to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
    {
        match TcpStream::connect(a) {
            Ok(s) => {
                let _ = s.set_nodelay(true);
                return Ok(s);
            }
            Err(e) => last_err = Some(e),
        }
    }
    Err(match last_err {
        Some(e) => anyhow::Error::from(e).context(format!("connecting to {addr}")),
        None => anyhow::anyhow!("{addr} resolved to no addresses"),
    })
}

// The serving hot path must degrade, not panic: poisoned locks recover
// through `crate::util::sync`, wire decoding uses infallible array
// construction. Tests may still unwrap (a failed assertion is the
// point there).
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

//! The network serving front-end: wire-level ingress for the
//! coordinator's executor pool, plus the measurement harness that
//! puts traffic on it.
//!
//! After PR 2 the sharded executor pool was only reachable in-process
//! through `ServerHandle` channels; this subsystem is what makes the
//! ROADMAP's "serves heavy traffic" claim testable — FlowGNN-style
//! explicit streaming ingress in front of the lanes, GNNBuilder-style
//! measure-everything harness around them:
//!
//! * [`proto`]   — length-prefixed binary frames (version byte,
//!   FNV-1a checksum, raw COO graphs, TTL/priority QoS in v2+ request
//!   frames, bit-exact f32 outputs, in v3 the typed control [`Op`]
//!   family driving the live model registry, and in v4 the
//!   resident-graph ops: [`WireGraphQuery`] / [`WireGraphMutate`] and
//!   their responses)
//! * [`reactor`] — the nonblocking event-loop pool: a fixed set of
//!   `polly`-driven reactor threads owning every connection's frame
//!   reassembly, write draining, and admission state machine — plus,
//!   in resident mode, k-hop extraction and copy-on-write mutation
//!   application against the shared [`crate::resident::ResidentState`]
//! * [`server`]  — front-end wiring: accept loop handing connections
//!   to the reactors, response pump settling the route table,
//!   admission backpressure mapped to wire statuses (`Rejected`,
//!   `Expired`)
//! * [`client`]  — blocking client with connection pooling,
//!   deadline-carrying retries, and the v4 `graph_query` /
//!   `graph_mutate` calls
//! * [`loadgen`] — open-loop load generator: deterministic
//!   inter-arrival schedule (flat or diurnal), model mix, TTL/priority
//!   QoS profiles, mixed molecular/query/mutate scenario streams,
//!   HDR-style latency histogram reporting p50/p95/p99 + throughput,
//!   `BENCH_*.json` export
//!
//! `rust/tests/net_e2e.rs` pins the contract: outputs served over TCP
//! are bit-identical to in-process results for every manifest model,
//! a saturated Reject-mode queue surfaces as a `Rejected` wire status
//! rather than a hang or a dropped connection, and overload with TTLs
//! sheds by deadline (`Expired`) instead of by arrival.
//! `rust/tests/resident_e2e.rs` pins the v4 plane: wire-served k-hop
//! query rows bit-identical to full-graph forwards across interleaved
//! mutations, with pre-v4 clients unaffected (`docs/SCENARIOS.md`).

pub mod client;
pub mod loadgen;
pub mod proto;
pub mod reactor;
pub mod server;

pub use client::{NetClient, RequestOptions};
pub use loadgen::{LoadGenConfig, LoadGenReport};
pub use proto::{
    Op, WireControl, WireControlResp, WireFrame, WireGraphMutate, WireGraphMutateResp,
    WireGraphQuery, WireGraphQueryResp, WireQos, WireRequest, WireResponse, WireStatus, PROTO_V1,
    PROTO_V3, PROTO_V4, PROTO_VERSION,
};
pub use server::{NetServer, NetServerConfig};

//! The length-prefixed binary wire protocol of the serving front-end.
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! ┌──────────────┬─────────────────────────────────────────────────┐
//! │ u32 len      │ payload (len bytes)                             │
//! └──────────────┴─────────────────────────────────────────────────┘
//! payload:
//!   [0]      version byte (4 = current; 3, 2, and 1 still decoded)
//!   [1]      kind byte (1 = request, 2 = response,
//!            3 = control, 4 = control response — v3 frames only;
//!            5 = graph query, 6 = graph query response,
//!            7 = graph mutate, 8 = graph mutate response — v4 only)
//!   [2..6]   u32 FNV-1a checksum of the body
//!   [6..]    body
//!
//! request body (v2 and v3):
//!   u64 id · u32 ttl_ms · u8 priority · u16 model_len · model (utf-8)
//!   u32 n · u16 f_node · u16 f_edge · u32 num_edges
//!   edges   (num_edges × [u32 src, u32 dst])
//!   node_feat (n × f_node × f32)
//!   edge_feat (num_edges × f_edge × f32)
//!
//! request body (v1): identical minus the `ttl_ms`/`priority` fields
//! (decodes with default QoS: no deadline, normal priority).
//!
//! response body (identical in every version):
//!   u64 id · u16 model_len · model (utf-8) · u8 status
//!   status Ok:         u32 out_len · output (f32 × out_len)
//!   status otherwise:  u32 msg_len · message (utf-8)
//!
//! control body (v3 only; the typed [`Op`] enum):
//!   u64 id · u8 op · u16 model_len · model (utf-8)
//!   u16 digest_len · digest (utf-8, lowercase hex; may be empty)
//!   u64 version_arg (rollback target; 0 otherwise)
//!
//! control response body (v3 only):
//!   u64 id · u8 op · u8 status · u64 version
//!   u32 msg_len · message (utf-8)
//!
//! graph query body (v4 only; resident serving mode):
//!   u64 id · u32 ttl_ms · u8 priority · u8 hops · u16 fanout
//!   u16 num_seeds · seeds (num_seeds × u32)
//!
//! graph query response body (v4 only):
//!   u64 id · u8 status · u64 snapshot_version
//!   status Ok:         u16 num_seeds · u16 out_dim
//!                      outputs (num_seeds × out_dim × f32)
//!   status otherwise:  u32 msg_len · message (utf-8)
//!
//! graph mutate body (v4 only):
//!   u64 id · u16 num_ops · ops, each:
//!     u8 1 (add edge) · u32 a · u32 b
//!     u8 2 (remove edge) · u32 a · u32 b
//!     u8 3 (add node) · u16 f · features (f × f32)
//!
//! graph mutate response body (v4 only):
//!   u64 id · u8 status · u64 snapshot_version
//!   u32 applied · u32 rejected · u32 msg_len · message (utf-8)
//! ```
//!
//! Version negotiation is per-frame and server-side only: the server
//! decodes every version (the QoS fields default for v1) and always
//! answers each frame stamped with *that frame's* version; the
//! response layout never changed, so a v1 client never needs to know
//! v2 or v3 exist. Unknown versions are decode errors answered as
//! `BadRequest`. What v3 adds is not a new inference layout but a new
//! *frame family*: control ops ([`Op`]: `LOAD_MODEL` / `UNLOAD_MODEL`
//! / `ROLLBACK` / `LIST_MODELS`) against the live model registry —
//! before v3, every frame was implicitly an inference. v4 likewise
//! adds only a frame family: resident graph ops (`GRAPH_QUERY` /
//! `GRAPH_MUTATE`) against a server-hosted graph — inference and
//! control layouts are byte-identical under v4, so v1–v3 clients
//! interoperate with a resident server unmodified.
//!
//! Graphs cross the wire as raw COO — exactly the zero-preprocessing
//! input contract of the in-process path (paper §3.1), so the TCP
//! front-end feeds `Server::submit` the same `CooGraph` a local caller
//! would. f32 values are transmitted as their IEEE-754 bit patterns,
//! so a served output is **bit-identical** to the in-process result
//! (pinned by `rust/tests/net_e2e.rs`).
//!
//! Encoding is single-allocation (the frame buffer is sized up front
//! and filled in place); decoding walks one immutable byte slice with
//! a cursor and only materializes the feature vectors it must hand to
//! [`CooGraph`] — no intermediate reframing or re-parsing.

use anyhow::{bail, Result};

use crate::coordinator::Priority;
use crate::graph::CooGraph;

// The version table and negotiation rule live in the shared
// control-plane module (the ingress proxy needs them without this
// codec); re-exported here so wire-level callers keep one import path.
pub use crate::controlplane::version::{
    known_version, PROTO_V1, PROTO_V3, PROTO_V4, PROTO_VERSION,
};

/// Frame kind bytes. Public so the ingress proxy can route on the kind
/// without fully decoding the frame (see [`peek_frame`]).
pub const KIND_REQUEST: u8 = 1;
pub const KIND_RESPONSE: u8 = 2;
pub const KIND_CONTROL: u8 = 3;
pub const KIND_CONTROL_RESP: u8 = 4;
pub const KIND_GRAPH_QUERY: u8 = 5;
pub const KIND_GRAPH_QUERY_RESP: u8 = 6;
pub const KIND_GRAPH_MUTATE: u8 = 7;
pub const KIND_GRAPH_MUTATE_RESP: u8 = 8;

/// Refuse frames above this payload size (a corrupt or hostile length
/// prefix must not allocate unbounded memory).
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Correlation id the server uses when answering a frame whose own id
/// could not be trusted (see [`salvage_request_id`]). Clients must not
/// assign this id to real requests.
pub const BAD_FRAME_ID: u64 = u64::MAX;

/// Bytes of frame overhead before the body (version, kind, checksum).
const HEADER_BYTES: usize = 6;

/// Wire status of a response.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireStatus {
    /// Inference succeeded; the payload is the output vector.
    Ok,
    /// Admission control shed the request (Reject policy, queue full).
    Rejected,
    /// The request was admitted but failed (unknown model, oversized
    /// graph, executor error); the payload is the error message.
    Error,
    /// The server could not decode the request frame.
    BadRequest,
    /// The request's TTL ran out before a lane executed it
    /// (shed-by-deadline; the payload is the explanatory message).
    Expired,
}

impl WireStatus {
    fn to_byte(self) -> u8 {
        match self {
            WireStatus::Ok => 0,
            WireStatus::Rejected => 1,
            WireStatus::Error => 2,
            WireStatus::BadRequest => 3,
            WireStatus::Expired => 4,
        }
    }

    fn from_byte(b: u8) -> Result<WireStatus> {
        Ok(match b {
            0 => WireStatus::Ok,
            1 => WireStatus::Rejected,
            2 => WireStatus::Error,
            3 => WireStatus::BadRequest,
            4 => WireStatus::Expired,
            _ => bail!("unknown wire status byte {b}"),
        })
    }
}

/// Per-request QoS carried in a v2 request frame.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireQos {
    /// Time-to-live in milliseconds from submission; 0 = no deadline
    /// (also what every v1 frame decodes to).
    pub ttl_ms: u32,
    pub priority: Priority,
}

impl WireQos {
    pub fn new(ttl_ms: u32, priority: Priority) -> WireQos {
        WireQos { ttl_ms, priority }
    }
}

/// One inference request as it crosses the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct WireRequest {
    /// Caller-chosen correlation id, echoed verbatim in the response.
    pub id: u64,
    pub model: String,
    pub qos: WireQos,
    pub graph: CooGraph,
}

/// One inference response as it crosses the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct WireResponse {
    pub id: u64,
    pub model: String,
    pub status: WireStatus,
    /// Output vector (empty unless `status == Ok`).
    pub output: Vec<f32>,
    /// Error message (empty when `status == Ok`).
    pub error: String,
}

impl WireResponse {
    pub fn ok(id: u64, model: impl Into<String>, output: Vec<f32>) -> WireResponse {
        WireResponse {
            id,
            model: model.into(),
            status: WireStatus::Ok,
            output,
            error: String::new(),
        }
    }

    pub fn err(
        id: u64,
        model: impl Into<String>,
        status: WireStatus,
        error: impl Into<String>,
    ) -> WireResponse {
        WireResponse {
            id,
            model: model.into(),
            status,
            output: Vec::new(),
            error: error.into(),
        }
    }

    pub fn is_ok(&self) -> bool {
        self.status == WireStatus::Ok
    }
}

/// A control-plane operation against the server's model registry —
/// the typed op table of the v3 wire protocol.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Make a model live (validates blob digests + re-runs the plan
    /// analyzer before the cutover).
    LoadModel,
    /// Remove a model from admission; in-flight work completes.
    UnloadModel,
    /// Restore an earlier registry version's serving set.
    Rollback,
    /// Report catalog, live set, and version history.
    ListModels,
}

impl Op {
    fn to_byte(self) -> u8 {
        match self {
            Op::LoadModel => 1,
            Op::UnloadModel => 2,
            Op::Rollback => 3,
            Op::ListModels => 4,
        }
    }

    /// Decode an op byte (public so the ingress can answer a control
    /// frame it peeked but never forwarded, echoing the caller's op).
    pub fn from_byte(b: u8) -> Result<Op> {
        Ok(match b {
            1 => Op::LoadModel,
            2 => Op::UnloadModel,
            3 => Op::Rollback,
            4 => Op::ListModels,
            _ => bail!("unknown control op byte {b}"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Op::LoadModel => "LOAD_MODEL",
            Op::UnloadModel => "UNLOAD_MODEL",
            Op::Rollback => "ROLLBACK",
            Op::ListModels => "LIST_MODELS",
        }
    }
}

/// One control request as it crosses the wire (v3 frames only).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireControl {
    /// Caller-chosen correlation id, echoed in the control response.
    pub id: u64,
    pub op: Op,
    /// Model the op applies to (empty for `Rollback`/`ListModels`).
    pub model: String,
    /// Expected model digest for `LoadModel` (lowercase hex; empty =
    /// unpinned, trust the server catalog).
    pub digest: String,
    /// Rollback target version; 0 otherwise.
    pub version: u64,
}

/// The server's answer to a control request.
#[derive(Clone, Debug, PartialEq)]
pub struct WireControlResp {
    pub id: u64,
    pub op: Op,
    /// `Ok` on success; `Error` (message explains) on a rejected op.
    pub status: WireStatus,
    /// Registry head version after the op.
    pub version: u64,
    /// Detail message; for `ListModels`, a JSON document.
    pub message: String,
}

impl WireControlResp {
    pub fn is_ok(&self) -> bool {
        self.status == WireStatus::Ok
    }
}

/// One resident k-hop query as it crosses the wire (v4 frames only).
/// The server extracts the `hops`-hop closure of `seeds` from its
/// resident graph and answers with one output row per seed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireGraphQuery {
    /// Caller-chosen correlation id, echoed in the response.
    pub id: u64,
    pub qos: WireQos,
    /// Neighborhood depth; must be at least the resident model's layer
    /// count or the server rejects the query (exactness contract).
    pub hops: u8,
    /// 0 = full expansion (bit-exact); k > 0 = expand only the first
    /// k ascending neighbors per node (bounded approximation).
    pub fanout: u16,
    /// Global node ids in the resident graph (distinct, non-empty).
    pub seeds: Vec<u32>,
}

/// The server's answer to a graph query.
#[derive(Clone, Debug, PartialEq)]
pub struct WireGraphQueryResp {
    pub id: u64,
    pub status: WireStatus,
    /// Version of the resident snapshot the query resolved (0 when the
    /// query never reached the store).
    pub snapshot_version: u64,
    /// Output width per seed (0 unless `status == Ok`).
    pub out_dim: usize,
    /// Row-major `[num_seeds, out_dim]` outputs, seed request order.
    pub outputs: Vec<f32>,
    /// Error message (empty when `status == Ok`).
    pub error: String,
}

impl WireGraphQueryResp {
    pub fn ok(id: u64, snapshot_version: u64, out_dim: usize, outputs: Vec<f32>) -> Self {
        WireGraphQueryResp {
            id,
            status: WireStatus::Ok,
            snapshot_version,
            out_dim,
            outputs,
            error: String::new(),
        }
    }

    pub fn err(
        id: u64,
        status: WireStatus,
        snapshot_version: u64,
        error: impl Into<String>,
    ) -> Self {
        WireGraphQueryResp {
            id,
            status,
            snapshot_version,
            out_dim: 0,
            outputs: Vec::new(),
            error: error.into(),
        }
    }

    pub fn is_ok(&self) -> bool {
        self.status == WireStatus::Ok
    }

    /// Output row of the `i`-th requested seed.
    pub fn seed_output(&self, i: usize) -> Option<&[f32]> {
        if self.out_dim == 0 {
            return None;
        }
        self.outputs.get(i * self.out_dim..(i + 1) * self.out_dim)
    }
}

/// One mutation batch against the resident graph (v4 frames only).
/// Ops apply in order with copy-on-write snapshot semantics — see
/// [`crate::resident::ResidentStore::apply`].
#[derive(Clone, Debug, PartialEq)]
pub struct WireGraphMutate {
    pub id: u64,
    pub ops: Vec<crate::resident::MutateOp>,
}

/// The server's answer to a mutation batch.
#[derive(Clone, Debug, PartialEq)]
pub struct WireGraphMutateResp {
    pub id: u64,
    /// `Ok` when the batch was processed (even if some ops were
    /// rejected — the counts tell the story); `Error`/`BadRequest`
    /// when it never reached the store.
    pub status: WireStatus,
    /// Resident snapshot version after the batch.
    pub snapshot_version: u64,
    /// Ops applied / rejected within the batch.
    pub applied: u32,
    pub rejected: u32,
    pub message: String,
}

impl WireGraphMutateResp {
    pub fn is_ok(&self) -> bool {
        self.status == WireStatus::Ok
    }
}

/// A decoded frame.
#[derive(Clone, Debug, PartialEq)]
pub enum WireFrame {
    Request(WireRequest),
    Response(WireResponse),
    Control(WireControl),
    ControlResp(WireControlResp),
    GraphQuery(WireGraphQuery),
    GraphQueryResp(WireGraphQueryResp),
    GraphMutate(WireGraphMutate),
    GraphMutateResp(WireGraphMutateResp),
}

/// FNV-1a over the body bytes — cheap, deterministic, and enough to
/// catch framing slips and truncation (this is an integrity check for
/// a trusted link, not an authenticity mechanism).
fn checksum(body: &[u8]) -> u32 {
    let mut h: u32 = 0x811c9dc5;
    for &b in body {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

// ---- encoding -----------------------------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Seal a body into a full frame (length prefix + header + body).
fn seal(version: u8, kind: u8, body: Vec<u8>) -> Vec<u8> {
    let payload_len = HEADER_BYTES + body.len();
    let mut out = Vec::with_capacity(4 + payload_len);
    put_u32(&mut out, payload_len as u32);
    out.push(version);
    out.push(kind);
    put_u32(&mut out, checksum(&body));
    out.extend_from_slice(&body);
    out
}

/// Encode a request into one contiguous frame ready for `write_all`.
pub fn encode_request(req: &WireRequest) -> Result<Vec<u8>> {
    encode_request_parts(req.id, &req.model, req.qos, &req.graph)
}

fn check_graph_bounds(model: &str, g: &CooGraph) -> Result<()> {
    if model.len() > u16::MAX as usize {
        bail!("model name too long");
    }
    if g.n > u32::MAX as usize || g.edges.len() > u32::MAX as usize {
        bail!("graph too large for the wire format");
    }
    if g.f_node > u16::MAX as usize || g.f_edge > u16::MAX as usize {
        bail!("feature width too large for the wire format");
    }
    Ok(())
}

fn put_graph(body: &mut Vec<u8>, model: &str, g: &CooGraph) {
    put_u16(body, model.len() as u16);
    body.extend_from_slice(model.as_bytes());
    put_u32(body, g.n as u32);
    put_u16(body, g.f_node as u16);
    put_u16(body, g.f_edge as u16);
    put_u32(body, g.edges.len() as u32);
    for &(s, t) in &g.edges {
        put_u32(body, s);
        put_u32(body, t);
    }
    put_f32s(body, &g.node_feat);
    put_f32s(body, &g.edge_feat);
}

/// Borrowed-parts variant of [`encode_request`]: hot paths (the load
/// generator's writer, [`super::NetClient::infer`]) serialize straight
/// from a borrowed graph without cloning it into a [`WireRequest`].
/// Emits the current (v2) layout.
pub fn encode_request_parts(id: u64, model: &str, qos: WireQos, g: &CooGraph) -> Result<Vec<u8>> {
    check_graph_bounds(model, g)?;
    let mut body = Vec::with_capacity(
        8 + 5
            + 2
            + model.len()
            + 12
            + g.edges.len() * 8
            + (g.node_feat.len() + g.edge_feat.len()) * 4,
    );
    put_u64(&mut body, id);
    put_u32(&mut body, qos.ttl_ms);
    body.push(qos.priority.to_byte());
    put_graph(&mut body, model, g);
    Ok(seal(PROTO_VERSION, KIND_REQUEST, body))
}

/// Encode the legacy v1 request layout (no QoS fields). Kept for the
/// version-compatibility tests and for talking to pre-v2 servers,
/// which reject unknown versions as `BadRequest`.
pub fn encode_request_parts_v1(id: u64, model: &str, g: &CooGraph) -> Result<Vec<u8>> {
    check_graph_bounds(model, g)?;
    let mut body = Vec::with_capacity(
        8 + 2
            + model.len()
            + 12
            + g.edges.len() * 8
            + (g.node_feat.len() + g.edge_feat.len()) * 4,
    );
    put_u64(&mut body, id);
    put_graph(&mut body, model, g);
    Ok(seal(PROTO_V1, KIND_REQUEST, body))
}

/// Encode a response into one contiguous frame, stamped
/// [`PROTO_VERSION`]. Servers answering a v1 client use
/// [`encode_response_with_version`] to echo the caller's version.
pub fn encode_response(resp: &WireResponse) -> Result<Vec<u8>> {
    encode_response_with_version(PROTO_VERSION, resp)
}

/// Encode a control request (always a v3 frame — control ops did not
/// exist before v3, so there is no version to negotiate).
pub fn encode_control(ctrl: &WireControl) -> Result<Vec<u8>> {
    if ctrl.model.len() > u16::MAX as usize {
        bail!("model name too long");
    }
    if ctrl.digest.len() > u16::MAX as usize {
        bail!("digest too long");
    }
    let mut body = Vec::with_capacity(8 + 1 + 2 + ctrl.model.len() + 2 + ctrl.digest.len() + 8);
    put_u64(&mut body, ctrl.id);
    body.push(ctrl.op.to_byte());
    put_u16(&mut body, ctrl.model.len() as u16);
    body.extend_from_slice(ctrl.model.as_bytes());
    put_u16(&mut body, ctrl.digest.len() as u16);
    body.extend_from_slice(ctrl.digest.as_bytes());
    put_u64(&mut body, ctrl.version);
    Ok(seal(PROTO_V3, KIND_CONTROL, body))
}

/// Encode a control response (always a v3 frame).
pub fn encode_control_resp(resp: &WireControlResp) -> Result<Vec<u8>> {
    if resp.message.len() > u32::MAX as usize {
        bail!("control message too large");
    }
    let mut body = Vec::with_capacity(8 + 1 + 1 + 8 + 4 + resp.message.len());
    put_u64(&mut body, resp.id);
    body.push(resp.op.to_byte());
    body.push(resp.status.to_byte());
    put_u64(&mut body, resp.version);
    put_u32(&mut body, resp.message.len() as u32);
    body.extend_from_slice(resp.message.as_bytes());
    Ok(seal(PROTO_V3, KIND_CONTROL_RESP, body))
}

/// Encode a resident k-hop query (always a v4 frame — resident ops
/// did not exist before v4).
pub fn encode_graph_query(q: &WireGraphQuery) -> Result<Vec<u8>> {
    if q.seeds.is_empty() {
        bail!("graph query carries no seeds");
    }
    if q.seeds.len() > u16::MAX as usize {
        bail!("too many seeds for the wire format");
    }
    let mut body = Vec::with_capacity(8 + 5 + 3 + 2 + q.seeds.len() * 4);
    put_u64(&mut body, q.id);
    put_u32(&mut body, q.qos.ttl_ms);
    body.push(q.qos.priority.to_byte());
    body.push(q.hops);
    put_u16(&mut body, q.fanout);
    put_u16(&mut body, q.seeds.len() as u16);
    for &s in &q.seeds {
        put_u32(&mut body, s);
    }
    Ok(seal(PROTO_V4, KIND_GRAPH_QUERY, body))
}

/// Encode a graph query response (always a v4 frame).
pub fn encode_graph_query_resp(resp: &WireGraphQueryResp) -> Result<Vec<u8>> {
    let mut body = Vec::with_capacity(8 + 1 + 8 + 8 + resp.outputs.len() * 4 + resp.error.len());
    put_u64(&mut body, resp.id);
    body.push(resp.status.to_byte());
    put_u64(&mut body, resp.snapshot_version);
    if resp.status == WireStatus::Ok {
        if resp.out_dim == 0 || resp.outputs.len() % resp.out_dim != 0 {
            bail!(
                "graph query outputs ({}) are not rows of out_dim {}",
                resp.outputs.len(),
                resp.out_dim
            );
        }
        let num_seeds = resp.outputs.len() / resp.out_dim;
        if num_seeds > u16::MAX as usize || resp.out_dim > u16::MAX as usize {
            bail!("graph query response too large for the wire format");
        }
        put_u16(&mut body, num_seeds as u16);
        put_u16(&mut body, resp.out_dim as u16);
        put_f32s(&mut body, &resp.outputs);
    } else {
        if resp.error.len() > u32::MAX as usize {
            bail!("error message too large");
        }
        put_u32(&mut body, resp.error.len() as u32);
        body.extend_from_slice(resp.error.as_bytes());
    }
    Ok(seal(PROTO_V4, KIND_GRAPH_QUERY_RESP, body))
}

/// Encode a resident mutation batch (always a v4 frame).
pub fn encode_graph_mutate(m: &WireGraphMutate) -> Result<Vec<u8>> {
    use crate::resident::MutateOp;
    if m.ops.len() > u16::MAX as usize {
        bail!("too many mutation ops for the wire format");
    }
    let mut body = Vec::with_capacity(8 + 2 + m.ops.len() * 9);
    put_u64(&mut body, m.id);
    put_u16(&mut body, m.ops.len() as u16);
    for op in &m.ops {
        match op {
            MutateOp::AddEdge(a, b) => {
                body.push(1);
                put_u32(&mut body, *a);
                put_u32(&mut body, *b);
            }
            MutateOp::RemoveEdge(a, b) => {
                body.push(2);
                put_u32(&mut body, *a);
                put_u32(&mut body, *b);
            }
            MutateOp::AddNode(feat) => {
                if feat.len() > u16::MAX as usize {
                    bail!("node feature width too large for the wire format");
                }
                body.push(3);
                put_u16(&mut body, feat.len() as u16);
                put_f32s(&mut body, feat);
            }
        }
    }
    Ok(seal(PROTO_V4, KIND_GRAPH_MUTATE, body))
}

/// Encode a graph mutate response (always a v4 frame).
pub fn encode_graph_mutate_resp(resp: &WireGraphMutateResp) -> Result<Vec<u8>> {
    if resp.message.len() > u32::MAX as usize {
        bail!("mutate message too large");
    }
    let mut body = Vec::with_capacity(8 + 1 + 8 + 8 + 4 + resp.message.len());
    put_u64(&mut body, resp.id);
    body.push(resp.status.to_byte());
    put_u64(&mut body, resp.snapshot_version);
    put_u32(&mut body, resp.applied);
    put_u32(&mut body, resp.rejected);
    put_u32(&mut body, resp.message.len() as u32);
    body.extend_from_slice(resp.message.as_bytes());
    Ok(seal(PROTO_V4, KIND_GRAPH_MUTATE_RESP, body))
}

/// Encode a response stamped with an explicit protocol version (the
/// body layout is identical in every version, so a server negotiates
/// by simply echoing whatever version the request frame carried — a
/// v1 client never sees a version byte it does not understand).
pub fn encode_response_with_version(version: u8, resp: &WireResponse) -> Result<Vec<u8>> {
    if !known_version(version) {
        bail!("cannot encode protocol version {version}");
    }
    if resp.model.len() > u16::MAX as usize {
        bail!("model name too long");
    }
    let mut body =
        Vec::with_capacity(8 + 2 + resp.model.len() + 5 + resp.output.len() * 4 + resp.error.len());
    put_u64(&mut body, resp.id);
    put_u16(&mut body, resp.model.len() as u16);
    body.extend_from_slice(resp.model.as_bytes());
    body.push(resp.status.to_byte());
    if resp.status == WireStatus::Ok {
        if resp.output.len() > u32::MAX as usize {
            bail!("output too large for the wire format");
        }
        put_u32(&mut body, resp.output.len() as u32);
        put_f32s(&mut body, &resp.output);
    } else {
        if resp.error.len() > u32::MAX as usize {
            bail!("error message too large");
        }
        put_u32(&mut body, resp.error.len() as u32);
        body.extend_from_slice(resp.error.as_bytes());
    }
    Ok(seal(version, KIND_RESPONSE, body))
}

// ---- decoding -----------------------------------------------------------

/// Infallible fixed-width array construction from already
/// length-checked slices. Indexing keeps the bounds check (a short
/// slice is a plain panic-free `take` error upstream) while avoiding
/// the `try_into().unwrap()` panic path this module forbids.
fn arr2(b: &[u8]) -> [u8; 2] {
    [b[0], b[1]]
}

fn arr4(b: &[u8]) -> [u8; 4] {
    [b[0], b[1], b[2], b[3]]
}

fn arr8(b: &[u8]) -> [u8; 8] {
    [b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]
}

/// Cursor over one immutable payload slice.
struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.b.len() - self.i < n {
            bail!(
                "truncated frame: wanted {n} bytes at offset {}, have {}",
                self.i,
                self.b.len() - self.i
            );
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(arr2(self.take(2)?)))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(arr4(self.take(4)?)))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(arr8(self.take(8)?)))
    }

    fn utf8(&mut self, n: usize) -> Result<String> {
        Ok(std::str::from_utf8(self.take(n)?)?.to_string())
    }

    fn f32s(&mut self, count: usize) -> Result<Vec<f32>> {
        let raw = self.take(count.checked_mul(4).ok_or_else(|| {
            anyhow::anyhow!("f32 vector length overflow")
        })?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(arr4(c)))
            .collect())
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn done(&self) -> bool {
        self.i == self.b.len()
    }
}

/// Decode one payload (a frame minus its length prefix) into a typed
/// frame, verifying version and checksum. Both protocol versions are
/// accepted: v1 request frames carry no QoS fields and decode with
/// [`WireQos::default`] (no deadline, normal priority).
pub fn decode_frame(payload: &[u8]) -> Result<WireFrame> {
    if payload.len() < HEADER_BYTES {
        bail!("frame too short ({} bytes)", payload.len());
    }
    let version = payload[0];
    if !known_version(version) {
        bail!(
            "unsupported protocol version {version} (expected {PROTO_V1}, {PROTO_VERSION}, {PROTO_V3}, or {PROTO_V4})"
        );
    }
    let kind = payload[1];
    let want = u32::from_le_bytes(arr4(&payload[2..6]));
    let body = &payload[HEADER_BYTES..];
    let got = checksum(body);
    if want != got {
        bail!("checksum mismatch: frame says {want:#010x}, body hashes to {got:#010x}");
    }
    let mut c = Cursor { b: body, i: 0 };
    let frame = match kind {
        KIND_REQUEST => {
            let id = c.u64()?;
            let qos = if version >= PROTO_VERSION {
                WireQos {
                    ttl_ms: c.u32()?,
                    priority: Priority::from_byte(c.u8()?)?,
                }
            } else {
                WireQos::default()
            };
            let model_len = c.u16()? as usize;
            let model = c.utf8(model_len)?;
            let n = c.u32()? as usize;
            let f_node = c.u16()? as usize;
            let f_edge = c.u16()? as usize;
            let num_edges = c.u32()? as usize;
            // Bound the claimed count by the bytes actually present
            // before allocating for it (a corrupt count that passed the
            // checksum must not drive a multi-GiB reservation).
            if num_edges.saturating_mul(8) > c.remaining() {
                bail!("edge count {num_edges} exceeds the frame body");
            }
            let mut edges = Vec::with_capacity(num_edges);
            for _ in 0..num_edges {
                let s = c.u32()?;
                let t = c.u32()?;
                edges.push((s, t));
            }
            let node_feat = c.f32s(n.checked_mul(f_node).ok_or_else(|| {
                anyhow::anyhow!("node feature size overflow")
            })?)?;
            let edge_feat = c.f32s(num_edges.checked_mul(f_edge).ok_or_else(|| {
                anyhow::anyhow!("edge feature size overflow")
            })?)?;
            let graph = CooGraph {
                n,
                edges,
                node_feat,
                f_node,
                edge_feat,
                f_edge,
            };
            graph.validate()?;
            WireFrame::Request(WireRequest {
                id,
                model,
                qos,
                graph,
            })
        }
        KIND_RESPONSE => {
            let id = c.u64()?;
            let model_len = c.u16()? as usize;
            let model = c.utf8(model_len)?;
            let status = WireStatus::from_byte(c.u8()?)?;
            let (output, error) = if status == WireStatus::Ok {
                let out_len = c.u32()? as usize;
                (c.f32s(out_len)?, String::new())
            } else {
                let msg_len = c.u32()? as usize;
                (Vec::new(), c.utf8(msg_len)?)
            };
            WireFrame::Response(WireResponse {
                id,
                model,
                status,
                output,
                error,
            })
        }
        KIND_CONTROL => {
            if version != PROTO_V3 {
                bail!("control frames require protocol version {PROTO_V3} (got {version})");
            }
            let id = c.u64()?;
            let op = Op::from_byte(c.u8()?)?;
            let model_len = c.u16()? as usize;
            let model = c.utf8(model_len)?;
            let digest_len = c.u16()? as usize;
            let digest = c.utf8(digest_len)?;
            let version_arg = c.u64()?;
            WireFrame::Control(WireControl {
                id,
                op,
                model,
                digest,
                version: version_arg,
            })
        }
        KIND_CONTROL_RESP => {
            if version != PROTO_V3 {
                bail!("control frames require protocol version {PROTO_V3} (got {version})");
            }
            let id = c.u64()?;
            let op = Op::from_byte(c.u8()?)?;
            let status = WireStatus::from_byte(c.u8()?)?;
            let head = c.u64()?;
            let msg_len = c.u32()? as usize;
            let message = c.utf8(msg_len)?;
            WireFrame::ControlResp(WireControlResp {
                id,
                op,
                status,
                version: head,
                message,
            })
        }
        KIND_GRAPH_QUERY => {
            if version != PROTO_V4 {
                bail!("resident frames require protocol version {PROTO_V4} (got {version})");
            }
            let id = c.u64()?;
            let qos = WireQos {
                ttl_ms: c.u32()?,
                priority: Priority::from_byte(c.u8()?)?,
            };
            let hops = c.u8()?;
            let fanout = c.u16()?;
            let num_seeds = c.u16()? as usize;
            if num_seeds.saturating_mul(4) > c.remaining() {
                bail!("seed count {num_seeds} exceeds the frame body");
            }
            let mut seeds = Vec::with_capacity(num_seeds);
            for _ in 0..num_seeds {
                seeds.push(c.u32()?);
            }
            WireFrame::GraphQuery(WireGraphQuery {
                id,
                qos,
                hops,
                fanout,
                seeds,
            })
        }
        KIND_GRAPH_QUERY_RESP => {
            if version != PROTO_V4 {
                bail!("resident frames require protocol version {PROTO_V4} (got {version})");
            }
            let id = c.u64()?;
            let status = WireStatus::from_byte(c.u8()?)?;
            let snapshot_version = c.u64()?;
            let resp = if status == WireStatus::Ok {
                let num_seeds = c.u16()? as usize;
                let out_dim = c.u16()? as usize;
                let outputs = c.f32s(num_seeds.checked_mul(out_dim).ok_or_else(|| {
                    anyhow::anyhow!("graph query output size overflow")
                })?)?;
                WireGraphQueryResp::ok(id, snapshot_version, out_dim, outputs)
            } else {
                let msg_len = c.u32()? as usize;
                WireGraphQueryResp::err(id, status, snapshot_version, c.utf8(msg_len)?)
            };
            WireFrame::GraphQueryResp(resp)
        }
        KIND_GRAPH_MUTATE => {
            if version != PROTO_V4 {
                bail!("resident frames require protocol version {PROTO_V4} (got {version})");
            }
            let id = c.u64()?;
            let num_ops = c.u16()? as usize;
            let mut ops = Vec::with_capacity(num_ops.min(c.remaining()));
            for _ in 0..num_ops {
                ops.push(match c.u8()? {
                    1 => crate::resident::MutateOp::AddEdge(c.u32()?, c.u32()?),
                    2 => crate::resident::MutateOp::RemoveEdge(c.u32()?, c.u32()?),
                    3 => {
                        let f = c.u16()? as usize;
                        crate::resident::MutateOp::AddNode(c.f32s(f)?)
                    }
                    k => bail!("unknown mutation op byte {k}"),
                });
            }
            WireFrame::GraphMutate(WireGraphMutate { id, ops })
        }
        KIND_GRAPH_MUTATE_RESP => {
            if version != PROTO_V4 {
                bail!("resident frames require protocol version {PROTO_V4} (got {version})");
            }
            let id = c.u64()?;
            let status = WireStatus::from_byte(c.u8()?)?;
            let snapshot_version = c.u64()?;
            let applied = c.u32()?;
            let rejected = c.u32()?;
            let msg_len = c.u32()? as usize;
            let message = c.utf8(msg_len)?;
            WireFrame::GraphMutateResp(WireGraphMutateResp {
                id,
                status,
                snapshot_version,
                applied,
                rejected,
                message,
            })
        }
        k => bail!("unknown frame kind byte {k}"),
    };
    if !c.done() {
        bail!("frame has {} trailing bytes", payload.len() - HEADER_BYTES - c.i);
    }
    Ok(frame)
}

/// Best-effort request-id extraction from a payload that failed full
/// decoding, so a `BadRequest` answer can carry the caller's own
/// correlation id (e.g. a well-framed request whose graph failed
/// validation). The id is returned only when the envelope is
/// trustworthy — right version, request kind, matching checksum;
/// anything less yields `None` and the server answers under
/// [`BAD_FRAME_ID`], never under a guessed id that could collide with
/// a different in-flight request.
pub fn salvage_request_id(payload: &[u8]) -> Option<u64> {
    // Control and resident bodies also lead with the u64 id, so a
    // well-framed v3 control op or v4 graph op that fails full
    // decoding (e.g. unknown op byte, out-of-range seed) still gets
    // its answer under the caller's own correlation id.
    let kind_ok = payload.len() >= 2
        && (payload[1] == KIND_REQUEST
            || (payload[0] == PROTO_V3 && payload[1] == KIND_CONTROL)
            || (payload[0] == PROTO_V4
                && (payload[1] == KIND_GRAPH_QUERY || payload[1] == KIND_GRAPH_MUTATE)));
    if payload.len() < HEADER_BYTES + 8 || !known_version(payload[0]) || !kind_ok {
        return None;
    }
    let want = u32::from_le_bytes(arr4(&payload[2..6]));
    let body = &payload[HEADER_BYTES..];
    if checksum(body) != want {
        return None;
    }
    Some(u64::from_le_bytes(arr8(&body[..8])))
}

/// The routing-relevant envelope of a client→server payload, decoded
/// without materializing the graph body: what the ingress proxy needs
/// to pick a backend (model, kind) and to install a response route
/// (id, version), nothing more.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FramePeek {
    pub version: u8,
    /// One of the client→server kinds: [`KIND_REQUEST`],
    /// [`KIND_CONTROL`], [`KIND_GRAPH_QUERY`], [`KIND_GRAPH_MUTATE`].
    pub kind: u8,
    /// Caller-chosen correlation id (the leading u64 of every body).
    pub id: u64,
    /// Model an inference request targets; `None` for control and
    /// resident frames, which route model-free.
    pub model: Option<String>,
    /// Control op byte ([`KIND_CONTROL`] frames only, 0 otherwise).
    pub ctrl_op: u8,
}

/// Peek a payload's routing envelope, verifying version, checksum, and
/// that the kind is a client→server one. Validation mirrors
/// [`decode_frame`]'s envelope checks exactly, so every frame the
/// ingress forwards is one a backend will at least answer under the
/// (rewritten) caller id — deeper body corruption still decodes to a
/// canonical backend-side `BadRequest`.
pub fn peek_frame(payload: &[u8]) -> Result<FramePeek> {
    if payload.len() < HEADER_BYTES + 8 {
        bail!("frame too short to route ({} bytes)", payload.len());
    }
    let version = payload[0];
    if !known_version(version) {
        bail!(
            "unsupported protocol version {version} (expected {PROTO_V1}, {PROTO_VERSION}, {PROTO_V3}, or {PROTO_V4})"
        );
    }
    let kind = payload[1];
    let want = u32::from_le_bytes(arr4(&payload[2..6]));
    let body = &payload[HEADER_BYTES..];
    let got = checksum(body);
    if want != got {
        bail!("checksum mismatch: frame says {want:#010x}, body hashes to {got:#010x}");
    }
    let mut c = Cursor { b: body, i: 0 };
    let id = c.u64()?;
    let (model, ctrl_op) = match kind {
        KIND_REQUEST => {
            if version >= PROTO_VERSION {
                c.take(5)?; // ttl_ms + priority, irrelevant for routing
            }
            let model_len = c.u16()? as usize;
            (Some(c.utf8(model_len)?), 0)
        }
        KIND_CONTROL => (None, c.u8()?),
        KIND_GRAPH_QUERY | KIND_GRAPH_MUTATE => (None, 0),
        k => bail!("frame kind byte {k} is not a client request"),
    };
    Ok(FramePeek {
        version,
        kind,
        id,
        model,
        ctrl_op,
    })
}

/// Rewrite the correlation id of a sealed payload in place, fixing the
/// checksum. Every frame kind's body leads with the u64 id, so the
/// ingress can stamp its own id onto a proxied frame (and stamp the
/// caller's id back onto the relayed response) while leaving every
/// other byte untouched — the mechanism behind the fleet-scope
/// bit-exactness contract (`docs/CLUSTER.md`). Because the checksum is
/// recomputed over the whole body, only call this on payloads whose
/// checksum already verified (via [`peek_frame`] or [`decode_frame`]);
/// resealing an unverified body would mask transit corruption.
pub fn rewrite_frame_id(payload: &mut [u8], id: u64) -> Result<()> {
    if payload.len() < HEADER_BYTES + 8 {
        bail!("frame too short to carry an id ({} bytes)", payload.len());
    }
    payload[HEADER_BYTES..HEADER_BYTES + 8].copy_from_slice(&id.to_le_bytes());
    let sum = checksum(&payload[HEADER_BYTES..]);
    payload[2..6].copy_from_slice(&sum.to_le_bytes());
    Ok(())
}

/// The correlation id of a sealed payload (the leading u64 of every
/// body), with no validation beyond length — how the ingress demuxes
/// backend responses back onto client routes. Returns `None` for
/// payloads too short to carry an id.
pub fn frame_id(payload: &[u8]) -> Option<u64> {
    if payload.len() < HEADER_BYTES + 8 {
        return None;
    }
    Some(u64::from_le_bytes(arr8(
        &payload[HEADER_BYTES..HEADER_BYTES + 8],
    )))
}

/// Fault-injection primitive: flip a sealed v2+ inference request's
/// QoS priority byte to an invalid value and re-seal the checksum.
/// The checksum stays valid, so the receiving backend's id salvage
/// works and its `BadRequest` answer comes back under the frame's own
/// correlation id — the corruption surfaces as a reconciled `failed`
/// outcome, never as a lost request. Returns `false` (payload
/// untouched) when the frame is not a v2+ inference request.
pub fn corrupt_request_priority(payload: &mut [u8]) -> bool {
    // Body layout: id u64, ttl u32, priority u8 — offset 12.
    if payload.len() < HEADER_BYTES + 13
        || payload[1] != KIND_REQUEST
        || payload[0] < PROTO_VERSION
        || !known_version(payload[0])
    {
        return false;
    }
    payload[HEADER_BYTES + 12] = 0xFF;
    let sum = checksum(&payload[HEADER_BYTES..]);
    payload[2..6].copy_from_slice(&sum.to_le_bytes());
    true
}

/// Read one frame's payload from a stream. Returns `Ok(None)` on a
/// clean EOF at a frame boundary (the peer closed the connection);
/// mid-frame EOF and oversized lengths are errors.
pub fn read_frame(r: &mut impl std::io::Read) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    let mut filled = 0;
    while filled < 4 {
        let k = r.read(&mut len_buf[filled..])?;
        if k == 0 {
            if filled == 0 {
                return Ok(None);
            }
            bail!("EOF inside a frame length prefix");
        }
        filled += k;
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len < HEADER_BYTES {
        bail!("frame length {len} below header size");
    }
    if len > MAX_FRAME_BYTES {
        bail!("frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Some(payload))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{molecular_graph, MolConfig};
    use crate::util::rng::Rng;

    fn graph() -> CooGraph {
        molecular_graph(&mut Rng::new(3), &MolConfig::molhiv())
    }

    #[test]
    fn request_roundtrips_bit_exact() {
        let req = WireRequest {
            id: 0xDEAD_BEEF_1234,
            model: "gin_vn".into(),
            qos: WireQos::new(1500, Priority::High),
            graph: graph(),
        };
        let frame = encode_request(&req).unwrap();
        // The borrowed-parts encoder is byte-identical to the owned one.
        assert_eq!(
            frame,
            encode_request_parts(req.id, &req.model, req.qos, &req.graph).unwrap()
        );
        let mut r = std::io::Cursor::new(&frame);
        let payload = read_frame(&mut r).unwrap().unwrap();
        match decode_frame(&payload).unwrap() {
            WireFrame::Request(got) => assert_eq!(got, req),
            other => panic!("decoded {other:?}"),
        }
        // Exactly one frame in the buffer.
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn response_roundtrips_all_statuses() {
        let cases = vec![
            WireResponse::ok(7, "gcn", vec![0.25, -1.5e-7, f32::MIN_POSITIVE]),
            WireResponse::err(8, "gcn", WireStatus::Rejected, "queue full"),
            WireResponse::err(9, "", WireStatus::Error, "model \"bert\" not served"),
            WireResponse::err(0, "", WireStatus::BadRequest, "checksum mismatch"),
            WireResponse::err(11, "gcn", WireStatus::Expired, "deadline expired"),
        ];
        for resp in cases {
            let frame = encode_response(&resp).unwrap();
            let payload = read_frame(&mut std::io::Cursor::new(&frame))
                .unwrap()
                .unwrap();
            match decode_frame(&payload).unwrap() {
                WireFrame::Response(got) => assert_eq!(got, resp),
                other => panic!("decoded {other:?}"),
            }
        }
    }

    #[test]
    fn output_floats_cross_the_wire_bit_identically() {
        // NaN payloads and denormals must survive: compare bit patterns,
        // not float equality.
        let out = vec![f32::NAN, -0.0, 1e-40, f32::INFINITY];
        let resp = WireResponse::ok(1, "m", out.clone());
        let frame = encode_response(&resp).unwrap();
        let payload = read_frame(&mut std::io::Cursor::new(&frame))
            .unwrap()
            .unwrap();
        let WireFrame::Response(got) = decode_frame(&payload).unwrap() else {
            panic!("not a response");
        };
        let got_bits: Vec<u32> = got.output.iter().map(|x| x.to_bits()).collect();
        let want_bits: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got_bits, want_bits);
    }

    #[test]
    fn corruption_is_detected() {
        let req = WireRequest {
            id: 1,
            model: "gcn".into(),
            qos: WireQos::default(),
            graph: graph(),
        };
        let frame = encode_request(&req).unwrap();
        // Flip one body byte: the checksum must catch it.
        let mut bad = frame.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x40;
        let payload = read_frame(&mut std::io::Cursor::new(&bad)).unwrap().unwrap();
        let e = decode_frame(&payload).unwrap_err();
        assert!(e.to_string().contains("checksum"), "{e}");
        // Wrong version byte.
        let mut wrong_ver = frame.clone();
        wrong_ver[4] = 99;
        let payload = read_frame(&mut std::io::Cursor::new(&wrong_ver))
            .unwrap()
            .unwrap();
        assert!(decode_frame(&payload)
            .unwrap_err()
            .to_string()
            .contains("version"));
        // Truncated payload.
        let payload = read_frame(&mut std::io::Cursor::new(&frame)).unwrap().unwrap();
        assert!(decode_frame(&payload[..payload.len() - 3]).is_err());
    }

    #[test]
    fn oversized_length_prefix_is_refused() {
        let mut frame = Vec::new();
        frame.extend_from_slice(&(u32::MAX).to_le_bytes());
        frame.extend_from_slice(&[0u8; 16]);
        let e = read_frame(&mut std::io::Cursor::new(&frame)).unwrap_err();
        assert!(e.to_string().contains("cap"), "{e}");
    }

    #[test]
    fn eof_mid_frame_is_an_error_not_a_clean_close() {
        let req = WireRequest {
            id: 2,
            model: "gat".into(),
            qos: WireQos::default(),
            graph: graph(),
        };
        let frame = encode_request(&req).unwrap();
        let cut = &frame[..frame.len() / 2];
        assert!(read_frame(&mut std::io::Cursor::new(cut)).is_err());
        // Clean close at a boundary is None, not an error.
        assert!(read_frame(&mut std::io::Cursor::new(&[] as &[u8]))
            .unwrap()
            .is_none());
    }

    #[test]
    fn invalid_graph_payload_is_rejected_at_decode() {
        // Edge index out of range for n: the decoder must refuse it so
        // malformed graphs never reach the coordinator.
        let mut g = graph();
        g.edges[0] = (9999, 0);
        let req = WireRequest {
            id: 3,
            model: "gcn".into(),
            qos: WireQos::default(),
            graph: g,
        };
        let frame = encode_request(&req).unwrap();
        let payload = read_frame(&mut std::io::Cursor::new(&frame)).unwrap().unwrap();
        assert!(decode_frame(&payload).is_err());
    }

    #[test]
    fn salvage_recovers_ids_only_from_trustworthy_envelopes() {
        // A well-framed request whose graph fails validation: the
        // checksum vouches for the body, so the id is recoverable.
        let mut g = graph();
        g.edges[0] = (9999, 0);
        let frame = encode_request(&WireRequest {
            id: 77,
            model: "gcn".into(),
            qos: WireQos::default(),
            graph: g,
        })
        .unwrap();
        let payload = read_frame(&mut std::io::Cursor::new(&frame)).unwrap().unwrap();
        assert!(decode_frame(&payload).is_err());
        assert_eq!(salvage_request_id(&payload), Some(77));
        // Corrupt body: checksum fails, id is untrusted.
        let mut bad = payload.clone();
        let last = bad.len() - 1;
        bad[last] ^= 1;
        assert_eq!(salvage_request_id(&bad), None);
        // Response frames and wrong versions never yield an id.
        let resp = encode_response(&WireResponse::ok(5, "m", vec![1.0])).unwrap();
        let rp = read_frame(&mut std::io::Cursor::new(&resp)).unwrap().unwrap();
        assert_eq!(salvage_request_id(&rp), None);
        let mut wrong_ver = payload;
        wrong_ver[0] = 9;
        assert_eq!(salvage_request_id(&wrong_ver), None);
    }

    #[test]
    fn v1_frames_decode_with_default_qos() {
        // A legacy client's frame (no TTL/priority fields) must still
        // be served, with QoS defaulting to "no deadline, normal".
        let g = graph();
        let frame = encode_request_parts_v1(42, "gcn", &g).unwrap();
        assert_eq!(frame[4], PROTO_V1, "version byte");
        let payload = read_frame(&mut std::io::Cursor::new(&frame)).unwrap().unwrap();
        let WireFrame::Request(req) = decode_frame(&payload).unwrap() else {
            panic!("not a request");
        };
        assert_eq!(req.id, 42);
        assert_eq!(req.model, "gcn");
        assert_eq!(req.qos, WireQos::default());
        assert_eq!(req.graph, g);
        // And its id is salvageable like any trustworthy envelope.
        assert_eq!(salvage_request_id(&payload), Some(42));
    }

    #[test]
    fn response_version_echoes_the_request() {
        // The response layout is version-invariant: a server answering
        // a v1 client stamps v1 so the client's strict decoder accepts
        // it; the body bytes are identical either way.
        let resp = WireResponse::ok(3, "gcn", vec![1.0, 2.0]);
        let v1 = encode_response_with_version(PROTO_V1, &resp).unwrap();
        let v2 = encode_response_with_version(PROTO_VERSION, &resp).unwrap();
        let v3 = encode_response_with_version(PROTO_V3, &resp).unwrap();
        let v4 = encode_response_with_version(PROTO_V4, &resp).unwrap();
        assert_eq!(v1[4], PROTO_V1);
        assert_eq!(v2[4], PROTO_VERSION);
        assert_eq!(v3[4], PROTO_V3);
        assert_eq!(v4[4], PROTO_V4);
        assert_eq!(v1[..4], v2[..4], "length prefix");
        assert_eq!(v1[5..], v2[5..], "kind + checksum + body");
        assert_eq!(v2[5..], v3[5..], "v3 response body is unchanged");
        assert_eq!(v3[5..], v4[5..], "v4 response body is unchanged");
        for frame in [v1, v2, v3, v4] {
            let payload = read_frame(&mut std::io::Cursor::new(&frame)).unwrap().unwrap();
            match decode_frame(&payload).unwrap() {
                WireFrame::Response(got) => assert_eq!(got, resp),
                other => panic!("decoded {other:?}"),
            }
        }
        assert!(encode_response_with_version(5, &resp).is_err());
        assert!(encode_response_with_version(99, &resp).is_err());
    }

    #[test]
    fn control_frames_round_trip() {
        let cases = vec![
            WireControl {
                id: 101,
                op: Op::LoadModel,
                model: "gin".into(),
                digest: "ab".repeat(32),
                version: 0,
            },
            WireControl {
                id: 102,
                op: Op::UnloadModel,
                model: "gcn".into(),
                digest: String::new(),
                version: 0,
            },
            WireControl {
                id: 103,
                op: Op::Rollback,
                model: String::new(),
                digest: String::new(),
                version: 42,
            },
            WireControl {
                id: 104,
                op: Op::ListModels,
                model: String::new(),
                digest: String::new(),
                version: 0,
            },
        ];
        for ctrl in cases {
            let frame = encode_control(&ctrl).unwrap();
            assert_eq!(frame[4], PROTO_V3, "control frames are v3");
            let payload = read_frame(&mut std::io::Cursor::new(&frame)).unwrap().unwrap();
            match decode_frame(&payload).unwrap() {
                WireFrame::Control(got) => assert_eq!(got, ctrl),
                other => panic!("decoded {other:?}"),
            }
            // A failed full decode of a control frame still salvages
            // the id (the body leads with it, checksum vouches).
            assert_eq!(salvage_request_id(&payload), Some(ctrl.id));
        }
        let resp = WireControlResp {
            id: 103,
            op: Op::Rollback,
            status: WireStatus::Error,
            version: 41,
            message: "version 42 not in this process's history".into(),
        };
        let frame = encode_control_resp(&resp).unwrap();
        let payload = read_frame(&mut std::io::Cursor::new(&frame)).unwrap().unwrap();
        match decode_frame(&payload).unwrap() {
            WireFrame::ControlResp(got) => assert_eq!(got, resp),
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn control_kinds_require_v3() {
        // A control frame re-stamped v2 must be refused even with a
        // valid checksum: pre-v3 peers defined no such kind.
        let frame = encode_control(&WireControl {
            id: 1,
            op: Op::ListModels,
            model: String::new(),
            digest: String::new(),
            version: 0,
        })
        .unwrap();
        let mut payload = read_frame(&mut std::io::Cursor::new(&frame)).unwrap().unwrap();
        payload[0] = PROTO_VERSION;
        let e = decode_frame(&payload).unwrap_err();
        assert!(e.to_string().contains("require protocol version"), "{e}");
        // And an unknown op byte inside a valid v3 envelope fails
        // decoding but keeps the id salvageable.
        let mut bad_op = read_frame(&mut std::io::Cursor::new(&frame)).unwrap().unwrap();
        bad_op[HEADER_BYTES + 8] = 9;
        let fixed = checksum(&bad_op[HEADER_BYTES..]);
        bad_op[2..6].copy_from_slice(&fixed.to_le_bytes());
        let e = decode_frame(&bad_op).unwrap_err();
        assert!(e.to_string().contains("control op"), "{e}");
        assert_eq!(salvage_request_id(&bad_op), Some(1));
    }

    #[test]
    fn v3_inference_requests_decode_like_v2() {
        // The inference body did not change in v3: re-stamp a v2
        // request as v3 (checksum covers the body only) and it must
        // decode identically.
        let req = WireRequest {
            id: 55,
            model: "sage".into(),
            qos: WireQos::new(250, Priority::Low),
            graph: graph(),
        };
        let frame = encode_request(&req).unwrap();
        let mut payload = read_frame(&mut std::io::Cursor::new(&frame)).unwrap().unwrap();
        payload[0] = PROTO_V3;
        match decode_frame(&payload).unwrap() {
            WireFrame::Request(got) => assert_eq!(got, req),
            other => panic!("decoded {other:?}"),
        }
        assert_eq!(salvage_request_id(&payload), Some(55));
    }

    #[test]
    fn unknown_priority_byte_is_a_decode_error() {
        let frame = encode_request_parts(
            1,
            "gcn",
            WireQos::new(0, Priority::Normal),
            &graph(),
        )
        .unwrap();
        let mut payload = read_frame(&mut std::io::Cursor::new(&frame)).unwrap().unwrap();
        // Body offset 12 is the priority byte (8 id + 4 ttl); patch it
        // and re-seal the checksum so only the priority is wrong.
        payload[HEADER_BYTES + 12] = 7;
        let fixed = checksum(&payload[HEADER_BYTES..]);
        payload[2..6].copy_from_slice(&fixed.to_le_bytes());
        let e = decode_frame(&payload).unwrap_err();
        assert!(e.to_string().contains("priority"), "{e}");
    }

    #[test]
    fn graph_query_frames_round_trip() {
        let q = WireGraphQuery {
            id: 0xFEED,
            qos: WireQos::new(750, Priority::High),
            hops: 2,
            fanout: 16,
            seeds: vec![5, 900, 31],
        };
        let frame = encode_graph_query(&q).unwrap();
        assert_eq!(frame[4], PROTO_V4, "resident frames are v4");
        let payload = read_frame(&mut std::io::Cursor::new(&frame)).unwrap().unwrap();
        match decode_frame(&payload).unwrap() {
            WireFrame::GraphQuery(got) => assert_eq!(got, q),
            other => panic!("decoded {other:?}"),
        }
        assert_eq!(salvage_request_id(&payload), Some(0xFEED));
        assert!(encode_graph_query(&WireGraphQuery {
            seeds: vec![],
            ..q.clone()
        })
        .is_err());

        let ok = WireGraphQueryResp::ok(0xFEED, 3, 2, vec![1.5, -2.5, 0.0, f32::MIN_POSITIVE, 4.0, 5.0]);
        let frame = encode_graph_query_resp(&ok).unwrap();
        let payload = read_frame(&mut std::io::Cursor::new(&frame)).unwrap().unwrap();
        match decode_frame(&payload).unwrap() {
            WireFrame::GraphQueryResp(got) => {
                assert_eq!(got, ok);
                assert_eq!(got.seed_output(1), Some(&[0.0, f32::MIN_POSITIVE][..]));
                assert_eq!(got.seed_output(3), None);
            }
            other => panic!("decoded {other:?}"),
        }
        let rej = WireGraphQueryResp::err(9, WireStatus::Rejected, 3, "extraction spans 600+ nodes");
        let payload = read_frame(&mut std::io::Cursor::new(&encode_graph_query_resp(&rej).unwrap()))
            .unwrap()
            .unwrap();
        match decode_frame(&payload).unwrap() {
            WireFrame::GraphQueryResp(got) => assert_eq!(got, rej),
            other => panic!("decoded {other:?}"),
        }
        // Ragged outputs cannot be encoded.
        let mut bad = ok;
        bad.outputs.pop();
        assert!(encode_graph_query_resp(&bad).is_err());
    }

    #[test]
    fn graph_mutate_frames_round_trip() {
        use crate::resident::MutateOp;
        let m = WireGraphMutate {
            id: 404,
            ops: vec![
                MutateOp::AddEdge(1, 2),
                MutateOp::RemoveEdge(7, 3),
                MutateOp::AddNode(vec![0.5, -1.0, 2.25]),
            ],
        };
        let frame = encode_graph_mutate(&m).unwrap();
        assert_eq!(frame[4], PROTO_V4);
        let payload = read_frame(&mut std::io::Cursor::new(&frame)).unwrap().unwrap();
        match decode_frame(&payload).unwrap() {
            WireFrame::GraphMutate(got) => assert_eq!(got, m),
            other => panic!("decoded {other:?}"),
        }
        assert_eq!(salvage_request_id(&payload), Some(404));
        // Unknown op byte fails decoding but keeps the id salvageable.
        let mut bad_op = read_frame(&mut std::io::Cursor::new(&frame)).unwrap().unwrap();
        bad_op[HEADER_BYTES + 10] = 9;
        let fixed = checksum(&bad_op[HEADER_BYTES..]);
        bad_op[2..6].copy_from_slice(&fixed.to_le_bytes());
        let e = decode_frame(&bad_op).unwrap_err();
        assert!(e.to_string().contains("mutation op"), "{e}");
        assert_eq!(salvage_request_id(&bad_op), Some(404));

        let resp = WireGraphMutateResp {
            id: 404,
            status: WireStatus::Ok,
            snapshot_version: 12,
            applied: 2,
            rejected: 1,
            message: "1 op rejected".into(),
        };
        let payload =
            read_frame(&mut std::io::Cursor::new(&encode_graph_mutate_resp(&resp).unwrap()))
                .unwrap()
                .unwrap();
        match decode_frame(&payload).unwrap() {
            WireFrame::GraphMutateResp(got) => assert_eq!(got, resp),
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn resident_kinds_require_v4() {
        // A resident frame re-stamped v3 must be refused even with a
        // valid checksum: pre-v4 peers defined no such kind.
        let frame = encode_graph_query(&WireGraphQuery {
            id: 1,
            qos: WireQos::default(),
            hops: 2,
            fanout: 0,
            seeds: vec![0],
        })
        .unwrap();
        let mut payload = read_frame(&mut std::io::Cursor::new(&frame)).unwrap().unwrap();
        payload[0] = PROTO_V3;
        let e = decode_frame(&payload).unwrap_err();
        assert!(e.to_string().contains("require protocol version"), "{e}");
        // And a v3-stamped resident kind salvages nothing: the
        // envelope is not trustworthy under that version.
        assert_eq!(salvage_request_id(&payload), None);
    }

    #[test]
    fn v4_inference_requests_decode_like_v2() {
        // The inference body did not change in v4 either: a mixed
        // workload interleaves v2 molecular frames and v4 resident
        // frames on one connection.
        let req = WireRequest {
            id: 66,
            model: "dgn_resident".into(),
            qos: WireQos::new(100, Priority::Normal),
            graph: graph(),
        };
        let frame = encode_request(&req).unwrap();
        let mut payload = read_frame(&mut std::io::Cursor::new(&frame)).unwrap().unwrap();
        payload[0] = PROTO_V4;
        match decode_frame(&payload).unwrap() {
            WireFrame::Request(got) => assert_eq!(got, req),
            other => panic!("decoded {other:?}"),
        }
        assert_eq!(salvage_request_id(&payload), Some(66));
    }

    #[test]
    fn back_to_back_frames_parse_in_order() {
        let a = encode_response(&WireResponse::ok(1, "a", vec![1.0])).unwrap();
        let b = encode_response(&WireResponse::err(2, "b", WireStatus::Rejected, "shed")).unwrap();
        let mut stream = a.clone();
        stream.extend_from_slice(&b);
        let mut r = std::io::Cursor::new(&stream);
        let p1 = read_frame(&mut r).unwrap().unwrap();
        let p2 = read_frame(&mut r).unwrap().unwrap();
        assert!(read_frame(&mut r).unwrap().is_none());
        let WireFrame::Response(r1) = decode_frame(&p1).unwrap() else {
            panic!()
        };
        let WireFrame::Response(r2) = decode_frame(&p2).unwrap() else {
            panic!()
        };
        assert_eq!((r1.id, r2.id), (1, 2));
        assert_eq!(r2.status, WireStatus::Rejected);
    }

    fn payload_of(frame: &[u8]) -> Vec<u8> {
        read_frame(&mut std::io::Cursor::new(frame)).unwrap().unwrap()
    }

    #[test]
    fn peek_reads_the_routing_envelope_of_every_client_kind() {
        let g = graph();
        let v2 = payload_of(&encode_request_parts(9, "gat", WireQos::new(5, Priority::Low), &g).unwrap());
        let p = peek_frame(&v2).unwrap();
        assert_eq!(
            (p.version, p.kind, p.id, p.model.as_deref(), p.ctrl_op),
            (PROTO_VERSION, KIND_REQUEST, 9, Some("gat"), 0)
        );

        let v1 = payload_of(&encode_request_parts_v1(10, "gcn", &g).unwrap());
        let p = peek_frame(&v1).unwrap();
        assert_eq!((p.version, p.id, p.model.as_deref()), (PROTO_V1, 10, Some("gcn")));

        let ctl = payload_of(
            &encode_control(&WireControl {
                id: 11,
                op: Op::ListModels,
                model: String::new(),
                digest: String::new(),
                version: 0,
            })
            .unwrap(),
        );
        let p = peek_frame(&ctl).unwrap();
        assert_eq!((p.kind, p.id, p.model, p.ctrl_op), (KIND_CONTROL, 11, None, 4));

        let q = payload_of(
            &encode_graph_query(&WireGraphQuery {
                id: 12,
                qos: WireQos::default(),
                hops: 2,
                fanout: 0,
                seeds: vec![0, 1],
            })
            .unwrap(),
        );
        let p = peek_frame(&q).unwrap();
        assert_eq!((p.kind, p.id, p.model), (KIND_GRAPH_QUERY, 12, None));

        // Server→client kinds and corrupt envelopes refuse to peek.
        let resp = payload_of(&encode_response(&WireResponse::ok(1, "gcn", vec![1.0])).unwrap());
        assert!(peek_frame(&resp).is_err());
        let mut bad = v2.clone();
        bad[7] ^= 1; // body byte flip → checksum mismatch
        assert!(peek_frame(&bad).is_err());
        bad = v2.clone();
        bad[0] = 77; // unknown version
        assert!(peek_frame(&bad).is_err());
    }

    #[test]
    fn rewrite_frame_id_changes_only_the_id_and_checksum_bytes() {
        let g = graph();
        let original =
            payload_of(&encode_request_parts(0x1111, "dgn", WireQos::new(9, Priority::High), &g).unwrap());
        let mut rewritten = original.clone();
        rewrite_frame_id(&mut rewritten, 0x2222).unwrap();
        // Still a fully valid frame, now under the new id.
        match decode_frame(&rewritten).unwrap() {
            WireFrame::Request(r) => assert_eq!(r.id, 0x2222),
            other => panic!("decoded {other:?}"),
        }
        assert_eq!(peek_frame(&rewritten).unwrap().id, 0x2222);
        // Byte-for-byte: only the checksum ([2..6]) and id ([6..14])
        // regions may differ — the bit-exactness guarantee the ingress
        // relies on when proxying.
        assert_eq!(original.len(), rewritten.len());
        for (i, (a, b)) in original.iter().zip(&rewritten).enumerate() {
            if !(2..14).contains(&i) {
                assert_eq!(a, b, "byte {i} changed");
            }
        }
        // Rewriting back restores the exact original bytes.
        rewrite_frame_id(&mut rewritten, 0x1111).unwrap();
        assert_eq!(original, rewritten);
        // frame_id reads without validating.
        assert_eq!(frame_id(&original), Some(0x1111));
        assert_eq!(frame_id(&[0u8; 5]), None);
    }

    #[test]
    fn corrupted_priority_fails_decode_but_salvages_the_id() {
        let g = graph();
        let mut payload =
            payload_of(&encode_request_parts(77, "gin", WireQos::new(0, Priority::Normal), &g).unwrap());
        assert!(corrupt_request_priority(&mut payload));
        // The checksum was re-sealed: full decode fails on the bad
        // priority byte, but the envelope is trustworthy enough to
        // salvage the caller's id — so a backend answers `BadRequest`
        // under id 77, and an ingress can still route the answer.
        assert!(decode_frame(&payload).is_err());
        assert_eq!(salvage_request_id(&payload), Some(77));

        // v1 frames carry no priority byte; the fault refuses them.
        let mut v1 = payload_of(&encode_request_parts_v1(5, "gin", &g).unwrap());
        let before = v1.clone();
        assert!(!corrupt_request_priority(&mut v1));
        assert_eq!(v1, before);
    }
}

//! The nonblocking reactor pool: a fixed set of event-loop threads
//! multiplexing every client connection, replacing the old
//! thread-per-connection reader/writer pairs.
//!
//! ```text
//!             ┌► reactor 0 ─ conns {a, b, …} ─┐ try_submit   lanes
//! accept ─────┼► reactor 1 ─ conns {c, d, …} ─┼────────────► … ──┐
//!  (rr)       └► reactor … ─ conns {…}       ─┘                  │
//!                  ▲     Deliver {token, frame}        responses │
//!                  └──────────────── response pump ◄─────────────┘
//! ```
//!
//! Each reactor owns a [`polly::Poller`] plus the full state machine
//! of every connection assigned to it: an inbound [`FrameBuf`]
//! reassembling length-prefixed frames from nonblocking reads, an
//! outbound [`WriteBuf`] drained on writability, and the set of
//! in-flight request ids routed to the connection. Nothing about a
//! connection is shared across threads — the response pump reaches a
//! connection only by posting a [`ReactorMsg::Deliver`] to its
//! reactor's inbox and waking the poller.
//!
//! Backpressure under `AdmissionPolicy::Block` is modeled without a
//! blocked thread: when the ingest queue is full the decoded request
//! is *parked* on its connection and the reactor drops the
//! connection's read interest, so the kernel socket buffer — and then
//! the client's TCP window — absorbs the stall, exactly like the old
//! blocked reader but at zero thread cost. Parked requests are
//! retried on a short tick; one whose TTL lapses while parked is
//! answered `Expired` (shed-by-deadline at the front door).
//!
//! Gauge discipline (`requests_in_flight`): incremented exactly once
//! when a route is installed, decremented exactly once by whoever
//! successfully removes the route — the response pump on delivery,
//! the reject/expiry paths, or the connection teardown sweeping its
//! still-pending ids. A connection that dies mid-flight therefore
//! returns the gauge to zero instead of leaking it (the old demux
//! skipped the decrement when the route was already gone).

use std::collections::{HashMap, HashSet};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::os::unix::io::AsRawFd;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::{Metrics, Request, Server, TrySubmit};
use crate::registry::ControlRequest;
use crate::resident::{extract_khop, QueryPending, ResidentState, RESIDENT_LAYERS, RESIDENT_MODEL};

use super::proto::{
    self, Op, WireControlResp, WireFrame, WireGraphMutateResp, WireGraphQueryResp, WireResponse,
    WireStatus, PROTO_V4, PROTO_VERSION,
};

/// Poller token of the reactor's waker; connection tokens start above.
const WAKER_TOKEN: u64 = 0;

/// Per-connection outbound buffer ceiling. A client that stops
/// reading long enough to queue this much has wedged its TCP window;
/// further responses for it are dropped (`responses_dropped`) so one
/// stalled reader never holds memory or the reactor hostage.
const OUTBUF_CAP: usize = 8 << 20;

/// Poll timeout while any connection has a parked request: bounds how
/// quickly admission is retried / a parked TTL is noticed.
const PARK_TICK_MS: i32 = 5;

/// Max bytes pulled off one socket per readiness event. Level-
/// triggered polling re-reports a still-readable socket, so capping
/// the per-event quantum keeps one firehose client from starving its
/// reactor siblings without losing data.
const READ_QUANTUM: usize = 256 * 1024;

/// Stripe count of the routing table. Requests hash to a shard by id,
/// so the reactors and the response pump contend per-stripe, not on
/// one global lock — the same sharding story as the per-model metrics.
const ROUTE_SHARDS: usize = 16;

/// Routing entry for one in-flight wire request: which reactor and
/// connection to answer on, under which client-side id, speaking
/// which protocol version (responses echo the request frame's
/// version, so v1 clients never see a v2 byte).
pub(crate) struct RouteEntry {
    pub reactor: usize,
    pub token: u64,
    pub client_id: u64,
    pub version: u8,
}

/// Sharded routing table for in-flight wire requests, keyed by the
/// reserved coordinator id.
pub(crate) struct RouteTable {
    shards: Vec<Mutex<HashMap<u64, RouteEntry>>>,
}

impl RouteTable {
    pub(crate) fn new() -> RouteTable {
        RouteTable {
            shards: (0..ROUTE_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    pub(crate) fn insert(&self, id: u64, entry: RouteEntry) {
        crate::util::sync::lock(&self.shards[id as usize % ROUTE_SHARDS]).insert(id, entry);
    }

    pub(crate) fn remove(&self, id: u64) -> Option<RouteEntry> {
        crate::util::sync::lock(&self.shards[id as usize % ROUTE_SHARDS]).remove(&id)
    }
}

/// Work posted to a reactor from outside its thread (the accept loop
/// and the response pump).
pub(crate) enum ReactorMsg {
    /// A freshly accepted (already nonblocking) connection to adopt.
    NewConn(TcpStream),
    /// An encoded response frame for connection `token`; `id` is the
    /// coordinator id to clear from the connection's pending set.
    Deliver { token: u64, id: u64, frame: Vec<u8> },
    /// Drain the inbox, tear every connection down, and exit.
    Shutdown,
}

/// A reactor's cross-thread mailbox: push under the mutex, then wake
/// the poller so the message is seen even mid-`wait`.
pub(crate) struct ReactorQueue {
    inbox: Mutex<Vec<ReactorMsg>>,
    waker: polly::Waker,
}

impl ReactorQueue {
    pub(crate) fn send(&self, msg: ReactorMsg) {
        crate::util::sync::lock(&self.inbox).push(msg);
        let _ = self.waker.wake();
    }
}

/// Incremental reassembly of `u32 len · payload` frames from
/// nonblocking reads. `next_payload` yields `Ok(None)` until a full
/// frame is buffered and errors only on a hostile length prefix —
/// the one condition the blocking front-end also answered by closing
/// the connection rather than with a `BadRequest` frame.
pub(crate) struct FrameBuf {
    buf: Vec<u8>,
    off: usize,
}

impl FrameBuf {
    pub(crate) fn new() -> FrameBuf {
        FrameBuf { buf: Vec::new(), off: 0 }
    }

    pub(crate) fn extend(&mut self, bytes: &[u8]) {
        if self.off > 0 && (self.off == self.buf.len() || self.off >= 64 * 1024) {
            self.buf.drain(..self.off);
            self.off = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    pub(crate) fn next_payload(&mut self) -> Result<Option<Vec<u8>>> {
        let avail = self.buf.len() - self.off;
        if avail < 4 {
            return Ok(None);
        }
        let b = &self.buf[self.off..];
        let len = u32::from_le_bytes([b[0], b[1], b[2], b[3]]) as usize;
        if len > proto::MAX_FRAME_BYTES {
            bail!("frame length {len} exceeds the {} byte limit", proto::MAX_FRAME_BYTES);
        }
        if avail < 4 + len {
            return Ok(None);
        }
        let payload = self.buf[self.off + 4..self.off + 4 + len].to_vec();
        self.off += 4 + len;
        Ok(Some(payload))
    }
}

/// Outbound byte queue with a hard ceiling and cursor-based draining.
pub(crate) struct WriteBuf {
    buf: Vec<u8>,
    off: usize,
    cap: usize,
}

impl WriteBuf {
    pub(crate) fn with_cap(cap: usize) -> WriteBuf {
        WriteBuf { buf: Vec::new(), off: 0, cap }
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.off == self.buf.len()
    }

    pub(crate) fn queued(&self) -> usize {
        self.buf.len() - self.off
    }

    /// Append one frame; `false` means the ceiling would be exceeded
    /// and the frame was dropped (the caller counts it).
    pub(crate) fn push(&mut self, frame: &[u8]) -> bool {
        if self.queued() + frame.len() > self.cap {
            return false;
        }
        if self.off > 0 && (self.is_empty() || self.off >= 64 * 1024) {
            self.buf.drain(..self.off);
            self.off = 0;
        }
        self.buf.extend_from_slice(frame);
        true
    }

    /// Drain as much as the socket accepts right now. `WouldBlock`
    /// simply stops (poll for writability); any real error is the
    /// caller's cue to close the connection.
    pub(crate) fn write_to(&mut self, w: &mut impl Write) -> std::io::Result<()> {
        while !self.is_empty() {
            match w.write(&self.buf[self.off..]) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "socket accepted zero bytes",
                    ))
                }
                Ok(n) => self.off += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if self.is_empty() && self.off > 0 {
            self.buf.clear();
            self.off = 0;
        }
        Ok(())
    }
}

/// The full state machine of one connection, owned by its reactor.
struct Conn {
    sock: TcpStream,
    inbuf: FrameBuf,
    outbuf: WriteBuf,
    /// Coordinator ids in flight on this connection; teardown sweeps
    /// these out of the route table (closing a connection really does
    /// forget its requests now — see the module docs on the gauge).
    pending: HashSet<u64>,
    /// The request waiting out a full ingest queue (`Block` policy).
    /// While set, read interest is dropped: TCP absorbs the stall.
    parked: Option<Request>,
    /// Whether we currently want read events (false while parked).
    reading: bool,
    /// Interest bits last registered with the poller.
    reg_read: bool,
    reg_write: bool,
}

impl Conn {
    fn new(sock: TcpStream) -> Conn {
        Conn {
            sock,
            inbuf: FrameBuf::new(),
            outbuf: WriteBuf::with_cap(OUTBUF_CAP),
            pending: HashSet::new(),
            parked: None,
            reading: true,
            reg_read: true,
            reg_write: false,
        }
    }
}

/// One event-loop thread: poller + every connection assigned to it.
struct Reactor {
    idx: usize,
    poller: polly::Poller,
    queue: Arc<ReactorQueue>,
    server: Arc<Server>,
    metrics: Arc<Metrics>,
    routes: Arc<RouteTable>,
    /// Resident graph-serving state; `None` outside resident mode, in
    /// which case v4 `GRAPH_QUERY` / `GRAPH_MUTATE` frames are
    /// answered `Rejected` without touching the executor pipeline.
    resident: Option<Arc<ResidentState>>,
    conns: HashMap<u64, Conn>,
    next_token: u64,
}

/// Spawn `count` reactor threads. Each returned [`ReactorQueue`] is
/// the only way to reach its reactor from outside.
pub(crate) fn spawn_reactors(
    count: usize,
    server: &Arc<Server>,
    metrics: &Arc<Metrics>,
    routes: &Arc<RouteTable>,
    resident: Option<&Arc<ResidentState>>,
) -> Result<(Vec<Arc<ReactorQueue>>, Vec<JoinHandle<()>>)> {
    let count = count.max(1);
    let mut queues = Vec::with_capacity(count);
    let mut handles = Vec::with_capacity(count);
    for idx in 0..count {
        let poller = polly::Poller::new().context("creating reactor poller")?;
        let waker = polly::Waker::new().context("creating reactor waker")?;
        waker
            .register(&poller, WAKER_TOKEN)
            .context("registering reactor waker")?;
        let queue = Arc::new(ReactorQueue {
            inbox: Mutex::new(Vec::new()),
            waker,
        });
        let reactor = Reactor {
            idx,
            poller,
            queue: Arc::clone(&queue),
            server: Arc::clone(server),
            metrics: Arc::clone(metrics),
            routes: Arc::clone(routes),
            resident: resident.map(Arc::clone),
            conns: HashMap::new(),
            next_token: WAKER_TOKEN + 1,
        };
        handles.push(
            std::thread::Builder::new()
                .name(format!("gengnn-net-reactor-{idx}"))
                .spawn(move || reactor.run())
                .context("spawning reactor thread")?,
        );
        queues.push(queue);
    }
    Ok((queues, handles))
}

impl Reactor {
    fn run(mut self) {
        let mut events: Vec<polly::Event> = Vec::new();
        loop {
            let timeout = if self.conns.values().any(|c| c.parked.is_some()) {
                PARK_TICK_MS
            } else {
                -1 // nothing parked: sleep until an event or a wake
            };
            if self.poller.wait(&mut events, timeout).is_err() {
                // Poller errors other than EINTR (handled inside
                // polly) are not actionable per-iteration; yield so a
                // persistent failure cannot spin a core.
                std::thread::sleep(std::time::Duration::from_millis(1));
                continue;
            }
            for ev in events.drain(..) {
                if ev.token == WAKER_TOKEN {
                    self.queue.waker.drain();
                    if self.drain_inbox() {
                        self.cleanup();
                        return;
                    }
                } else {
                    self.conn_event(ev);
                }
            }
            self.tick_parked();
        }
    }

    /// Process queued cross-thread messages; `true` means shutdown.
    fn drain_inbox(&mut self) -> bool {
        let msgs: Vec<ReactorMsg> =
            std::mem::take(&mut *crate::util::sync::lock(&self.queue.inbox));
        let mut stop = false;
        for msg in msgs {
            match msg {
                ReactorMsg::NewConn(sock) => self.add_conn(sock),
                ReactorMsg::Deliver { token, id, frame } => self.deliver(token, id, frame),
                ReactorMsg::Shutdown => stop = true,
            }
        }
        stop
    }

    fn add_conn(&mut self, sock: TcpStream) {
        let token = self.next_token;
        self.next_token += 1;
        if self.poller.register(sock.as_raw_fd(), token, polly::Interest::READ).is_err() {
            // fd exhaustion or a socket that died before adoption:
            // the accept loop already counted it open.
            self.metrics.net().connections_open.fetch_sub(1, Ordering::Relaxed);
            return;
        }
        self.conns.insert(token, Conn::new(sock));
    }

    /// A response frame from the pump. The pump already settled the
    /// route (and the in-flight gauge); here the frame either lands in
    /// the connection's write buffer or is counted dropped.
    fn deliver(&mut self, token: u64, id: u64, frame: Vec<u8>) {
        let Some(mut conn) = self.conns.remove(&token) else {
            self.metrics.net().responses_dropped.fetch_add(1, Ordering::Relaxed);
            return;
        };
        conn.pending.remove(&id);
        if !conn.outbuf.push(&frame) {
            self.metrics.net().responses_dropped.fetch_add(1, Ordering::Relaxed);
        }
        // Opportunistic flush: most sockets accept the frame outright,
        // so the common case never registers write interest at all.
        let close = self.flush(&mut conn);
        self.settle(token, conn, close);
    }

    /// Readiness on one connection. The connection is removed from the
    /// map while serviced so helper methods can borrow the reactor
    /// freely, then reinserted or destroyed.
    fn conn_event(&mut self, ev: polly::Event) {
        let Some(mut conn) = self.conns.remove(&ev.token) else {
            return;
        };
        let mut close = false;
        if conn.reading {
            if ev.readable {
                close = self.read_and_parse(ev.token, &mut conn);
            }
        } else if ev.readable || ev.hangup {
            // A parked connection holds no read interest, so any
            // readable/hangup edge here is ERR or HUP from the kernel:
            // the peer is gone and the parked request with it.
            close = true;
        }
        if !close && !conn.outbuf.is_empty() {
            close = self.flush(&mut conn);
        }
        self.settle(ev.token, conn, close);
    }

    /// Reinsert a serviced connection (syncing poller interest) or
    /// tear it down.
    fn settle(&mut self, token: u64, mut conn: Conn, close: bool) {
        if close {
            self.destroy(token, conn);
            return;
        }
        let want_read = conn.reading;
        let want_write = !conn.outbuf.is_empty();
        if (want_read, want_write) != (conn.reg_read, conn.reg_write) {
            let interest = polly::Interest {
                readable: want_read,
                writable: want_write,
            };
            if self.poller.modify(conn.sock.as_raw_fd(), token, interest).is_err() {
                self.destroy(token, conn);
                return;
            }
            conn.reg_read = want_read;
            conn.reg_write = want_write;
        }
        self.conns.insert(token, conn);
    }

    /// Full teardown: deregister, sweep the connection's in-flight
    /// routes (decrementing the gauge for every route actually
    /// removed — the other half of the pump's accounting), close.
    fn destroy(&mut self, _token: u64, conn: Conn) {
        let _ = self.poller.deregister(conn.sock.as_raw_fd());
        for id in &conn.pending {
            if self.routes.remove(*id).is_some() {
                self.metrics.net().requests_in_flight.fetch_sub(1, Ordering::Relaxed);
            }
            // A resident query's slice bookkeeping dies with its
            // connection (the pump's take_pending will simply miss).
            if let Some(r) = &self.resident {
                r.take_pending(*id);
            }
        }
        self.metrics.net().connections_open.fetch_sub(1, Ordering::Relaxed);
        // Dropping the stream closes the fd; a client blocked on a
        // response observes EOF.
    }

    /// Drain the socket (bounded per event) and process every complete
    /// frame. Returns `true` when the connection must close (EOF,
    /// socket error, or a hostile length prefix).
    fn read_and_parse(&mut self, token: u64, conn: &mut Conn) -> bool {
        let mut tmp = [0u8; 64 * 1024];
        let mut total = 0usize;
        loop {
            match conn.sock.read(&mut tmp) {
                Ok(0) => return true, // EOF
                Ok(n) => {
                    conn.inbuf.extend(&tmp[..n]);
                    total += n;
                    if total >= READ_QUANTUM {
                        break; // level-triggered poll re-reports the rest
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return true,
            }
        }
        if self.parse_frames(token, conn) {
            return true;
        }
        if !conn.outbuf.is_empty() {
            return self.flush(conn);
        }
        false
    }

    /// Decode buffered frames until the buffer runs dry or a request
    /// parks (backpressure stops consuming input at a frame boundary).
    fn parse_frames(&mut self, token: u64, conn: &mut Conn) -> bool {
        while conn.parked.is_none() {
            match conn.inbuf.next_payload() {
                Ok(Some(payload)) => self.handle_payload(token, conn, &payload),
                Ok(None) => break,
                // An unframeable length prefix is transport-level
                // garbage, not a decodable-but-bad request: close,
                // exactly like the blocking front-end's read path.
                Err(_) => return true,
            }
        }
        false
    }

    fn handle_payload(&mut self, token: u64, conn: &mut Conn, payload: &[u8]) {
        // Responses echo the version of the frame they answer; frames
        // whose version byte is itself unknown get the current one.
        // (The rule is shared with the ingress proxy, which must
        // self-answer in the same version a backend would.)
        let version = crate::controlplane::response_version(payload.first().copied());
        match proto::decode_frame(payload) {
            Ok(WireFrame::Request(req)) => self.admit(token, conn, req, version),
            Ok(WireFrame::Control(ctrl)) => self.handle_control(conn, ctrl),
            Ok(WireFrame::GraphQuery(q)) => self.handle_graph_query(token, conn, q),
            Ok(WireFrame::GraphMutate(m)) => self.handle_graph_mutate(conn, m),
            Ok(WireFrame::Response(_))
            | Ok(WireFrame::ControlResp(_))
            | Ok(WireFrame::GraphQueryResp(_))
            | Ok(WireFrame::GraphMutateResp(_)) => {
                // A response frame on the server's ingress is a
                // protocol violation; answer and move on.
                self.metrics.net().decode_errors.fetch_add(1, Ordering::Relaxed);
                self.answer(
                    conn,
                    version,
                    WireResponse::err(
                        proto::BAD_FRAME_ID,
                        "",
                        WireStatus::BadRequest,
                        "response frame sent to server",
                    ),
                );
            }
            Err(e) => {
                // Framing is intact but the payload is bad: report it
                // on this connection — under the caller's own id when
                // the envelope checksum vouches for it — and keep
                // serving.
                self.metrics.net().decode_errors.fetch_add(1, Ordering::Relaxed);
                let id = proto::salvage_request_id(payload).unwrap_or(proto::BAD_FRAME_ID);
                self.answer(
                    conn,
                    version,
                    WireResponse::err(id, "", WireStatus::BadRequest, format!("{e}")),
                );
            }
        }
    }

    /// Route registration precedes admission (see module docs of
    /// [`super::server`]): reserve, install, then submit — a response
    /// can never race past its routing entry.
    fn admit(&mut self, token: u64, conn: &mut Conn, req: proto::WireRequest, version: u8) {
        let server_id = self.server.reserve_id();
        self.routes.insert(
            server_id,
            RouteEntry {
                reactor: self.idx,
                token,
                client_id: req.id,
                version,
            },
        );
        self.metrics.net().requests_in_flight.fetch_add(1, Ordering::Relaxed);
        let creq =
            Request::with_qos(server_id, req.model, req.graph, req.qos.ttl_ms, req.qos.priority);
        self.try_admit(conn, creq);
    }

    /// One control-plane op, handled synchronously on the reactor
    /// thread: deploys are rare, and the registry's deploy lock bounds
    /// the work anyway (the data-plane lanes never wait on it — they
    /// read the published snapshot). No routing entry is installed:
    /// the reply is generated and queued before the next frame of this
    /// connection is even parsed.
    fn handle_control(&mut self, conn: &mut Conn, ctrl: proto::WireControl) {
        let req = match ctrl.op {
            Op::LoadModel => ControlRequest::Load {
                model: ctrl.model.clone(),
                digest: if ctrl.digest.is_empty() {
                    None
                } else {
                    Some(ctrl.digest.clone())
                },
            },
            Op::UnloadModel => ControlRequest::Unload {
                model: ctrl.model.clone(),
            },
            Op::Rollback => ControlRequest::Rollback { version: ctrl.version },
            Op::ListModels => ControlRequest::List,
        };
        let reply = self.server.control(&req);
        let resp = WireControlResp {
            id: ctrl.id,
            op: ctrl.op,
            status: if reply.ok { WireStatus::Ok } else { WireStatus::Error },
            version: reply.version,
            message: reply.message,
        };
        match proto::encode_control_resp(&resp) {
            Ok(frame) => {
                if !conn.outbuf.push(&frame) {
                    self.metrics.net().responses_dropped.fetch_add(1, Ordering::Relaxed);
                }
            }
            // Unreachable for replies the registry produced (their
            // messages are far under the frame limit), but a dropped
            // answer must still be counted.
            Err(_) => {
                self.metrics.net().responses_dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// One resident k-hop query. Extraction happens here on the
    /// reactor thread (it is a bounded BFS over the cap, comparable to
    /// frame decoding); the forward itself goes through the ordinary
    /// reserve → route → admit pipeline under [`RESIDENT_MODEL`], with
    /// the snapshot's full-graph Fiedler vector attached so prep never
    /// re-solves on the subgraph (the exactness contract).
    fn handle_graph_query(&mut self, token: u64, conn: &mut Conn, q: proto::WireGraphQuery) {
        let Some(resident) = self.resident.clone() else {
            self.metrics.resident().queries_rejected.fetch_add(1, Ordering::Relaxed);
            self.answer_query(
                conn,
                WireGraphQueryResp::err(
                    q.id,
                    WireStatus::Rejected,
                    0,
                    "server is not in resident mode",
                ),
            );
            return;
        };
        if (q.hops as usize) < RESIDENT_LAYERS {
            self.metrics.resident().queries_rejected.fetch_add(1, Ordering::Relaxed);
            self.answer_query(
                conn,
                WireGraphQueryResp::err(
                    q.id,
                    WireStatus::Rejected,
                    0,
                    format!(
                        "hops {} below the resident model's {} layers (exactness contract)",
                        q.hops, RESIDENT_LAYERS
                    ),
                ),
            );
            return;
        }
        let snap = resident.store.snapshot();
        let ex = match extract_khop(&snap, &q.seeds, q.hops, q.fanout, resident.meta.n_max) {
            Ok(ex) => ex,
            Err(e) => {
                self.metrics.resident().queries_rejected.fetch_add(1, Ordering::Relaxed);
                let status = if e.is_bad_request() {
                    WireStatus::BadRequest
                } else {
                    WireStatus::Rejected
                };
                self.answer_query(
                    conn,
                    WireGraphQueryResp::err(q.id, status, snap.version, format!("{e}")),
                );
                return;
            }
        };
        self.metrics.resident().record_query(ex.nodes.len() as u64);
        self.metrics.resident().snapshot_version.store(snap.version, Ordering::Relaxed);
        let server_id = self.server.reserve_id();
        self.routes.insert(
            server_id,
            RouteEntry {
                reactor: self.idx,
                token,
                client_id: q.id,
                version: PROTO_V4,
            },
        );
        self.metrics.net().requests_in_flight.fetch_add(1, Ordering::Relaxed);
        resident.register_pending(
            server_id,
            QueryPending {
                seed_locals: ex.seed_locals.clone(),
                out_dim: resident.meta.out_dim,
                snapshot_version: ex.snapshot_version,
            },
        );
        let mut eig = ex.eig;
        eig.resize(resident.meta.n_max, 0.0);
        let mut creq =
            Request::with_qos(server_id, RESIDENT_MODEL, ex.graph, q.qos.ttl_ms, q.qos.priority);
        creq.eig = Some(eig);
        self.try_admit(conn, creq);
    }

    /// One mutation batch, applied synchronously on the reactor thread
    /// (copy-on-write assembly is bounded by the resident graph size,
    /// and the store's mutate lock serializes concurrent batches).
    fn handle_graph_mutate(&mut self, conn: &mut Conn, m: proto::WireGraphMutate) {
        let resp = match &self.resident {
            None => WireGraphMutateResp {
                id: m.id,
                status: WireStatus::Rejected,
                snapshot_version: 0,
                applied: 0,
                rejected: 0,
                message: "server is not in resident mode".into(),
            },
            Some(resident) => {
                let out = resident.store.apply(&m.ops);
                let rc = self.metrics.resident();
                if out.applied > 0 {
                    rc.mutations_applied.fetch_add(1, Ordering::Relaxed);
                }
                rc.mutation_ops_rejected.fetch_add(out.rejected as u64, Ordering::Relaxed);
                rc.snapshot_version.store(out.version, Ordering::Relaxed);
                WireGraphMutateResp {
                    id: m.id,
                    status: WireStatus::Ok,
                    snapshot_version: out.version,
                    applied: out.applied,
                    rejected: out.rejected,
                    message: String::new(),
                }
            }
        };
        match proto::encode_graph_mutate_resp(&resp) {
            Ok(frame) => {
                if !conn.outbuf.push(&frame) {
                    self.metrics.net().responses_dropped.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(_) => {
                self.metrics.net().responses_dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Encode and queue one locally generated graph-query response.
    fn answer_query(&mut self, conn: &mut Conn, resp: WireGraphQueryResp) {
        match proto::encode_graph_query_resp(&resp) {
            Ok(frame) => {
                if !conn.outbuf.push(&frame) {
                    self.metrics.net().responses_dropped.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(_) => {
                self.metrics.net().responses_dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Answer a request shed before execution (queue-full rejection or
    /// parked-TTL expiry) in the client's own dialect: a resident query
    /// — identified by its pending entry — gets a v4 GRAPH_QUERY_RESP;
    /// everything else gets the plain response frame.
    fn answer_shed(
        &mut self,
        conn: &mut Conn,
        server_id: u64,
        entry: &RouteEntry,
        model: &str,
        status: WireStatus,
        msg: &str,
    ) {
        let pending = self
            .resident
            .as_ref()
            .and_then(|r| r.take_pending(server_id));
        if let Some(p) = pending {
            self.metrics.resident().queries_rejected.fetch_add(1, Ordering::Relaxed);
            self.answer_query(
                conn,
                WireGraphQueryResp::err(entry.client_id, status, p.snapshot_version, msg),
            );
        } else {
            self.answer(
                conn,
                entry.version,
                WireResponse::err(entry.client_id, model, status, msg),
            );
        }
    }

    fn try_admit(&mut self, conn: &mut Conn, creq: Request) {
        let id = creq.id;
        let model = creq.model.clone();
        match self.server.try_submit(creq) {
            TrySubmit::Accepted => {
                conn.pending.insert(id);
            }
            TrySubmit::Rejected => {
                // Shed: unregister and answer immediately with the
                // Rejected wire status; the connection stays up.
                if let Some(entry) = self.routes.remove(id) {
                    self.metrics.net().requests_in_flight.fetch_sub(1, Ordering::Relaxed);
                    self.answer_shed(
                        conn,
                        id,
                        &entry,
                        &model,
                        WireStatus::Rejected,
                        "ingest queue full",
                    );
                }
            }
            TrySubmit::Retry(creq) => {
                // Full queue under Block: park the request and stop
                // reading this socket — TCP carries the stall to the
                // client until the queue drains or the TTL lapses.
                conn.pending.insert(id);
                conn.parked = Some(creq);
                conn.reading = false;
            }
        }
    }

    /// Retry every parked request: admit it, expire it, or keep it
    /// parked for the next tick.
    fn tick_parked(&mut self) {
        let tokens: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| c.parked.is_some())
            .map(|(t, _)| *t)
            .collect();
        for token in tokens {
            let Some(mut conn) = self.conns.remove(&token) else {
                continue;
            };
            let close = self.tick_conn(token, &mut conn);
            self.settle(token, conn, close);
        }
    }

    fn tick_conn(&mut self, token: u64, conn: &mut Conn) -> bool {
        let Some(creq) = conn.parked.take() else {
            return false;
        };
        if creq.is_expired(Instant::now()) {
            // Shed-by-deadline at the front door: the TTL lapsed while
            // the request waited out a full queue.
            conn.pending.remove(&creq.id);
            if let Some(entry) = self.routes.remove(creq.id) {
                self.metrics.net().requests_in_flight.fetch_sub(1, Ordering::Relaxed);
                self.metrics.record_deadline_expired();
                self.answer_shed(
                    conn,
                    creq.id,
                    &entry,
                    &creq.model,
                    WireStatus::Expired,
                    "deadline expired before admission",
                );
            }
        } else {
            let id = creq.id;
            let model = creq.model.clone();
            match self.server.try_submit(creq) {
                TrySubmit::Accepted => {} // already in conn.pending
                TrySubmit::Rejected => {
                    // Unreachable under Block (the only policy that
                    // parks), kept total for safety.
                    conn.pending.remove(&id);
                    if let Some(entry) = self.routes.remove(id) {
                        self.metrics.net().requests_in_flight.fetch_sub(1, Ordering::Relaxed);
                        self.answer_shed(
                            conn,
                            id,
                            &entry,
                            &model,
                            WireStatus::Rejected,
                            "ingest queue full",
                        );
                    }
                }
                TrySubmit::Retry(creq) => {
                    conn.parked = Some(creq);
                    return false; // still parked; stay off the socket
                }
            }
        }
        // Unparked (admitted, expired, or rejected): resume reading
        // and work through whatever frames buffered meanwhile.
        conn.reading = true;
        if self.parse_frames(token, conn) {
            return true;
        }
        if !conn.outbuf.is_empty() {
            return self.flush(conn);
        }
        false
    }

    /// Encode and queue one locally generated response (rejections,
    /// expiries, decode errors), in the version the client speaks.
    fn answer(&mut self, conn: &mut Conn, version: u8, wire: WireResponse) {
        match proto::encode_response_with_version(version, &wire) {
            Ok(frame) => {
                if !conn.outbuf.push(&frame) {
                    self.metrics.net().responses_dropped.fetch_add(1, Ordering::Relaxed);
                }
            }
            // Unreachable: `version` comes from a frame the decoder
            // accepted, but a dropped answer must still be counted.
            Err(_) => {
                self.metrics.net().responses_dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    fn flush(&mut self, conn: &mut Conn) -> bool {
        conn.outbuf.write_to(&mut conn.sock).is_err()
    }

    /// Shutdown: tear down every connection (sweeping their routes so
    /// the gauge lands back at zero) before the thread exits.
    fn cleanup(&mut self) {
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for token in tokens {
            if let Some(conn) = self.conns.remove(&token) {
                self.destroy(token, conn);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::CooGraph;

    fn tiny_graph() -> CooGraph {
        CooGraph {
            n: 1,
            edges: vec![],
            node_feat: vec![0.5; 9],
            f_node: 9,
            edge_feat: vec![],
            f_edge: 0,
        }
    }

    #[test]
    fn frame_reassembly_survives_arbitrary_splits() {
        let f1 = proto::encode_request_parts(7, "gcn", proto::WireQos::default(), &tiny_graph())
            .unwrap();
        let f2 =
            proto::encode_response(&WireResponse::ok(8, "gcn", vec![1.0, 2.0])).unwrap();
        let stream: Vec<u8> = f1.iter().chain(f2.iter()).copied().collect();
        // Feed the concatenated stream one byte at a time; exactly two
        // payloads must pop out, each equal to its frame minus the
        // length prefix.
        let mut fb = FrameBuf::new();
        let mut payloads = Vec::new();
        for b in &stream {
            fb.extend(std::slice::from_ref(b));
            while let Some(p) = fb.next_payload().unwrap() {
                payloads.push(p);
            }
        }
        assert_eq!(payloads.len(), 2);
        assert_eq!(payloads[0], f1[4..].to_vec());
        assert_eq!(payloads[1], f2[4..].to_vec());
        // And both decode back to typed frames.
        assert!(matches!(
            proto::decode_frame(&payloads[0]).unwrap(),
            WireFrame::Request(_)
        ));
        assert!(matches!(
            proto::decode_frame(&payloads[1]).unwrap(),
            WireFrame::Response(_)
        ));
    }

    #[test]
    fn hostile_length_prefix_is_an_error() {
        let mut fb = FrameBuf::new();
        let len = (proto::MAX_FRAME_BYTES as u32 + 1).to_le_bytes();
        fb.extend(&len);
        assert!(fb.next_payload().is_err());
        // A zero-length frame, by contrast, is well-framed (it will
        // fail *decoding* and be answered BadRequest, like the
        // blocking path).
        let mut fb = FrameBuf::new();
        fb.extend(&0u32.to_le_bytes());
        assert_eq!(fb.next_payload().unwrap(), Some(Vec::new()));
    }

    /// A writer that accepts a fixed number of bytes per call, then
    /// reports `WouldBlock` — the shape of a nonblocking socket under
    /// a slow reader.
    struct Trickle {
        accepted: Vec<u8>,
        per_call: usize,
        budget: usize,
    }

    impl Write for Trickle {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            if self.budget == 0 {
                return Err(std::io::ErrorKind::WouldBlock.into());
            }
            let n = buf.len().min(self.per_call).min(self.budget);
            self.accepted.extend_from_slice(&buf[..n]);
            self.budget -= n;
            Ok(n)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn write_buffer_caps_queueing_and_drains_incrementally() {
        let mut wb = WriteBuf::with_cap(10);
        assert!(wb.push(&[1, 2, 3, 4, 5, 6]));
        assert!(!wb.push(&[0; 5]), "over-cap push must report the drop");
        assert!(wb.push(&[7, 8, 9, 10]), "exactly-at-cap push fits");
        assert_eq!(wb.queued(), 10);

        let mut w = Trickle {
            accepted: Vec::new(),
            per_call: 3,
            budget: 4,
        };
        wb.write_to(&mut w).unwrap();
        assert_eq!(w.accepted, vec![1, 2, 3, 4], "partial drain stops at WouldBlock");
        assert_eq!(wb.queued(), 6, "cursor advanced past written bytes");

        // Freed capacity is reusable, and a full drain resets the
        // buffer entirely.
        assert!(wb.push(&[11, 12]));
        let mut w2 = Trickle {
            accepted: Vec::new(),
            per_call: 64,
            budget: 64,
        };
        wb.write_to(&mut w2).unwrap();
        assert_eq!(w2.accepted, vec![5, 6, 7, 8, 9, 10, 11, 12]);
        assert!(wb.is_empty());
        assert_eq!(wb.queued(), 0);
    }

    #[test]
    fn route_table_settles_each_id_exactly_once() {
        let routes = RouteTable::new();
        for id in 0..64u64 {
            routes.insert(
                id,
                RouteEntry {
                    reactor: 0,
                    token: id,
                    client_id: id * 2,
                    version: PROTO_VERSION,
                },
            );
        }
        let mut hits = 0;
        for id in 0..64u64 {
            if let Some(e) = routes.remove(id) {
                assert_eq!(e.client_id, id * 2);
                hits += 1;
            }
            assert!(routes.remove(id).is_none(), "double remove must miss");
        }
        assert_eq!(hits, 64);
    }
}

//! Open-loop load generator for the TCP serving front-end.
//!
//! Open-loop means arrivals follow a fixed schedule — request `k` is
//! sent at `t0 + k/rps` regardless of how fast responses come back —
//! so a saturated server shows up as growing latency (and, under the
//! `Reject` admission policy, as `Rejected` wire statuses) instead of
//! silently throttling the generator (the coordinated-omission trap of
//! closed-loop benchmarks). Latency is therefore measured from the
//! *scheduled* arrival time: queueing delay the server imposes on a
//! late request is part of the number.
//!
//! The request stream is deterministic: a seeded pool of
//! datagen-sourced molecular graphs, a round-robin model mix, and the
//! `k/rps` inter-arrival grid, so two runs with the same config put an
//! identical byte stream on the wire.
//!
//! Requests are striped over `connections` sockets; each socket has a
//! writer thread (paces the schedule, pipelines frames without
//! waiting) and a reader thread (drains responses, classifies
//! Ok / Rejected / Error, feeds the latency histogram). The report
//! reconciles `submitted = completed + rejected + failed + lost`;
//! `lost` is nonzero only if the server dropped a connection or the
//! drain timed out.

use std::collections::{BTreeMap, HashMap};
use std::io::{BufReader, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::datagen::{molecular_graph, MolConfig};
use crate::graph::CooGraph;
use crate::util::bench::BenchResult;
use crate::util::rng::Rng;
use crate::util::stats::{fmt_secs, LatencyHistogram};

use crate::coordinator::Priority;

use crate::resident::MutateOp;

use super::client::RequestOptions;
use super::proto::{self, WireFrame, WireGraphMutate, WireGraphQuery, WireStatus};
use super::server::dial;

/// Load generator parameters.
#[derive(Clone, Debug)]
pub struct LoadGenConfig {
    /// Server address, e.g. `127.0.0.1:7447`.
    pub addr: String,
    /// Target request rate (the open-loop schedule).
    pub rps: f64,
    /// Total requests to send.
    pub count: usize,
    /// Connections to stripe the stream over.
    pub connections: usize,
    /// Model mix, applied round-robin per request.
    pub models: Vec<String>,
    /// Seed for the graph pool.
    pub seed: u64,
    /// Distinct pre-generated graphs cycled through the stream.
    pub graph_pool: usize,
    /// How long a reader waits on a silent socket — *beyond the full
    /// open-loop schedule span* (`count/rps`, during which silence is
    /// normal at low rates) — before declaring the remaining responses
    /// lost.
    pub drain_timeout: Duration,
    /// TTL stamped on every request (`0` = none): under overload the
    /// server sheds lapsed requests as `Expired` instead of queueing
    /// them to a deadline nobody will meet.
    pub ttl_ms: u32,
    /// Priority-class mix, e.g. `"high:1,normal:8,low:1"` — weights
    /// expand into a deterministic repeating pattern applied by
    /// request index. Empty = all normal.
    pub priority_mix: String,
    /// Mixed-scenario traffic, e.g. `"molecular:2,query:6,mutate:1"`
    /// — same weight syntax as `priority_mix`, expanded into a
    /// deterministic repeating [`Scenario`] pattern by request index.
    /// Empty = all molecular (the pre-v4 stream, byte-identical).
    pub scenario: String,
    /// Shape the open-loop schedule with a deterministic sinusoidal
    /// rate curve — one synthetic "day" mapped onto the run, sweeping
    /// 0.5× to 1.5× the target rate — instead of a flat `k/rps` grid.
    pub diurnal: bool,
    /// Hop depth stamped on `query` scenario requests.
    pub query_hops: u8,
    /// Fanout stamped on `query` scenario requests (0 = bit-exact
    /// full expansion).
    pub query_fanout: u16,
    /// Node-id range `[0, resident_nodes)` that query seeds and
    /// mutation endpoints are drawn from; must match the resident
    /// dataset (e.g. 2708 for Cora).
    pub resident_nodes: u32,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            addr: "127.0.0.1:7447".to_string(),
            rps: 200.0,
            count: 1000,
            connections: 2,
            models: vec!["gcn".to_string()],
            seed: 7,
            graph_pool: 32,
            drain_timeout: Duration::from_secs(30),
            ttl_ms: 0,
            priority_mix: String::new(),
            scenario: String::new(),
            diurnal: false,
            query_hops: 2,
            query_fanout: 0,
            resident_nodes: 2708,
        }
    }
}

/// One request's traffic class in a mixed-scenario run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// A whole molecular graph shipped in the request (v2 frames).
    Molecular,
    /// A resident k-hop `GRAPH_QUERY` (v4 frames).
    Query,
    /// A resident `GRAPH_MUTATE` batch (v4 frames).
    Mutate,
}

impl Scenario {
    fn parse(name: &str) -> Result<Scenario> {
        match name {
            "molecular" => Ok(Scenario::Molecular),
            "query" => Ok(Scenario::Query),
            "mutate" => Ok(Scenario::Mutate),
            other => anyhow::bail!(
                "unknown scenario {other:?} (expected molecular, query, or mutate)"
            ),
        }
    }
}

/// Expand a `"molecular:2,query:6,mutate:1"` mix into the
/// deterministic repeating scenario pattern applied by request index
/// (same weight syntax and determinism story as [`priority_pattern`]).
pub fn scenario_pattern(mix: &str) -> Result<Vec<Scenario>> {
    let mix = mix.trim();
    if mix.is_empty() {
        return Ok(vec![Scenario::Molecular]);
    }
    let mut pattern = Vec::new();
    for part in mix.split(',') {
        let part = part.trim();
        let (name, weight) = match part.split_once(':') {
            Some((n, w)) => (
                n.trim(),
                w.trim()
                    .parse::<usize>()
                    .with_context(|| format!("bad weight in scenario entry {part:?}"))?,
            ),
            None => (part, 1),
        };
        let sc = Scenario::parse(name)?;
        anyhow::ensure!(weight > 0, "zero weight in scenario entry {part:?}");
        pattern.extend(std::iter::repeat(sc).take(weight));
    }
    anyhow::ensure!(
        pattern.len() <= 4096,
        "scenario mix expands to {} slots (max 4096)",
        pattern.len()
    );
    Ok(pattern)
}

/// Per-request departure offsets from `t0`. Flat mode is the classic
/// `k/rps` grid. Diurnal mode accumulates inter-arrival gaps
/// `1/(rps·m(x))` with `m(x) = 1 + 0.5·sin(2πx)`, `x = k/count` — the
/// whole run is one synthetic day, so the stream sweeps trough
/// (0.5×), peak (1.5×), and back, deterministically: two runs with
/// the same config still put an identical schedule on the wire.
fn departure_offsets(cfg: &LoadGenConfig) -> Vec<Duration> {
    let mut offs = Vec::with_capacity(cfg.count);
    let mut t = 0.0f64;
    for k in 0..cfg.count {
        offs.push(Duration::from_secs_f64(t));
        let rate = if cfg.diurnal {
            let x = k as f64 / cfg.count as f64;
            cfg.rps * (1.0 + 0.5 * (2.0 * std::f64::consts::PI * x).sin())
        } else {
            cfg.rps
        };
        t += 1.0 / rate.max(1e-9);
    }
    offs
}

/// Expand a `"high:1,normal:8,low:1"` mix into the deterministic
/// repeating priority pattern applied by request index (so two runs
/// with the same config stamp identical QoS on the wire).
pub fn priority_pattern(mix: &str) -> Result<Vec<Priority>> {
    let mix = mix.trim();
    if mix.is_empty() {
        return Ok(vec![Priority::Normal]);
    }
    let mut pattern = Vec::new();
    for part in mix.split(',') {
        let part = part.trim();
        let (name, weight) = match part.split_once(':') {
            Some((n, w)) => (
                n.trim(),
                w.trim()
                    .parse::<usize>()
                    .with_context(|| format!("bad weight in priority mix entry {part:?}"))?,
            ),
            None => (part, 1),
        };
        let prio = Priority::parse(name)?;
        anyhow::ensure!(weight > 0, "zero weight in priority mix entry {part:?}");
        pattern.extend(std::iter::repeat(prio).take(weight));
    }
    anyhow::ensure!(
        pattern.len() <= 4096,
        "priority mix expands to {} slots (max 4096)",
        pattern.len()
    );
    Ok(pattern)
}

/// What one load-generation run produced.
#[derive(Clone, Debug)]
pub struct LoadGenReport {
    pub submitted: u64,
    pub completed: u64,
    /// Requests the server shed: admission rejections plus deadline
    /// expiries (`shed_by_deadline` is the expiry sub-count).
    pub rejected: u64,
    /// Of `rejected`, how many came back `Expired` — the server chose
    /// to shed by lapsed TTL rather than by arrival order.
    pub shed_by_deadline: u64,
    pub failed: u64,
    /// Requests that never received a response (connection drop or
    /// drain timeout) — zero on a healthy run.
    pub lost: u64,
    pub wall_secs: f64,
    pub target_rps: f64,
    /// Completed responses per second of wall clock.
    pub achieved_rps: f64,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
    /// Completed responses per model.
    pub per_model: Vec<(String, u64)>,
    /// Of `completed`, resident k-hop queries answered `Ok`.
    pub query_completed: u64,
    /// Of `completed`, mutation batches the server processed.
    pub mutate_completed: u64,
    /// Individual mutation ops the server applied across all
    /// completed mutate batches.
    pub mutate_ops_applied: u64,
}

impl LoadGenReport {
    /// Every submitted request is accounted for and none were lost.
    pub fn reconciles(&self) -> bool {
        self.lost == 0
            && self.submitted == self.completed + self.rejected + self.failed
    }

    /// Human-readable summary.
    pub fn render(&self) -> String {
        let mut out = format!(
            "loadgen: {} submitted @ {:.0} rps target → {} ok, {} rejected ({} shed by deadline), {} failed, {} lost\n\
             wall {} → {:.1} rps achieved\n",
            self.submitted,
            self.target_rps,
            self.completed,
            self.rejected,
            self.shed_by_deadline,
            self.failed,
            self.lost,
            fmt_secs(self.wall_secs),
            self.achieved_rps,
        );
        if self.completed == 0 {
            // Total shedding (e.g. Reject-mode saturation) is a
            // first-class outcome: no latencies exist, say so instead
            // of printing NaNs.
            out.push_str("latency: no requests completed\n");
        } else {
            out.push_str(&format!(
                "latency (from scheduled arrival): mean {} p50 {} p95 {} p99 {} max {}\n",
                fmt_secs(self.mean),
                fmt_secs(self.p50),
                fmt_secs(self.p95),
                fmt_secs(self.p99),
                fmt_secs(self.max),
            ));
        }
        for (model, n) in &self.per_model {
            out.push_str(&format!("  {model:<10} {n} completed\n"));
        }
        if self.query_completed > 0 || self.mutate_completed > 0 {
            out.push_str(&format!(
                "resident: {} queries ok, {} mutate batches ({} ops applied)\n",
                self.query_completed, self.mutate_completed, self.mutate_ops_applied,
            ));
        }
        out
    }

    /// The run as `BENCH_*.json`-schema entries (the perf-trajectory
    /// anchor format of `util::bench::results_to_json`). Every entry
    /// honors the snapshot invariants `check_bench_schema.py` enforces
    /// (finite non-negative values, `min_s <= mean_s`); a run with no
    /// completions exports nothing rather than NaNs.
    pub fn to_bench_results(&self) -> Vec<BenchResult> {
        let n = self.completed as usize;
        if n == 0 {
            return Vec::new();
        }
        let per_completed = 1.0 / self.achieved_rps;
        let mut out = vec![
            BenchResult {
                name: "loadgen/e2e_latency".to_string(),
                iters: n,
                mean: self.mean,
                p50: self.p50,
                min: self.min,
            },
            BenchResult {
                name: "loadgen/e2e_latency_p95".to_string(),
                iters: n,
                mean: self.p95,
                p50: self.p95,
                min: self.p95,
            },
            BenchResult {
                name: "loadgen/e2e_latency_p99".to_string(),
                iters: n,
                mean: self.p99,
                p50: self.p99,
                min: self.p99,
            },
            BenchResult {
                name: "loadgen/seconds_per_completed".to_string(),
                iters: n,
                mean: per_completed,
                p50: per_completed,
                min: per_completed,
            },
            // A count, not a duration — exported so the deadline-shed
            // path stays observable in the perf trajectory (zero on an
            // unloaded run is itself the signal).
            BenchResult {
                name: "loadgen/shed_by_deadline".to_string(),
                iters: self.submitted as usize,
                mean: self.shed_by_deadline as f64,
                p50: self.shed_by_deadline as f64,
                min: self.shed_by_deadline as f64,
            },
            // Likewise a count: server-side `Error` answers (e.g. a
            // backend dying under an ingress mid-request). Zero on a
            // healthy run; the ingress fault-injection smoke asserts
            // it goes positive when a backend is killed mid-load.
            BenchResult {
                name: "loadgen/failed".to_string(),
                iters: self.submitted as usize,
                mean: self.failed as f64,
                p50: self.failed as f64,
                min: self.failed as f64,
            },
        ];
        // Mixed-scenario series (counts, like shed_by_deadline):
        // exported only when resident traffic ran, so molecular-only
        // snapshots keep their exact pre-v4 shape.
        if self.query_completed > 0 || self.mutate_completed > 0 {
            out.push(BenchResult {
                name: "loadgen/query_completed".to_string(),
                iters: self.submitted as usize,
                mean: self.query_completed as f64,
                p50: self.query_completed as f64,
                min: self.query_completed as f64,
            });
            out.push(BenchResult {
                name: "loadgen/mutate_applied".to_string(),
                iters: self.submitted as usize,
                mean: self.mutate_ops_applied as f64,
                p50: self.mutate_ops_applied as f64,
                min: self.mutate_ops_applied as f64,
            });
        }
        out
    }
}

/// Shared run state: the latency histogram and outcome counters — all
/// lock-free. Pending maps (request id → scheduled arrival) are per
/// connection (ids are striped by connection, so each map has exactly
/// one writer and one reader), and per-model counts are local to each
/// reader and merged at join time: the hot path takes no cross-
/// connection lock, so the generator cannot serialize on its own
/// bookkeeping while measuring the server.
struct RunState {
    latency: LatencyHistogram,
    completed: AtomicU64,
    rejected: AtomicU64,
    shed_by_deadline: AtomicU64,
    failed: AtomicU64,
    query_completed: AtomicU64,
    mutate_completed: AtomicU64,
    mutate_ops_applied: AtomicU64,
}

type PendingMap = Arc<Mutex<HashMap<u64, Instant>>>;

/// Deterministic query seed set for request `k`: one or two distinct
/// node ids hashed from the request index (requires `nodes >= 2`).
fn query_seeds(k: usize, nodes: u32) -> Vec<u32> {
    let n = u64::from(nodes);
    let a = ((k as u64).wrapping_mul(2_654_435_761) % n) as u32;
    if k % 2 == 0 {
        // An offset in [1, n-1] can never collide with `a` mod n.
        let off = 1 + (k as u64 % (n - 1));
        let b = ((u64::from(a) + off) % n) as u32;
        vec![a, b]
    } else {
        vec![a]
    }
}

/// Deterministic mutation batch for request `k`: alternating add /
/// remove of a hashed edge, so the resident graph churns under load
/// without drifting unboundedly.
fn mutate_ops(k: usize, nodes: u32) -> Vec<MutateOp> {
    let n = u64::from(nodes);
    let a = ((k as u64).wrapping_mul(7_919) % n) as u32;
    let off = 1 + ((k as u64).wrapping_mul(104_729) % (n - 1));
    let b = ((u64::from(a) + off) % n) as u32;
    if k % 2 == 0 {
        vec![MutateOp::AddEdge(a, b)]
    } else {
        vec![MutateOp::RemoveEdge(a, b)]
    }
}

/// Run one open-loop load generation pass against a live server.
pub fn run(cfg: &LoadGenConfig) -> Result<LoadGenReport> {
    anyhow::ensure!(cfg.rps > 0.0, "rps must be positive");
    anyhow::ensure!(cfg.count > 0, "count must be positive");
    anyhow::ensure!(!cfg.models.is_empty(), "need at least one model");
    let connections = cfg.connections.clamp(1, cfg.count);
    let pattern = Arc::new(priority_pattern(&cfg.priority_mix)?);
    let scenarios = Arc::new(scenario_pattern(&cfg.scenario)?);
    if scenarios.iter().any(|s| *s != Scenario::Molecular) {
        anyhow::ensure!(
            cfg.resident_nodes >= 2,
            "resident scenarios need resident_nodes >= 2 (got {})",
            cfg.resident_nodes
        );
    }
    // The departure schedule (flat or diurnal), computed once and
    // indexed by request number from every writer.
    let offsets = Arc::new(departure_offsets(cfg));

    // Deterministic graph pool: `graph_pool` seeded molecular graphs
    // total, shared across the model mix and cycled through the
    // schedule (every manifest model accepts the MolHIV envelope).
    let mut rng = Rng::new(cfg.seed);
    let pool_size = cfg.graph_pool.max(1);
    let graphs: Vec<CooGraph> = (0..pool_size)
        .map(|_| molecular_graph(&mut rng, &MolConfig::molhiv()))
        .collect();
    let graphs = Arc::new(graphs);

    let state = Arc::new(RunState {
        latency: LatencyHistogram::new(),
        completed: AtomicU64::new(0),
        rejected: AtomicU64::new(0),
        shed_by_deadline: AtomicU64::new(0),
        failed: AtomicU64::new(0),
        query_completed: AtomicU64::new(0),
        mutate_completed: AtomicU64::new(0),
        mutate_ops_applied: AtomicU64::new(0),
    });

    let t0 = Instant::now();
    let mut writer_handles = Vec::new();
    let mut reader_handles: Vec<std::thread::JoinHandle<BTreeMap<String, u64>>> =
        Vec::new();
    let mut written_counters = Vec::new();
    let mut pending_maps: Vec<PendingMap> = Vec::new();
    // The socket read timeout must tolerate the whole schedule: at low
    // rates a reader legitimately sees nothing for `connections/rps`
    // between arrivals, so only silence outlasting the remaining
    // schedule *plus* the drain allowance means responses are lost.
    let read_timeout = cfg
        .drain_timeout
        .saturating_add(Duration::from_secs_f64(cfg.count as f64 / cfg.rps));
    for conn_no in 0..connections {
        let sock = dial(&cfg.addr)
            .with_context(|| format!("loadgen connection {conn_no}"))?;
        sock.set_read_timeout(Some(read_timeout))
            .context("setting drain timeout")?;
        let read_half = BufReader::new(sock.try_clone().context("cloning loadgen socket")?);

        // Per-connection accounting the reader drains against.
        let written = Arc::new(AtomicU64::new(0));
        let writer_done = Arc::new(AtomicBool::new(false));
        let pending: PendingMap = Arc::new(Mutex::new(HashMap::new()));
        written_counters.push(Arc::clone(&written));
        pending_maps.push(Arc::clone(&pending));

        let writer = {
            let cfg = cfg.clone();
            let graphs = Arc::clone(&graphs);
            let pattern = Arc::clone(&pattern);
            let scenarios = Arc::clone(&scenarios);
            let offsets = Arc::clone(&offsets);
            let pending = Arc::clone(&pending);
            let written = Arc::clone(&written);
            let writer_done = Arc::clone(&writer_done);
            let mut sock = sock;
            std::thread::Builder::new()
                .name(format!("gengnn-loadgen-writer-{conn_no}"))
                .spawn(move || {
                    for k in (conn_no..cfg.count).step_by(connections) {
                        // The open-loop schedule: request k departs at
                        // its precomputed offset (flat `k/rps` or the
                        // diurnal curve), never earlier.
                        let sched = t0 + offsets[k];
                        let now = Instant::now();
                        if sched > now {
                            std::thread::sleep(sched - now);
                        }
                        // Same per-request options struct as the
                        // client's `call` path, so loadgen and client
                        // traffic stamp QoS identically.
                        let opts =
                            RequestOptions::new(cfg.ttl_ms, pattern[k % pattern.len()]);
                        let frame = match scenarios[k % scenarios.len()] {
                            Scenario::Molecular => {
                                let model = &cfg.models[k % cfg.models.len()];
                                let graph = &graphs[(k / cfg.models.len()) % graphs.len()];
                                proto::encode_request_parts(k as u64, model, opts.qos(), graph)
                            }
                            Scenario::Query => proto::encode_graph_query(&WireGraphQuery {
                                id: k as u64,
                                qos: opts.qos(),
                                hops: cfg.query_hops,
                                fanout: cfg.query_fanout,
                                seeds: query_seeds(k, cfg.resident_nodes),
                            }),
                            Scenario::Mutate => proto::encode_graph_mutate(&WireGraphMutate {
                                id: k as u64,
                                ops: mutate_ops(k, cfg.resident_nodes),
                            }),
                        };
                        let Ok(frame) = frame else {
                            continue;
                        };
                        // Count + register *before* the write: the
                        // response to a written frame can arrive (and be
                        // checked against `written`) before control
                        // returns from write_all.
                        crate::util::sync::lock(&pending).insert(k as u64, sched);
                        written.fetch_add(1, Ordering::Release);
                        if sock.write_all(&frame).is_err() {
                            crate::util::sync::lock(&pending).remove(&(k as u64));
                            written.fetch_sub(1, Ordering::Release);
                            break;
                        }
                    }
                    let _ = sock.flush();
                    writer_done.store(true, Ordering::Release);
                })
                .expect("spawn loadgen writer")
        };
        writer_handles.push(writer);

        let reader = {
            let state = Arc::clone(&state);
            let pending = Arc::clone(&pending);
            let written = Arc::clone(&written);
            let writer_done = Arc::clone(&writer_done);
            let mut rx = read_half;
            std::thread::Builder::new()
                .name(format!("gengnn-loadgen-reader-{conn_no}"))
                .spawn(move || {
                    let mut per_model: BTreeMap<String, u64> = BTreeMap::new();
                    let mut received = 0u64;
                    loop {
                        // Only park in a socket read when a response is
                        // actually owed (`written` counts before the
                        // frame hits the wire), so the end-of-run
                        // writer_done race can never strand this reader
                        // in a long blocking read. The 1 ms flag poll
                        // between arrivals cannot bias latency: an owed
                        // response always takes the read path below.
                        if received >= written.load(Ordering::Acquire) {
                            if writer_done.load(Ordering::Acquire) {
                                break;
                            }
                            std::thread::sleep(Duration::from_millis(1));
                            continue;
                        }
                        let payload = match proto::read_frame(&mut rx) {
                            Ok(Some(p)) => p,
                            // Server closed, socket error, or drain
                            // timeout: the rest is lost.
                            Ok(None) | Err(_) => break,
                        };
                        let Ok(frame) = proto::decode_frame(&payload) else {
                            break;
                        };
                        // Every answer frame classifies into the same
                        // four buckets, so `submitted = completed +
                        // rejected + failed (+ lost)` reconciles across
                        // mixed-scenario streams too.
                        let (id, status, label, ops_applied) = match frame {
                            WireFrame::Response(resp) => {
                                (resp.id, resp.status, resp.model, 0)
                            }
                            WireFrame::GraphQueryResp(resp) => (
                                resp.id,
                                resp.status,
                                "resident_query".to_string(),
                                0,
                            ),
                            WireFrame::GraphMutateResp(resp) => (
                                resp.id,
                                resp.status,
                                "resident_mutate".to_string(),
                                u64::from(resp.applied),
                            ),
                            // A request or control frame from the
                            // server is a protocol violation.
                            _ => break,
                        };
                        received += 1;
                        let sched = crate::util::sync::lock(&pending).remove(&id);
                        match status {
                            WireStatus::Ok => {
                                state.completed.fetch_add(1, Ordering::Relaxed);
                                match label.as_str() {
                                    "resident_query" => {
                                        state.query_completed.fetch_add(1, Ordering::Relaxed);
                                    }
                                    "resident_mutate" => {
                                        state.mutate_completed.fetch_add(1, Ordering::Relaxed);
                                        state
                                            .mutate_ops_applied
                                            .fetch_add(ops_applied, Ordering::Relaxed);
                                    }
                                    _ => {}
                                }
                                if let Some(sched) = sched {
                                    state.latency.record(
                                        Instant::now()
                                            .saturating_duration_since(sched)
                                            .as_secs_f64(),
                                    );
                                }
                                *per_model.entry(label).or_default() += 1;
                            }
                            WireStatus::Rejected => {
                                state.rejected.fetch_add(1, Ordering::Relaxed);
                            }
                            WireStatus::Expired => {
                                // Shed-by-deadline is a sub-class of
                                // rejection (the server chose what to
                                // shed by TTL, not arrival), so the
                                // reconciliation formula is unchanged.
                                state.rejected.fetch_add(1, Ordering::Relaxed);
                                state.shed_by_deadline.fetch_add(1, Ordering::Relaxed);
                            }
                            WireStatus::Error | WireStatus::BadRequest => {
                                state.failed.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    per_model
                })
                .expect("spawn loadgen reader")
        };
        reader_handles.push(reader);
    }

    for h in writer_handles {
        h.join().map_err(|_| anyhow::anyhow!("loadgen writer panicked"))?;
    }
    let mut per_model: BTreeMap<String, u64> = BTreeMap::new();
    for h in reader_handles {
        let conn_counts =
            h.join().map_err(|_| anyhow::anyhow!("loadgen reader panicked"))?;
        for (model, n) in conn_counts {
            *per_model.entry(model).or_default() += n;
        }
    }
    // Submitted = frames actually written. Everything still pending
    // after the drain is lost; pending inserts that failed to write
    // were removed by the writer, so the maps now hold exactly the
    // unanswered requests.
    let submitted: u64 = written_counters
        .iter()
        .map(|w| w.load(Ordering::Relaxed))
        .sum();
    let lost: u64 = pending_maps
        .iter()
        .map(|p| crate::util::sync::lock(p).len() as u64)
        .sum();
    let completed = state.completed.load(Ordering::Relaxed);
    let rejected = state.rejected.load(Ordering::Relaxed);
    let shed_by_deadline = state.shed_by_deadline.load(Ordering::Relaxed);
    let failed = state.failed.load(Ordering::Relaxed);
    let wall_secs = t0.elapsed().as_secs_f64();

    let h = &state.latency;
    Ok(LoadGenReport {
        submitted,
        completed,
        rejected,
        shed_by_deadline,
        failed,
        lost,
        wall_secs,
        target_rps: cfg.rps,
        achieved_rps: completed as f64 / wall_secs.max(1e-9),
        mean: h.mean(),
        p50: h.quantile(0.50),
        p95: h.quantile(0.95),
        p99: h.quantile(0.99),
        min: h.min(),
        max: h.max(),
        per_model: per_model.into_iter().collect(),
        query_completed: state.query_completed.load(Ordering::Relaxed),
        mutate_completed: state.mutate_completed.load(Ordering::Relaxed),
        mutate_ops_applied: state.mutate_ops_applied.load(Ordering::Relaxed),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_reconciliation_logic() {
        let mut r = LoadGenReport {
            submitted: 10,
            completed: 7,
            rejected: 2,
            shed_by_deadline: 1,
            failed: 1,
            lost: 0,
            wall_secs: 1.0,
            target_rps: 10.0,
            achieved_rps: 7.0,
            mean: 1e-3,
            p50: 1e-3,
            p95: 2e-3,
            p99: 3e-3,
            min: 5e-4,
            max: 4e-3,
            per_model: vec![("gcn".to_string(), 7)],
            query_completed: 0,
            mutate_completed: 0,
            mutate_ops_applied: 0,
        };
        assert!(r.reconciles());
        r.lost = 1;
        assert!(!r.reconciles());
        r.lost = 0;
        r.failed = 0;
        assert!(!r.reconciles(), "accounting gap must fail reconciliation");
    }

    #[test]
    fn report_renders_and_exports_bench_schema() {
        let r = LoadGenReport {
            submitted: 100,
            completed: 100,
            rejected: 0,
            shed_by_deadline: 0,
            failed: 0,
            lost: 0,
            wall_secs: 0.5,
            target_rps: 200.0,
            achieved_rps: 200.0,
            mean: 2e-3,
            p50: 1.8e-3,
            p95: 3e-3,
            p99: 4e-3,
            min: 1e-3,
            max: 5e-3,
            per_model: vec![("gcn".to_string(), 50), ("gat".to_string(), 50)],
            query_completed: 0,
            mutate_completed: 0,
            mutate_ops_applied: 0,
        };
        let text = r.render();
        assert!(text.contains("p99"), "{text}");
        assert!(text.contains("gcn"), "{text}");
        let results = r.to_bench_results();
        assert_eq!(results.len(), 6);
        assert!(
            results.iter().any(|b| b.name == "loadgen/shed_by_deadline"),
            "deadline shedding must stay observable in the bench export"
        );
        assert!(
            results.iter().any(|b| b.name == "loadgen/failed"),
            "server-side failures must stay observable in the bench export"
        );
        // The snapshot invariants check_bench_schema.py enforces.
        for b in &results {
            assert!(b.mean.is_finite() && b.mean >= 0.0, "{}: {}", b.name, b.mean);
            assert!(
                b.min <= b.mean * 1.01 + 1e-12,
                "{}: min {} exceeds mean {}",
                b.name,
                b.min,
                b.mean
            );
        }
        let json = crate::util::bench::results_to_json("loadgen", &results);
        let v = crate::util::json::Json::parse(&json).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str().unwrap(), "loadgen");
        assert_eq!(v.get("results").unwrap().as_arr().unwrap().len(), 6);
        // A run with no completions must export nothing, not NaNs.
        let empty = LoadGenReport {
            completed: 0,
            achieved_rps: 0.0,
            mean: f64::NAN,
            p50: f64::NAN,
            p95: f64::NAN,
            p99: f64::NAN,
            min: f64::NAN,
            max: f64::NAN,
            per_model: Vec::new(),
            ..r
        };
        assert!(empty.to_bench_results().is_empty());
        // Total shedding renders a clear line, not NaN latencies.
        let shed = LoadGenReport {
            completed: 0,
            rejected: empty.submitted,
            ..empty
        };
        let text = shed.render();
        assert!(text.contains("no requests completed"), "{text}");
        assert!(!text.contains("NaN"), "{text}");
    }

    #[test]
    fn config_validation() {
        let bad = LoadGenConfig {
            rps: 0.0,
            ..LoadGenConfig::default()
        };
        assert!(run(&bad).is_err());
        let bad = LoadGenConfig {
            models: vec![],
            ..LoadGenConfig::default()
        };
        assert!(run(&bad).is_err());
        let bad = LoadGenConfig {
            priority_mix: "urgent:3".to_string(),
            ..LoadGenConfig::default()
        };
        assert!(run(&bad).is_err(), "unknown priority class must refuse");
    }

    #[test]
    fn scenario_mix_expands_deterministically() {
        assert_eq!(scenario_pattern("").unwrap(), vec![Scenario::Molecular]);
        let p = scenario_pattern("molecular:2,query:6,mutate:1").unwrap();
        assert_eq!(p.len(), 9);
        assert_eq!(p[0], Scenario::Molecular);
        assert_eq!(p[2], Scenario::Query);
        assert_eq!(p[8], Scenario::Mutate);
        assert_eq!(
            scenario_pattern("query,mutate").unwrap(),
            vec![Scenario::Query, Scenario::Mutate]
        );
        assert!(scenario_pattern("replay:2").is_err());
        assert!(scenario_pattern("query:0").is_err());
        // Resident traffic against a degenerate node range refuses.
        let bad = LoadGenConfig {
            scenario: "query".to_string(),
            resident_nodes: 1,
            ..LoadGenConfig::default()
        };
        assert!(run(&bad).is_err());
    }

    #[test]
    fn scenario_bench_series_appear_only_with_resident_traffic() {
        let base = LoadGenReport {
            submitted: 10,
            completed: 10,
            rejected: 0,
            shed_by_deadline: 0,
            failed: 0,
            lost: 0,
            wall_secs: 1.0,
            target_rps: 10.0,
            achieved_rps: 10.0,
            mean: 1e-3,
            p50: 1e-3,
            p95: 1e-3,
            p99: 1e-3,
            min: 1e-3,
            max: 1e-3,
            per_model: vec![],
            query_completed: 0,
            mutate_completed: 0,
            mutate_ops_applied: 0,
        };
        let names: Vec<String> =
            base.to_bench_results().into_iter().map(|b| b.name).collect();
        assert!(!names.iter().any(|n| n.contains("query")), "{names:?}");
        let mixed = LoadGenReport {
            query_completed: 6,
            mutate_completed: 2,
            mutate_ops_applied: 2,
            ..base
        };
        let results = mixed.to_bench_results();
        let q = results
            .iter()
            .find(|b| b.name == "loadgen/query_completed")
            .expect("query series");
        assert_eq!(q.mean, 6.0);
        let m = results
            .iter()
            .find(|b| b.name == "loadgen/mutate_applied")
            .expect("mutate series");
        assert_eq!(m.mean, 2.0);
        assert!(mixed.render().contains("6 queries ok"), "{}", mixed.render());
    }

    #[test]
    fn deterministic_seed_and_mutation_generators() {
        for k in 0..200 {
            let s = query_seeds(k, 40);
            assert!(!s.is_empty() && s.len() <= 2);
            assert!(s.iter().all(|&v| v < 40), "{s:?}");
            if s.len() == 2 {
                assert_ne!(s[0], s[1], "k={k}");
            }
            assert_eq!(s, query_seeds(k, 40), "must be deterministic");
            for op in mutate_ops(k, 40) {
                match op {
                    MutateOp::AddEdge(a, b) | MutateOp::RemoveEdge(a, b) => {
                        assert!(a < 40 && b < 40 && a != b, "k={k}");
                    }
                    MutateOp::AddNode(_) => panic!("generator emits edge churn only"),
                }
            }
        }
    }

    #[test]
    fn diurnal_schedule_is_monotone_and_sweeps_the_rate() {
        let cfg = LoadGenConfig {
            rps: 100.0,
            count: 400,
            diurnal: true,
            ..LoadGenConfig::default()
        };
        let offs = departure_offsets(&cfg);
        assert_eq!(offs.len(), 400);
        assert!(offs.windows(2).all(|w| w[0] < w[1]), "monotone departures");
        assert_eq!(offs, departure_offsets(&cfg), "deterministic");
        // Peak gaps (around x=0.25, rate 1.5x) are shorter than trough
        // gaps (around x=0.75, rate 0.5x).
        let gap = |i: usize| (offs[i + 1] - offs[i]).as_secs_f64();
        assert!(gap(100) < gap(300), "peak {} vs trough {}", gap(100), gap(300));
        // Flat mode is the classic grid.
        let flat = LoadGenConfig {
            diurnal: false,
            ..cfg
        };
        let f = departure_offsets(&flat);
        assert!((f[100].as_secs_f64() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn priority_mix_expands_deterministically() {
        assert_eq!(priority_pattern("").unwrap(), vec![Priority::Normal]);
        let p = priority_pattern("high:1,normal:2,low:1").unwrap();
        assert_eq!(
            p,
            vec![
                Priority::High,
                Priority::Normal,
                Priority::Normal,
                Priority::Low
            ]
        );
        // Bare names default to weight 1.
        assert_eq!(
            priority_pattern("high,low").unwrap(),
            vec![Priority::High, Priority::Low]
        );
        assert!(priority_pattern("high:0").is_err());
        assert!(priority_pattern("high:x").is_err());
        assert!(priority_pattern("normal:99999").is_err());
    }
}

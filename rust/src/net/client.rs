//! Blocking wire client with connection pooling.
//!
//! [`NetClient`] is the programmatic counterpart of the TCP front-end.
//! Every data-plane entry point funnels through one core,
//! [`NetClient::call`], taking the request knobs as a
//! [`RequestOptions`] struct — `infer`/`infer_with_qos` remain as thin
//! wrappers over it. The control plane ([`NetClient::deploy`],
//! [`NetClient::undeploy`], [`NetClient::rollback`],
//! [`NetClient::models`]) speaks v3 control frames to the server's
//! live model registry over the same pooled connections.
//!
//! Connections are checked out per call; up to `max_pool` idle sockets
//! are retained between calls, and concurrent callers beyond that dial
//! transient connections that are torn down on return — the pool
//! bounds idle state, not peak concurrency. Each socket carries one
//! request at a time (pipelined streaming is the load generator's
//! business, see [`super::loadgen`]).

use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::Priority;
use crate::graph::CooGraph;
use crate::resident::MutateOp;

use super::proto::{
    self, Op, WireControl, WireControlResp, WireFrame, WireGraphMutate, WireGraphMutateResp,
    WireGraphQuery, WireGraphQueryResp, WireQos, WireResponse, WireStatus,
};
use super::server::dial;

/// Per-request knobs for [`NetClient::call`], so QoS travels as one
/// named struct instead of positional arguments. `Default` is exactly
/// the v1 wire semantics: no TTL, normal priority.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RequestOptions {
    /// Time-to-live in milliseconds from submission; 0 = no deadline.
    /// Past the TTL the server may shed the request (`Expired`).
    pub ttl_ms: u32,
    /// Dispatch priority class.
    pub priority: Priority,
}

impl RequestOptions {
    pub fn new(ttl_ms: u32, priority: Priority) -> RequestOptions {
        RequestOptions { ttl_ms, priority }
    }

    /// The wire QoS block this encodes to.
    pub fn qos(&self) -> WireQos {
        WireQos::new(self.ttl_ms, self.priority)
    }
}

impl From<WireQos> for RequestOptions {
    fn from(qos: WireQos) -> RequestOptions {
        RequestOptions {
            ttl_ms: qos.ttl_ms,
            priority: qos.priority,
        }
    }
}

/// One pooled connection: the write half and a buffered read half over
/// a clone of the same socket.
struct PooledConn {
    tx: TcpStream,
    rx: BufReader<TcpStream>,
}

impl PooledConn {
    fn dial(addr: &str, timeout: Duration) -> Result<PooledConn> {
        let tx = dial(addr)?;
        // A server that admits a request but never answers (dead lane,
        // dropped response) must surface as an error, not an infinite
        // block in `infer`.
        tx.set_read_timeout(Some(timeout))
            .context("setting client read timeout")?;
        let rx = BufReader::new(tx.try_clone().context("cloning client socket")?);
        Ok(PooledConn { tx, rx })
    }
}

/// Default per-response wait before [`NetClient::call`] gives up on a
/// silent server.
const DEFAULT_CLIENT_TIMEOUT: Duration = Duration::from_secs(60);

/// Blocking inference + control client over the wire protocol.
pub struct NetClient {
    addr: String,
    pool: Mutex<Vec<PooledConn>>,
    max_pool: usize,
    timeout: Duration,
    next_id: AtomicU64,
}

impl NetClient {
    /// Connect to a serving front-end; dials one connection eagerly so
    /// an unreachable address fails here, not on the first `infer`.
    /// Responses are waited on for a 60 s default — see
    /// [`NetClient::connect_with_timeout`] to tune it.
    pub fn connect(addr: impl Into<String>, max_pool: usize) -> Result<NetClient> {
        Self::connect_with_timeout(addr, max_pool, DEFAULT_CLIENT_TIMEOUT)
    }

    /// [`NetClient::connect`] with an explicit per-response timeout.
    pub fn connect_with_timeout(
        addr: impl Into<String>,
        max_pool: usize,
        timeout: Duration,
    ) -> Result<NetClient> {
        let addr = addr.into();
        let first = PooledConn::dial(&addr, timeout)?;
        Ok(NetClient {
            addr,
            pool: Mutex::new(vec![first]),
            max_pool: max_pool.max(1),
            timeout,
            next_id: AtomicU64::new(0),
        })
    }

    /// Run one inference over the wire; blocks for the response. This
    /// is the single data-plane core — every other inference entry
    /// point wraps it.
    ///
    /// `Rejected` / `Error` / `BadRequest` wire statuses are returned
    /// as an `Ok(WireResponse)` — they are protocol-level answers, not
    /// transport failures — so callers can distinguish shed load from
    /// a dead server.
    pub fn call(
        &self,
        model: &str,
        graph: &CooGraph,
        opts: &RequestOptions,
    ) -> Result<WireResponse> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let frame = proto::encode_request_parts(id, model, opts.qos(), graph)?;
        self.with_conn(|conn| Self::exchange(conn, &frame, id))
    }

    /// [`NetClient::call`] with default options (no TTL, normal
    /// priority — exactly what a v1 frame decodes to).
    pub fn infer(&self, model: &str, graph: &CooGraph) -> Result<WireResponse> {
        self.call(model, graph, &RequestOptions::default())
    }

    /// [`NetClient::call`] with QoS given as the wire block (legacy
    /// surface; prefer [`RequestOptions`]).
    pub fn infer_with_qos(
        &self,
        model: &str,
        graph: &CooGraph,
        qos: WireQos,
    ) -> Result<WireResponse> {
        self.call(model, graph, &RequestOptions::from(qos))
    }

    /// Remaining TTL of a deadline budget: `budget_ms` minus the time
    /// already elapsed, `None` once the budget is spent. Pure — retry
    /// loops (and the unit test pinning the shrink sequence) drive it
    /// with explicit clocks. Never yields 0, which would decode as "no
    /// deadline" on the wire: a fully consumed budget is `None`.
    pub fn shrink_ttl(budget_ms: u32, start: Instant, now: Instant) -> Option<u32> {
        let elapsed = now.saturating_duration_since(start).as_millis();
        let remaining = u128::from(budget_ms).checked_sub(elapsed)?;
        (remaining > 0).then_some(remaining as u32)
    }

    /// Deadline-propagating retry wrapper around [`NetClient::call`]:
    /// a `Rejected` answer is retried (up to `max_retries` times) with
    /// the TTL shrunk to budget-minus-elapsed, so no retry can outlive
    /// the caller's original deadline — the server sees the *remaining*
    /// budget, not a fresh one. A budget that runs out between
    /// attempts comes back as a synthetic `Expired` response.
    pub fn call_with_budget(
        &self,
        model: &str,
        graph: &CooGraph,
        budget_ms: u32,
        priority: Priority,
        max_retries: u32,
    ) -> Result<WireResponse> {
        let start = Instant::now();
        let mut attempts = 0u32;
        loop {
            let Some(ttl) = Self::shrink_ttl(budget_ms, start, Instant::now()) else {
                return Ok(WireResponse::err(
                    0,
                    model,
                    WireStatus::Expired,
                    "deadline budget exhausted before submission",
                ));
            };
            let resp = self.call(model, graph, &RequestOptions::new(ttl, priority))?;
            if resp.status != WireStatus::Rejected || attempts >= max_retries {
                return Ok(resp);
            }
            attempts += 1;
        }
    }

    /// One resident k-hop query (wire v4 `GRAPH_QUERY`); blocks for
    /// the per-seed output rows. Non-`Ok` statuses (`Rejected` on a
    /// non-resident server or shallow hops, `BadRequest` on bad seeds)
    /// come back as an `Ok(WireGraphQueryResp)` — inspect `status`.
    pub fn graph_query(
        &self,
        seeds: &[u32],
        hops: u8,
        fanout: u16,
        opts: &RequestOptions,
    ) -> Result<WireGraphQueryResp> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let frame = proto::encode_graph_query(&WireGraphQuery {
            id,
            qos: opts.qos(),
            hops,
            fanout,
            seeds: seeds.to_vec(),
        })?;
        self.with_conn(|conn| Self::exchange_query(conn, &frame, id))
    }

    /// One mutation batch against the resident graph (wire v4
    /// `GRAPH_MUTATE`); blocks for the applied/rejected counts and the
    /// published snapshot version.
    pub fn graph_mutate(&self, ops: &[MutateOp]) -> Result<WireGraphMutateResp> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let frame = proto::encode_graph_mutate(&WireGraphMutate {
            id,
            ops: ops.to_vec(),
        })?;
        self.with_conn(|conn| Self::exchange_mutate(conn, &frame, id))
    }

    /// Issue one control-plane op; blocks for the control response.
    /// A rejected op (unknown model, digest mismatch, analyzer
    /// refusal) comes back as an `Ok` reply whose
    /// [`WireControlResp::is_ok`] is false — inspect `message`.
    pub fn control(&self, op: Op, model: &str, digest: &str, version: u64) -> Result<WireControlResp> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let frame = proto::encode_control(&WireControl {
            id,
            op,
            model: model.to_string(),
            digest: digest.to_string(),
            version,
        })?;
        self.with_conn(|conn| Self::exchange_control(conn, &frame, id))
    }

    /// `LOAD_MODEL`: make `model` live on the server. `digest`, when
    /// given, pins the exact catalog digest the caller audited.
    pub fn deploy(&self, model: &str, digest: Option<&str>) -> Result<WireControlResp> {
        self.control(Op::LoadModel, model, digest.unwrap_or(""), 0)
    }

    /// `UNLOAD_MODEL`: remove `model` from admission (in-flight work
    /// still completes server-side).
    pub fn undeploy(&self, model: &str) -> Result<WireControlResp> {
        self.control(Op::UnloadModel, model, "", 0)
    }

    /// `ROLLBACK`: restore the serving set of registry `version`
    /// (0 = the previous serving set).
    pub fn rollback(&self, version: u64) -> Result<WireControlResp> {
        self.control(Op::Rollback, "", "", version)
    }

    /// `LIST_MODELS`: the server's catalog + live set + history as a
    /// JSON document in the reply message.
    pub fn models(&self) -> Result<WireControlResp> {
        self.control(Op::ListModels, "", "", 0)
    }

    /// Check out a pooled connection (or dial), run `f`, and return
    /// the connection to the pool on success. A transport error tears
    /// the connection down instead of returning it, so one bad socket
    /// cannot poison later calls.
    fn with_conn<T>(&self, f: impl FnOnce(&mut PooledConn) -> Result<T>) -> Result<T> {
        let mut conn = match crate::util::sync::lock(&self.pool).pop() {
            Some(c) => c,
            None => PooledConn::dial(&self.addr, self.timeout)?,
        };
        let resp = f(&mut conn);
        if resp.is_ok() {
            let mut pool = crate::util::sync::lock(&self.pool);
            if pool.len() < self.max_pool {
                pool.push(conn);
            }
        }
        resp
    }

    fn exchange(conn: &mut PooledConn, frame: &[u8], want_id: u64) -> Result<WireResponse> {
        conn.tx.write_all(frame).context("sending request frame")?;
        conn.tx.flush().context("flushing request frame")?;
        loop {
            match Self::read_reply(conn)? {
                WireFrame::Response(resp) if resp.id == want_id => return Ok(resp),
                // Stale frames (e.g. from an aborted earlier call on
                // this socket) are skipped, not an error.
                WireFrame::Response(_)
                | WireFrame::ControlResp(_)
                | WireFrame::GraphQueryResp(_)
                | WireFrame::GraphMutateResp(_) => continue,
                WireFrame::Request(_)
                | WireFrame::Control(_)
                | WireFrame::GraphQuery(_)
                | WireFrame::GraphMutate(_) => {
                    bail!("server sent a request frame")
                }
            }
        }
    }

    fn exchange_control(
        conn: &mut PooledConn,
        frame: &[u8],
        want_id: u64,
    ) -> Result<WireControlResp> {
        conn.tx.write_all(frame).context("sending control frame")?;
        conn.tx.flush().context("flushing control frame")?;
        loop {
            match Self::read_reply(conn)? {
                WireFrame::ControlResp(resp) if resp.id == want_id => return Ok(resp),
                WireFrame::ControlResp(_)
                | WireFrame::Response(_)
                | WireFrame::GraphQueryResp(_)
                | WireFrame::GraphMutateResp(_) => continue,
                WireFrame::Request(_)
                | WireFrame::Control(_)
                | WireFrame::GraphQuery(_)
                | WireFrame::GraphMutate(_) => {
                    bail!("server sent a request frame")
                }
            }
        }
    }

    fn exchange_query(
        conn: &mut PooledConn,
        frame: &[u8],
        want_id: u64,
    ) -> Result<WireGraphQueryResp> {
        conn.tx.write_all(frame).context("sending graph query frame")?;
        conn.tx.flush().context("flushing graph query frame")?;
        loop {
            match Self::read_reply(conn)? {
                WireFrame::GraphQueryResp(resp) if resp.id == want_id => return Ok(resp),
                // A plain response under our id: a front-door path
                // (decode salvage) that could not tell the frame was a
                // query. Surface it as a query-shaped error outcome.
                WireFrame::Response(r) if r.id == want_id => {
                    return Ok(WireGraphQueryResp::err(r.id, r.status, 0, r.error))
                }
                WireFrame::GraphQueryResp(_)
                | WireFrame::GraphMutateResp(_)
                | WireFrame::Response(_)
                | WireFrame::ControlResp(_) => continue,
                WireFrame::Request(_)
                | WireFrame::Control(_)
                | WireFrame::GraphQuery(_)
                | WireFrame::GraphMutate(_) => {
                    bail!("server sent a request frame")
                }
            }
        }
    }

    fn exchange_mutate(
        conn: &mut PooledConn,
        frame: &[u8],
        want_id: u64,
    ) -> Result<WireGraphMutateResp> {
        conn.tx.write_all(frame).context("sending graph mutate frame")?;
        conn.tx.flush().context("flushing graph mutate frame")?;
        loop {
            match Self::read_reply(conn)? {
                WireFrame::GraphMutateResp(resp) if resp.id == want_id => return Ok(resp),
                WireFrame::Response(r) if r.id == want_id => {
                    return Ok(WireGraphMutateResp {
                        id: r.id,
                        status: r.status,
                        snapshot_version: 0,
                        applied: 0,
                        rejected: 0,
                        message: r.error,
                    })
                }
                WireFrame::GraphQueryResp(_)
                | WireFrame::GraphMutateResp(_)
                | WireFrame::Response(_)
                | WireFrame::ControlResp(_) => continue,
                WireFrame::Request(_)
                | WireFrame::Control(_)
                | WireFrame::GraphQuery(_)
                | WireFrame::GraphMutate(_) => {
                    bail!("server sent a request frame")
                }
            }
        }
    }

    fn read_reply(conn: &mut PooledConn) -> Result<WireFrame> {
        let payload = match proto::read_frame(&mut conn.rx)? {
            Some(p) => p,
            None => bail!("server closed the connection before responding"),
        };
        proto::decode_frame(&payload)
    }

    /// Connections currently parked in the pool.
    pub fn pooled_connections(&self) -> usize {
        crate::util::sync::lock(&self.pool).len()
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The deadline-propagation satellite's pin: a 100 ms budget
    /// shrinks to exactly the remaining milliseconds at each retry
    /// instant, and runs dry (None, never a 0 = "no deadline" TTL)
    /// once the budget is consumed.
    #[test]
    fn retry_ttl_shrinks_with_the_consumed_budget() {
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        assert_eq!(NetClient::shrink_ttl(100, t0, at(0)), Some(100));
        assert_eq!(NetClient::shrink_ttl(100, t0, at(30)), Some(70));
        assert_eq!(NetClient::shrink_ttl(100, t0, at(70)), Some(30));
        assert_eq!(NetClient::shrink_ttl(100, t0, at(100)), None);
        assert_eq!(NetClient::shrink_ttl(100, t0, at(250)), None);
        // A zero budget is already spent — not the wire's "no
        // deadline" sentinel.
        assert_eq!(NetClient::shrink_ttl(0, t0, at(0)), None);
        // A clock that runs backwards (now < start) saturates to no
        // elapsed time instead of inflating the budget.
        assert_eq!(NetClient::shrink_ttl(50, at(10), t0), Some(50));
    }
}

//! Blocking wire client with connection pooling.
//!
//! [`NetClient`] is the programmatic counterpart of the TCP front-end:
//! `infer(model, graph)` encodes a request frame, sends it on a pooled
//! connection, and blocks for the matching response. Connections are
//! checked out per call; up to `max_pool` idle sockets are retained
//! between calls, and concurrent callers beyond that dial transient
//! connections that are torn down on return — the pool bounds idle
//! state, not peak concurrency. Each socket carries one request at a
//! time (pipelined streaming is the load generator's business, see
//! [`super::loadgen`]).

use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::graph::CooGraph;

use super::proto::{self, WireFrame, WireQos, WireResponse};
use super::server::dial;

/// One pooled connection: the write half and a buffered read half over
/// a clone of the same socket.
struct PooledConn {
    tx: TcpStream,
    rx: BufReader<TcpStream>,
}

impl PooledConn {
    fn dial(addr: &str, timeout: Duration) -> Result<PooledConn> {
        let tx = dial(addr)?;
        // A server that admits a request but never answers (dead lane,
        // dropped response) must surface as an error, not an infinite
        // block in `infer`.
        tx.set_read_timeout(Some(timeout))
            .context("setting client read timeout")?;
        let rx = BufReader::new(tx.try_clone().context("cloning client socket")?);
        Ok(PooledConn { tx, rx })
    }
}

/// Default per-response wait before [`NetClient::infer`] gives up on a
/// silent server.
const DEFAULT_CLIENT_TIMEOUT: Duration = Duration::from_secs(60);

/// Blocking inference client over the wire protocol.
pub struct NetClient {
    addr: String,
    pool: Mutex<Vec<PooledConn>>,
    max_pool: usize,
    timeout: Duration,
    next_id: AtomicU64,
}

impl NetClient {
    /// Connect to a serving front-end; dials one connection eagerly so
    /// an unreachable address fails here, not on the first `infer`.
    /// Responses are waited on for a 60 s default — see
    /// [`NetClient::connect_with_timeout`] to tune it.
    pub fn connect(addr: impl Into<String>, max_pool: usize) -> Result<NetClient> {
        Self::connect_with_timeout(addr, max_pool, DEFAULT_CLIENT_TIMEOUT)
    }

    /// [`NetClient::connect`] with an explicit per-response timeout.
    pub fn connect_with_timeout(
        addr: impl Into<String>,
        max_pool: usize,
        timeout: Duration,
    ) -> Result<NetClient> {
        let addr = addr.into();
        let first = PooledConn::dial(&addr, timeout)?;
        Ok(NetClient {
            addr,
            pool: Mutex::new(vec![first]),
            max_pool: max_pool.max(1),
            timeout,
            next_id: AtomicU64::new(0),
        })
    }

    /// Run one inference over the wire; blocks for the response.
    ///
    /// `Rejected` / `Error` / `BadRequest` wire statuses are returned
    /// as an `Ok(WireResponse)` — they are protocol-level answers, not
    /// transport failures — so callers can distinguish shed load from
    /// a dead server.
    pub fn infer(&self, model: &str, graph: &CooGraph) -> Result<WireResponse> {
        self.infer_with_qos(model, graph, WireQos::default())
    }

    /// [`NetClient::infer`] with explicit QoS: a TTL after which the
    /// server may shed the request (answered `Expired`) and a priority
    /// class for its dispatch queue. The default QoS (no TTL, normal
    /// priority) is exactly what a v1 frame decodes to.
    pub fn infer_with_qos(
        &self,
        model: &str,
        graph: &CooGraph,
        qos: WireQos,
    ) -> Result<WireResponse> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let frame = proto::encode_request_parts(id, model, qos, graph)?;
        // Checkout (or dial) a connection. A transport error tears the
        // connection down instead of returning it, so one bad socket
        // cannot poison later calls.
        let mut conn = match crate::util::sync::lock(&self.pool).pop() {
            Some(c) => c,
            None => PooledConn::dial(&self.addr, self.timeout)?,
        };
        let resp = Self::exchange(&mut conn, &frame, id);
        if resp.is_ok() {
            let mut pool = crate::util::sync::lock(&self.pool);
            if pool.len() < self.max_pool {
                pool.push(conn);
            }
        }
        resp
    }

    fn exchange(conn: &mut PooledConn, frame: &[u8], want_id: u64) -> Result<WireResponse> {
        conn.tx.write_all(frame).context("sending request frame")?;
        conn.tx.flush().context("flushing request frame")?;
        loop {
            let payload = match proto::read_frame(&mut conn.rx)? {
                Some(p) => p,
                None => bail!("server closed the connection before responding"),
            };
            match proto::decode_frame(&payload)? {
                WireFrame::Response(resp) if resp.id == want_id => return Ok(resp),
                // A stale response (e.g. from an aborted earlier call on
                // this socket) is skipped, not an error.
                WireFrame::Response(_) => continue,
                WireFrame::Request(_) => bail!("server sent a request frame"),
            }
        }
    }

    /// Connections currently parked in the pool.
    pub fn pooled_connections(&self) -> usize {
        crate::util::sync::lock(&self.pool).len()
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }
}

//! Blocking wire client with connection pooling.
//!
//! [`NetClient`] is the programmatic counterpart of the TCP front-end.
//! Every data-plane entry point funnels through one core,
//! [`NetClient::call`], taking the request knobs as a
//! [`RequestOptions`] struct — `infer`/`infer_with_qos` remain as thin
//! wrappers over it. The control plane ([`NetClient::deploy`],
//! [`NetClient::undeploy`], [`NetClient::rollback`],
//! [`NetClient::models`]) speaks v3 control frames to the server's
//! live model registry over the same pooled connections.
//!
//! Connections are checked out per call; up to `max_pool` idle sockets
//! are retained between calls, and concurrent callers beyond that dial
//! transient connections that are torn down on return — the pool
//! bounds idle state, not peak concurrency. Each socket carries one
//! request at a time (pipelined streaming is the load generator's
//! business, see [`super::loadgen`]).

use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::Priority;
use crate::graph::CooGraph;

use super::proto::{self, Op, WireControl, WireControlResp, WireFrame, WireQos, WireResponse};
use super::server::dial;

/// Per-request knobs for [`NetClient::call`], so QoS travels as one
/// named struct instead of positional arguments. `Default` is exactly
/// the v1 wire semantics: no TTL, normal priority.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RequestOptions {
    /// Time-to-live in milliseconds from submission; 0 = no deadline.
    /// Past the TTL the server may shed the request (`Expired`).
    pub ttl_ms: u32,
    /// Dispatch priority class.
    pub priority: Priority,
}

impl RequestOptions {
    pub fn new(ttl_ms: u32, priority: Priority) -> RequestOptions {
        RequestOptions { ttl_ms, priority }
    }

    /// The wire QoS block this encodes to.
    pub fn qos(&self) -> WireQos {
        WireQos::new(self.ttl_ms, self.priority)
    }
}

impl From<WireQos> for RequestOptions {
    fn from(qos: WireQos) -> RequestOptions {
        RequestOptions {
            ttl_ms: qos.ttl_ms,
            priority: qos.priority,
        }
    }
}

/// One pooled connection: the write half and a buffered read half over
/// a clone of the same socket.
struct PooledConn {
    tx: TcpStream,
    rx: BufReader<TcpStream>,
}

impl PooledConn {
    fn dial(addr: &str, timeout: Duration) -> Result<PooledConn> {
        let tx = dial(addr)?;
        // A server that admits a request but never answers (dead lane,
        // dropped response) must surface as an error, not an infinite
        // block in `infer`.
        tx.set_read_timeout(Some(timeout))
            .context("setting client read timeout")?;
        let rx = BufReader::new(tx.try_clone().context("cloning client socket")?);
        Ok(PooledConn { tx, rx })
    }
}

/// Default per-response wait before [`NetClient::call`] gives up on a
/// silent server.
const DEFAULT_CLIENT_TIMEOUT: Duration = Duration::from_secs(60);

/// Blocking inference + control client over the wire protocol.
pub struct NetClient {
    addr: String,
    pool: Mutex<Vec<PooledConn>>,
    max_pool: usize,
    timeout: Duration,
    next_id: AtomicU64,
}

impl NetClient {
    /// Connect to a serving front-end; dials one connection eagerly so
    /// an unreachable address fails here, not on the first `infer`.
    /// Responses are waited on for a 60 s default — see
    /// [`NetClient::connect_with_timeout`] to tune it.
    pub fn connect(addr: impl Into<String>, max_pool: usize) -> Result<NetClient> {
        Self::connect_with_timeout(addr, max_pool, DEFAULT_CLIENT_TIMEOUT)
    }

    /// [`NetClient::connect`] with an explicit per-response timeout.
    pub fn connect_with_timeout(
        addr: impl Into<String>,
        max_pool: usize,
        timeout: Duration,
    ) -> Result<NetClient> {
        let addr = addr.into();
        let first = PooledConn::dial(&addr, timeout)?;
        Ok(NetClient {
            addr,
            pool: Mutex::new(vec![first]),
            max_pool: max_pool.max(1),
            timeout,
            next_id: AtomicU64::new(0),
        })
    }

    /// Run one inference over the wire; blocks for the response. This
    /// is the single data-plane core — every other inference entry
    /// point wraps it.
    ///
    /// `Rejected` / `Error` / `BadRequest` wire statuses are returned
    /// as an `Ok(WireResponse)` — they are protocol-level answers, not
    /// transport failures — so callers can distinguish shed load from
    /// a dead server.
    pub fn call(
        &self,
        model: &str,
        graph: &CooGraph,
        opts: &RequestOptions,
    ) -> Result<WireResponse> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let frame = proto::encode_request_parts(id, model, opts.qos(), graph)?;
        self.with_conn(|conn| Self::exchange(conn, &frame, id))
    }

    /// [`NetClient::call`] with default options (no TTL, normal
    /// priority — exactly what a v1 frame decodes to).
    pub fn infer(&self, model: &str, graph: &CooGraph) -> Result<WireResponse> {
        self.call(model, graph, &RequestOptions::default())
    }

    /// [`NetClient::call`] with QoS given as the wire block (legacy
    /// surface; prefer [`RequestOptions`]).
    pub fn infer_with_qos(
        &self,
        model: &str,
        graph: &CooGraph,
        qos: WireQos,
    ) -> Result<WireResponse> {
        self.call(model, graph, &RequestOptions::from(qos))
    }

    /// Issue one control-plane op; blocks for the control response.
    /// A rejected op (unknown model, digest mismatch, analyzer
    /// refusal) comes back as an `Ok` reply whose
    /// [`WireControlResp::is_ok`] is false — inspect `message`.
    pub fn control(&self, op: Op, model: &str, digest: &str, version: u64) -> Result<WireControlResp> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let frame = proto::encode_control(&WireControl {
            id,
            op,
            model: model.to_string(),
            digest: digest.to_string(),
            version,
        })?;
        self.with_conn(|conn| Self::exchange_control(conn, &frame, id))
    }

    /// `LOAD_MODEL`: make `model` live on the server. `digest`, when
    /// given, pins the exact catalog digest the caller audited.
    pub fn deploy(&self, model: &str, digest: Option<&str>) -> Result<WireControlResp> {
        self.control(Op::LoadModel, model, digest.unwrap_or(""), 0)
    }

    /// `UNLOAD_MODEL`: remove `model` from admission (in-flight work
    /// still completes server-side).
    pub fn undeploy(&self, model: &str) -> Result<WireControlResp> {
        self.control(Op::UnloadModel, model, "", 0)
    }

    /// `ROLLBACK`: restore the serving set of registry `version`
    /// (0 = the previous serving set).
    pub fn rollback(&self, version: u64) -> Result<WireControlResp> {
        self.control(Op::Rollback, "", "", version)
    }

    /// `LIST_MODELS`: the server's catalog + live set + history as a
    /// JSON document in the reply message.
    pub fn models(&self) -> Result<WireControlResp> {
        self.control(Op::ListModels, "", "", 0)
    }

    /// Check out a pooled connection (or dial), run `f`, and return
    /// the connection to the pool on success. A transport error tears
    /// the connection down instead of returning it, so one bad socket
    /// cannot poison later calls.
    fn with_conn<T>(&self, f: impl FnOnce(&mut PooledConn) -> Result<T>) -> Result<T> {
        let mut conn = match crate::util::sync::lock(&self.pool).pop() {
            Some(c) => c,
            None => PooledConn::dial(&self.addr, self.timeout)?,
        };
        let resp = f(&mut conn);
        if resp.is_ok() {
            let mut pool = crate::util::sync::lock(&self.pool);
            if pool.len() < self.max_pool {
                pool.push(conn);
            }
        }
        resp
    }

    fn exchange(conn: &mut PooledConn, frame: &[u8], want_id: u64) -> Result<WireResponse> {
        conn.tx.write_all(frame).context("sending request frame")?;
        conn.tx.flush().context("flushing request frame")?;
        loop {
            match Self::read_reply(conn)? {
                WireFrame::Response(resp) if resp.id == want_id => return Ok(resp),
                // Stale frames (e.g. from an aborted earlier call on
                // this socket) are skipped, not an error.
                WireFrame::Response(_) | WireFrame::ControlResp(_) => continue,
                WireFrame::Request(_) | WireFrame::Control(_) => {
                    bail!("server sent a request frame")
                }
            }
        }
    }

    fn exchange_control(
        conn: &mut PooledConn,
        frame: &[u8],
        want_id: u64,
    ) -> Result<WireControlResp> {
        conn.tx.write_all(frame).context("sending control frame")?;
        conn.tx.flush().context("flushing control frame")?;
        loop {
            match Self::read_reply(conn)? {
                WireFrame::ControlResp(resp) if resp.id == want_id => return Ok(resp),
                WireFrame::ControlResp(_) | WireFrame::Response(_) => continue,
                WireFrame::Request(_) | WireFrame::Control(_) => {
                    bail!("server sent a request frame")
                }
            }
        }
    }

    fn read_reply(conn: &mut PooledConn) -> Result<WireFrame> {
        let payload = match proto::read_frame(&mut conn.rx)? {
            Some(p) => p,
            None => bail!("server closed the connection before responding"),
        };
        proto::decode_frame(&payload)
    }

    /// Connections currently parked in the pool.
    pub fn pooled_connections(&self) -> usize {
        crate::util::sync::lock(&self.pool).len()
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }
}

//! # GenGNN — a generic GNN acceleration framework
//!
//! Reproduction of *GenGNN: A Generic FPGA Framework for Graph Neural
//! Network Acceleration* (Abi-Karam et al., 2022) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: a
//!   streaming inference server over raw COO graphs with zero
//!   preprocessing ([`coordinator`], ingesting through
//!   [`graph::GraphBatch`]), a wire-level TCP serving front-end with
//!   an open-loop load generator ([`net`]), a cluster tier fronting N
//!   backend processes with model-aware routing, health probes, and a
//!   reconciler ([`ingress`], sharing front-end plumbing through
//!   [`controlplane`]), a content-addressed model
//!   registry with live deploys ([`registry`]), a static plan
//!   analyzer gating every lowering ([`analysis`]), a cycle-level
//!   simulator of the GenGNN microarchitecture ([`sim`]), an
//!   HLS-style resource estimator ([`resources`]), and analytic
//!   CPU/GPU baselines ([`baselines`]).
//! * **Layer 2** — JAX forward passes of the representative GNNs
//!   (GCN, GIN, GIN+VN, GAT, PNA, DGN, plus the SGC/SAGE extension
//!   models), AOT-lowered to HLO text at build time
//!   (`python/compile/`) and executed from the Rust hot path via the
//!   [`runtime`] backends — the always-available native reference
//!   executor, or PJRT behind the `xla` feature. Python never runs at
//!   request time.
//! * **Layer 1** — Pallas kernels for the compute hot-spots (gather,
//!   MLP, attention, multi-aggregation), lowered into the same HLO.
//!
//! See `rust/README.md` for the crate layout, the tier-1 verify
//! command, the backend story, and the artifact flow.

pub mod analysis;
pub mod baselines;
pub mod controlplane;
pub mod coordinator;
pub mod datagen;
pub mod ingress;
pub mod dse;
pub mod graph;
pub mod models;
pub mod net;
pub mod registry;
pub mod report;
pub mod resident;
pub mod resources;
pub mod runtime;
pub mod sim;
pub mod util;

/// Convenient re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::coordinator::{Server, ServerConfig, ServerConfigBuilder};
    pub use crate::datagen::{molecular_graph, MolConfig};
    pub use crate::net::{NetClient, NetServer, NetServerConfig, RequestOptions};
    pub use crate::graph::{CooGraph, Csc, Csr, DenseGraph, FusedBatch, GraphBatch};
    pub use crate::models::{GnnKind, ModelConfig};
    pub use crate::registry::{ControlReply, ControlRequest, ModelRegistry, Snapshot};
    pub use crate::runtime::{Artifacts, Engine};
    pub use crate::sim::{Accelerator, PipelineMode};
    pub use crate::util::rng::Rng;
}

//! # GenGNN — a generic GNN acceleration framework
//!
//! Reproduction of *GenGNN: A Generic FPGA Framework for Graph Neural
//! Network Acceleration* (Abi-Karam et al., 2022) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: a
//!   streaming inference server over raw COO graphs with zero
//!   preprocessing ([`coordinator`]), a cycle-level simulator of the
//!   GenGNN microarchitecture ([`sim`]), an HLS-style resource
//!   estimator ([`resources`]), and analytic CPU/GPU baselines
//!   ([`baselines`]).
//! * **Layer 2** — JAX forward passes of the six representative GNNs
//!   (GCN, GIN, GIN+VN, GAT, PNA, DGN), AOT-lowered to HLO text at
//!   build time (`python/compile/`), loaded and executed from the Rust
//!   hot path via PJRT ([`runtime`]). Python never runs at request time.
//! * **Layer 1** — Pallas kernels for the compute hot-spots (gather,
//!   MLP, attention, multi-aggregation), lowered into the same HLO.
//!
//! See `DESIGN.md` for the experiment inventory and the FPGA→TPU
//! hardware-adaptation rationale, and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

pub mod baselines;
pub mod coordinator;
pub mod datagen;
pub mod dse;
pub mod graph;
pub mod models;
pub mod report;
pub mod resources;
pub mod runtime;
pub mod sim;
pub mod util;

/// Convenient re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::coordinator::{Server, ServerConfig};
    pub use crate::datagen::{molecular_graph, MolConfig};
    pub use crate::graph::{CooGraph, Csc, Csr, DenseGraph};
    pub use crate::models::{GnnKind, ModelConfig};
    pub use crate::runtime::{Artifacts, Engine};
    pub use crate::sim::{Accelerator, PipelineMode};
    pub use crate::util::rng::Rng;
}

//! Node-embedding PE cost model (paper §3.4 yellow block, §4.1 Fig. 5).
//!
//! The NE PE applies the node transformation γ(·): identity, linear,
//! weighted sum, or an MLP over the aggregated message and the current
//! embedding. It is "the main component that distinguishes different
//! GNN models", so its per-node latency is model-specific:
//!
//! * **GCN**  — one `d→d` linear (the `h W` half of A_norm (h W)).
//! * **GIN**  — the 2-layer MLP `d→2d→d` (Fig. 5) over `(1+ε)x + m`.
//! * **GAT**  — the shared `d→d` projection plus per-head attention
//!   logit preparation (parallelized along heads, §4.2).
//! * **PNA**  — degree-scaler application over the 4 aggregator buffers
//!   (12d-wide concat) + the pipelined linear-ReLU (§4.3).
//! * **DGN**  — linear over the 2d-wide concat of mean ∥ |B_dx X| (§4.4).
//!
//! The virtual node of GIN+VN runs its own 2-layer MLP through the same
//! PE; in the simulator it appears as one more node in the schedule
//! (augmented by `datagen::virtual_node`).

use crate::models::{GnnKind, ModelConfig};

use super::cycles::CostParams;

/// Per-node NE latency at a steady-state layer (dim -> dim).
pub fn ne_cycles(p: &CostParams, m: &ModelConfig) -> u64 {
    let d = m.dim;
    match m.kind {
        GnnKind::Gcn => p.linear_cycles(d, d),
        GnnKind::Gin | GnnKind::GinVn => {
            // (1+eps)x + m vector op, then the 2-layer MLP.
            p.vector_cycles(d) + p.mlp_cycles(&[d, 2 * d, d])
        }
        GnnKind::Gat => {
            let fh = d / m.heads.max(1);
            // Shared projection + per-head src/dst logit dot products;
            // heads run in parallel (paper parallelizes the head dim).
            p.linear_cycles(d, d) + 2 * p.vector_cycles(fh)
        }
        GnnKind::Pna => {
            // Scale the 4 aggregator buffers by the 3 degree scalers
            // (12d-wide concat build) + linear 12d -> d with ReLU.
            3 * p.vector_cycles(4 * d) + p.linear_cycles(12 * d, d)
        }
        GnnKind::Dgn => {
            // concat(mean, |B_dx X|) is produced by the MP PE; NE is the
            // linear 2d -> d with the PNA-style skip connection.
            p.linear_cycles(2 * d, d) + p.vector_cycles(d)
        }
    }
}

/// Per-node latency of the input embedding layer (`in_dim -> dim`),
/// charged once before layer 0.
pub fn embed_cycles(p: &CostParams, m: &ModelConfig) -> u64 {
    p.linear_cycles(m.in_dim, m.dim)
}

/// Global pooling + prediction-head latency, charged once per graph
/// after the last layer (graph-level tasks, §3.3).
pub fn head_cycles(p: &CostParams, m: &ModelConfig, n: usize) -> u64 {
    let pool = if m.node_level {
        0
    } else {
        // Masked mean pool: one vector accumulation per node.
        n as u64 * p.vector_cycles(m.dim)
    };
    let mut dims = vec![m.dim];
    dims.extend(&m.head_dims);
    let head = p.mlp_cycles(&dims);
    // Node-level heads run the MLP per node.
    pool + if m.node_level { n as u64 * head } else { head }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelConfig;

    fn p() -> CostParams {
        CostParams::default()
    }

    #[test]
    fn gin_ne_is_mlp_dominated() {
        let gin = ModelConfig::by_name("gin").unwrap();
        let c = ne_cycles(&p(), &gin);
        // vec(100)@2 lanes + MLP 100->200->100 at 8x8 lanes:
        // 50 + (13*25 + 12) + (25*13 + 12) = 724.
        assert_eq!(c, 50 + 325 + 12 + 325 + 12);
    }

    #[test]
    fn gcn_ne_is_single_linear() {
        let gcn = ModelConfig::by_name("gcn").unwrap();
        assert_eq!(ne_cycles(&p(), &gcn), 13 * 13 + 12);
    }

    #[test]
    fn pna_ne_heaviest_gat_lightest() {
        // PNA's 12d-wide linear dominates every other NE; GAT's d=64
        // projection with parallel heads is the lightest.
        let ne = |name: &str| ne_cycles(&p(), &ModelConfig::by_name(name).unwrap());
        assert!(ne("pna") > ne("gin"));
        assert!(ne("gin") > ne("dgn"));
        assert!(ne("dgn") > ne("gat"));
    }

    #[test]
    fn head_cycles_scale_with_nodes_for_node_level() {
        let dgn_l = ModelConfig::by_name("dgn_large").unwrap();
        let h100 = head_cycles(&p(), &dgn_l, 100);
        let h200 = head_cycles(&p(), &dgn_l, 200);
        assert_eq!(h200, 2 * h100);
    }

    #[test]
    fn graph_level_head_has_pool_term() {
        let gin = ModelConfig::by_name("gin").unwrap();
        let h10 = head_cycles(&p(), &gin, 10);
        let h20 = head_cycles(&p(), &gin, 20);
        assert!(h20 > h10);
        assert_eq!(h20 - h10, 10 * CostParams::default().vector_cycles(100));
    }
}

//! Discrete-event engine cross-validating the streaming schedule.
//!
//! [`super::pipeline`] computes the streaming pipeline with an O(n)
//! recurrence. This module simulates the same two-actor system (NE PE,
//! MP PE, bounded FIFO) event by event — the "obviously correct but
//! slower" reference the recurrence is tested against, and a reusable
//! engine for the DRAM/prefetch interplay in [`super::large`].

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// A time-ordered event queue (min-heap keyed on timestamp).
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<(u64, u64, E)>>,
    seq: u64,
}

impl<E: Ord> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    /// Schedule `event` at absolute time `t`. Ties break FIFO.
    pub fn push(&mut self, t: u64, event: E) {
        self.heap.push(Reverse((t, self.seq, event)));
        self.seq += 1;
    }

    pub fn pop(&mut self) -> Option<(u64, E)> {
        self.heap.pop().map(|Reverse((t, _, e))| (t, e))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<E: Ord> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum Ev {
    /// NE PE finished node i and wants to enqueue it.
    NeDone(usize),
    /// MP PE finished node i and is free.
    MpDone(usize),
}

/// Event-driven simulation of the streaming NE/MP pipeline.
/// Returns the makespan in cycles; must agree exactly with
/// `pipeline::schedule(Streaming, ...)`.
pub fn streaming_via_events(ne: &[u64], mp: &[u64], depth: usize) -> u64 {
    let n = ne.len();
    if n == 0 {
        return 0;
    }
    let depth = depth.max(1);
    let mut q = EventQueue::new();
    let mut fifo: Vec<usize> = Vec::new(); // nodes resident in the FIFO
    let mut next_ne; // next node NE will compute
    let mut ne_blocked: Option<usize> = None; // NE holding a finished node
    let mut mp_busy = false;
    let mut finished = 0usize;
    let mut makespan = 0u64;

    q.push(ne[0], Ev::NeDone(0));
    next_ne = 1;

    while let Some((t, ev)) = q.pop() {
        makespan = makespan.max(t);
        match ev {
            Ev::NeDone(i) => {
                if fifo.len() < depth {
                    fifo.push(i);
                    if next_ne < n {
                        q.push(t + ne[next_ne], Ev::NeDone(next_ne));
                        next_ne += 1;
                    }
                    if !mp_busy {
                        let j = fifo.remove(0);
                        mp_busy = true;
                        q.push(t + mp[j], Ev::MpDone(j));
                    }
                } else {
                    // FIFO full: NE stalls holding node i.
                    ne_blocked = Some(i);
                }
            }
            Ev::MpDone(i) => {
                let _ = i;
                finished += 1;
                mp_busy = false;
                if let Some(b) = ne_blocked.take() {
                    // The pop just freed a slot; NE's held node enters
                    // and NE resumes.
                    fifo.push(b);
                    if next_ne < n {
                        q.push(t + ne[next_ne], Ev::NeDone(next_ne));
                        next_ne += 1;
                    }
                }
                if !fifo.is_empty() {
                    let j = fifo.remove(0);
                    mp_busy = true;
                    q.push(t + mp[j], Ev::MpDone(j));
                }
            }
        }
    }
    debug_assert_eq!(finished, n);
    makespan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::sim::pipeline::{schedule, PipelineMode};
    use crate::util::proptest::forall;

    #[test]
    fn event_queue_orders_by_time_then_fifo() {
        let mut q = EventQueue::new();
        q.push(5, "b");
        q.push(1, "a");
        q.push(5, "c");
        assert_eq!(q.pop(), Some((1, "a")));
        assert_eq!(q.pop(), Some((5, "b")));
        assert_eq!(q.pop(), Some((5, "c")));
        assert!(q.is_empty());
    }

    #[test]
    fn agrees_with_recurrence_on_basics() {
        let ne = vec![10u64; 6];
        let mp = vec![10u64; 6];
        assert_eq!(streaming_via_events(&ne, &mp, 10), 70);
        let mp2 = vec![2u64, 50, 2, 2, 2, 2];
        assert_eq!(
            streaming_via_events(&ne, &mp2, 10),
            schedule(PipelineMode::Streaming, &ne, &mp2, 10).cycles
        );
    }

    #[test]
    fn prop_event_sim_equals_recurrence() {
        forall("events-vs-recurrence", 300, 0xE7E47, |rng| {
            let n = rng.range(1, 40);
            let ne: Vec<u64> = (0..n).map(|_| rng.range(1, 100) as u64).collect();
            let mp: Vec<u64> = (0..n).map(|_| rng.range(0, 250) as u64).collect();
            let depth = rng.range(1, 12);
            let ev = streaming_via_events(&ne, &mp, depth);
            let rec = schedule(PipelineMode::Streaming, &ne, &mp, depth).cycles;
            prop_assert!(ev == rec, "event {ev} != recurrence {rec} (depth {depth})");
            Ok(())
        });
    }
}

//! Cycle-accounting primitives (see rust/README.md).
//!
//! All cycle formulas in the simulator bottom out here. The parameters
//! mirror the HLS design knobs of the paper: fully-partitioned
//! input/output buffer widths of the MLP PE (§4.1 "parallelize the
//! multiplications at the partitioned input and output buffers"), the
//! message-lane width of the MP PE, per-row fetch setup, and the
//! streaming FIFO depth ("we set the queue depth to be 10 nodes", §5.4).

/// FPGA logic clock (paper §5.1: 300 MHz).
pub const CLOCK_HZ: f64 = 300.0e6;

/// Convert a cycle count to seconds at the 300 MHz design clock.
pub fn cycles_to_secs(cycles: u64) -> f64 {
    cycles as f64 / CLOCK_HZ
}

pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Tunable microarchitecture parameters shared by the PE models.
#[derive(Clone, Debug)]
pub struct CostParams {
    /// Input-side multiplier lanes of the MLP PE (partitioned in-buffer).
    pub p_in: usize,
    /// Output-side accumulation lanes of the MLP PE.
    pub p_out: usize,
    /// Pipeline fill/drain overhead per linear layer (II=1 body).
    pub d_pipe: u64,
    /// Vector lanes of the MP PE message datapath.
    pub p_msg: usize,
    /// CSR row fetch setup cycles per node (address gen + first beat).
    pub c_fetch: u64,
    /// Inter-PE streaming FIFO depth in nodes (paper §5.4: 10).
    pub fifo_depth: usize,
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams {
            p_in: 8,
            p_out: 8,
            d_pipe: 12,
            p_msg: 2,
            c_fetch: 8,
            fifo_depth: 10,
        }
    }
}

impl CostParams {
    /// One dense layer `fin -> fout` on the MLP PE (Fig. 5): the
    /// multiplications are parallelized `p_in x p_out`, pipelined along
    /// the hidden elements; the ping-pong local buffers overlap the
    /// node-embedding-buffer copies with compute, so only fill/drain
    /// (`d_pipe`) is exposed.
    pub fn linear_cycles(&self, fin: usize, fout: usize) -> u64 {
        (ceil_div(fin, self.p_in) * ceil_div(fout, self.p_out)) as u64 + self.d_pipe
    }

    /// A chain of dense layers (`dims = [f0, f1, ..., fk]`).
    pub fn mlp_cycles(&self, dims: &[usize]) -> u64 {
        dims.windows(2)
            .map(|w| self.linear_cycles(w[0], w[1]))
            .sum()
    }

    /// One elementwise pass over an f-wide vector on the MP datapath.
    pub fn vector_cycles(&self, f: usize) -> u64 {
        ceil_div(f, self.p_msg) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_300mhz() {
        assert_eq!(CLOCK_HZ, 3.0e8);
        assert!((cycles_to_secs(300) - 1e-6).abs() < 1e-18);
    }

    #[test]
    fn ceil_div_edges() {
        assert_eq!(ceil_div(0, 8), 0);
        assert_eq!(ceil_div(1, 8), 1);
        assert_eq!(ceil_div(8, 8), 1);
        assert_eq!(ceil_div(9, 8), 2);
    }

    #[test]
    fn linear_cycles_formula() {
        let p = CostParams::default();
        // ceil(100/8)*ceil(100/8) + 12 = 13*13 + 12.
        assert_eq!(p.linear_cycles(100, 100), 13 * 13 + 12);
    }

    #[test]
    fn mlp_is_sum_of_layers() {
        let p = CostParams::default();
        assert_eq!(
            p.mlp_cycles(&[100, 200, 100]),
            p.linear_cycles(100, 200) + p.linear_cycles(200, 100)
        );
    }

    #[test]
    fn wider_lanes_are_faster() {
        let narrow = CostParams::default();
        let wide = CostParams {
            p_in: 16,
            p_out: 16,
            ..CostParams::default()
        };
        assert!(wide.linear_cycles(128, 128) < narrow.linear_cycles(128, 128));
    }
}

//! The three NE/MP scheduling strategies of paper Fig. 4 / §3.5.
//!
//! Within one layer, node i's NE must precede its MP, but nodes are
//! independent — the scheduling freedom the paper exploits:
//!
//! 1. **Non-pipelined** (Fig. 4a): strictly serial, `Σ (ne_i + mp_i)`.
//! 2. **Fixed pipelining** (Fig. 4b): lock-step two-stage pipeline —
//!    NE of node i overlaps MP of node i-1; each step takes the max of
//!    the two, so degree imbalance leaves idle cycles.
//! 3. **Streaming** (Fig. 4c): the PEs are decoupled by a depth-bounded
//!    FIFO; NE runs ahead until the queue fills, MP drains at its own
//!    pace. Computed by an O(n) recurrence (validated against the
//!    discrete-event engine in [`super::event`]):
//!
//!    ```text
//!    push_i = max(push_{i-1} + ne_i, pop_{i-B})      (B = FIFO depth)
//!    pop_i  = max(done_{i-1}, push_i)
//!    done_i = pop_i + mp_i
//!    ```
//!
//!    (NE computes node i after the blocking FIFO write of node i-1 and
//!    stalls on its own write until slot i-B is dequeued — the HLS
//!    dataflow semantics of a full stream.)

use super::fifo::{stats_from_events, FifoStats};

/// Scheduling strategy selector (paper Fig. 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PipelineMode {
    NonPipelined,
    Fixed,
    Streaming,
}

impl PipelineMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            PipelineMode::NonPipelined => "non-pipelined",
            PipelineMode::Fixed => "fixed",
            PipelineMode::Streaming => "streaming",
        }
    }

    pub fn all() -> [PipelineMode; 3] {
        [
            PipelineMode::NonPipelined,
            PipelineMode::Fixed,
            PipelineMode::Streaming,
        ]
    }
}

/// Schedule outcome for one layer.
#[derive(Clone, Copy, Debug, Default)]
pub struct ScheduleResult {
    pub cycles: u64,
    /// FIFO diagnostics (zeroed for the non-streaming modes).
    pub fifo: FifoStats,
}

/// Cycles-only fast path: identical numbers to [`schedule`] without
/// materializing the FIFO diagnostics (no per-node `ready`/`mp_free`
/// arrays). This is the inner loop of the Fig. 9 population sweeps.
pub fn schedule_cycles(mode: PipelineMode, ne: &[u64], mp: &[u64], fifo_depth: usize) -> u64 {
    assert_eq!(ne.len(), mp.len());
    let n = ne.len();
    if n == 0 {
        return 0;
    }
    match mode {
        PipelineMode::NonPipelined => ne.iter().sum::<u64>() + mp.iter().sum::<u64>(),
        PipelineMode::Fixed => fixed(ne, mp),
        PipelineMode::Streaming => {
            let depth = fifo_depth.max(1);
            let mut push = vec![0u64; n];
            let mut pop = vec![0u64; n];
            let mut done_prev = 0u64;
            for i in 0..n {
                let prev_push = if i > 0 { push[i - 1] } else { 0 };
                let gate = if i >= depth { pop[i - depth] } else { 0 };
                push[i] = (prev_push + ne[i]).max(gate);
                pop[i] = done_prev.max(push[i]);
                done_prev = pop[i] + mp[i];
            }
            done_prev
        }
    }
}

/// Total cycles for one layer's node sweep under `mode`.
pub fn schedule(mode: PipelineMode, ne: &[u64], mp: &[u64], fifo_depth: usize) -> ScheduleResult {
    assert_eq!(ne.len(), mp.len());
    match mode {
        PipelineMode::NonPipelined => ScheduleResult {
            cycles: ne.iter().sum::<u64>() + mp.iter().sum::<u64>(),
            fifo: FifoStats::default(),
        },
        PipelineMode::Fixed => ScheduleResult {
            cycles: fixed(ne, mp),
            fifo: FifoStats::default(),
        },
        PipelineMode::Streaming => streaming(ne, mp, fifo_depth),
    }
}

/// Lock-step two-stage pipeline: step k runs NE(k) beside MP(k-1) and
/// advances only when both finish (the paper's "fixed manner").
fn fixed(ne: &[u64], mp: &[u64]) -> u64 {
    let n = ne.len();
    if n == 0 {
        return 0;
    }
    let mut total = ne[0];
    for i in 1..n {
        total += ne[i].max(mp[i - 1]);
    }
    total + mp[n - 1]
}

/// FIFO-decoupled streaming pipeline with bounded queue depth.
fn streaming(ne: &[u64], mp: &[u64], depth: usize) -> ScheduleResult {
    let n = ne.len();
    if n == 0 {
        return ScheduleResult::default();
    }
    let depth = depth.max(1);
    let mut push = vec![0u64; n]; // NE finish (= FIFO enqueue) time
    let mut pop = vec![0u64; n]; // MP dequeue time
    let mut done = vec![0u64; n]; // MP finish time
    let mut ready = vec![0u64; n]; // NE finish absent backpressure
    let mut mp_free = vec![0u64; n]; // MP idle-from time before node i
    for i in 0..n {
        let prev_push = if i > 0 { push[i - 1] } else { 0 };
        let finish = prev_push + ne[i]; // compute done, pre-backpressure
        // Slot i-depth must have been dequeued before node i can enqueue.
        let gate = if i >= depth { pop[i - depth] } else { 0 };
        ready[i] = finish;
        push[i] = finish.max(gate);
        mp_free[i] = if i > 0 { done[i - 1] } else { 0 };
        pop[i] = mp_free[i].max(push[i]);
        done[i] = pop[i] + mp[i];
    }
    ScheduleResult {
        cycles: done[n - 1],
        fifo: stats_from_events(&push, &pop, &ready, &mp_free),
    }
}

/// Speed-up triple reported by Fig. 9: (fixed/non, streaming/fixed,
/// streaming/non) for one workload.
pub fn speedups(ne: &[u64], mp: &[u64], fifo_depth: usize) -> (f64, f64, f64) {
    let non = schedule_cycles(PipelineMode::NonPipelined, ne, mp, fifo_depth) as f64;
    let fix = schedule_cycles(PipelineMode::Fixed, ne, mp, fifo_depth) as f64;
    let st = schedule_cycles(PipelineMode::Streaming, ne, mp, fifo_depth) as f64;
    (non / fix, fix / st, non / st)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::forall;

    #[test]
    fn empty_and_singleton() {
        for mode in PipelineMode::all() {
            assert_eq!(schedule(mode, &[], &[], 10).cycles, 0);
            assert_eq!(schedule(mode, &[5], &[3], 10).cycles, 8);
        }
    }

    #[test]
    fn uniform_fixed_matches_closed_form() {
        // ne = mp = c: non = 2nc, fixed = (n+1)c.
        let ne = vec![10u64; 6];
        let mp = vec![10u64; 6];
        assert_eq!(schedule(PipelineMode::NonPipelined, &ne, &mp, 10).cycles, 120);
        assert_eq!(schedule(PipelineMode::Fixed, &ne, &mp, 10).cycles, 70);
        assert_eq!(schedule(PipelineMode::Streaming, &ne, &mp, 10).cycles, 70);
    }

    #[test]
    fn streaming_absorbs_degree_imbalance() {
        // One hot node (mp=50) among cheap ones: fixed stalls NE behind
        // it; streaming overlaps it with later NE work.
        let ne = vec![10u64; 8];
        let mp = vec![2, 50, 2, 2, 2, 2, 2, 2];
        let fx = schedule(PipelineMode::Fixed, &ne, &mp, 10).cycles;
        let st = schedule(PipelineMode::Streaming, &ne, &mp, 10).cycles;
        assert!(st < fx, "streaming {st} !< fixed {fx}");
    }

    #[test]
    fn depth_one_behaves_like_tight_coupling() {
        let ne = vec![4u64, 4, 4, 4];
        let mp = vec![9u64, 9, 9, 9];
        let st1 = schedule(PipelineMode::Streaming, &ne, &mp, 1).cycles;
        let st10 = schedule(PipelineMode::Streaming, &ne, &mp, 10).cycles;
        assert!(st1 >= st10);
    }

    #[test]
    fn fifo_peak_bounded_by_depth(){
        let ne = vec![1u64; 64];
        let mp = vec![40u64; 64];
        let r = schedule(PipelineMode::Streaming, &ne, &mp, 10);
        assert!(r.fifo.peak_depth <= 10, "peak {}", r.fifo.peak_depth);
        assert!(r.fifo.producer_stall > 0, "NE must backpressure");
    }

    #[test]
    fn prop_ordering_and_bounds() {
        forall("pipeline-ordering", 300, 0xF19, |rng| {
            let n = rng.range(1, 60);
            let ne: Vec<u64> = (0..n).map(|_| rng.range(1, 200) as u64).collect();
            let mp: Vec<u64> = (0..n).map(|_| rng.range(1, 400) as u64).collect();
            let depth = rng.range(1, 16);
            let non = schedule(PipelineMode::NonPipelined, &ne, &mp, depth).cycles;
            let fx = schedule(PipelineMode::Fixed, &ne, &mp, depth).cycles;
            let st = schedule(PipelineMode::Streaming, &ne, &mp, depth).cycles;
            let sum_ne: u64 = ne.iter().sum();
            let sum_mp: u64 = mp.iter().sum();
            prop_assert!(st <= fx, "streaming {st} > fixed {fx}");
            prop_assert!(fx <= non, "fixed {fx} > non {non}");
            // Streaming can never beat the busier engine running alone.
            prop_assert!(
                st >= sum_ne.max(sum_mp),
                "streaming {st} < critical path {}",
                sum_ne.max(sum_mp)
            );
            // First NE and last MP are always exposed.
            prop_assert!(st >= ne[0] + mp[n - 1], "pipeline fill/drain missing");
            Ok(())
        });
    }

    #[test]
    fn ordering_on_empty_single_node_and_self_loop_graphs() {
        // Schedule-ordering invariants hold on degenerate real graphs,
        // with per-node profiles derived through the unified ingest path.
        use crate::graph::{CooGraph, GraphBatch};
        use crate::models::ModelConfig;
        use crate::sim::cycles::CostParams;
        use crate::sim::mp_pe::mp_profile;
        use crate::sim::ne_pe::ne_cycles;

        let p = CostParams::default();
        let gin = ModelConfig::by_name("gin").unwrap();
        let mk = |n: usize, edges: Vec<(u32, u32)>| CooGraph {
            node_feat: vec![0.0; n * 9],
            f_node: 9,
            edge_feat: vec![1.0; edges.len() * 3],
            f_edge: 3,
            n,
            edges,
        };
        let cases = [
            mk(0, vec![]),                                // empty graph
            mk(1, vec![]),                                // single isolated node
            mk(1, vec![(0, 0)]),                          // single node, self-loop
            mk(2, vec![(0, 0), (0, 1), (1, 0), (1, 1)]),  // self-loops + edge
        ];
        for g in cases {
            let b = GraphBatch::ingest(g).unwrap();
            let ne = vec![ne_cycles(&p, &gin); b.n()];
            let mp = mp_profile(&p, &gin, &b.csr.degree);
            let non = schedule(PipelineMode::NonPipelined, &ne, &mp, p.fifo_depth).cycles;
            let fx = schedule(PipelineMode::Fixed, &ne, &mp, p.fifo_depth).cycles;
            let st = schedule(PipelineMode::Streaming, &ne, &mp, p.fifo_depth).cycles;
            assert!(st <= fx && fx <= non, "ordering broke: {st} {fx} {non}");
            if b.n() == 0 {
                assert_eq!((non, fx, st), (0, 0, 0), "empty graph costs nothing");
            } else {
                let sum_ne: u64 = ne.iter().sum();
                let sum_mp: u64 = mp.iter().sum();
                assert!(st >= sum_ne.max(sum_mp), "beat the busier engine");
                assert_eq!(non, sum_ne + sum_mp, "non-pipelined is the serial sum");
            }
        }
    }

    #[test]
    fn prop_deeper_fifo_never_hurts() {
        forall("fifo-monotone", 200, 0xF1F0, |rng| {
            let n = rng.range(1, 50);
            let ne: Vec<u64> = (0..n).map(|_| rng.range(1, 100) as u64).collect();
            let mp: Vec<u64> = (0..n).map(|_| rng.range(1, 300) as u64).collect();
            let d1 = rng.range(1, 8);
            let d2 = d1 + rng.range(1, 8);
            let s1 = schedule(PipelineMode::Streaming, &ne, &mp, d1).cycles;
            let s2 = schedule(PipelineMode::Streaming, &ne, &mp, d2).cycles;
            prop_assert!(s2 <= s1, "deeper fifo slower: {s2} > {s1}");
            Ok(())
        });
    }
}

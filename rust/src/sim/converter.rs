//! On-chip COO→CSR/CSC converter model (paper §3.2).
//!
//! The hardware converter makes one counting pass and one placement pass
//! over the streamed edge list, plus a prefix-sum pass over the degree
//! table: `2E + N` cycles. It "runs once when the graph is streamed into
//! the FPGA and is reused for all the GNN layers".
//!
//! The ingest entry point is [`crate::graph::GraphBatch`]; this module
//! re-exports the converter cost model and offers borrowed one-matrix
//! conversions for callers that need exactly one adjacency view
//! without taking ownership of the graph.

use crate::graph::{CooGraph, Csc, Csr};

pub use crate::graph::batch::converter_cycles;

/// Functional conversion paired with its cycle cost — what the
/// accelerator front-end does when a raw graph arrives.
pub fn convert_csr(g: &CooGraph) -> (Csr, u64) {
    (Csr::from_coo(g), converter_cycles(g.n, g.num_edges()))
}

/// CSC variant (gather-first execution order, §3.4).
pub fn convert_csc(g: &CooGraph) -> (Csc, u64) {
    (Csc::from_coo(g), converter_cycles(g.n, g.num_edges()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_is_two_e_plus_n() {
        assert_eq!(converter_cycles(4, 6), 16);
        assert_eq!(converter_cycles(0, 0), 0);
    }

    #[test]
    fn conversion_matches_direct() {
        let g = CooGraph {
            n: 3,
            edges: vec![(0, 1), (1, 2), (2, 0)],
            node_feat: vec![0.0; 3],
            f_node: 1,
            edge_feat: vec![],
            f_edge: 0,
        };
        let (csr, c) = convert_csr(&g);
        assert_eq!(csr, Csr::from_coo(&g));
        assert_eq!(c, converter_cycles(3, 3));
        let (csc, c2) = convert_csc(&g);
        assert_eq!(csc, Csc::from_coo(&g));
        assert_eq!(c2, c);
    }

    #[test]
    fn facade_agrees_with_graph_batch() {
        use crate::graph::GraphBatch;
        let g = CooGraph {
            n: 4,
            edges: vec![(0, 1), (0, 2), (1, 2), (2, 3)],
            node_feat: vec![0.0; 4],
            f_node: 1,
            edge_feat: vec![],
            f_edge: 0,
        };
        let b = GraphBatch::ingest(g.clone()).unwrap();
        let (csr, c) = convert_csr(&g);
        assert_eq!(csr, b.csr);
        assert_eq!(c, b.converter_cycles);
        let (csc, c2) = convert_csc(&g);
        assert_eq!(csc, b.csc());
        assert_eq!(c2, c);
    }
}

//! Message-passing PE cost model (paper §3.4 blue block).
//!
//! The MP PE implements the *merged scatter-gather*: once node i's
//! embedding is updated, it walks the CSR row of i, computes the message
//! φ(x, e) for each out-edge, and updates the receiver's partial
//! aggregate in the message buffer in place. Per node:
//!
//! ```text
//! mp(i) = c_fetch + deg(i) · (c_msg(model) + ceil(F / P_msg))
//! ```
//!
//! `c_msg` is the model-specific message transformation φ (§4):
//!
//! * **GCN**  — scale by the normalized adjacency coefficient (1 mul).
//! * **GIN**  — bond-feature linear `3→d` (edge embedding, §4.1) + add.
//! * **GAT**  — attention logit combine + exp + weighted accumulate; the
//!   softmax normalization pass is folded into the receiving gather.
//! * **PNA**  — four aggregator buffers updated per edge (§4.3): the
//!   `ceil(F/P_msg)` accumulate covers one, three more are charged.
//! * **DGN**  — two concurrent aggregations (mean and |B_dx·|, §4.4)
//!   plus the per-edge directional weight.

use crate::models::{GnnKind, ModelConfig};

use super::cycles::CostParams;

/// Model-specific per-edge message transformation cost φ.
pub fn msg_cycles(p: &CostParams, m: &ModelConfig) -> u64 {
    let d = m.dim;
    match m.kind {
        GnnKind::Gcn => 1,
        GnnKind::Gin | GnnKind::GinVn => {
            // Edge-embedding linear 3 -> d (+ bias add) on the p_msg-wide
            // message datapath: a real matrix-vector per edge, the
            // heaviest φ of the zoo (§4.1).
            ((m.edge_dim + 1) as u64) * p.vector_cycles(d)
        }
        GnnKind::Gat => {
            // logit = LeakyReLU(sl_i + dl_j); exp; weighted accumulate.
            let fh = d / m.heads.max(1);
            4 + p.vector_cycles(fh)
        }
        GnnKind::Pna => {
            // max/min/sumsq buffers beyond the base accumulate.
            3 * p.vector_cycles(d)
        }
        GnnKind::Dgn => {
            // Directional weight (eig difference, normalize) + the
            // second (|B_dx|) aggregation stream.
            4 + p.vector_cycles(d)
        }
    }
}

/// Per-node MP latency given its out-degree (CSR row length).
pub fn mp_cycles(p: &CostParams, m: &ModelConfig, deg: u32) -> u64 {
    p.c_fetch + deg as u64 * (msg_cycles(p, m) + p.vector_cycles(m.dim))
}

/// Per-node MP latencies for a whole degree table.
pub fn mp_profile(p: &CostParams, m: &ModelConfig, degrees: &[u32]) -> Vec<u64> {
    degrees.iter().map(|&d| mp_cycles(p, m, d)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelConfig;

    fn p() -> CostParams {
        CostParams::default()
    }

    #[test]
    fn mp_is_affine_in_degree() {
        let gin = ModelConfig::by_name("gin").unwrap();
        let c0 = mp_cycles(&p(), &gin, 0);
        let c1 = mp_cycles(&p(), &gin, 1);
        let c5 = mp_cycles(&p(), &gin, 5);
        assert_eq!(c0, CostParams::default().c_fetch);
        assert_eq!(c5 - c0, 5 * (c1 - c0));
    }

    #[test]
    fn gin_edge_embedding_costs_more_than_gcn() {
        let gin = ModelConfig::by_name("gin").unwrap();
        let gcn = ModelConfig::by_name("gcn").unwrap();
        assert!(msg_cycles(&p(), &gin) > msg_cycles(&p(), &gcn));
    }

    #[test]
    fn gin_edge_linear_is_heaviest_per_edge() {
        // GIN's per-edge bond linear is a matrix-vector; PNA's extra
        // aggregators and DGN's directional weight are elementwise.
        let by = |n: &str| msg_cycles(&p(), &ModelConfig::by_name(n).unwrap());
        assert!(by("gin") > by("pna"));
        assert!(by("pna") > by("dgn"));
        assert!(by("pna") > by("gat"));
    }

    #[test]
    fn profile_matches_scalar() {
        let dgn = ModelConfig::by_name("dgn").unwrap();
        let degs = [0u32, 3, 7, 1];
        let prof = mp_profile(&p(), &dgn, &degs);
        for (i, &d) in degs.iter().enumerate() {
            assert_eq!(prof[i], mp_cycles(&p(), &dgn, d));
        }
    }
}

//! Packed AXI data transfers (paper §4.6 "Packed Data Transfers").
//!
//! "Transferring one 16-bit array element per clock cycle is a waste for
//! a 64-bit bus. … Given four 64-bit AXI buses, we pack 8 16-bit values
//! and parallelize the fetching in one cycle." This module computes the
//! cycles to move `elems` values of `elem_bits` each, with and without
//! packing — the ablation behind the large-graph numbers.

use super::cycles::ceil_div;

/// Values moved per cycle with packing across all buses.
pub fn elems_per_cycle(bus_bits: usize, buses: usize, elem_bits: usize) -> usize {
    ((bus_bits / elem_bits).max(1)) * buses
}

/// Transfer cycles with packed, typecast pointers.
pub fn packed_cycles(elems: usize, elem_bits: usize, bus_bits: usize, buses: usize) -> u64 {
    ceil_div(elems, elems_per_cycle(bus_bits, buses, elem_bits)) as u64
}

/// Naive transfer: one element per cycle per bus, regardless of width.
pub fn unpacked_cycles(elems: usize, buses: usize) -> u64 {
    ceil_div(elems, buses.max(1)) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_four_16bit_per_bus_cycle() {
        // 4 x 64-bit buses, 16-bit elements -> 4 per bus = 16 per cycle
        // (the paper's "pack 8 16-bit values" counts a 128-bit beat).
        assert_eq!(elems_per_cycle(64, 4, 16), 16);
        assert_eq!(packed_cycles(16, 16, 64, 4), 1);
        assert_eq!(packed_cycles(17, 16, 64, 4), 2);
    }

    #[test]
    fn packing_speedup_is_bus_over_elem_width() {
        let packed = packed_cycles(1024, 16, 64, 4);
        let naive = unpacked_cycles(1024, 4);
        assert_eq!(naive / packed, 64 / 16);
    }

    #[test]
    fn wide_elements_degenerate_to_one_per_bus() {
        assert_eq!(elems_per_cycle(64, 4, 64), 4);
        assert_eq!(packed_cycles(8, 64, 64, 4), 2);
    }

    #[test]
    fn zero_elems_zero_cycles() {
        assert_eq!(packed_cycles(0, 16, 64, 4), 0);
        assert_eq!(unpacked_cycles(0, 4), 0);
    }
}

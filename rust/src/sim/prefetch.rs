//! Degree-table prefetcher (paper §4.6 "Prefetching").
//!
//! For big graphs the CSR degree/neighbor tables live in DRAM; a
//! loop-carried dependence on those reads would stall the MP PE for the
//! full access latency every node. The prefetcher streams degrees of
//! consecutive nodes into an on-chip FIFO ahead of consumption; the MP
//! PE pops them and "behaves in the same way as for small graphs" —
//! provided the FIFO never runs dry.

use super::dram::DramModel;

/// Prefetcher configuration.
#[derive(Clone, Copy, Debug)]
pub struct Prefetcher {
    /// On-chip FIFO depth (entries).
    pub depth: usize,
    /// Entry width in bits (degree-table entries; paper uses 32-bit).
    pub elem_bits: usize,
}

impl Default for Prefetcher {
    fn default() -> Self {
        Prefetcher {
            depth: 64,
            elem_bits: 32,
        }
    }
}

impl Prefetcher {
    /// Stall cycles the MP PE sees with prefetching, given the cycle at
    /// which it *wants* each consecutive entry. The prefetcher issues
    /// ahead within its FIFO depth; entry i becomes ready at
    /// `latency + (i+1)/epc` in the best case, gated by slot reuse.
    pub fn stall_cycles(&self, want: &[u64], dram: &DramModel) -> u64 {
        let n = want.len();
        if n == 0 {
            return 0;
        }
        let mut ready = vec![0u64; n];
        let mut consume = vec![0u64; n];
        let mut stall = 0u64;
        for i in 0..n {
            // Refill of entry i starts when its FIFO slot is free;
            // refill rate is conservatively one entry per cycle (packed
            // beats deliver several, but the FIFO write port is one).
            let slot_free = if i >= self.depth {
                consume[i - self.depth]
            } else {
                0
            };
            let prev_ready = if i > 0 { ready[i - 1] } else { dram.latency };
            ready[i] = prev_ready.max(slot_free) + 1;
            consume[i] = want[i].max(ready[i]);
            stall += consume[i] - want[i];
        }
        stall
    }

    /// Stall cycles without prefetching: every node pays the full DRAM
    /// burst latency for its degree inline (the §4.6 motivation).
    pub fn stall_cycles_naive(&self, n: usize, dram: &DramModel) -> u64 {
        n as u64 * dram.burst_cycles(1, self.elem_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetch_hides_latency_for_slow_consumer() {
        let p = Prefetcher::default();
        let d = DramModel::default();
        // MP PE wants one degree every 200 cycles, starting at 200:
        // the prefetcher runs far ahead -> zero stalls after warm-up.
        let want: Vec<u64> = (1..=100).map(|i| i * 200).collect();
        assert_eq!(p.stall_cycles(&want, &d), 0);
    }

    #[test]
    fn moderately_fast_consumer_beats_naive_fetching() {
        let p = Prefetcher::default();
        let d = DramModel::default();
        // MP PE consumes a degree every 5 cycles — far faster than the
        // naive per-node DRAM latency, slower than the refill rate.
        let want: Vec<u64> = (0..32).map(|i| i * 5).collect();
        let s = p.stall_cycles(&want, &d);
        assert!(s > 0, "warm-up stalls expected");
        assert!(s < p.stall_cycles_naive(32, &d), "{s}");
    }

    #[test]
    fn naive_scales_linearly() {
        let p = Prefetcher::default();
        let d = DramModel::default();
        assert_eq!(
            p.stall_cycles_naive(10, &d) * 10,
            p.stall_cycles_naive(100, &d)
        );
    }

    #[test]
    fn empty_want_no_stall() {
        let p = Prefetcher::default();
        assert_eq!(p.stall_cycles(&[], &DramModel::default()), 0);
    }
}

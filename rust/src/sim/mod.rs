//! Cycle-level simulator of the GenGNN microarchitecture (paper §3–§4.6).
//!
//! This is the substitute for the paper's on-board Alveo U50 measurement
//! (rust/README.md § Backends): the claims of Figs. 7–9 are properties of
//! the *architecture schedule* — NE/MP pipeline overlap, degree
//! imbalance, virtual-node overlap, prefetch latency hiding — all of
//! which are cycle-accounting phenomena this model reproduces. We claim
//! shape, not absolute cycle parity.
//!
//! Module map (one hardware block per module):
//! * [`cycles`]    — cost primitives and the tunable [`cycles::CostParams`]
//! * [`converter`] — on-chip COO→CSR/CSC converter (§3.2)
//! * [`ne_pe`]     — node-embedding PE (§3.4, §4.1 MLP PE)
//! * [`mp_pe`]     — message-passing PE with merged scatter-gather (§3.4)
//! * [`fifo`]      — the inter-PE streaming FIFO (§3.5, depth 10)
//! * [`pipeline`]  — the three NE/MP scheduling strategies (Fig. 4)
//! * [`event`]     — discrete-event engine cross-validating the schedules
//! * [`dram`]      — off-chip memory model (§4.6)
//! * [`pack`]      — packed AXI transfers (§4.6)
//! * [`prefetch`]  — degree-table prefetcher (§4.6)
//! * [`large`]     — large-graph extension composite (§4.6, Fig. 8)
//! * [`accel`]     — the whole accelerator: per-graph end-to-end cycles

pub mod accel;
pub mod converter;
pub mod cycles;
pub mod dram;
pub mod event;
pub mod fifo;
pub mod large;
pub mod mp_pe;
pub mod ne_pe;
pub mod pack;
pub mod pipeline;
pub mod prefetch;

pub use accel::{Accelerator, SimResult};
pub use cycles::{cycles_to_secs, CostParams, CLOCK_HZ};
pub use large::{LargeGraphSim, LargeSimResult};
pub use pipeline::PipelineMode;

//! Inter-PE streaming FIFO model (paper §3.5, Fig. 4(c)).
//!
//! The streaming pipeline pushes a node into the FIFO the moment its NE
//! finishes and the MP PE pops nodes as it drains. This module tracks
//! occupancy from the push/pop timestamp streams the scheduler produces,
//! yielding the two diagnostics the paper's design argument rests on:
//! peak depth ("it also reduces memory cost since we set the queue depth
//! to be 10 nodes") and producer stall cycles (backpressure when full).

/// Occupancy statistics of one scheduled layer.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FifoStats {
    /// Maximum simultaneous occupancy reached.
    pub peak_depth: usize,
    /// Cycles the NE PE spent blocked on a full FIFO.
    pub producer_stall: u64,
    /// Cycles the MP PE spent blocked on an empty FIFO.
    pub consumer_stall: u64,
}

/// Compute occupancy stats from per-node event times.
///
/// * `push[i]` — cycle at which node i's embedding enters the FIFO
///   (its NE finish time, after any full-FIFO stall).
/// * `pop[i]`  — cycle at which the MP PE dequeues node i.
/// * `ne_ready[i]` — cycle NE *would* have finished absent backpressure
///   (used to attribute producer stalls).
/// * `mp_free[i]`  — cycle the MP PE became free before taking node i.
pub fn stats_from_events(
    push: &[u64],
    pop: &[u64],
    ne_ready: &[u64],
    mp_free: &[u64],
) -> FifoStats {
    assert_eq!(push.len(), pop.len());
    let n = push.len();
    let mut peak = 0usize;
    // Occupancy at any push instant = #pushed - #popped before that time.
    // Push/pop times are monotone per stream, so a two-pointer sweep works.
    let mut j = 0usize;
    for i in 0..n {
        while j < n && pop[j] <= push[i] {
            j += 1;
        }
        peak = peak.max(i + 1 - j);
    }
    let producer_stall = push
        .iter()
        .zip(ne_ready)
        .map(|(&p, &r)| p.saturating_sub(r))
        .sum();
    let consumer_stall = pop
        .iter()
        .zip(mp_free)
        .map(|(&p, &f)| p.saturating_sub(f))
        .sum();
    FifoStats {
        peak_depth: peak,
        producer_stall,
        consumer_stall,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_counts_simultaneous_residents() {
        // Pushes at 1,2,3; pops at 10,11,12 -> all three resident.
        let s = stats_from_events(&[1, 2, 3], &[10, 11, 12], &[1, 2, 3], &[0, 10, 11]);
        assert_eq!(s.peak_depth, 3);
    }

    #[test]
    fn immediate_drain_keeps_depth_one() {
        let s = stats_from_events(&[1, 5, 9], &[2, 6, 10], &[1, 5, 9], &[1, 5, 9]);
        assert_eq!(s.peak_depth, 1);
    }

    #[test]
    fn stall_attribution() {
        // Node 1 ready at 4 but pushed at 7 -> 3 producer stall cycles.
        let s = stats_from_events(&[2, 7], &[3, 8], &[2, 4], &[0, 3]);
        assert_eq!(s.producer_stall, 3);
        assert_eq!(s.consumer_stall, 3 + 5);
    }

    #[test]
    fn empty_stream() {
        let s = stats_from_events(&[], &[], &[], &[]);
        assert_eq!(s, FifoStats::default());
    }
}

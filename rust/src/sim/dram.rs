//! Off-chip DRAM model for the large-graph extension (paper §4.6).
//!
//! The U50's memory is reached through AXI: a burst pays a fixed
//! first-beat latency, then streams at bus width per cycle. Graph
//! buffers too big for BRAM/URAM (node embeddings, message buffers,
//! neighbor lists of Cora/CiteSeer/PubMed) live here.

use super::pack;

/// AXI/DRAM channel model.
#[derive(Clone, Copy, Debug)]
pub struct DramModel {
    /// First-beat latency of a burst (address + row activation), cycles.
    pub latency: u64,
    /// Width of one AXI bus in bits (paper: 64).
    pub bus_bits: usize,
    /// Number of parallel buses (paper: four).
    pub buses: usize,
}

impl Default for DramModel {
    fn default() -> Self {
        DramModel {
            latency: 64,
            bus_bits: 64,
            buses: 4,
        }
    }
}

impl DramModel {
    /// One random-access burst of `elems` x `elem_bits`, packed.
    pub fn burst_cycles(&self, elems: usize, elem_bits: usize) -> u64 {
        if elems == 0 {
            return 0;
        }
        self.latency + pack::packed_cycles(elems, elem_bits, self.bus_bits, self.buses)
    }

    /// Streaming transfer (sequential, latency amortized away).
    pub fn stream_cycles(&self, elems: usize, elem_bits: usize) -> u64 {
        pack::packed_cycles(elems, elem_bits, self.bus_bits, self.buses)
    }

    /// Streaming transfer *without* packing (one elem per bus-cycle) —
    /// the ablation baseline of §4.6.
    pub fn stream_cycles_unpacked(&self, elems: usize) -> u64 {
        pack::unpacked_cycles(elems, self.buses)
    }

    /// Effective bandwidth in bytes/cycle with packing.
    pub fn bytes_per_cycle(&self) -> f64 {
        (self.bus_bits * self.buses) as f64 / 8.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_pays_latency_once() {
        let d = DramModel::default();
        assert_eq!(d.burst_cycles(16, 16), 64 + 1);
        assert_eq!(d.burst_cycles(0, 16), 0);
    }

    #[test]
    fn stream_hides_latency() {
        let d = DramModel::default();
        assert!(d.stream_cycles(1024, 16) < d.burst_cycles(1024, 16));
    }

    #[test]
    fn packing_beats_unpacked_stream() {
        // 64-bit bus / 16-bit elems: packing moves 4x per bus-cycle.
        let d = DramModel::default();
        assert_eq!(d.stream_cycles(4096, 16) * 4, d.stream_cycles_unpacked(4096));
    }

    #[test]
    fn bandwidth() {
        assert_eq!(DramModel::default().bytes_per_cycle(), 32.0);
    }
}

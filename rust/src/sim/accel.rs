//! Whole-accelerator simulation: one raw COO graph, end to end
//! (paper Fig. 3 execution flow).
//!
//! Composition per graph: on-chip COO→CSR conversion (once, reused by
//! all layers), then per layer a node sweep scheduled across the NE and
//! MP PEs under the configured pipelining strategy, then global pooling
//! and the prediction head. For GIN+VN the virtual node is materialized
//! as a real node in the processing order — by default *first*, which is
//! what lets the streaming pipeline hide its whole-graph fan-out
//! (paper §4.5, Fig. 6).

use crate::datagen::{augment_with_virtual_node, augment_with_virtual_node_first};
use crate::graph::{CooGraph, GraphBatch};
use crate::models::{GnnKind, ModelConfig};

use super::cycles::{cycles_to_secs, CostParams};
use super::fifo::FifoStats;
use super::mp_pe::mp_profile;
use super::ne_pe::{embed_cycles, head_cycles, ne_cycles};
use super::pipeline::{schedule, PipelineMode};

/// A configured accelerator instance for one model.
#[derive(Clone, Debug)]
pub struct Accelerator {
    pub params: CostParams,
    pub model: ModelConfig,
    pub mode: PipelineMode,
    /// Process the virtual node first (GIN+VN only). The paper notes VN
    /// overlap works "as long as it is processed early enough".
    pub vn_first: bool,
}

/// End-to-end simulation outcome for one graph.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimResult {
    pub cycles: u64,
    pub secs: f64,
    pub converter_cycles: u64,
    pub layer_cycles: u64,
    pub head_cycles: u64,
    /// Worst FIFO stats across layers (streaming mode only).
    pub fifo: FifoStats,
}

impl Accelerator {
    pub fn new(model: ModelConfig, mode: PipelineMode) -> Self {
        Accelerator {
            params: CostParams::default(),
            model,
            mode,
            vn_first: true,
        }
    }

    /// Simulate one raw COO graph end to end; returns cycle counts at
    /// the 300 MHz design clock. Ingests through [`GraphBatch`] — the
    /// crate's single COO→CSR conversion path.
    pub fn simulate(&self, g: &CooGraph) -> SimResult {
        // GIN+VN: the virtual node becomes part of the node schedule.
        if self.model.kind == GnnKind::GinVn {
            let augmented = if self.vn_first {
                augment_with_virtual_node_first(g)
            } else {
                augment_with_virtual_node(g)
            };
            self.simulate_batch(&GraphBatch::ingest_unchecked(augmented))
        } else {
            self.simulate_batch(&GraphBatch::ingest_unchecked(g.clone()))
        }
    }

    /// Core schedule over an already-ingested batch (no re-conversion).
    /// Callers with a GIN+VN model must augment before ingesting —
    /// [`Accelerator::simulate`] does exactly that.
    pub fn simulate_batch(&self, batch: &GraphBatch) -> SimResult {
        let csr = &batch.csr;
        let conv = batch.converter_cycles;
        let n = batch.n();
        let p = &self.params;
        let m = &self.model;

        let ne_steady = ne_cycles(p, m);
        let embed = embed_cycles(p, m);
        let mp = mp_profile(p, m, &csr.degree);

        // Layers 1..L share an identical per-node profile, so their
        // schedule is computed once and multiplied (perf: this is the
        // Fig. 7/9 sweep hot path — the schedule is reused across layers).
        let ne0: Vec<u64> = vec![embed + ne_steady; n];
        let r0 = schedule(self.mode, &ne0, &mp, p.fifo_depth);
        let mut layer_total = r0.cycles;
        let mut worst_fifo = r0.fifo;
        if m.layers > 1 {
            let ne: Vec<u64> = vec![ne_steady; n];
            let r = schedule(self.mode, &ne, &mp, p.fifo_depth);
            layer_total += (m.layers as u64 - 1) * r.cycles;
            if r.fifo.peak_depth >= worst_fifo.peak_depth {
                worst_fifo = r.fifo;
            }
        }

        let head = head_cycles(p, m, n);
        let cycles = conv + layer_total + head;
        SimResult {
            cycles,
            secs: cycles_to_secs(cycles),
            converter_cycles: conv,
            layer_cycles: layer_total,
            head_cycles: head,
            fifo: worst_fifo,
        }
    }

    /// Average latency (seconds) over a batch of graphs — the quantity
    /// Fig. 7 plots ("average execution time" over the test set).
    pub fn mean_latency(&self, graphs: &[CooGraph]) -> f64 {
        if graphs.is_empty() {
            return 0.0;
        }
        graphs.iter().map(|g| self.simulate(g).secs).sum::<f64>() / graphs.len() as f64
    }

    /// `mean_latency` over already-ingested batches. GIN+VN re-ingests
    /// per graph (the virtual node changes the schedule's node set);
    /// every other model reuses the shared conversion.
    pub fn mean_latency_batches(&self, batches: &[GraphBatch]) -> f64 {
        if batches.is_empty() {
            return 0.0;
        }
        let total: f64 = batches
            .iter()
            .map(|b| {
                if self.model.kind == GnnKind::GinVn {
                    self.simulate(&b.graph).secs
                } else {
                    self.simulate_batch(b).secs
                }
            })
            .sum();
        total / batches.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{molecular_graph, MolConfig};
    use crate::util::rng::Rng;

    fn mol(seed: u64) -> CooGraph {
        let mut rng = Rng::new(seed);
        molecular_graph(&mut rng, &MolConfig::molhiv())
    }

    #[test]
    fn streaming_fastest_non_slowest_for_every_model() {
        let g = mol(7);
        for cfg in ModelConfig::fig7_models() {
            let non = Accelerator::new(cfg.clone(), PipelineMode::NonPipelined)
                .simulate(&g)
                .cycles;
            let fx = Accelerator::new(cfg.clone(), PipelineMode::Fixed)
                .simulate(&g)
                .cycles;
            let st = Accelerator::new(cfg.clone(), PipelineMode::Streaming)
                .simulate(&g)
                .cycles;
            assert!(st <= fx && fx <= non, "{}: {st} {fx} {non}", cfg.name);
        }
    }

    #[test]
    fn converter_counted_once_not_per_layer() {
        let g = mol(9);
        let cfg = ModelConfig::by_name("gcn").unwrap();
        let r = Accelerator::new(cfg, PipelineMode::Streaming).simulate(&g);
        assert_eq!(
            r.converter_cycles,
            (2 * g.num_edges() + g.n) as u64
        );
        assert_eq!(r.cycles, r.converter_cycles + r.layer_cycles + r.head_cycles);
    }

    #[test]
    fn vn_first_placement_helps_streaming() {
        let g = mol(21);
        let cfg = ModelConfig::by_name("gin_vn").unwrap();
        let mut first = Accelerator::new(cfg.clone(), PipelineMode::Streaming);
        first.vn_first = true;
        let mut last = Accelerator::new(cfg, PipelineMode::Streaming);
        last.vn_first = false;
        assert!(
            first.simulate(&g).cycles <= last.simulate(&g).cycles,
            "processing the virtual node early should never hurt"
        );
    }

    #[test]
    fn bigger_graphs_take_longer() {
        let cfg = ModelConfig::by_name("gin").unwrap();
        let acc = Accelerator::new(cfg, PipelineMode::Streaming);
        let mut rng = Rng::new(3);
        let small = molecular_graph(&mut rng, &MolConfig { mean_nodes: 10.0, ..MolConfig::molhiv() });
        let big = molecular_graph(&mut rng, &MolConfig { mean_nodes: 50.0, ..MolConfig::molhiv() });
        if big.n > small.n {
            assert!(acc.simulate(&big).cycles > acc.simulate(&small).cycles);
        }
    }

    #[test]
    fn latency_in_plausible_microsecond_range() {
        // Molecular graphs at 300 MHz should land in the 10 us - 10 ms
        // window (paper Fig. 7 is microseconds-to-milliseconds).
        let g = mol(11);
        for cfg in ModelConfig::fig7_models() {
            let r = Accelerator::new(cfg.clone(), PipelineMode::Streaming).simulate(&g);
            assert!(
                r.secs > 1e-5 && r.secs < 1e-2,
                "{} latency {:.2e}s out of range",
                cfg.name,
                r.secs
            );
        }
    }

    #[test]
    fn mean_latency_averages() {
        let cfg = ModelConfig::by_name("gcn").unwrap();
        let acc = Accelerator::new(cfg, PipelineMode::Streaming);
        let graphs = vec![mol(1), mol(2)];
        let m = acc.mean_latency(&graphs);
        let s1 = acc.simulate(&graphs[0]).secs;
        let s2 = acc.simulate(&graphs[1]).secs;
        assert!((m - (s1 + s2) / 2.0).abs() < 1e-12);
    }
}

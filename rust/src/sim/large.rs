//! Large-graph extension simulation (paper §4.6, Fig. 8, Table 5).
//!
//! For Cora/CiteSeer/PubMed the node-embedding and message buffers do
//! not fit on-chip: they move to DRAM, only the streaming FIFO and the
//! prefetcher's buffers stay on-chip, elements are quantized to 16-bit,
//! and every transfer is packed to saturate the four 64-bit AXI buses.
//! The NE/MP streaming pipeline itself is unchanged; what this module
//! adds is the memory system: per-node embedding fetch/writeback,
//! per-edge message-buffer traffic, the degree-table prefetcher, and a
//! whole-bus saturation bound.

use crate::graph::{CooGraph, GraphBatch};
use crate::models::ModelConfig;

use super::cycles::{cycles_to_secs, CostParams};
use super::dram::DramModel;
use super::mp_pe::msg_cycles;
use super::ne_pe::{head_cycles, ne_cycles};
use super::pipeline::{schedule, PipelineMode};
use super::prefetch::Prefetcher;

/// Configuration of the large-graph datapath.
#[derive(Clone, Debug)]
pub struct LargeGraphSim {
    pub params: CostParams,
    pub dram: DramModel,
    pub prefetcher: Prefetcher,
    pub mode: PipelineMode,
    /// Element width after quantization (paper: 16-bit for large ext).
    pub elem_bits: usize,
    /// Enable the degree-table prefetcher (§4.6 ablation knob).
    pub prefetch: bool,
    /// Enable packed AXI transfers (§4.6 ablation knob).
    pub packed: bool,
    /// On-chip budget for the message buffer (Table 5: 494 BRAM18 ≈
    /// 1.1 MB). When the 16-bit message buffer of the graph fits, the
    /// per-edge off-chip read-modify-write disappears — Cora/CiteSeer
    /// qualify, PubMed does not (the Fig. 8 crossover).
    pub onchip_msg_bytes: usize,
}

impl Default for LargeGraphSim {
    fn default() -> Self {
        LargeGraphSim {
            // Table 5: the Large Graph Extension instantiates a wider
            // compute array — 1,344 DSPs of 16-bit MACs (~32x32 lanes)
            // vs ~800 DSPs of 32-bit MACs for the on-chip models.
            params: CostParams {
                p_in: 32,
                p_out: 32,
                p_msg: 32,
                ..CostParams::default()
            },
            dram: DramModel::default(),
            prefetcher: Prefetcher::default(),
            mode: PipelineMode::Streaming,
            elem_bits: 16,
            prefetch: true,
            packed: true,
            onchip_msg_bytes: 1_100_000,
        }
    }
}

/// Cycle breakdown of one large-graph inference.
#[derive(Clone, Copy, Debug, Default)]
pub struct LargeSimResult {
    pub cycles: u64,
    pub secs: f64,
    pub converter_cycles: u64,
    pub embed_cycles: u64,
    pub layer_cycles: u64,
    pub head_cycles: u64,
    /// Degree-table stall cycles charged to the MP PE.
    pub prefetch_stall: u64,
    /// Total DRAM traffic in bytes (for the bus-saturation bound).
    pub dram_bytes: u64,
}

impl LargeGraphSim {
    fn xfer(&self, elems: usize) -> u64 {
        if self.packed {
            self.dram.stream_cycles(elems, self.elem_bits)
        } else {
            self.dram.stream_cycles_unpacked(elems)
        }
    }

    /// Simulate one graph that exceeds on-chip capacity. Convenience
    /// wrapper over [`LargeGraphSim::simulate_batch`]; callers running
    /// several ablations on the same graph should ingest once.
    pub fn simulate(&self, g: &CooGraph, m: &ModelConfig) -> LargeSimResult {
        self.simulate_batch(&GraphBatch::ingest_unchecked(g.clone()), m)
    }

    /// Simulate an already-ingested batch (single conversion path).
    pub fn simulate_batch(&self, batch: &GraphBatch, m: &ModelConfig) -> LargeSimResult {
        let g = &batch.graph;
        let csr = &batch.csr;
        let n = g.n;
        let e = g.num_edges();
        let p = &self.params;
        let d = m.dim;

        // --- Front end: edge list streamed from DRAM, converted once.
        // Edges are (src, dst) pairs of 32-bit ids.
        let conv = batch.converter_cycles + self.xfer_32(2 * e);

        // --- Input embedding layer: fetch x row (F wide), linear F->d,
        // write h row back; double-buffered so fetch overlaps compute.
        // F is the *graph's* feature width (CiteSeer 3703 vs PubMed 500
        // — Table 5), not the artifact's padded in_dim.
        let f_in = g.f_node.max(1);
        let embed_fetch = self.xfer(f_in);
        let embed_compute = p.linear_cycles(f_in, d);
        let embed_per_node = embed_fetch.max(embed_compute) + self.xfer(d);
        let embed = embed_per_node * n as u64;

        // --- Steady-state layers under the NE/MP pipeline with DRAM
        // costs folded into the per-node latencies.
        let ne_compute = ne_cycles(p, m);
        let h_fetch = self.xfer(d);
        let h_write = self.xfer(d);
        let ne_per_node = h_fetch.max(ne_compute) + h_write;

        // MP: degree fetch (hidden by the prefetcher or paid inline),
        // then per out-edge the message transform plus — only when the
        // message buffer spills off-chip — its DRAM read-modify-write.
        let msg = msg_cycles(p, m);
        let degree_cost = if self.prefetch {
            0
        } else {
            self.dram.burst_cycles(1, 32)
        };
        let msg_rmw = if self.msg_buffer_fits(n, d) {
            0
        } else {
            2 * self.xfer(d)
        };
        let mp: Vec<u64> = csr
            .degree
            .iter()
            .map(|&deg| {
                p.c_fetch
                    + degree_cost
                    + deg as u64 * (msg + p.vector_cycles(d) + msg_rmw)
            })
            .collect();
        let ne: Vec<u64> = vec![ne_per_node; n];

        let mut layer_total = 0u64;
        let mut stall_total = 0u64;
        for _ in 0..m.layers {
            let r = schedule(self.mode, &ne, &mp, p.fifo_depth);
            // Prefetcher stalls: the MP PE wants node i's degree when it
            // dequeues node i; approximate want times by an even spread
            // of the layer makespan (the pipeline's steady cadence).
            let stall = if self.prefetch {
                let want: Vec<u64> = (0..n)
                    .map(|i| r.cycles * i as u64 / n.max(1) as u64)
                    .collect();
                self.prefetcher.stall_cycles(&want, &self.dram)
            } else {
                0 // already charged inline per node
            };
            layer_total += r.cycles + stall;
            stall_total += stall;
        }

        // --- Head: node-level prediction per node + output writeback.
        let head =
            head_cycles(p, m, n) + n as u64 * self.xfer(m.out_dim);

        // --- Bus saturation bound: all traffic through 4 buses.
        let bytes = self.total_bytes(g, m);
        let bus_bound = (bytes as f64 / self.dram.bytes_per_cycle()) as u64;

        let compute_total = conv + embed + layer_total + head;
        let cycles = compute_total.max(bus_bound);
        LargeSimResult {
            cycles,
            secs: cycles_to_secs(cycles),
            converter_cycles: conv,
            embed_cycles: embed,
            layer_cycles: layer_total,
            head_cycles: head,
            prefetch_stall: stall_total,
            dram_bytes: bytes,
        }
    }

    fn xfer_32(&self, elems: usize) -> u64 {
        if self.packed {
            self.dram.stream_cycles(elems, 32)
        } else {
            self.dram.stream_cycles_unpacked(elems)
        }
    }

    /// Whether the 16-bit message buffer for `n` nodes fits on-chip.
    pub fn msg_buffer_fits(&self, n: usize, d: usize) -> bool {
        n * d * self.elem_bits / 8 <= self.onchip_msg_bytes
    }

    /// Total off-chip traffic in bytes for one inference.
    pub fn total_bytes(&self, g: &CooGraph, m: &ModelConfig) -> u64 {
        let n = g.n as u64;
        let e = g.num_edges() as u64;
        let d = m.dim as u64;
        let eb = (self.elem_bits as u64) / 8;
        let edges = e * 2 * 4; // 32-bit id pairs
        let embed = n * (g.f_node.max(1) as u64) * eb + n * d * eb;
        let msg_rmw = if self.msg_buffer_fits(g.n, m.dim) {
            0
        } else {
            e * d * eb * 2
        };
        let per_layer = n * d * eb * 2          // h fetch + writeback
            + msg_rmw                           // message buffer RMW
            + n * 4; // degree table (32-bit)
        let head = n * (m.out_dim as u64) * eb;
        edges + embed + per_layer * m.layers as u64 + head
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::citation::{dataset_scaled, CitationDataset};

    fn cfg() -> ModelConfig {
        ModelConfig::by_name("dgn_large").unwrap()
    }

    fn small_citation() -> CooGraph {
        dataset_scaled(CitationDataset::Cora, 7, 300, 500)
    }

    #[test]
    fn prefetch_and_packing_both_help() {
        let g = small_citation();
        let m = cfg();
        let base = LargeGraphSim::default().simulate(&g, &m).cycles;
        let no_pf = LargeGraphSim {
            prefetch: false,
            ..LargeGraphSim::default()
        }
        .simulate(&g, &m)
        .cycles;
        let no_pack = LargeGraphSim {
            packed: false,
            ..LargeGraphSim::default()
        }
        .simulate(&g, &m)
        .cycles;
        assert!(no_pf > base, "prefetcher must reduce cycles: {no_pf} vs {base}");
        assert!(no_pack > base, "packing must reduce cycles: {no_pack} vs {base}");
    }

    #[test]
    fn streaming_beats_non_pipelined_on_large_graphs() {
        let g = small_citation();
        let m = cfg();
        let st = LargeGraphSim::default().simulate(&g, &m).cycles;
        let non = LargeGraphSim {
            mode: PipelineMode::NonPipelined,
            ..LargeGraphSim::default()
        }
        .simulate(&g, &m)
        .cycles;
        assert!(st < non);
    }

    #[test]
    fn cycles_never_beat_the_bus_bound() {
        let g = small_citation();
        let m = cfg();
        let sim = LargeGraphSim::default();
        let r = sim.simulate(&g, &m);
        let bound = (r.dram_bytes as f64 / sim.dram.bytes_per_cycle()) as u64;
        assert!(r.cycles >= bound);
    }

    #[test]
    fn traffic_scales_with_edges_and_layers() {
        let g = small_citation();
        let m = cfg();
        let sim = LargeGraphSim::default();
        let b = sim.total_bytes(&g, &m);
        let mut m2 = cfg();
        m2.layers = 8;
        assert!(sim.total_bytes(&g, &m2) > b);
    }

    #[test]
    fn prop_cycles_monotone_in_edges() {
        use crate::datagen::citation::citation_graph;
        use crate::util::proptest::forall;
        forall("large-sim-edge-monotone", 25, 0x1A26E, |rng| {
            let n = rng.range(100, 400);
            let e1 = rng.range(n, 4 * n);
            let e2 = e1 + rng.range(n, 3 * n);
            let seed = rng.next_u64();
            let m = cfg();
            let sim = LargeGraphSim::default();
            let g1 = citation_graph(seed, n, e1, 64);
            let g2 = citation_graph(seed, n, e2, 64);
            if g2.num_edges() <= g1.num_edges() {
                return Ok(()); // generator saturated; nothing to compare
            }
            let c1 = sim.simulate(&g1, &m).cycles;
            let c2 = sim.simulate(&g2, &m).cycles;
            if c2 < c1 {
                return Err(format!(
                    "more edges got cheaper: E{}={c1} vs E{}={c2}",
                    g1.num_edges(),
                    g2.num_edges()
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn pubmed_scale_latency_in_milliseconds() {
        // PubMed-sized graph should land in the 1-100 ms window the
        // paper's Fig. 8 implies for large graphs.
        let g = dataset_scaled(CitationDataset::PubMed, 3, 2000, 500);
        let r = LargeGraphSim::default().simulate(&g, &cfg());
        assert!(r.secs > 1e-4 && r.secs < 1.0, "latency {:.3e}", r.secs);
    }
}

//! Request/response types of the streaming inference server.

use std::time::Instant;

use crate::graph::{CooGraph, GraphBatch};

/// One inference request: a raw COO graph aimed at a model — exactly
/// what the paper's real-time sources produce ("the graphs are streamed
/// in consecutively", §3.1), zero preprocessing attached.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub model: String,
    pub graph: CooGraph,
    /// Precomputed Laplacian eigenvector if the producer has one
    /// (DGN's contract); otherwise the prep stage computes it.
    pub eig: Option<Vec<f32>>,
    pub submitted: Instant,
}

impl Request {
    pub fn new(id: u64, model: impl Into<String>, graph: CooGraph) -> Request {
        Request {
            id,
            model: model.into(),
            graph,
            eig: None,
            submitted: Instant::now(),
        }
    }
}

/// A prepared request: the prep workers have routed it, ingested the
/// raw graph through [`GraphBatch`] (the one COO→CSR/CSC conversion),
/// and solved the eigenvector if the model needs one — ready for the
/// executor (the "FPGA") to pack and run with zero re-derivation.
#[derive(Clone, Debug)]
pub struct Prepared {
    pub id: u64,
    pub model: String,
    /// Laplacian eigenvector, padded to the model capacity (DGN only).
    pub eig: Option<Vec<f32>>,
    pub submitted: Instant,
    /// The ingested graph: raw COO + its converted CSR.
    pub batch: GraphBatch,
    pub prep_done: Instant,
}

impl Prepared {
    /// Ingest a request (no eigensolve — the server's prep stage adds
    /// the eigenvector for models that need it).
    pub fn new(req: Request) -> Prepared {
        let Request {
            id,
            model,
            graph,
            eig,
            submitted,
        } = req;
        Prepared {
            id,
            model,
            eig,
            submitted,
            batch: GraphBatch::ingest_unchecked(graph),
            prep_done: Instant::now(),
        }
    }
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub model: String,
    pub output: Result<Vec<f32>, String>,
    pub submitted: Instant,
    pub completed: Instant,
}

impl Response {
    /// End-to-end latency in seconds.
    pub fn latency(&self) -> f64 {
        self.completed.duration_since(self.submitted).as_secs_f64()
    }

    pub fn is_ok(&self) -> bool {
        self.output.is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph() -> CooGraph {
        CooGraph {
            n: 2,
            edges: vec![(0, 1)],
            node_feat: vec![0.0; 2 * 9],
            f_node: 9,
            edge_feat: vec![],
            f_edge: 0,
        }
    }

    #[test]
    fn latency_is_nonnegative() {
        let r = Request::new(1, "gcn", graph());
        let resp = Response {
            id: r.id,
            model: r.model.clone(),
            output: Ok(vec![0.5]),
            submitted: r.submitted,
            completed: Instant::now(),
        };
        assert!(resp.latency() >= 0.0);
        assert!(resp.is_ok());
    }

    #[test]
    fn error_response() {
        let resp = Response {
            id: 9,
            model: "gat".into(),
            output: Err("too big".into()),
            submitted: Instant::now(),
            completed: Instant::now(),
        };
        assert!(!resp.is_ok());
    }
}

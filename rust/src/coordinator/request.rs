//! Request/response types of the streaming inference server.

use std::time::{Duration, Instant};

use crate::coordinator::backpressure::Priority;
use crate::graph::{CooGraph, GraphBatch};

/// One inference request: a raw COO graph aimed at a model — exactly
/// what the paper's real-time sources produce ("the graphs are streamed
/// in consecutively", §3.1), zero preprocessing attached.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub model: String,
    pub graph: CooGraph,
    /// Precomputed Laplacian eigenvector if the producer has one
    /// (DGN's contract); otherwise the prep stage computes it.
    pub eig: Option<Vec<f32>>,
    pub submitted: Instant,
    /// Absolute deadline derived from the wire TTL; `None` means the
    /// caller will wait forever (v1 frames, in-process callers).
    pub deadline: Option<Instant>,
    /// Scheduling class: the batcher drains higher classes first.
    pub priority: Priority,
}

impl Request {
    pub fn new(id: u64, model: impl Into<String>, graph: CooGraph) -> Request {
        Request {
            id,
            model: model.into(),
            graph,
            eig: None,
            submitted: Instant::now(),
            deadline: None,
            priority: Priority::Normal,
        }
    }

    /// A request carrying wire QoS: `ttl_ms == 0` means no deadline.
    pub fn with_qos(
        id: u64,
        model: impl Into<String>,
        graph: CooGraph,
        ttl_ms: u32,
        priority: Priority,
    ) -> Request {
        let submitted = Instant::now();
        Request {
            id,
            model: model.into(),
            graph,
            eig: None,
            submitted,
            deadline: (ttl_ms > 0).then(|| submitted + Duration::from_millis(ttl_ms as u64)),
            priority,
        }
    }

    /// True once the deadline (if any) has passed: executing this
    /// request would burn a lane on an answer nobody is waiting for.
    pub fn is_expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// A prepared request: the prep workers have routed it, ingested the
/// raw graph through [`GraphBatch`] (the one COO→CSR/CSC conversion),
/// and solved the eigenvector if the model needs one — ready for the
/// executor (the "FPGA") to pack and run with zero re-derivation.
#[derive(Clone, Debug)]
pub struct Prepared {
    pub id: u64,
    pub model: String,
    /// Laplacian eigenvector, padded to the model capacity (DGN only).
    pub eig: Option<Vec<f32>>,
    pub submitted: Instant,
    /// The ingested graph: raw COO + its converted CSR.
    pub batch: GraphBatch,
    pub prep_done: Instant,
    pub deadline: Option<Instant>,
    pub priority: Priority,
}

impl Prepared {
    /// Ingest a request (no eigensolve — the server's prep stage adds
    /// the eigenvector for models that need it).
    pub fn new(req: Request) -> Prepared {
        let Request {
            id,
            model,
            graph,
            eig,
            submitted,
            deadline,
            priority,
        } = req;
        Prepared {
            id,
            model,
            eig,
            submitted,
            batch: GraphBatch::ingest_unchecked(graph),
            prep_done: Instant::now(),
            deadline,
            priority,
        }
    }

    /// See [`Request::is_expired`].
    pub fn is_expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub model: String,
    pub output: Result<Vec<f32>, String>,
    pub submitted: Instant,
    pub completed: Instant,
    /// True when the request was shed because its deadline passed
    /// before execution (distinct from `Err` executor failures: the
    /// wire front-end answers with `Expired`, not `Error`).
    pub expired: bool,
}

impl Response {
    /// The shed-by-deadline response every pipeline stage emits when a
    /// request's TTL runs out before it reaches a lane.
    pub fn deadline_expired(id: u64, model: impl Into<String>, submitted: Instant) -> Response {
        Response {
            id,
            model: model.into(),
            output: Err("deadline expired before execution".into()),
            submitted,
            completed: Instant::now(),
            expired: true,
        }
    }

    /// End-to-end latency in seconds.
    pub fn latency(&self) -> f64 {
        self.completed.duration_since(self.submitted).as_secs_f64()
    }

    pub fn is_ok(&self) -> bool {
        self.output.is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph() -> CooGraph {
        CooGraph {
            n: 2,
            edges: vec![(0, 1)],
            node_feat: vec![0.0; 2 * 9],
            f_node: 9,
            edge_feat: vec![],
            f_edge: 0,
        }
    }

    #[test]
    fn latency_is_nonnegative() {
        let r = Request::new(1, "gcn", graph());
        let resp = Response {
            id: r.id,
            model: r.model.clone(),
            output: Ok(vec![0.5]),
            submitted: r.submitted,
            completed: Instant::now(),
            expired: false,
        };
        assert!(resp.latency() >= 0.0);
        assert!(resp.is_ok());
    }

    #[test]
    fn error_response() {
        let resp = Response {
            id: 9,
            model: "gat".into(),
            output: Err("too big".into()),
            submitted: Instant::now(),
            completed: Instant::now(),
            expired: false,
        };
        assert!(!resp.is_ok());
    }

    #[test]
    fn qos_deadlines_expire_and_survive_prep() {
        let r = Request::new(1, "gcn", graph());
        assert!(r.deadline.is_none() && r.priority == Priority::Normal);
        assert!(!r.is_expired(Instant::now() + Duration::from_secs(3600)));

        let r = Request::with_qos(2, "gcn", graph(), 0, Priority::High);
        assert!(r.deadline.is_none(), "ttl 0 means no deadline");

        let r = Request::with_qos(3, "gcn", graph(), 5, Priority::Low);
        let d = r.deadline.expect("ttl > 0 sets a deadline");
        assert!(!r.is_expired(r.submitted));
        assert!(r.is_expired(d));
        let p = Prepared::new(r);
        assert_eq!(p.deadline, Some(d));
        assert_eq!(p.priority, Priority::Low);
        assert!(p.is_expired(d + Duration::from_millis(1)));

        let resp = Response::deadline_expired(p.id, &p.model, p.submitted);
        assert!(resp.expired && !resp.is_ok());
    }
}
